"""Implicit-GEMM conv kernels vs the explicit im2col + GEMM lowering
(DESIGN.md §8): bit-exact on the INT8 datapath, tolerance-checked for
floats, across stride / padding / kernel-size / ragged-tile cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dbb import dbb_project, pack_dbb
from repro.kernels.conv_gemm.ops import (conv_gemm, conv_gemm_dbb,
                                         conv_gemm_packed, out_spatial)
from repro.kernels.conv_gemm.ref import conv_gemm_dbb_ref, conv_gemm_ref, im2col
from repro.kernels.epilogue import Epilogue


def _rand(shape, seed, dtype):
    k = jax.random.PRNGKey(seed)
    if dtype == jnp.int8:
        return jax.random.randint(k, shape, -127, 128, jnp.int32).astype(
            jnp.int8)
    return jax.random.normal(k, shape, jnp.float32).astype(dtype)


# (B, H, W, C, N, k, stride, padding) — H·W deliberately not tile-divisible
# in several cases (ragged bottom row-tiles, odd widths, VALID leftovers)
_CASES = [
    (2, 8, 8, 4, 16, 3, 1, "SAME"),       # baseline 3x3
    (1, 16, 16, 8, 32, 3, 1, "SAME"),     # DBB-compatible channels
    (2, 7, 9, 4, 8, 3, 1, "SAME"),        # odd ragged spatial dims
    (1, 10, 10, 4, 8, 3, 2, "SAME"),      # stride 2
    (1, 11, 13, 6, 20, 5, 2, "VALID"),    # 5x5, stride 2, VALID leftovers
    (2, 9, 9, 8, 32, 3, 1, "VALID"),
    (1, 8, 8, 4, 16, 1, 1, "SAME"),       # 1x1 (pure pointwise GEMM)
    (1, 32, 32, 3, 64, 7, 2, "SAME"),     # conv1-style: 7x7 s2, C=3
]


class TestConvGemm:
    @pytest.mark.parametrize("b,h,w,c,n,k,s,pad", _CASES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_matches_im2col_oracle(self, b, h, w, c, n, k, s, pad, dtype):
        x = _rand((b, h, w, c), 0, dtype)
        wm = _rand((k * k * c, n), 1, dtype)
        got = conv_gemm(x, wm, kh=k, kw=k, stride=s, padding=pad)
        want = conv_gemm_ref(x, wm, kh=k, kw=k, stride=s, padding=pad)
        assert got.shape == want.shape and got.dtype == want.dtype
        if dtype == jnp.int8:
            # INT8×INT8→INT32: integer accumulation must be bit-exact
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        else:
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                atol=3e-2 if dtype == jnp.bfloat16 else
                1e-4 * ((k * k * c) ** 0.5))

    def test_out_spatial_matches_xla(self):
        for size, k, s, pad in [(8, 3, 1, "SAME"), (10, 3, 2, "SAME"),
                                (11, 5, 2, "VALID"), (7, 1, 1, "SAME"),
                                (9, 3, 2, "VALID")]:
            out, lo, hi = out_spatial(size, k, s, pad)
            x = jnp.zeros((1, size, size, 1))
            want = jax.lax.conv_general_dilated_patches(
                x, (k, k), (s, s), pad,
                dimension_numbers=("NHWC", "HWIO", "NHWC")).shape[1]
            assert out == want, (size, k, s, pad, out, want)

    def test_against_lax_conv(self):
        """Independent oracle: jax.lax.conv_general_dilated on the HWIO
        weight tensor (not any of our GEMM lowerings)."""
        b, h, w, c, n, k = 2, 8, 8, 4, 16, 3
        x = _rand((b, h, w, c), 0, jnp.float32)
        wm = _rand((k * k * c, n), 1, jnp.float32)
        got = conv_gemm(x, wm, kh=k, kw=k)
        whwio = wm.reshape(k, k, c, n)
        want = jax.lax.conv_general_dilated(
            x, whwio, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("th", [1, 2, 3, 5])
    def test_row_tile_sweep_nondivisible(self, th):
        """Ho % th != 0: bottom row-tiles are zero-padded and sliced off."""
        x = _rand((1, 7, 7, 4), 2, jnp.float32)
        wm = _rand((9 * 4, 8), 3, jnp.float32)
        got = conv_gemm(x, wm, kh=3, kw=3, rows_per_tile=th)
        want = conv_gemm_ref(x, wm, kh=3, kw=3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("act", ["none", "relu", "gelu"])
    def test_fused_epilogue(self, act):
        b, h, w, c, n, k = 2, 8, 8, 8, 16, 3
        x = _rand((b, h, w, c), 0, jnp.float32)
        wm = _rand((k * k * c, n), 1, jnp.float32)
        bias = _rand((n,), 2, jnp.float32)
        scale = jnp.linspace(0.25, 1.5, n)
        got = conv_gemm(x, wm, bias, scale, kh=k, kw=k, act=act)
        want = conv_gemm(x, wm, bias, scale, kh=k, kw=k, act=act,
                         use_kernel=False)
        assert got.dtype == want.dtype
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_int8_requant_store(self):
        """INT8 in, INT8 out: fused dequant×requant scale + round/clip in
        the final-K store, bit-exact vs the explicit oracle."""
        x = _rand((1, 8, 8, 8), 4, jnp.int8)
        wm = _rand((9 * 8, 16), 5, jnp.int8)
        s = jnp.float32(2e-3)
        got = conv_gemm(x, wm, scale=s, act="relu", out_dtype=jnp.int8,
                        kh=3, kw=3)
        assert got.dtype == jnp.int8
        want = conv_gemm_ref(
            x, wm, kh=3, kw=3,
            epilogue=Epilogue(act="relu", has_scale=True),
            scale=jnp.full((1, 16), s), out_dtype=jnp.int8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_inside_jit_and_batched(self):
        x = _rand((3, 8, 8, 4), 6, jnp.float32)
        wm = _rand((9 * 4, 8), 7, jnp.float32)
        f = jax.jit(lambda x: conv_gemm(x, wm, kh=3, kw=3))
        np.testing.assert_allclose(
            np.asarray(f(x)),
            np.asarray(conv_gemm_ref(x, wm, kh=3, kw=3)),
            rtol=1e-4, atol=1e-4)


class TestConvGemmDbb:
    @pytest.mark.parametrize("b,h,w,c,n,k,s,pad", [
        (2, 8, 8, 8, 16, 3, 1, "SAME"),
        (1, 10, 10, 8, 16, 3, 2, "SAME"),
        (1, 9, 11, 16, 24, 3, 1, "VALID"),
        (1, 8, 8, 16, 16, 1, 1, "SAME"),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
    def test_matches_oracle(self, b, h, w, c, n, k, s, pad, dtype):
        x = _rand((b, h, w, c), 0, dtype)
        wm = _rand((k * k * c, n), 1, jnp.float32)
        p = pack_dbb(wm, 8, 4)
        vals = p.values.astype(dtype)
        got = conv_gemm_dbb(x, vals, p.bitmask, kh=k, kw=k, stride=s,
                            padding=pad)
        want = conv_gemm_dbb_ref(x, vals, p.bitmask.astype(jnp.int32),
                                 kh=k, kw=k, stride=s, padding=pad)
        assert got.shape == want.shape and got.dtype == want.dtype
        if dtype == jnp.int8:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        else:
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)

    def test_packed_scale_bias_act(self):
        """conv_gemm_packed folds the per-channel quant scale into the
        epilogue — equals project→im2col→GEMM→scale→bias→relu."""
        b, h, w, c, n, k = 1, 8, 8, 8, 16, 3
        x = _rand((b, h, w, c), 0, jnp.float32)
        wm = _rand((k * k * c, n), 1, jnp.float32)
        scale = jnp.linspace(0.5, 2.0, n)
        p = pack_dbb(wm, 8, 4, scale=scale)
        bias = _rand((n,), 2, jnp.float32)
        got = conv_gemm_packed(x, p, bias, kh=k, kw=k, act="relu")
        cols = im2col(x, k, k).reshape(-1, k * k * c)
        want = jnp.maximum(
            (cols @ dbb_project(wm, 8, 4)) * scale[None, :] + bias[None, :],
            0).reshape(b, h, w, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_block_misaligned_geometry_falls_back(self):
        """(kw·C) % B != 0 (K steps would straddle DBB blocks): the wrapper
        must still be correct via the dense-decompress oracle."""
        b, h, w, c, n, k = 1, 6, 6, 4, 8, 2   # k_dim = 16 ok, kw*C = 8 ok
        # force misalignment with block=16: kw*C = 8 % 16 != 0
        x = _rand((b, h, w, c), 0, jnp.float32)
        wm = _rand((k * k * c, n), 1, jnp.float32)
        p = pack_dbb(wm, 16, 8)
        got = conv_gemm_packed(x, p, kh=k, kw=k)
        want = conv_gemm_dbb_ref(x, p.values, p.bitmask.astype(jnp.int32),
                                 kh=k, kw=k, block=16, nnz=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_dense_compat_full_nnz(self):
        """nnz == block reproduces the dense conv exactly (paper §IV-B)."""
        x = _rand((1, 8, 8, 8), 8, jnp.float32)
        wm = _rand((9 * 8, 16), 9, jnp.float32)
        p = pack_dbb(wm, 8, 8)
        got = conv_gemm_packed(x, p, kh=3, kw=3)
        want = conv_gemm_ref(x, wm, kh=3, kw=3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestCnnRouting:
    def test_cnn_apply_routes_match(self):
        """cnn_apply: implicit-kernel routes == explicit-fallback routes ==
        plain XLA path, dense and DBB-packed."""
        from repro.configs import get_config
        from repro.core.dbb_linear import pack_tree
        from repro.core.sparsity import apply_dbb_to_tree
        from repro.models import registry
        from repro.models.cnn import cnn_apply

        cfg = get_config("convnet-dbb", smoke=True)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (2, cfg.cnn_img, cfg.cnn_img, cfg.cnn_in_ch))
        y_xla = cnn_apply(params, cfg, x)
        y_sta = cnn_apply(params, cfg, x, matmul="sta")
        y_fb = cnn_apply(params, cfg, x, matmul="sta", use_kernel=False)
        np.testing.assert_allclose(np.asarray(y_sta), np.asarray(y_xla),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_fb), np.asarray(y_xla),
                                   rtol=1e-4, atol=1e-4)

        proj = apply_dbb_to_tree(params, cfg.dbb, straight_through=False)
        packed = pack_tree(proj, cfg.dbb)
        y_dbb = cnn_apply(packed, cfg, x, matmul="dbb")
        y_proj = cnn_apply(proj, cfg, x)
        np.testing.assert_allclose(np.asarray(y_dbb), np.asarray(y_proj),
                                   rtol=1e-4, atol=1e-4)


class TestNoIm2colTensor:
    B, H, W, C, KH, KW, N = 4, 16, 16, 16, 3, 3, 32

    def test_implicit_gemm_never_materializes_patches(self):
        """Trace-time assertion via the shared repro.analysis walker: the
        implicit-GEMM conv route never holds the [M, K] = [B·Ho·Wo,
        Kh·Kw·C] im2col patch matrix; the explicit im2col reference
        (control) materializes exactly that."""
        from repro.analysis.materialize import (
            assert_no_intermediate_larger_than, max_intermediate_elems)
        from repro.kernels import dispatch

        x = jnp.zeros((self.B, self.H, self.W, self.C), jnp.float32)
        w = jnp.zeros((self.KH * self.KW * self.C, self.N), jnp.float32)
        patch_elems = (self.B * self.H * self.W
                       * self.KH * self.KW * self.C)   # SAME, stride 1

        assert_no_intermediate_larger_than(
            lambda x, w: dispatch.conv(x, w, kh=self.KH, kw=self.KW,
                                       stride=1, route="conv_sta"),
            x, w, max_elems=patch_elems, what="implicit-GEMM conv")
        naive = max_intermediate_elems(
            lambda x: im2col(x, self.KH, self.KW, 1, "SAME"), x)
        assert naive >= patch_elems     # control: explicit im2col does
