"""Tiling layer: block-shape heuristic, MXU utilization, and the measured
autotuner (candidate generation, cache behavior, wiring into the ops)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import StaConfig
from repro.core.sta import (LANE, MXU_DIM, SUBLANE, VMEM_BYTES,
                            choose_block_shape, mxu_utilization)
from repro.kernels import autotune


class TestChooseBlockShape:
    def test_defaults_aligned(self):
        bm, bk, bn = choose_block_shape(1024, 4096, 4096, StaConfig())
        assert bm % SUBLANE == 0 and bk % LANE == 0 and bn % LANE == 0
        assert (bm, bk, bn) == (128, 128, 128)

    def test_small_m_shrinks_bm(self):
        bm, _, _ = choose_block_shape(1, 4096, 4096, StaConfig())
        assert bm == SUBLANE                  # decode row: one sublane

    def test_small_problem_clamps_every_dim(self):
        bm, bk, bn = choose_block_shape(4, 64, 32, StaConfig())
        assert bm == SUBLANE and bk == LANE and bn == LANE

    def test_vmem_budget_shrinks_k_first(self):
        """Oversized blocks shrink K before M (K streams, M is batch)."""
        cfg = StaConfig(block_m=1024, block_k=65536, block_n=1024)
        bm, bk, bn = choose_block_shape(1024, 65536, 1024, cfg, itemsize=4)
        footprint = (bm * bk + bk * bn) * 4 + bm * bn * 4
        assert footprint <= VMEM_BYTES // 2
        assert bk < 65536                     # K took the cut
        assert bn == 1024                     # N kept lane-aligned width

    def test_respects_itemsize(self):
        cfg = StaConfig(block_m=2048, block_k=8192, block_n=2048)
        f32 = choose_block_shape(2048, 8192, 2048, cfg, itemsize=4)
        i8 = choose_block_shape(2048, 8192, 2048, cfg, itemsize=1)
        def fp(s, i):
            return (s[0] * s[1] + s[1] * s[2]) * i + s[0] * s[2] * 4
        assert fp(f32, 4) <= VMEM_BYTES // 2
        assert fp(i8, 1) <= VMEM_BYTES // 2
        # int8 affords at-least-as-big tiles in every dim
        assert all(a >= b for a, b in zip(i8, f32))


class TestMxuUtilization:
    def test_aligned_is_one(self):
        assert mxu_utilization(256, 512, 128) == 1.0

    def test_padding_waste(self):
        # 1 row in a 128-row MXU pass: 1/128 utilization
        assert mxu_utilization(1, 128, 128) == pytest.approx(1 / 128)
        got = mxu_utilization(100, 200, 72)
        want = (100 * 200 * 72) / (128 * 256 * 128)
        assert got == pytest.approx(want)

    def test_monotone_in_alignment(self):
        assert mxu_utilization(127, 128, 128) < mxu_utilization(128, 128, 128)


class TestAutotune:
    def test_candidates_constraint_filtered(self):
        cands = autotune.candidate_block_shapes(64, 512, 256, itemsize=4)
        assert cands, "no candidates"
        base = choose_block_shape(64, 512, 256, StaConfig(), itemsize=4)
        assert cands[0] == base               # heuristic prior leads
        for bm, bk, bn in cands:
            assert bm % SUBLANE == 0 and bn % LANE == 0 and bk % LANE == 0
            assert (bm * bk + bk * bn) * 4 + bm * bn * 4 <= VMEM_BYTES // 2

    def test_align_k_honored(self):
        cands = autotune.candidate_block_shapes(64, 768, 256, itemsize=1,
                                                align_k=384)
        assert all(bk % 384 == 0 for _, bk, _ in cands)

    def test_measures_once_then_caches(self, tmp_path, monkeypatch):
        path = str(tmp_path / "autotune.json")
        autotune.clear_memory_cache()
        calls = []

        def make_fn(shape):
            def fn():
                calls.append(shape)
                return jnp.zeros(())
            return fn

        pick = autotune.autotune_block_shape(
            "test_kernel", 64, 256, 128, jnp.float32, make_fn,
            candidates=[(8, 128, 128), (64, 128, 128)], repeats=1, path=path)
        assert pick in [(8, 128, 128), (64, 128, 128)]
        assert calls, "no measurements on a cold cache"
        assert os.path.exists(path)
        table = json.load(open(path))
        assert list(table.values()) == [list(pick)]

        # warm cache (same process): no new measurements
        n_before = len(calls)
        pick2 = autotune.autotune_block_shape(
            "test_kernel", 64, 256, 128, jnp.float32, make_fn,
            candidates=[(8, 128, 128), (64, 128, 128)], repeats=1, path=path)
        assert pick2 == pick and len(calls) == n_before

        # cold process (memory cleared): served from disk, still no timing
        autotune.clear_memory_cache()
        pick3 = autotune.autotune_block_shape(
            "test_kernel", 64, 256, 128, jnp.float32, make_fn,
            candidates=[(8, 128, 128), (64, 128, 128)], repeats=1, path=path)
        assert pick3 == pick and len(calls) == n_before

    def test_distinct_keys_per_epilogue_and_dtype(self, tmp_path):
        path = str(tmp_path / "autotune.json")
        autotune.clear_memory_cache()
        mk = lambda shape: (lambda: jnp.zeros(()))
        for tag, dt in (("none", jnp.float32), ("silu+bias", jnp.float32),
                        ("none", jnp.int8)):
            autotune.autotune_block_shape(
                "k", 8, 128, 128, dt, mk, epilogue_tag=tag,
                candidates=[(8, 128, 128)], repeats=1, path=path)
        assert len(json.load(open(path))) == 3

    def test_end_to_end_through_sta_gemm(self, tmp_path, monkeypatch):
        """REPRO_AUTOTUNE=1 routes sta_gemm through the tuner and the result
        still matches XLA."""
        path = str(tmp_path / "autotune.json")
        monkeypatch.setenv("REPRO_AUTOTUNE", "1")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
        autotune.clear_memory_cache()
        from repro.kernels.sta_gemm.ops import sta_gemm
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 256), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
        y = sta_gemm(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)
        assert os.path.exists(path) and json.load(open(path))
