"""Known-bad fixture for the vmem pass: w4 expanded-tile undercount.

The INT4 weight-streaming kernels (DESIGN.md §16) stream a nibble-packed
values plane whose BlockSpecs alone undercount residency — the dequant
step expands each tile through int8 slots, a dense int8 tile, and a
dequantized f32 tile, all declared as ``extra_vmem_bytes``. This
contract models the bug where that expansion chain is sized for huge
K/N tiles the guard happily admits: the streamed blocks fit, the
expansion does not. Expected code: ``vmem-overflow``.
"""
from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET

# a 4096-deep K tile over 512 lanes: the *packed* stream is small, but
# the in-VMEM expansion (int8 slots + dense int8 + dense f32) is ~11 MiB
_BK, _BN, _BLOCK, _NNZ = 4096, 512, 8, 4
_BKC = _BK // _BLOCK * _NNZ            # compressed int8-slot rows / tile

w4_overflow = KernelContract(
    name="bad_quant_w4_expansion", route="fixture", domain="matmul",
    grid=(2, 2, 2),
    dimension_semantics=("parallel", "parallel", "arbitrary"),
    inputs=(
        BlockDecl("x", (8, _BK), lambda i, j, kk: (i, kk),
                  (16, 2 * _BK), 4),
        BlockDecl("values", (_BKC // 2, _BN), lambda i, j, kk: (kk, j),
                  (_BKC, 2 * _BN), 1),
        BlockDecl("bitmask", (_BK // _BLOCK, _BN),
                  lambda i, j, kk: (kk, j),
                  (2 * _BK // _BLOCK, 2 * _BN), 4),
        BlockDecl("gscale", (_BK // 128, _BN), lambda i, j, kk: (kk, j),
                  (2 * _BK // 128, 2 * _BN), 4),
    ),
    outputs=(BlockDecl("out", (8, _BN), lambda i, j, kk: (i, j),
                       (16, 2 * _BN), 4),),
    scratch=(ScratchDecl("acc", (8, _BN), 4),),
    acc_dims=(2,), guarded_init=True, guarded_store=True,
    vmem_budget=KERNEL_VMEM_BUDGET,
    # the dequant expansion chain, honestly declared — and far over
    # budget at this tile shape
    extra_vmem_bytes=_BKC * _BN + _BK * _BN + _BK * _BN * 4,
    admitted=True)                      # guard bug: expansion can't fit

CONTRACTS = [w4_overflow]
