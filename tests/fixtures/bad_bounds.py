"""Known-bad fixture for the bounds pass: one input whose index map is
shifted off-by-one (last grid step reads a block past the padded array)
and one output whose index map collapses two grid steps onto the same
block without declaring accumulation (two grid cells write the same
tile). Expected codes: ``oob`` and ``overlapping-write``.
"""
from repro.analysis.contracts import BlockDecl, KernelContract
from repro.core.sta import KERNEL_VMEM_BUDGET

oob = KernelContract(
    name="bad_bounds_off_by_one", route="fixture", domain="matmul",
    grid=(4,),
    dimension_semantics=("parallel",),
    # classic fencepost: block index i+1 — grid step 3 covers rows
    # [32, 40) of a 32-row array
    inputs=(BlockDecl("x", (8, 128), lambda i: (i + 1, 0),
                      (32, 128), 4),),
    outputs=(BlockDecl("out", (8, 128), lambda i: (i, 0), (32, 128), 4),),
    vmem_budget=KERNEL_VMEM_BUDGET,
    admitted=True)

overlap = KernelContract(
    name="bad_bounds_overlapping_write", route="fixture", domain="matmul",
    grid=(4,),
    dimension_semantics=("parallel",),
    inputs=(BlockDecl("x", (8, 128), lambda i: (i, 0), (32, 128), 4),),
    # i // 2 maps grid steps {0,1} and {2,3} onto the same output block
    # with no acc_dims declaration: concurrent writers to one tile
    outputs=(BlockDecl("out", (8, 128), lambda i: (i // 2, 0),
                       (16, 128), 4),),
    vmem_budget=KERNEL_VMEM_BUDGET,
    admitted=True)

CONTRACTS = [oob, overlap]
