"""Known-bad fixture for the races pass: a K-revisited output whose
grid declares the revisit dim ``parallel`` (grid-order race under a
real scheduler) and whose accumulator init/final-store are not
``pl.when``-guarded. Expected codes: ``race`` and
``unguarded-accumulation``.

The accumulation itself *is* declared (``acc_dims=(1,)``) and the index
maps are in-bounds, so the vmem and bounds passes stay quiet — the only
defects are the race-discipline ones.
"""
from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET

racy = KernelContract(
    name="bad_race_parallel_k", route="fixture", domain="matmul",
    grid=(4, 4),
    # dim 1 is the K loop the output is revisited over — it must be
    # "arbitrary", but this kernel declared it "parallel"
    dimension_semantics=("parallel", "parallel"),
    inputs=(
        BlockDecl("x", (8, 128), lambda i, kk: (i, kk), (32, 512), 4),
        BlockDecl("w", (128, 128), lambda i, kk: (kk, 0), (512, 128), 4),
    ),
    outputs=(BlockDecl("out", (8, 128), lambda i, kk: (i, 0),
                       (32, 128), 4),),
    scratch=(ScratchDecl("acc", (8, 128), 4),),
    acc_dims=(1,),
    guarded_init=False, guarded_store=False,    # missing pl.when guards
    vmem_budget=KERNEL_VMEM_BUDGET,
    admitted=True)

CONTRACTS = [racy]
