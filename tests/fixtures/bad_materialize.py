"""Known-bad fixture for the materialization pass: a "pairwise scores"
computation that builds the full [M, K, N] outer-product tensor before
reducing — exactly the intermediate a fused kernel exists to avoid.
The declared limit is the output size, so the trace must flag the
``materialized`` code.
"""
from repro.analysis.materialize import MaterializationCheck

_M = _K = _N = 32


def _build():
    import jax.numpy as jnp

    a = jnp.ones((_M, _K), jnp.float32)
    b = jnp.ones((_K, _N), jnp.float32)

    def fn(x, y):
        # materializes [M, K, N] = 32768 elems before the reduction
        return (x[:, :, None] * y[None, :, :]).sum(axis=1)

    return fn, (a, b), _M * _N


MATERIALIZATION_CHECKS = [
    MaterializationCheck(
        name="bad-materialize-outer-product",
        describe=f"[{_M},{_K}]x[{_K},{_N}] matmul via explicit "
                 f"[{_M},{_K},{_N}] outer product",
        build=_build),
]
