"""Known-bad fixture for the dispatch pass: a registry with all three
route-table rot modes.

  * ``dead_route`` — guard rejects every spec: ``unreachable``;
  * ``overpriced`` — applicable everywhere but its cost is 1000x the
    winner's, so auto-dispatch can never pick it: ``shadowed``;
  * ``inverse`` — typo'd cost model whose modeled time *falls* as M
    grows: ``non-monotone-cost`` (and, since the inflated floor also
    keeps it from ever winning, ``shadowed``).
"""
from repro.kernels.dispatch import OpSpec, Route


def _ok(spec):
    return ""


def _never(spec):
    return "fixture: permanently disabled"


def _cost_good(spec):
    flops = 2.0 * spec.m * spec.k * spec.n
    nbytes = 4.0 * (spec.m * spec.k + spec.k * spec.n + spec.m * spec.n)
    return flops, nbytes


def _cost_overpriced(spec):
    flops, nbytes = _cost_good(spec)
    return 1e3 * flops, 1e3 * nbytes


def _cost_inverse(spec):
    # the monotonicity bug class: a divided-instead-of-multiplied term
    wrong = float(2 ** 40) / max(spec.m, 1)
    return wrong, wrong


ROUTES = {
    "matmul": {
        "good": Route(name="good", domain="matmul", priority=0,
                      guard=_ok, cost=_cost_good,
                      describe="fixture: sane route"),
        "dead_route": Route(name="dead_route", domain="matmul", priority=1,
                            guard=_never, cost=_cost_good,
                            describe="fixture: guard rejects everything"),
        "overpriced": Route(name="overpriced", domain="matmul", priority=2,
                            guard=_ok, cost=_cost_overpriced,
                            describe="fixture: cost can never win"),
        "inverse": Route(name="inverse", domain="matmul", priority=3,
                         guard=_ok, cost=_cost_inverse,
                         describe="fixture: cost falls as M grows"),
    },
}

SPECS = {
    "matmul": [
        OpSpec(domain="matmul", m=8, k=256, n=256, itemsize=4,
               pallas=True),
        OpSpec(domain="matmul", m=64, k=512, n=512, itemsize=4,
               pallas=True),
    ],
}
