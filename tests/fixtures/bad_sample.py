"""Known-bad fixture for the races pass, fused-sampling-head flavor:
the running-argmax (score, index) outputs are revisited over *both*
grid dims, but the kernel declared the N dim ``"parallel"`` — legal
for a plain skinny GEMM (whose output row varies with N) but a
read-modify-write race for the argmax carry. Expected code: ``race``.

Everything else is disciplined on purpose: the accumulation is fully
declared (``acc_dims=(0, 1)``), init/store are guarded, the index maps
are in-bounds, and the instance fits its budgets — so the vmem and
bounds passes stay quiet and the only defect is the N-dim semantics.
"""
from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET

_row = lambda name: BlockDecl(name, (8, 1), lambda j, kk: (0, 0), (8, 1), 4)

racy_argmax = KernelContract(
    name="bad_sample_parallel_n", route="fixture", domain="head_sample",
    grid=(4, 4),
    # dim 0 is the N loop the argmax carry is revisited over — it must
    # be "arbitrary", but this kernel declared it "parallel"
    dimension_semantics=("parallel", "arbitrary"),
    inputs=(
        BlockDecl("x", (8, 512), lambda j, kk: (0, 0), (8, 512), 4,
                  resident=True),
        BlockDecl("w", (128, 128), lambda j, kk: (kk, j), (512, 512), 4),
        BlockDecl("counts", (8, 128), lambda j, kk: (0, j), (8, 512), 4),
        _row("temp"), _row("rep"), _row("pres"), _row("freq"),
        _row("seed"), _row("step"), _row("base"),
    ),
    outputs=(
        BlockDecl("best_score", (8, 1), lambda j, kk: (0, 0), (8, 1), 4),
        BlockDecl("best_idx", (8, 1), lambda j, kk: (0, 0), (8, 1), 4),
    ),
    scratch=(ScratchDecl("acc", (8, 128), 4),),
    acc_dims=(0, 1),
    guarded_init=True, guarded_store=True,
    vmem_budget=KERNEL_VMEM_BUDGET,
    admitted=True)

CONTRACTS = [racy_argmax]
