"""Known-bad kernel fixtures for the static verifier.

Each ``bad_*.py`` module exports lint inputs (``CONTRACTS``,
``MATERIALIZATION_CHECKS``, or ``ROUTES`` + ``SPECS``) containing
exactly the bug class one analysis pass exists to catch, so
``python -m repro.analysis.lint --contracts tests/fixtures/bad_X.py``
must exit nonzero with that pass's violation code in the JSON report.
tests/test_analysis.py pins this.
"""
