"""Known-bad fixture for the vmem pass: budget drift in both directions.

``overflow`` is a contract whose guard (``admitted=True``) waves through
blocks whose residency is ~4x KERNEL_VMEM_BUDGET — the
admits-what-doesn't-fit direction. ``headroom`` is rejected for VMEM
reasons even though its residency is tiny — the dead-headroom
(rejects-what-fits) direction. Expected codes: ``vmem-overflow`` and
``dead-headroom``.
"""
from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET

# 2048 x 2048 f32 blocks: 16 MiB each, wildly over the 8 MiB budget
_BIG = 2048

overflow = KernelContract(
    name="bad_vmem_overflow", route="fixture", domain="matmul",
    grid=(2, 2),
    dimension_semantics=("parallel", "parallel"),
    inputs=(
        BlockDecl("x", (_BIG, _BIG), lambda i, j: (i, 0),
                  (2 * _BIG, 2 * _BIG), 4),
        BlockDecl("w", (_BIG, _BIG), lambda i, j: (0, j),
                  (2 * _BIG, 2 * _BIG), 4),
    ),
    outputs=(BlockDecl("out", (_BIG, _BIG), lambda i, j: (i, j),
                       (2 * _BIG, 2 * _BIG), 4),),
    scratch=(ScratchDecl("acc", (_BIG, _BIG), 4),),
    vmem_budget=KERNEL_VMEM_BUDGET,
    admitted=True)                      # guard bug: this does not fit

headroom = KernelContract(
    name="bad_vmem_dead_headroom", route="fixture", domain="matmul",
    grid=(2,),
    dimension_semantics=("parallel",),
    inputs=(BlockDecl("x", (8, 128), lambda i: (i, 0), (16, 128), 4),),
    outputs=(BlockDecl("out", (8, 128), lambda i: (i, 0), (16, 128), 4),),
    vmem_budget=KERNEL_VMEM_BUDGET,
    admitted=False, vmem_reject=True)   # guard bug: this fits easily

CONTRACTS = [overflow, headroom]
