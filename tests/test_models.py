"""Per-architecture smoke tests (reduced configs, brief requirement) plus
model-core numerics: chunked-vs-recurrent scans, decode-vs-prefill parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, ShapeSpec, TrainConfig
from repro.configs import arch_ids, get_config
from repro.models import mamba2 as m2
from repro.models import registry
from repro.models import rwkv6 as rw
from repro.train.loop import init_train_state, make_train_step


def _batch_for(cfg, b=2, s=16, seed=1):
    if cfg.family == "cnn":
        return {"images": jax.random.normal(
            jax.random.PRNGKey(seed), (b, cfg.cnn_img, cfg.cnn_img,
                                       cfg.cnn_in_ch)),
            "labels": jax.random.randint(jax.random.PRNGKey(seed + 1),
                                         (b,), 0, cfg.cnn_classes)}
    out = {"labels": jax.random.randint(jax.random.PRNGKey(seed + 1),
                                        (b, s), 0, cfg.vocab_size),
           "loss_mask": jnp.ones((b, s), jnp.float32)}
    if cfg.embeds_input:
        out["embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(seed), (b, s, cfg.d_model))
    elif cfg.prefix_embed_len:
        out["tokens"] = jax.random.randint(
            jax.random.PRNGKey(seed), (b, s - cfg.prefix_embed_len), 0,
            cfg.vocab_size)
        out["prefix_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(seed + 2), (b, cfg.prefix_embed_len,
                                           cfg.d_model))
    else:
        out["tokens"] = jax.random.randint(
            jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", arch_ids())
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch_for(cfg, s=32)
        out, aux = registry.forward(params, cfg, batch)
        if cfg.family == "cnn":
            assert out.shape == (2, cfg.cnn_classes)
        else:
            assert out.shape[0] == 2 and out.shape[-1] == cfg.d_model
        assert np.isfinite(np.asarray(out, np.float32)).all()
        assert np.isfinite(float(aux))

    def test_one_train_step(self, arch):
        cfg = get_config(arch, smoke=True)
        rc = RunConfig(model=cfg, train=TrainConfig(steps=2))
        state = init_train_state(jax.random.PRNGKey(0), rc)
        step = make_train_step(rc)
        batch = _batch_for(cfg, s=32)
        new_state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        assert int(new_state.step) == 1
        # weights actually moved
        d0 = jax.tree_util.tree_leaves(state.params)[1]
        d1 = jax.tree_util.tree_leaves(new_state.params)[1]
        assert not np.allclose(np.asarray(d0), np.asarray(d1))


_DECODE_ARCHS = ["olmo-1b", "qwen2.5-14b", "rwkv6-1.6b", "zamba2-1.2b",
                 "arctic-480b"]


@pytest.mark.parametrize("arch", _DECODE_ARCHS)
def test_decode_matches_prefill(arch):
    """Prefill on t tokens + decode of token t must equal prefill on t+1
    tokens (same hidden for the last position)."""
    cfg = get_config(arch, smoke=True).replace(remat="none")
    if cfg.family == "moe_lm":
        # no-drop capacity: token dropping differs between a 13-token and a
        # 1-token dispatch by construction, which is inherent to capacity-
        # bounded MoE, not a cache bug
        cfg = cfg.replace(moe=cfg.moe.__class__(
            num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
            capacity_factor=16.0,
            dense_residual_ff=cfg.moe.dense_residual_ff))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    b, t = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, t + 1), 0,
                              cfg.vocab_size)
    cache = registry.init_cache(cfg, b, t + 8)
    h_pre, cache = registry.prefill(params, cfg, tokens=toks[:, :t],
                                    cache=cache)
    h_dec, _ = registry.decode_step(params, cfg, toks[:, t], cache)
    cache2 = registry.init_cache(cfg, b, t + 8)
    h_full, _ = registry.prefill(params, cfg, tokens=toks, cache=cache2)
    np.testing.assert_allclose(
        np.asarray(h_dec[:, 0], np.float32),
        np.asarray(h_full[:, t], np.float32), rtol=2e-2, atol=2e-2)


def test_rwkv_chunked_equals_recurrent():
    b, t, h, d = 2, 64, 2, 16
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 5)
    r, kk, v = (jax.random.normal(ks[i], (b, t, h, d)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, t, h, d)) * 0.5)
    u = jax.random.normal(ks[4], (h, d))
    s0 = jnp.zeros((b, h, d, d))
    y1, st1 = rw.wkv_recurrent(r, kk, v, logw, u, s0)
    y2, st2 = rw.wkv_chunked(r, kk, v, logw, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=2e-4,
                               atol=2e-4)


def test_mamba_chunked_equals_recurrent():
    b, t, h, p, n = 2, 64, 3, 8, 16
    k = jax.random.PRNGKey(1)
    ks = jax.random.split(k, 4)
    x = jax.random.normal(ks[0], (b, t, h, p))
    bm = jax.random.normal(ks[1], (b, t, n))
    cm = jax.random.normal(ks[2], (b, t, n))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (b, t, h)))
    s0 = jnp.zeros((b, h, p, n))
    y1, st1 = m2.ssd_recurrent(x, bm, cm, la, s0)
    y2, st2 = m2.ssd_chunked(x, bm, cm, la, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=2e-4,
                               atol=2e-4)


def test_chunked_attention_matches_naive():
    from repro.models import attention as attn
    cfg = get_config("olmo-1b", smoke=True).replace(attn_impl="naive")
    params = attn.attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y_naive = attn.attention_apply(params, cfg, x)
    y_chunk = attn.attention_apply(
        params, cfg.replace(attn_impl="chunked", attn_chunk=16), x)
    np.testing.assert_allclose(np.asarray(y_naive), np.asarray(y_chunk),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_attention():
    from repro.models import attention as attn
    cfg = get_config("starcoder2-15b", smoke=True).replace(
        attn_impl="naive", sliding_window=8)
    params = attn.attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    y_win = attn.attention_apply(params, cfg, x)
    y_chunk = attn.attention_apply(
        cfg=cfg.replace(attn_impl="chunked", attn_chunk=8), p=params, x=x)
    np.testing.assert_allclose(np.asarray(y_win), np.asarray(y_chunk),
                               rtol=2e-4, atol=2e-4)


def test_param_count_matches_analytic():
    """Analytic param_count (used for MODEL_FLOPS) within 15% of the real
    tree for the dense families (smoke sizes are LoRA/embedding-heavy, so
    the bound is loose; full sizes match published totals in configs)."""
    for arch in ("olmo-1b", "qwen2.5-14b", "musicgen-medium"):
        cfg = get_config(arch, smoke=True)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        real = sum(x.size for x in jax.tree_util.tree_leaves(params))
        assert abs(real - cfg.param_count()) / real < 0.15, arch
