"""Serving engine: greedy decode parity, DBB-packed serving, footprint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dbb_linear import (maybe_decompress_tree, pack_tree,
                                   tree_footprint_bytes)
from repro.core.sparsity import apply_dbb_to_tree
from repro.models import registry
from repro.serve.engine import ServeEngine, make_decode_step


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_greedy_generate_matches_full_forward(small_lm):
    """Engine output == argmax over a full-context forward, token by token."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, max_batch=2)
    prompt = [5, 17, 3, 250, 99]
    out = eng.generate([prompt], max_new_tokens=5)[0]

    seq = list(prompt)
    w_head = registry.lm_head_weight(params, cfg)
    for _ in range(5):
        toks = jnp.asarray([seq])
        h, _ = registry.forward(params, cfg, {"tokens": toks})
        logits = h[0, -1].astype(jnp.float32) @ w_head.astype(jnp.float32)
        nxt = int(jnp.argmax(logits))
        seq.append(nxt)
    assert out == seq[len(prompt):]


def test_generate_batch_isolation(small_lm):
    """Requests in one batch don't contaminate each other."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, max_batch=4)
    a = eng.generate([[5, 17, 3]], max_new_tokens=4)[0]
    b = eng.generate([[5, 17, 3], [9, 9, 9, 9, 1, 2]],
                     max_new_tokens=4)[0]
    assert a == b


def test_packed_serving_matches_projected_dense(small_lm):
    """DBB-packed decode == decode with the DBB-projected dense weights
    (the pack→on-the-fly-decompress path is exact)."""
    cfg, params = small_lm
    cfg = cfg.replace(dbb=cfg.dbb.__class__(enabled=True, block=8, nnz=4))
    proj = apply_dbb_to_tree(params, cfg.dbb, straight_through=False)
    packed = pack_tree(proj, cfg.dbb)
    # some leaf actually packed?
    from repro.core.dbb import DbbWeight
    n_packed = sum(isinstance(x, DbbWeight)
                   for x in jax.tree_util.tree_leaves(
                       packed, is_leaf=lambda y: isinstance(y, DbbWeight)))
    assert n_packed > 0

    cache1 = registry.init_cache(cfg, 1, 8)
    cache2 = registry.init_cache(cfg, 1, 8)
    step = jax.jit(make_decode_step(cfg))
    tok = jnp.asarray([7])
    n1, _ = step(proj, cache1, tok)
    n2, _ = step(packed, cache2, tok)
    assert int(n1[0]) == int(n2[0])


def test_footprint_reduction_matches_paper(small_lm):
    """Packed footprint of eligible leaves ≈ 56.25% of bf16-dense
    (4/8 values + 1 mask byte per 16 dense bytes)."""
    cfg, params = small_lm
    dbb = cfg.dbb.__class__(enabled=True, block=8, nnz=4)
    params16 = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    packed = pack_tree(params16, dbb)
    from repro.core.dbb import DbbWeight

    dense_b = packed_b = 0
    flat_dense = dict(jax.tree_util.tree_flatten_with_path(params16)[0])
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            packed, is_leaf=lambda x: isinstance(x, DbbWeight))[0]:
        if isinstance(leaf, DbbWeight):
            nb = leaf.values.size // leaf.nnz
            packed_b += leaf.values.size * 2 + nb
            dense_b += leaf.k_dim * leaf.n_dim * 2 * (
                leaf.values.size // (leaf.nnz * (leaf.k_dim // leaf.block)
                                     * leaf.n_dim))
    assert dense_b > 0
    ratio = packed_b / dense_b
    assert ratio == pytest.approx((4 * 2 + 1) / 16, rel=1e-3)  # 0.5625


def test_maybe_decompress_tree_roundtrip(small_lm):
    cfg, params = small_lm
    dbb = cfg.dbb.__class__(enabled=True, block=8, nnz=4)
    proj = apply_dbb_to_tree(params, dbb, straight_through=False)
    packed = pack_tree(proj, dbb)
    dense = maybe_decompress_tree(packed)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(proj)[0],
            jax.tree_util.tree_flatten_with_path(dense)[0]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_int8_packed_serving_close_to_dense(small_lm):
    """INT8+DBB packed (the paper's exact deployment format) tracks the
    projected-dense model within quantization tolerance."""
    cfg, params = small_lm
    dbb = cfg.dbb.__class__(enabled=True, block=8, nnz=4)
    proj = apply_dbb_to_tree(params, dbb, straight_through=False)
    packed = pack_tree(proj, dbb, quantize=True)
    from repro.core.dbb import DbbWeight
    leaves = [x for x in jax.tree_util.tree_leaves(
        packed, is_leaf=lambda y: isinstance(y, DbbWeight))
        if isinstance(x, DbbWeight)]
    assert leaves and all(l.values.dtype == jnp.int8 for l in leaves)
    assert all(l.scale is not None for l in leaves)

    toks = jnp.asarray([[5, 17, 3, 250, 99]])
    h_d, _ = registry.forward(proj, cfg, {"tokens": toks})
    dense_from_packed = maybe_decompress_tree(packed, dtype=jnp.float32)
    h_q, _ = registry.forward(dense_from_packed, cfg, {"tokens": toks})
    # per-channel INT8: small relative error on hidden states
    rel = (np.abs(np.asarray(h_d - h_q, np.float32)).mean()
           / (np.abs(np.asarray(h_d, np.float32)).mean() + 1e-9))
    assert rel < 0.05, rel


def test_int8_packed_footprint():
    """INT8 DBB at NNZ<=4: (4 value bytes + 1 mask byte)/8 = 62.5% of INT8
    dense — the paper's 37.5% saving — and 31.25% of bf16 dense."""
    from repro.config import DbbConfig
    cfg = DbbConfig(enabled=True, block=8, nnz=4)
    assert cfg.weight_footprint_ratio == pytest.approx(0.625)
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    packed = pack_tree({"mlp": {"wi": {"w": w}}}, cfg, quantize=True)
    leaf = packed["mlp"]["wi"]["w"]
    nb = leaf.values.size // leaf.nnz
    packed_bytes = leaf.values.size * 1 + nb * 1 + leaf.scale.size * 4
    bf16_dense = w.size * 2
    assert packed_bytes / bf16_dense < 0.33


def test_ragged_batch_matches_solo_decoding(small_lm):
    """A short prompt in a mixed-length batch must decode token-identically
    to running it alone: left-pad keys are masked and RoPE positions are
    per-row shifted (the pre-fix engine attended pads as real context)."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, max_batch=4)
    prompts = [[5, 17, 3], [9, 9, 9, 9, 1, 2], [42, 7, 13, 250, 99]]
    batched = eng.generate(prompts, max_new_tokens=6)
    for i, p in enumerate(prompts):
        solo = eng.generate([p], max_new_tokens=6)[0]
        assert batched[i] == solo, (i, batched[i], solo)


def test_ragged_prefill_cache_carries_offsets(small_lm):
    """prefill(start=...) stores per-row offsets in the cache and decode
    preserves them (the decode mask needs them every step)."""
    cfg, params = small_lm
    import jax.numpy as jnp
    toks = jnp.asarray([[0, 0, 5, 17], [9, 9, 9, 9]], jnp.int32)
    start = jnp.asarray([2, 0], jnp.int32)
    cache = registry.init_cache(cfg, 2, 8)
    _, cache = registry.prefill(params, cfg, tokens=toks, cache=cache,
                                start=start)
    assert "start" in cache
    np.testing.assert_array_equal(np.asarray(cache["start"]),
                                  np.asarray(start))
    _, cache2 = registry.decode_step(params, cfg, jnp.asarray([1, 2]), cache)
    np.testing.assert_array_equal(np.asarray(cache2["start"]),
                                  np.asarray(start))


def test_ragged_single_row_chunked_config(small_lm):
    """B=1 ragged prefill under a chunked-attention config must still mask
    pads (ragged routing is flagged explicitly, not inferred from batch
    size): last-position hidden == unpadded prefill."""
    cfg, params = small_lm
    import jax.numpy as jnp
    cfg = cfg.replace(attn_impl="chunked", attn_chunk=8)
    prompt = list(range(5, 13))                      # 8 real tokens
    toks_pad = jnp.asarray([[0] * 8 + prompt], jnp.int32)   # s=16 (8 pads)
    cache = registry.init_cache(cfg, 1, 20)
    h_pad, _ = registry.prefill(params, cfg, tokens=toks_pad, cache=cache,
                                start=jnp.asarray([8]))
    cache2 = registry.init_cache(cfg, 1, 20)
    h_solo, _ = registry.prefill(params, cfg,
                                 tokens=jnp.asarray([prompt], jnp.int32),
                                 cache=cache2)
    np.testing.assert_allclose(np.asarray(h_pad[:, -1], np.float32),
                               np.asarray(h_solo[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_nonlayer_decompress_hoisted(small_lm):
    """Packed embed/LM-head leaves are expanded once at engine build —
    the per-token decode step must see zero packed non-layer leaves —
    and packed serving still matches projected-dense serving."""
    cfg, params = small_lm
    dbb = cfg.dbb.__class__(enabled=True, block=8, nnz=4)
    cfgp = cfg.replace(dbb=dbb)
    proj = apply_dbb_to_tree(params, dbb, straight_through=False)
    packed = pack_tree(proj, dbb)
    from repro.core.dbb import DbbWeight

    eng = ServeEngine(cfgp, packed, max_batch=2)
    non_layer = {k: v for k, v in eng.params.items() if k != "layers"}
    packed_left = [x for x in jax.tree_util.tree_leaves(
        non_layer, is_leaf=lambda y: isinstance(y, DbbWeight))
        if isinstance(x, DbbWeight)]
    assert not packed_left, "non-layer leaves must be pre-expanded"
    # layer stack stays compressed in HBM (per-layer expand in the scan)
    layer_packed = [x for x in jax.tree_util.tree_leaves(
        eng.params["layers"],
        is_leaf=lambda y: isinstance(y, DbbWeight))
        if isinstance(x, DbbWeight)]
    assert layer_packed, "layer stack must stay packed"

    out_packed = eng.generate([[5, 17, 3, 250]], max_new_tokens=4)[0]
    out_dense = ServeEngine(cfgp, proj, max_batch=2).generate(
        [[5, 17, 3, 250]], max_new_tokens=4)[0]
    assert out_packed == out_dense


def test_ssm_engine_generates(small_lm):
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=2)
    out = eng.generate([[4, 8, 15], [16, 23]], max_new_tokens=3)
    assert len(out) == 2 and all(len(o) == 3 for o in out)
