"""Deterministic stand-in for `hypothesis` when it isn't installed.

The CI image pins hypothesis (requirements.txt), but the minimal container
only ships jax/numpy/pytest. Property tests still run here: `given` expands
each strategy into a small deterministic sample set and calls the test over
(a capped number of) combinations — strictly weaker than hypothesis's
search, but the invariants are still exercised and collection never breaks.
"""
from __future__ import annotations

import functools
import itertools

_MAX_EXAMPLES = 25


class _IntRange:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def samples(self):
        span = self.hi - self.lo
        vals = {self.lo, self.hi, self.lo + span // 2,
                self.lo + span // 3, self.lo + 2 * span // 3}
        return sorted(v for v in vals if self.lo <= v <= self.hi)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntRange:
        return _IntRange(min_value, max_value)


st = _Strategies()


def given(*strategies):
    def deco(fn):
        def wrapper(*args):          # args = (self,) for methods, () plain
            combos = list(itertools.product(
                *(s.samples() for s in strategies)))
            stride = max(1, len(combos) // _MAX_EXAMPLES)
            for combo in combos[::stride][:_MAX_EXAMPLES]:
                fn(*args, *combo)
        # NOT functools.wraps: pytest would introspect the wrapped
        # signature and treat the strategy params as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def settings(*_a, **_k):             # decorator-compatible no-op
    def deco(fn):
        return fn
    return deco
