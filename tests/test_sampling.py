"""On-device sampling subsystem (DESIGN.md §15) property suite.

Layers of coverage:

  * RNG primitives: counter-based uniforms strictly inside (0, 1) and
    independent salt streams;
  * the TensorRT-LLM penalty contract (defaults are exact identities,
    repetition divides positive / multiplies negative, presence/
    frequency act only on the output-token history);
  * temperature → 0 is bit-identical to the legacy greedy path through
    the full engine, for every prefill mode;
  * sampled streams are seed-reproducible across decode chunk sizes and
    across TP vs single-device layouts (subprocess-spawned virtual
    mesh);
  * the fused Pallas head-sample route is bit-exact with the XLA
    reference sampler at a fixed key.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings

    hypothesis.settings.register_profile(
        "fast", max_examples=10, deadline=None)
    hypothesis.settings.load_profile("fast")
except ModuleNotFoundError:      # bare container: deterministic fallback
    from _hyp_fallback import given, settings, st

from repro.configs import get_config
from repro.kernels import dispatch
from repro.kernels.sample import (NEG_INF, SALT_ACCEPT, SALT_TOKEN,
                                  apply_penalties, gumbel_noise,
                                  sample_logits, uniform_noise)
from repro.models import registry
from repro.serve.engine import ServeEngine
from repro.serve.sampling import SamplingParams

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def cfg():
    return get_config("olmo-1b", smoke=True).replace(remat="none")


@pytest.fixture(scope="module")
def params(cfg):
    return registry.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(3)
    return [list(rng.integers(2, 500, size=n)) for n in (5, 3, 6, 4)]


@pytest.fixture(scope="module")
def engine(cfg, params):
    return ServeEngine(cfg, params, max_batch=4, fetch_chunk=4)


# ---------------------------------------------------------------------------
# RNG primitives
# ---------------------------------------------------------------------------

class TestRngPrimitives:
    @given(st.integers(0, 2 ** 31 - 1))
    def test_uniform_strictly_inside_unit_interval(self, seed):
        s = jnp.full((1, 1), seed, jnp.int32)
        step = jnp.arange(8, dtype=jnp.int32).reshape(-1, 1)
        idx = jnp.arange(64, dtype=jnp.int32)[None, :]
        u = np.asarray(uniform_noise(s, step, idx, SALT_TOKEN))
        assert (u > 0.0).all() and (u < 1.0).all()
        assert np.isfinite(np.log(u)).all()
        g = np.asarray(gumbel_noise(s, step, idx, SALT_TOKEN))
        # bounded: NEG_INF on masked lanes must always dominate
        assert np.isfinite(g).all() and (np.abs(g) < 20.0).all()

    def test_salt_streams_independent(self):
        s = jnp.zeros((1, 1), jnp.int32)
        step = jnp.arange(4, dtype=jnp.int32).reshape(-1, 1)
        idx = jnp.arange(32, dtype=jnp.int32)[None, :]
        a = np.asarray(uniform_noise(s, step, idx, SALT_TOKEN))
        b = np.asarray(uniform_noise(s, step, idx, SALT_ACCEPT))
        assert (a != b).any()

    def test_counter_keying_ignores_layout(self):
        """Noise is a function of (seed, step, idx) only — reshaping or
        transposing the batch cannot change any drawn value."""
        seeds = jnp.arange(6, dtype=jnp.int32)
        steps = jnp.full((6,), 3, jnp.int32)
        idx = jnp.arange(16, dtype=jnp.int32)
        wide = np.asarray(uniform_noise(seeds[:, None], steps[:, None],
                                        idx[None, :], SALT_TOKEN))
        for r in range(6):
            row = np.asarray(uniform_noise(seeds[r], steps[r], idx,
                                           SALT_TOKEN))
            assert (row == wide[r]).all()


# ---------------------------------------------------------------------------
# penalty contract (TensorRT-LLM samplingPenaltyKernels semantics)
# ---------------------------------------------------------------------------

class TestPenaltyContract:
    def _logits(self, seed=0, b=4, v=32):
        x = jax.random.normal(jax.random.PRNGKey(seed), (b, v), jnp.float32)
        return x * 3.0       # both signs, away from zero

    def test_defaults_are_bitwise_identity(self):
        lg = self._logits()
        counts = jax.random.randint(jax.random.PRNGKey(1), lg.shape, 0, 3)
        one = jnp.ones((4, 1), jnp.float32)
        zero = jnp.zeros((4, 1), jnp.float32)
        out = np.asarray(apply_penalties(lg, counts, one, zero, zero))
        assert (out == np.asarray(lg)).all()

    def test_repetition_divides_positive_multiplies_negative(self):
        lg = self._logits(2)
        counts = jnp.ones(lg.shape, jnp.int32)
        rep = jnp.full((4, 1), 1.5, jnp.float32)
        zero = jnp.zeros((4, 1), jnp.float32)
        out = np.asarray(apply_penalties(lg, counts, rep, zero, zero))
        ref = np.where(np.asarray(lg) > 0, np.asarray(lg) / 1.5,
                       np.asarray(lg) * 1.5)
        np.testing.assert_allclose(out, ref, rtol=1e-6)
        # penalized scores never increase preference for a seen token
        assert (out <= np.asarray(lg) + 1e-6).all()

    def test_presence_frequency_use_output_history_only(self):
        lg = self._logits(3)
        counts = jnp.zeros(lg.shape, jnp.int32).at[:, :8].set(2)
        one = jnp.ones((4, 1), jnp.float32)
        pres = jnp.full((4, 1), 0.7, jnp.float32)
        freq = jnp.full((4, 1), 0.3, jnp.float32)
        out = np.asarray(apply_penalties(lg, counts, one, pres, freq))
        ref = np.asarray(lg).copy()
        ref[:, :8] -= 2 * 0.3 + 0.7     # count*freq + presence
        np.testing.assert_allclose(out, ref, rtol=1e-6)
        # unseen vocab (the prompt is never in counts) is untouched
        assert (out[:, 8:] == np.asarray(lg)[:, 8:]).all()

    def test_top_k_one_is_argmax(self):
        lg = self._logits(4)
        b = lg.shape[0]
        counts = jnp.zeros(lg.shape, jnp.int32)
        tok = sample_logits(
            lg, counts, jnp.full((b,), 0.9, jnp.float32),
            jnp.ones((b,), jnp.int32), jnp.ones((b,), jnp.float32),
            jnp.ones((b,), jnp.float32), jnp.zeros((b,), jnp.float32),
            jnp.zeros((b,), jnp.float32), jnp.arange(b, dtype=jnp.int32),
            jnp.zeros((b,), jnp.int32), use_tt=True)
        assert (np.asarray(tok) == np.asarray(jnp.argmax(lg, -1))).all()

    @given(st.integers(0, 20))
    def test_top_k_respected_at_high_temperature(self, seed):
        lg = self._logits(seed + 10)
        b, v = lg.shape
        k = 4
        counts = jnp.zeros(lg.shape, jnp.int32)
        tok = np.asarray(sample_logits(
            lg, counts, jnp.full((b,), 5.0, jnp.float32),
            jnp.full((b,), k, jnp.int32), jnp.ones((b,), jnp.float32),
            jnp.ones((b,), jnp.float32), jnp.zeros((b,), jnp.float32),
            jnp.zeros((b,), jnp.float32),
            jnp.arange(b, dtype=jnp.int32) + seed,
            jnp.zeros((b,), jnp.int32), use_tt=True))
        topk = np.argsort(np.asarray(lg), axis=-1)[:, -k:]
        for r in range(b):
            assert tok[r] in topk[r]


# ---------------------------------------------------------------------------
# engine-level: greedy equivalence + seed reproducibility
# ---------------------------------------------------------------------------

class TestEngineStreams:
    def test_default_params_bit_identical_to_greedy(self, engine, prompts):
        greedy = engine.generate(prompts, max_new_tokens=8)
        sampled = engine.generate(
            prompts, max_new_tokens=8,
            sampling=[SamplingParams() for _ in prompts])
        assert sampled == greedy

    def test_temp_zero_ignores_seed(self, engine, prompts):
        greedy = engine.generate(prompts, max_new_tokens=8)
        for s in (1, 17, 2 ** 30):
            sampled = engine.generate(
                prompts, max_new_tokens=8,
                sampling=[SamplingParams(temperature=0.0, seed=s + i)
                          for i in range(len(prompts))])
            assert sampled == greedy

    def test_seed_reproducible_across_chunk_sizes(self, cfg, params,
                                                  prompts):
        sp = [SamplingParams(temperature=0.9, seed=41 + i)
              for i in range(len(prompts))]
        outs = []
        for chunk in (4, 3, 7):
            eng = ServeEngine(cfg, params, max_batch=4, fetch_chunk=chunk)
            outs.append(eng.generate(prompts, max_new_tokens=8,
                                     sampling=sp))
        assert outs[0] == outs[1] == outs[2]

    def test_distinct_seeds_decorrelate(self, engine, prompts):
        # temperature high enough that the bounded gumbel noise (|g|<20)
        # dominates the random-init model's peaked tied-embedding logits
        a = engine.generate(
            prompts, max_new_tokens=8,
            sampling=[SamplingParams(temperature=50.0, seed=i)
                      for i in range(len(prompts))])
        b = engine.generate(
            prompts, max_new_tokens=8,
            sampling=[SamplingParams(temperature=50.0, seed=1000 + i)
                      for i in range(len(prompts))])
        assert a != b

    def test_serve_matches_generate_streams(self, cfg, params):
        """The continuous-batching scheduler must emit the same sampled
        stream as the static path — admission order must not leak into
        the RNG (counter keying is per request, not per slot/step)."""
        rng = np.random.default_rng(5)
        prompts = [list(rng.integers(2, 500, size=4)) for _ in range(6)]
        sp = [SamplingParams(temperature=0.8, seed=7 + i)
              for i in range(6)]
        eng = ServeEngine(cfg, params, max_batch=2, fetch_chunk=4)
        served = eng.serve(prompts, 8, sampling=sp)
        gen = []
        for i in range(0, 6, 2):
            gen.extend(eng.generate(prompts[i:i + 2], max_new_tokens=8,
                                    sampling=sp[i:i + 2]))
        assert served == gen


# ---------------------------------------------------------------------------
# fused Pallas route vs XLA reference sampler
# ---------------------------------------------------------------------------

class TestFusedRoute:
    @given(st.integers(0, 30))
    def test_fused_bit_exact_with_xla(self, seed):
        cfg = get_config("olmo-1b", smoke=True).replace(
            gemm_impl="pallas")
        b, d, v = 4, cfg.d_model, 512
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        h = jax.random.normal(k1, (b, d), jnp.float32)
        w = jax.random.normal(k2, (d, v), jnp.float32) * 0.1
        counts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                    (b, v), 0, 2)
        temp = jnp.asarray([0.0, 0.7, 1.3, 0.0], jnp.float32)
        rep = jnp.full((b,), 1.2, jnp.float32)
        pres = jnp.full((b,), 0.1, jnp.float32)
        freq = jnp.full((b,), 0.05, jnp.float32)
        seeds = jnp.arange(b, dtype=jnp.int32) + seed
        step = jnp.full((b,), 2, jnp.int32)
        toks = {}
        for route in ("head_sample_fused", "head_sample_xla"):
            toks[route] = np.asarray(dispatch.head_sample(
                h, w, counts, temp, rep, pres, freq, seeds, step,
                cfg=cfg, route=route))
        assert (toks["head_sample_fused"]
                == toks["head_sample_xla"]).all()

    def test_dispatch_prefers_fused_on_skinny_shape(self):
        cfg = get_config("olmo-1b", smoke=True).replace(
            gemm_impl="pallas")
        table = dispatch.explain("head_sample", m=4, k=128, n=512,
                                 dtype=jnp.float32, cfg=cfg)
        chosen = [t for t in table if t.chosen]
        assert chosen and chosen[0].name == "head_sample_fused"
        # top-k/top-p requests must fall back to the XLA sampler
        table = dispatch.explain("head_sample", m=4, k=128, n=512,
                                 dtype=jnp.float32, cfg=cfg,
                                 sample_tt=True)
        chosen = [t for t in table if t.chosen]
        assert chosen and chosen[0].name == "head_sample_xla"


# ---------------------------------------------------------------------------
# TP vs single-device (subprocess-spawned virtual mesh)
# ---------------------------------------------------------------------------

def _run(body: str, devices: int = 2, timeout: int = 900) -> dict:
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
import sys, json
sys.path.insert(0, {_SRC!r})
import jax, jax.numpy as jnp
import numpy as np
{body}
print("JSON::" + json.dumps(out))
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    for line in r.stdout.splitlines():
        if line.startswith("JSON::"):
            return json.loads(line[len("JSON::"):])
    raise AssertionError(f"no JSON in output: {r.stdout[-2000:]}")


def test_tp_sampled_stream_matches_single_device():
    out = _run("""
from repro.configs import get_config
from repro.dist.mesh_ctx import use_mesh
from repro.launch.mesh import make_smoke_mesh
from repro.models import registry
from repro.serve.engine import ServeEngine
from repro.serve.sampling import SamplingParams

cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
params = registry.init_params(jax.random.PRNGKey(0), cfg)
prompts = [[5, 6, 7, 8], [9, 10, 11], [12, 13, 14, 15, 16]]
sp = [SamplingParams(temperature=0.9, seed=11 + i) for i in range(3)]
single = ServeEngine(cfg, params, max_batch=4, fetch_chunk=4)
ref_greedy = single.generate(prompts, max_new_tokens=8)
ref_sampled = single.generate(prompts, max_new_tokens=8, sampling=sp)
mesh = make_smoke_mesh(data=1, model=2)
with use_mesh(mesh):
    eng = ServeEngine(cfg, params, max_batch=4, fetch_chunk=4)
    tp_greedy = eng.generate(prompts, max_new_tokens=8)
    tp_sampled = eng.generate(prompts, max_new_tokens=8, sampling=sp)
out = {"greedy_eq": tp_greedy == ref_greedy,
       "sampled_eq": tp_sampled == ref_sampled}
""")
    assert out["greedy_eq"], "TP greedy diverged from single-device"
    assert out["sampled_eq"], "TP sampled stream diverged (vocab-parallel"\
        " combine must preserve the global counter stream)"
