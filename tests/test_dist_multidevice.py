"""Multi-device semantics, each in a subprocess with virtual CPU devices
(XLA_FLAGS must not leak into the main test process — the brief requires
unit tests to see one device)."""
import json
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, devices: int = 8, timeout: int = 900) -> dict:
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
import sys, json
sys.path.insert(0, {_SRC!r})
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
{body}
print("JSON::" + json.dumps(out))
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    for line in r.stdout.splitlines():
        if line.startswith("JSON::"):
            return json.loads(line[len("JSON::"):])
    raise AssertionError(f"no JSON in output: {r.stdout[-2000:]}")


def test_vocab_parallel_ce_matches_dense():
    out = _run("""
from repro.dist.collectives import dense_ce, vocab_parallel_ce
from repro.launch.mesh import make_smoke_mesh
mesh = make_smoke_mesh(data=2, model=4)
k = jax.random.PRNGKey(0)
h = jax.random.normal(k, (4, 8, 32))
w = jax.random.normal(jax.random.fold_in(k, 1), (32, 64))
labels = jax.random.randint(jax.random.fold_in(k, 2), (4, 8), 0, 64)
mask = (jax.random.uniform(jax.random.fold_in(k, 3), (4, 8)) > 0.3).astype(jnp.float32)
with mesh:
    vp = float(vocab_parallel_ce(h, w, labels, mesh, mask))
dn = float(dense_ce(h, w, labels, mask))
# gradients must match too
with mesh:
    gv = jax.grad(lambda hh: vocab_parallel_ce(hh, w, labels, mesh, mask))(h)
gd = jax.grad(lambda hh: dense_ce(hh, w, labels, mask))(h)
out = {"vp": vp, "dn": dn,
       "gdiff": float(jnp.abs(gv - gd).max())}
""")
    assert out["vp"] == pytest.approx(out["dn"], rel=1e-5)
    assert out["gdiff"] < 1e-5


def test_vocab_parallel_embed_matches_gather():
    out = _run("""
from repro.dist.collectives import vocab_parallel_embed
from repro.launch.mesh import make_smoke_mesh
mesh = make_smoke_mesh(data=2, model=4)
k = jax.random.PRNGKey(0)
table = jax.random.normal(k, (64, 16))
toks = jax.random.randint(jax.random.fold_in(k, 1), (4, 8), 0, 64)
with mesh:
    vp = vocab_parallel_embed(table, toks, jnp.float32, mesh)
ref = table[toks]
out = {"diff": float(jnp.abs(vp - ref).max())}
""")
    assert out["diff"] < 1e-5


def test_sharded_train_step_matches_single_device():
    out = _run("""
from repro.config import RunConfig, TrainConfig
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.dist.mesh_ctx import use_mesh
from repro.launch.mesh import make_smoke_mesh
from repro.train.loop import init_train_state, make_train_step
cfg = get_config("olmo-1b", smoke=True)
rc = RunConfig(model=cfg, train=TrainConfig(learning_rate=1e-3))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
         "loss_mask": jnp.ones((8, 32), jnp.float32)}
# single device
state = init_train_state(jax.random.PRNGKey(0), rc)
s1, m1 = jax.jit(make_train_step(rc))(state, batch)
# sharded
mesh = make_smoke_mesh(data=2, model=4)
with use_mesh(mesh):
    state2 = init_train_state(jax.random.PRNGKey(0), rc)
    sh = shd.named_sharding_tree(shd.param_specs(state2.params, mesh, cfg), mesh)
    state2 = state2.__class__(params=jax.device_put(state2.params, sh),
                              opt_state=state2.opt_state, ef=state2.ef,
                              step=state2.step)
    s2, m2 = jax.jit(make_train_step(rc))(state2, batch)
l1 = jax.tree_util.tree_leaves(s1.params)
l2 = jax.tree_util.tree_leaves(s2.params)
diffs = [float(jnp.abs(a - b).max()) for a, b in zip(l1, l2)]
out = {"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
       "maxdiff": max(diffs)}
""")
    assert out["loss1"] == pytest.approx(out["loss2"], rel=1e-4)
    assert out["maxdiff"] < 5e-4


def test_moe_ep_matches_local():
    out = _run("""
from repro.configs import get_config
from repro.dist.mesh_ctx import use_mesh
from repro.launch.mesh import make_smoke_mesh
from repro.models.moe import moe_apply, moe_init
cfg = get_config("arctic-480b", smoke=True)
p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
cl = cfg.replace(moe=cfg.moe.__class__(num_experts=8, top_k=2,
                                       capacity_factor=8.0,
                                       dense_residual_ff=128, impl="local"))
ce = cl.replace(moe=cl.moe.__class__(num_experts=8, top_k=2,
                                     capacity_factor=8.0,
                                     dense_residual_ff=128, impl="ep"))
y_local, aux_l = moe_apply(p, cl, x)
mesh = make_smoke_mesh(data=2, model=4)
with use_mesh(mesh):
    y_ep, aux_e = jax.jit(lambda pp, xx: moe_apply(pp, ce, xx))(p, x)
out = {"diff": float(jnp.abs(y_local - y_ep).max()),
       "aux_l": float(aux_l), "aux_e": float(aux_e)}
""")
    # high capacity factor → no token dropping → paths agree
    assert out["diff"] < 1e-3
    assert out["aux_l"] == pytest.approx(out["aux_e"], rel=1e-4)


def test_pipeline_forward_matches_sequential():
    out = _run("""
from repro.dist.pipeline import pipeline_forward, stack_stages
from repro.launch.mesh import make_smoke_mesh
mesh = make_smoke_mesh(data=2, model=1, pod=4)
L, M, B, D = 8, 6, 4, 32
ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) / jnp.sqrt(D)
x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

def layer(c, w):
    return jnp.tanh(c @ w), None

def stage_fn(stage_ws, xx):
    return jax.lax.scan(layer, xx, stage_ws)[0]

stages = stack_stages(ws, 4)
y_pp = pipeline_forward(stages, x, stage_fn, mesh, axis="pod")
y_seq = jax.vmap(lambda xx: jax.lax.scan(layer, xx, ws)[0])(x)
out = {"diff": float(jnp.abs(y_pp - y_seq).max())}
""")
    assert out["diff"] < 1e-5


def test_tp_gemm_bit_exact_matrix():
    """Sharded-Pallas vs single-device-Pallas vs XLA over the TP GEMM
    matrix: {int8, bf16} × {dense, DBB-packed} × {column (N) split,
    row (K) split + boundary psum} on 2- and 4-device meshes.

    Splits without a reduction (column) must be BIT-identical on every
    dtype; K-splits are bit-identical for int8 (integer accumulate —
    addition order free) and tolerance-bounded for floats (the psum
    reorders the accumulation)."""
    out = _run("""
import dataclasses
from repro.core.dbb import pack_dbb
from repro.dist.compat import shard_map
from repro.dist.mesh_ctx import shard_tp_ctx, use_mesh
from repro.kernels import dispatch
from repro.launch.mesh import make_smoke_mesh

M, K, N, BLOCK, NNZ = 8, 256, 256, 8, 4
k0 = jax.random.PRNGKey(0)
out = {}
for tp in (2, 4):
    mesh = make_smoke_mesh(data=1, model=tp)
    for dt_name in ("int8", "bf16"):
        if dt_name == "int8":
            x = jax.random.randint(k0, (M, K), -4, 4, jnp.int8)
            w = jax.random.randint(jax.random.fold_in(k0, 1), (K, N),
                                   -4, 4, jnp.int8)
            cases = [("dense", w)]
        else:
            x = jax.random.normal(k0, (M, K)).astype(jnp.bfloat16)
            wf = (jax.random.normal(jax.random.fold_in(k0, 1), (K, N))
                  / jnp.sqrt(K)).astype(jnp.bfloat16)
            cases = [("dense", wf), ("packed", pack_dbb(wf, BLOCK, NNZ))]
        for wname, wv in cases:
            kw = dict(out_dtype=x.dtype) if wname == "packed" else {}
            y_pal = dispatch.matmul(x, wv, pallas=True, **kw)
            y_xla = dispatch.matmul(x, wv, pallas=False, **kw)
            is_dbb = wname == "packed"
            wspec = (jax.tree_util.tree_map(lambda _: P(None, "model"), wv)
                     if is_dbb else P(None, "model"))
            with use_mesh(mesh):
                def col(xl, wl):
                    with shard_tp_ctx(tp):
                        return dispatch.matmul(xl, wl, pallas=True, **kw)
                y_col = shard_map(col, mesh=mesh,
                                  in_specs=(P(), wspec),
                                  out_specs=P(None, "model"),
                                  check_vma=False)(x, wv)
                wspec_r = (jax.tree_util.tree_map(lambda _: P("model", None),
                                                  wv)
                           if is_dbb else P("model", None))
                def row(xl, wl):
                    with shard_tp_ctx(tp):
                        y = dispatch.matmul(xl, wl, pallas=True, **kw)
                    return jax.lax.psum(y, "model")
                y_row = shard_map(row, mesh=mesh,
                                  in_specs=(P(None, "model"), wspec_r),
                                  out_specs=P(),
                                  check_vma=False)(x, wv)
            key = f"tp{tp}/{dt_name}/{wname}"
            f32 = lambda a: jnp.asarray(a, jnp.float32)
            out[key + "/col_vs_pallas"] = float(
                jnp.abs(f32(y_col) - f32(y_pal)).max())
            out[key + "/col_vs_xla"] = float(
                jnp.abs(f32(y_col) - f32(y_xla)).max())
            out[key + "/row_vs_pallas"] = float(
                jnp.abs(f32(y_row) - f32(y_pal)).max())
            out[key + "/ref_scale"] = float(jnp.abs(f32(y_pal)).max())
""", devices=4)
    for key, diff in out.items():
        if key.endswith("/ref_scale"):
            continue
        scale = out[key.rsplit("/", 1)[0] + "/ref_scale"]
        if "/int8/" in key or "/col_vs_pallas" in key:
            assert diff == 0.0, (key, diff)       # bit-identical
        else:
            assert diff <= max(scale, 1.0) * 2e-2, (key, diff, scale)


def test_tp_serve_parity_matrix():
    """The acceptance contract on a 4-device mesh: with
    ``gemm_impl="pallas"`` the engine routes prefill GEMM, skinny decode
    and flash attention through shard_map'd Pallas kernels (asserted via
    dispatch.explain), and the ragged packed-prefill serving loop is
    token-identical to single-device Pallas AND the XLA route on BOTH KV
    backends, dense and DBB-packed, whole-prompt and chunked prefill."""
    out = _run("""
from repro.config import DbbConfig, ModelConfig
from repro.core.dbb_linear import pack_tree
from repro.dist.mesh_ctx import use_mesh
from repro.kernels import dispatch
from repro.models import registry
from repro.serve.engine import ServeEngine

dbb = DbbConfig(enabled=True, block=8, nnz=4)
cfg = ModelConfig(family="dense_lm", d_model=64, d_ff=256, num_layers=2,
                  num_heads=8, num_kv_heads=4, vocab_size=128,
                  dtype="float32", gemm_impl="pallas", kv_page_size=8,
                  dbb=dbb)
params = registry.init_params(jax.random.PRNGKey(0), cfg)
packed = pack_tree(params, dbb)
prompts = [[5, 6, 7, 8], [9, 10, 11], [3, 4], [12, 13, 14, 15, 16]]
mesh = jax.make_mesh((1, 4), ("data", "model"))

out = {"streams": {}, "routes": {}, "tp_reason": {}}
for label, p in (("dense", params), ("packed", packed)):
    ref_x = ServeEngine(cfg.replace(gemm_impl="xla"), p, max_batch=4,
                        paged=False).serve(prompts, max_new_tokens=6)
    ref_p = ServeEngine(cfg, p, max_batch=4).serve(prompts,
                                                   max_new_tokens=6)
    with use_mesh(mesh):
        eng = ServeEngine(cfg, p, max_batch=4)
        out["tp_reason"][label] = eng.tp_reason
        tp_paged = eng.serve(prompts, max_new_tokens=6)
        tp_contig = ServeEngine(cfg, p, max_batch=4, paged=False).serve(
            prompts, max_new_tokens=6)
        tp_chunked = ServeEngine(cfg, p, max_batch=4,
                                 prefill_chunk=3).serve(
            prompts, max_new_tokens=6)
    out["streams"][label] = {
        "xla": ref_x, "pallas1": ref_p, "tp_paged": tp_paged,
        "tp_contig": tp_contig, "tp_chunked": tp_chunked}

# route assertions: explain() costs the per-shard instance the shard_map
# bodies run, on representative serving shapes (global dims + tp=4)
with use_mesh(mesh):
    pre = dispatch.explain("matmul", m=512, k=1024, n=4096, cfg=cfg,
                           tp=4)
    dec = dispatch.explain("matmul", m=8, k=1024, n=32768, cfg=cfg,
                           tp=4, gemv=True)
    att = dispatch.explain("attention", m=512, k=128, n=512, batch=8,
                           cfg=cfg, tp=4)
    out["routes"]["prefill_gemm"] = next(d.name for d in pre if d.chosen)
    out["routes"]["decode_gemv"] = next(d.name for d in dec if d.chosen)
    out["routes"]["attention"] = next(d.name for d in att if d.chosen)
    out["routes"]["mesh_note"] = dispatch.format_table(pre).splitlines()[0]
""", devices=4)
    for label, streams in out["streams"].items():
        ref = streams["pallas1"]
        for name, got in streams.items():
            assert got == ref, (label, name, got, ref)
    assert out["tp_reason"] == {"dense": "", "packed": ""}
    assert out["routes"]["prefill_gemm"] in ("sta", "skinny_sta")
    assert out["routes"]["decode_gemv"] in ("skinny_sta", "skinny_dbb")
    assert out["routes"]["attention"] == "attn_flash"
    assert "costed for mesh" in out["routes"]["mesh_note"]


def test_tp_greedy_vocab_parallel_heads():
    """Satellite: both vocab-parallel greedy heads — the column-sharded
    scalar-combine (`greedy_vocab_parallel`) and the `psum_scatter`
    variant (`greedy_scatter`, each hop moves [B, vocab/tp] instead of
    [B, vocab]) — match the dense argmax."""
    out = _run("""
from repro.dist.collectives import greedy_scatter, greedy_vocab_parallel
from repro.launch.mesh import make_smoke_mesh

mesh = make_smoke_mesh(data=1, model=4)
k = jax.random.PRNGKey(0)
h = jax.random.normal(k, (6, 32))
w = jax.random.normal(jax.random.fold_in(k, 1), (32, 128)) / 8.0
ref = jnp.argmax(h @ w, axis=-1)
vp = greedy_vocab_parallel(h, w, mesh)
sc = greedy_scatter(h, w, mesh)
out = {"vp": int((vp == ref).all()), "sc": int((sc == ref).all())}
""", devices=4)
    assert out["vp"] == 1
    assert out["sc"] == 1


def test_dryrun_cell_on_virtual_devices():
    """End-to-end dry-run of one smoke-sized cell on 8 devices: lower +
    compile + roofline terms present."""
    out = _run("""
from repro.config import ShapeSpec
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.dist.mesh_ctx import use_mesh
from repro.launch import specs as sp
from repro.launch.mesh import make_smoke_mesh
from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo import analyze_hlo_text
from repro.train.loop import make_train_step
mesh = make_smoke_mesh(data=2, model=4)
cfg = get_config("qwen2.5-14b", smoke=True)
shape = ShapeSpec("t", 64, 8, "train")
with use_mesh(mesh):
    rc = sp.run_config_for(cfg, shape)
    state_sds, state_spec = sp.train_state_specs(rc, mesh, fsdp=1 << 12)
    state_sh = shd.named_sharding_tree(state_spec, mesh)
    batch_sds = sp.train_input_specs(rc.model, shape)
    bspecs = shd.batch_specs(rc.model, mesh, 8, 64)
    batch_sh = shd.named_sharding_tree({k: bspecs.get(k, P()) for k in batch_sds}, mesh)
    step = make_train_step(rc)
    compiled = jax.jit(step, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,)).lower(state_sds, batch_sds).compile()
st = analyze_hlo_text(compiled.as_text())
t = roofline_terms(st, model_flops_per_device=1e9, io_bytes_per_device=1e6)
out = {"flops": st.flops, "coll": sum(st.collective_bytes.values()),
       "bottleneck": t.bottleneck}
""")
    assert out["flops"] > 0
    assert out["coll"] > 0
