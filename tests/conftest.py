# NOTE: no XLA_FLAGS here on purpose — unit tests and benches must see the
# single real CPU device. Multi-device tests (tests/test_dist_multidevice.py)
# spawn subprocesses that set xla_force_host_platform_device_count themselves.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
