"""Single-process tests for the TP-aware dispatch layer (DESIGN.md §14):
per-shard costing with the collective-bytes term, honest guard reasons
under axis splits, the tp-vmem analysis pass, serving cache/param spec
inference, and the wrap's refusal conditions. No devices or meshes are
spawned — the multi-device behaviour lives in test_dist_multidevice.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import tp_vmem
from repro.config import DbbConfig, ModelConfig
from repro.kernels import dispatch
from repro.kernels.dispatch import OpSpec


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    def __hash__(self):
        return hash(tuple(self.shape.items()))


TP4 = _FakeMesh({"data": 1, "model": 4})


# ---------------------------------------------------------------------------
# collectives.axis_size — clear error outside a mesh (satellite 2)
# ---------------------------------------------------------------------------

def test_axis_size_outside_mesh_raises_actionable_error():
    from repro.dist.collectives import axis_size
    with pytest.raises(RuntimeError, match="outside a mesh"):
        axis_size("model")


# ---------------------------------------------------------------------------
# explain(): per-shard costing + collective term + mesh header
# ---------------------------------------------------------------------------

def test_explain_tp_collective_term_and_mesh_header():
    cfg = ModelConfig(family="dense_lm", gemm_impl="pallas")
    dec = dispatch.explain("matmul", m=256, k=2048, n=2048, cfg=cfg,
                           tp=4, collective="all-reduce")
    chosen = next(d for d in dec if d.chosen)
    assert chosen.collective_bytes > 0        # the all-reduce is priced
    table = dispatch.format_table(dec)
    assert "costed for mesh" in table.splitlines()[0]
    assert "tp=4" in table.splitlines()[0]
    # column-parallel (no boundary collective) prices zero wire bytes
    col = dispatch.explain("matmul", m=256, k=2048, n=2048, cfg=cfg, tp=4)
    assert all(d.collective_bytes == 0 for d in col)


def test_explain_tp_costs_per_shard_instance():
    """tp=4 must cost the LOCAL instance: a column split shrinks N (and
    the weight bytes) ~4x vs the tp=1 table for the same global dims."""
    cfg = ModelConfig(family="dense_lm", gemm_impl="pallas")
    one = dispatch.explain("matmul", m=256, k=2048, n=8192, cfg=cfg, tp=1)
    four = dispatch.explain("matmul", m=256, k=2048, n=8192, cfg=cfg, tp=4)
    f1 = next(d for d in one if d.name == "sta")
    f4 = next(d for d in four if d.name == "sta")
    assert f4.flops == pytest.approx(f1.flops / 4, rel=1e-6)
    assert f4.bytes < f1.bytes


# ---------------------------------------------------------------------------
# guard reasons name the real rejection (satellite 1)
# ---------------------------------------------------------------------------

def _guards(spec):
    return {name: r.guard(spec)
            for name, r in dispatch.routes_for("matmul").items()}


def test_guard_reason_names_axis_split():
    # N=100 does not divide tp=8: the column split has no local instance
    spec = OpSpec(domain="matmul", m=128, k=256, n=100, pallas=True, tp=8)
    g = _guards(spec)["sta"]
    assert "unsupported axis split" in g and "N=100" in g and "8" in g


def test_guard_reason_names_block_interior_split():
    # per-shard K = 8·8/16 = 4 < block 8: the row split lands inside a
    # DBB block — the guard must say so, not claim a generic failure
    spec = OpSpec(domain="matmul", m=128, k=64, n=256, packed=True,
                  pallas=True, tp=16, collective="all-reduce", block=8)
    g = _guards(spec)["dbb_packed"]
    assert "splits inside a block" in g or "unsupported axis split" in g


def test_guard_reason_inactive_route_mentions_shard_map_reenable():
    spec = OpSpec(domain="matmul", m=128, k=256, n=256, pallas=False)
    g = _guards(spec)["sta"]
    assert "shard_map" in g


# ---------------------------------------------------------------------------
# analysis pass 6: per-shard VMEM / route survival
# ---------------------------------------------------------------------------

def test_tp_vmem_pass_clean_on_real_registry():
    from repro.analysis import dispatch_check
    routes = {d: dispatch.routes_for(d) for d in dispatch.DOMAINS}
    checked, violations = tp_vmem.check_registry(
        routes, dispatch_check.default_specs())
    assert checked > 0
    assert violations == []


def test_tp_vmem_pass_catches_global_dim_guard():
    """A guard that consults GLOBAL dims under tp (here: rejects the
    sharded spec on a budget its local shape passes) must be flagged."""
    real = dispatch.routes_for("matmul")["sta"]

    def bad_guard(spec):
        g = real.guard(dataclasses.replace(spec, tp=1, collective=""))
        if g:
            return g
        if spec.tp > 1 and spec.k * spec.n * spec.itemsize > 2 ** 22:
            return "weight tile exceeds VMEM budget"   # global k·n!
        return ""

    routes = {"matmul": {"sta": dataclasses.replace(real, guard=bad_guard)}}
    specs = {"matmul": [OpSpec(domain="matmul", m=256, k=2048, n=2048,
                               pallas=True)]}
    _, violations = tp_vmem.check_registry(routes, specs)
    assert any(v.code == "tp-route-loss" for v in violations)


# ---------------------------------------------------------------------------
# serving spec inference (pure, _FakeMesh — no devices)
# ---------------------------------------------------------------------------

def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_serve_cache_specs_shards_kv_heads_both_layouts():
    from repro.dist.sharding import serve_cache_specs
    contig = {"k": _sds(2, 4, 64, 8, 32), "v": _sds(2, 4, 64, 8, 32),
              "length": _sds(4), "start": _sds(4)}
    paged = {"k_pages": _sds(2, 33, 8, 8, 32),
             "v_pages": _sds(2, 33, 8, 8, 32),
             "block_table": _sds(4, 8), "length": _sds(4)}
    cs = serve_cache_specs(contig, TP4)
    ps = serve_cache_specs(paged, TP4)
    kv_spec = P(None, None, None, "model", None)
    assert cs["k"] == kv_spec and cs["v"] == kv_spec
    assert ps["k_pages"] == kv_spec and ps["v_pages"] == kv_spec
    # bookkeeping replicates — paged block tables are per-shard-valid
    assert ps["block_table"] == P(None, None)
    assert cs["length"] == P(None)


def test_serve_cache_specs_replicates_when_heads_do_not_divide():
    from repro.dist.sharding import serve_cache_specs
    cache = {"k": _sds(2, 4, 64, 6, 32)}          # 6 heads, tp=4
    assert serve_cache_specs(cache, TP4)["k"] == P(None, None, None,
                                                   None, None)


def test_tp_spec_violations_flags_replicated_row_weight():
    from repro.dist.sharding import tp_spec_violations
    params = {"layers": {"o_proj": {"w": _sds(128, 128)},
                         "q_proj": {"w": _sds(128, 128)}}}
    good = {"layers": {"o_proj": {"w": P("model", None)},
                       "q_proj": {"w": P(None, "model")}}}
    assert tp_spec_violations(params, good) == []
    bad = {"layers": {"o_proj": {"w": P(None, None)},
                      "q_proj": {"w": P(None, "model")}}}
    gaps = tp_spec_violations(params, bad)
    assert gaps and "o_proj" in gaps[0]


def test_tp_spec_violations_flags_row_parallel_bias():
    from repro.dist.sharding import tp_spec_violations
    params = {"layers": {"wo": {"w": _sds(128, 128), "b": _sds(128)}}}
    specs = {"layers": {"wo": {"w": P("model", None), "b": P(None)}}}
    gaps = tp_spec_violations(params, specs)
    assert any("bias" in g for g in gaps)


# ---------------------------------------------------------------------------
# tp_serve_reason — the wrap's refusal conditions name real causes
# ---------------------------------------------------------------------------

def test_tp_serve_reason_conditions():
    from repro.serve.engine import tp_serve_reason
    cfg = ModelConfig(family="dense_lm", d_model=64, d_ff=256,
                      num_layers=1, num_heads=8, num_kv_heads=4,
                      vocab_size=128, gemm_impl="pallas")
    assert "no live mesh" in tp_serve_reason(cfg, None)
    assert "gemm_impl" in tp_serve_reason(
        cfg.replace(gemm_impl="xla"), TP4)
    assert "moe" in tp_serve_reason(
        cfg.replace(family="moe_lm"), TP4).lower()
    assert "heads" in tp_serve_reason(cfg.replace(num_kv_heads=3), TP4)
    assert "d_ff" in tp_serve_reason(cfg.replace(d_ff=130), TP4)
    assert "vocab" in tp_serve_reason(cfg.replace(vocab_size=130), TP4)
    assert tp_serve_reason(cfg, TP4) == ""


def test_roofline_collective_bw_public():
    from repro.roofline.analysis import HW_V5E, collective_bw
    ar = collective_bw("all-reduce", HW_V5E)
    ag = collective_bw("all-gather", HW_V5E)
    assert ar > 0 and ag == pytest.approx(2 * ar)
