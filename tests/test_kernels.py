"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
shape/dtype sweeps per the brief and fused-epilogue parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dbb import pack_dbb, dbb_project
from repro.kernels.dbb_gemm.ops import dbb_gemm, dbb_gemm_packed
from repro.kernels.dbb_gemm.ref import (dbb_gemm_ref,
                                        dbb_gemm_ref_from_packed,
                                        decompress_ref)
from repro.kernels.epilogue import ACTIVATIONS, Epilogue
from repro.kernels.sta_gemm.ops import sta_gemm
from repro.kernels.sta_gemm.ref import sta_gemm_ref


def _rand(shape, seed, dtype):
    k = jax.random.PRNGKey(seed)
    if dtype == jnp.int8:
        return jax.random.randint(k, shape, -127, 128, jnp.int32).astype(
            jnp.int8)
    return jax.random.normal(k, shape, jnp.float32).astype(dtype)


_SHAPES = [
    (8, 128, 128),       # single tile
    (128, 128, 128),
    (256, 384, 256),     # multi-tile every axis
    (100, 200, 72),      # ragged (padding path)
    (1, 128, 512),       # decode-like row
    (512, 1024, 256),    # deep K
]


class TestStaGemm:
    @pytest.mark.parametrize("m,k,n", _SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_matches_oracle(self, m, k, n, dtype):
        x = _rand((m, k), 0, dtype)
        w = _rand((k, n), 1, dtype)
        got = sta_gemm(x, w)
        want = sta_gemm_ref(x, w)
        assert got.dtype == want.dtype
        if dtype == jnp.int8:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        else:
            # tolerance scales with K: blocked accumulation reorders sums
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                atol=2e-2 if dtype == jnp.bfloat16 else 1e-4 * (k ** 0.5))

    def test_batched_input(self):
        x = _rand((2, 4, 128), 0, jnp.float32)
        w = _rand((128, 64), 1, jnp.float32)
        got = sta_gemm(x, w)
        assert got.shape == (2, 4, 64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)

    def test_int8_accumulates_int32(self):
        """INT8 operands, INT32 accumulation — the paper's datapath."""
        x = jnp.full((8, 512), 127, jnp.int8)
        w = jnp.full((512, 128), 127, jnp.int8)
        y = sta_gemm(x, w)
        assert y.dtype == jnp.int32
        assert int(y[0, 0]) == 127 * 127 * 512      # would overflow INT16

    @pytest.mark.parametrize("bm,bk,bn", [(8, 128, 128), (16, 256, 128),
                                          (64, 128, 256)])
    def test_block_shape_sweep(self, bm, bk, bn):
        x = _rand((64, 512), 2, jnp.float32)
        w = _rand((512, 256), 3, jnp.float32)
        got = sta_gemm(x, w, block_m=bm, block_k=bk, block_n=bn)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)


class TestDbbGemm:
    @pytest.mark.parametrize("m,k,n", [(8, 128, 128), (64, 256, 128),
                                       (128, 512, 256), (1, 128, 128)])
    @pytest.mark.parametrize("block,nnz", [(8, 4), (8, 2), (8, 8), (16, 4)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_matches_oracle(self, m, k, n, block, nnz, dtype):
        x = _rand((m, k), 0, dtype)
        w = _rand((k, n), 1, dtype)
        p = pack_dbb(w.astype(jnp.float32), block, nnz)
        vals = p.values.astype(dtype)
        mask = p.bitmask
        got = dbb_gemm(x, vals, mask, block=block, nnz=nnz)
        want = dbb_gemm_ref(x, vals, mask.astype(jnp.int32), block=block,
                            nnz=nnz)
        if dtype == jnp.int8:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        else:
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                atol=3e-2 if dtype == jnp.bfloat16 else 1e-4 * (k ** 0.5))

    def test_oracle_equals_semantic_reference(self):
        """kernel ref == unpack-then-matmul == project-then-matmul."""
        w = _rand((256, 64), 5, jnp.float32)
        x = _rand((32, 256), 6, jnp.float32)
        p = pack_dbb(w, 8, 4)
        y1 = dbb_gemm_ref_from_packed(x, p)
        y2 = x @ dbb_project(w, 8, 4)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
        y3 = dbb_gemm_packed(x, p)
        np.testing.assert_allclose(np.asarray(y3), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)

    def test_decompress_ref_roundtrip(self):
        w = _rand((128, 32), 7, jnp.float32)
        p = pack_dbb(w, 8, 4)
        np.testing.assert_allclose(
            np.asarray(decompress_ref(p.values, p.bitmask.astype(jnp.int32),
                                      block=8, nnz=4)),
            np.asarray(dbb_project(w, 8, 4)), rtol=1e-6)

    def test_dense_compat_full_nnz(self):
        """nnz == block: the DBB kernel must reproduce the dense GEMM
        (paper §IV-B backward compatibility)."""
        w = _rand((128, 64), 8, jnp.float32)
        x = _rand((16, 128), 9, jnp.float32)
        p = pack_dbb(w, 8, 8)
        np.testing.assert_allclose(np.asarray(dbb_gemm_packed(x, p)),
                                   np.asarray(x @ w), rtol=1e-5, atol=1e-5)

    def test_per_channel_scale(self):
        """The packed per-channel scale is fused into the kernel epilogue —
        result must equal the post-hoc multiply it replaced."""
        w = _rand((128, 64), 10, jnp.float32)
        x = _rand((16, 128), 11, jnp.float32)
        scale = jnp.linspace(0.5, 2.0, 64)
        p = pack_dbb(w, 8, 4, scale=scale)
        got = dbb_gemm_packed(x, p)
        want = (x @ dbb_project(w, 8, 4)) * scale[None, :]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestFusedEpilogue:
    """Fused bias/activation/requant in the final-K store vs references."""

    @pytest.mark.parametrize("act", ACTIVATIONS)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_sta_fused_matches_ref(self, act, dtype):
        m, k, n = 100, 256, 72                       # ragged: padding path
        x = _rand((m, k), 0, dtype)
        w = _rand((k, n), 1, dtype)
        bias = _rand((n,), 2, jnp.float32)
        scale = jnp.linspace(0.25, 1.5, n)
        got = sta_gemm(x, w, bias, scale, act=act)
        want = sta_gemm(x, w, bias, scale, act=act, use_kernel=False)
        assert got.dtype == want.dtype
        rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=rtol, atol=rtol)

    @pytest.mark.parametrize("act", ACTIVATIONS)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_dbb_fused_matches_ref(self, act, dtype):
        m, k, n = 32, 256, 128
        x = _rand((m, k), 3, dtype)
        w = _rand((k, n), 4, jnp.float32)
        p = pack_dbb(w, 8, 4)
        vals = p.values.astype(dtype)
        bias = _rand((n,), 5, jnp.float32)
        scale = jnp.linspace(0.25, 1.5, n)
        got = dbb_gemm(x, vals, p.bitmask, bias, scale, act=act,
                       block=8, nnz=4)
        want = dbb_gemm(x, vals, p.bitmask, bias, scale, act=act,
                        block=8, nnz=4, use_kernel=False)
        assert got.dtype == want.dtype
        rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=rtol, atol=rtol)

    def test_int8_requant_store(self):
        """INT8 requantization: scale+clip applied in the store, result is
        bit-exact vs the hand-computed round/clip."""
        x = _rand((16, 128), 6, jnp.int8)
        w = _rand((128, 128), 7, jnp.int8)
        s = jnp.float32(2e-3)
        got = sta_gemm(x, w, scale=s, act="relu", out_dtype=jnp.int8)
        assert got.dtype == jnp.int8
        acc = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        want = jnp.clip(jnp.round(jnp.maximum(
            acc.astype(jnp.float32) * s, 0)), -127, 127).astype(jnp.int8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_relu_on_int32_accumulator_is_exact(self):
        """ReLU alone on the INT8→INT32 path must stay on the integer
        datapath (no float round-trip)."""
        x = jnp.full((8, 512), 127, jnp.int8)
        w = jnp.full((512, 128), -127, jnp.int8)
        y = sta_gemm(x, w, act="relu")
        assert y.dtype == jnp.int32
        assert int(np.asarray(y).max()) == 0
        y2 = sta_gemm(x, -w, act="relu")
        assert int(np.asarray(y2)[0, 0]) == 127 * 127 * 512

    def test_bias_only_batched(self):
        x = _rand((2, 4, 128), 8, jnp.float32)
        w = _rand((128, 64), 9, jnp.float32)
        bias = _rand((64,), 10, jnp.float32)
        got = sta_gemm(x, w, bias)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(x @ w + bias[None, None, :]),
            rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    @pytest.mark.parametrize("has_bias,has_scale", [(True, False),
                                                    (False, True),
                                                    (True, True)])
    def test_epilogue_operand_dtype_matrix(self, dtype, has_bias, has_scale):
        """f32-coercion contract at the wrapper boundary: bias/scale handed
        over in *param* dtype (e.g. bf16 model trees) must behave exactly
        like pre-cast f32 operands, on both kernels, fused and unfused."""
        m, k, n = 24, 128, 72
        x = _rand((m, k), 0, dtype)
        w = _rand((k, n), 1, dtype)
        op_dt = jnp.bfloat16 if dtype != jnp.int8 else jnp.float32
        bias32 = _rand((n,), 2, jnp.float32) if has_bias else None
        scale32 = jnp.linspace(0.25, 1.5, n) if has_scale else None
        # param-dtype copies (bf16 values exactly representable in f32, so
        # coercion-at-boundary must be bit-identical to f32 input)
        bias_p = bias32.astype(op_dt) if has_bias else None
        scale_p = scale32.astype(op_dt) if has_scale else None
        bias_f = bias_p.astype(jnp.float32) if has_bias else None
        scale_f = scale_p.astype(jnp.float32) if has_scale else None

        got = sta_gemm(x, w, bias_p, scale_p, act="relu")
        want = sta_gemm(x, w, bias_f, scale_f, act="relu")
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        ref = sta_gemm(x, w, bias_p, scale_p, act="relu", use_kernel=False)
        rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=rtol, atol=rtol)

        p = pack_dbb(_rand((k, n), 3, jnp.float32), 8, 4)
        vals = p.values.astype(dtype)
        got = dbb_gemm(x, vals, p.bitmask, bias_p, scale_p, act="relu",
                       block=8, nnz=4)
        want = dbb_gemm(x, vals, p.bitmask, bias_f, scale_f, act="relu",
                        block=8, nnz=4)
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_epilogue_spec_validation(self):
        with pytest.raises(ValueError):
            Epilogue(act="tanh")
        assert Epilogue().is_identity
        assert Epilogue(act="silu", has_bias=True).tag() == "silu+bias"
