"""Invariants for the §Perf code paths: bitonic DBB masks, promoted
collective accounting, token-chunked CE, mask equivalence across block
sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dbb import _bitonic_kth_largest, dbb_mask
from repro.dist.collectives import dense_ce, dense_ce_chunked
from repro.roofline.hlo import analyze_hlo_text


@pytest.mark.parametrize("b", [2, 4, 8, 16])
@pytest.mark.parametrize("k", [1, 2, 3])
def test_bitonic_kth_largest_matches_sort(b, k):
    if k > b:
        pytest.skip("k>b")
    x = jax.random.normal(jax.random.PRNGKey(b * 10 + k), (37, b, 5))
    got = _bitonic_kth_largest(jnp.abs(x), k)
    want = -jnp.sort(-jnp.abs(x), axis=1)[:, k - 1, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("block,nnz", [(8, 4), (8, 1), (16, 6), (4, 2),
                                       (8, 7)])
def test_bitonic_mask_matches_topk_reference(block, nnz):
    """The compare-exchange mask must be element-identical to the stable
    top_k formulation, including ties."""
    w = jax.random.normal(jax.random.PRNGKey(0), (block * 9, 12))
    # inject ties
    w = w.at[0:block, 0].set(0.5)
    got = np.asarray(dbb_mask(w, block, nnz))
    # reference: stable top_k per block
    kd, n = w.shape
    blocks = np.abs(np.asarray(w)).reshape(kd // block, block, n)
    ref = np.zeros_like(blocks, dtype=bool)
    for bi in range(blocks.shape[0]):
        for col in range(n):
            # argsort descending, stable → lowest index wins ties
            order = np.argsort(-blocks[bi, :, col], kind="stable")
            ref[bi, order[:nnz], col] = True
    ref = ref.reshape(kd, n)
    assert got.sum() == ref.sum()
    # NNZ bound + identical chosen magnitudes (tie sets may permute among
    # equal values; the kept VALUES must match)
    kept_got = np.sort(np.abs(np.asarray(w))[got].reshape(-1))
    kept_ref = np.sort(np.abs(np.asarray(w))[ref].reshape(-1))
    np.testing.assert_allclose(kept_got, kept_ref, rtol=1e-6)


def test_dense_ce_chunked_matches_dense():
    k = jax.random.PRNGKey(0)
    h = jax.random.normal(k, (4, 96, 32))
    w = jax.random.normal(jax.random.fold_in(k, 1), (32, 128))
    labels = jax.random.randint(jax.random.fold_in(k, 2), (4, 96), 0, 128)
    mask = (jax.random.uniform(jax.random.fold_in(k, 3), (4, 96)) > 0.2
            ).astype(jnp.float32)
    a = float(dense_ce(h, w, labels, mask))
    b = float(dense_ce_chunked(h, w, labels, mask, rows=64))
    assert a == pytest.approx(b, rel=1e-5)
    # gradients too (chunk remat must not change them)
    ga = jax.grad(lambda hh: dense_ce(hh, w, labels, mask))(h)
    gb = jax.grad(lambda hh: dense_ce_chunked(hh, w, labels, mask,
                                              rows=64))(h)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), atol=1e-6)


def test_promoted_collective_counted_at_bf16_width():
    text = """
HloModule t, num_partitions=4

%add_promoted (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[64,32]) -> f32[64,32] {
  %p0 = f32[64,32]{1,0} parameter(0)
  ROOT %ar = f32[64,32]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%add_promoted
}
"""
    st = analyze_hlo_text(text)
    assert st.collective_bytes["all-reduce"] == 64 * 32 * 4 / 2


def test_unpromoted_f32_collective_full_width():
    text = """
HloModule t, num_partitions=4

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[64,32]) -> f32[64,32] {
  %p0 = f32[64,32]{1,0} parameter(0)
  ROOT %ar = f32[64,32]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%add
}
"""
    st = analyze_hlo_text(text)
    assert st.collective_bytes["all-reduce"] == 64 * 32 * 4


def test_cpu_upcast_param_bytes_detects_hoisted_convert():
    from repro.roofline.hlo import cpu_upcast_param_bytes
    text = """
HloModule t

%wrapped_convert_computation (p: bf16[8,16]) -> f32[8,16] {
  %p = bf16[8,16]{1,0} parameter(0)
  ROOT %c = f32[8,16]{1,0} convert(%p)
}

ENTRY %main (w: bf16[8,16]) -> f32[8,16] {
  %w = bf16[8,16]{1,0} parameter(0)
  ROOT %up = f32[8,16]{1,0} fusion(%w), kind=kLoop, calls=%wrapped_convert_computation
}
"""
    assert cpu_upcast_param_bytes(text) == 8 * 16 * 4
