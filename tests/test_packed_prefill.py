"""Padding-free packed batching + chunked prefill (DESIGN.md §12).

Ragged-traffic parity suite: the packed cu_seqlens admission path must be
token-identical to the legacy padded scheduler through the full serving
stack, on both KV backends, at every prefill chunk size — and the packed
flash kernel must never attend across request boundaries (oracle check
against the quadratic per-segment reference).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings

    hypothesis.settings.register_profile(
        "fast", max_examples=10, deadline=None)
    hypothesis.settings.load_profile("fast")
except ModuleNotFoundError:      # bare container: deterministic fallback
    from _hyp_fallback import given, settings, st

from repro.configs import get_config
from repro.kernels.attn.ops import packed_flash_attention
from repro.kernels.attn.ref import flash_prefill_ref, packed_prefill_ref
from repro.models import registry
from repro.serve.engine import ServeEngine


# ---------------------------------------------------------------------------
# kernel-level: block-diagonal masking oracle
# ---------------------------------------------------------------------------

def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def _seg_ids(lens):
    return jnp.asarray(np.repeat(np.arange(len(lens)), lens), jnp.int32)


def _ragged_lens(seed, n_max=5, l_max=24):
    """Random length mixture that always includes a length-1 request and
    (at the top seeds) a bucket-max one — the two degenerate shapes the
    packed layout must survive."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, n_max + 1))
    lens = [int(rng.integers(1, l_max + 1)) for _ in range(n)]
    lens[0] = 1                      # degenerate: single-token request
    if seed % 2:
        lens[-1] = l_max             # degenerate: bucket-max request
    return lens


class TestPackedKernelOracle:
    @given(st.integers(0, 9))
    @settings(max_examples=10, deadline=None)
    def test_no_cross_request_attention(self, seed):
        """Packed kernel output, sliced per segment, equals the solo
        quadratic reference run on that segment alone — i.e. zero
        attention across request boundaries, for random ragged
        mixtures including len-1 and bucket-max rows."""
        lens = _ragged_lens(seed)
        t, hq, hkv, d = sum(lens), 4, 2, 16
        q = _rand((t, hq, d), seed)
        k = _rand((t, hkv, d), seed + 100)
        v = _rand((t, hkv, d), seed + 200)
        seg = _seg_ids(lens)
        got = packed_flash_attention(q, k, v, seg)
        off = 0
        for ln in lens:
            qs = q[None, off:off + ln]
            ks = k[None, off:off + ln]
            vs = v[None, off:off + ln]
            solo = flash_prefill_ref(
                jnp.moveaxis(qs, 2, 1), jnp.moveaxis(ks, 2, 1),
                jnp.moveaxis(vs, 2, 1), jnp.zeros((1, 1), jnp.int32),
                sm_scale=d ** -0.5)
            np.testing.assert_allclose(
                np.asarray(got[off:off + ln]),
                np.asarray(jnp.moveaxis(solo[0], 0, 1)),
                rtol=2e-5, atol=2e-5, err_msg=f"lens={lens} seg_len={ln}")
            off += ln

    @given(st.integers(0, 9))
    @settings(max_examples=10, deadline=None)
    def test_kernel_matches_packed_ref(self, seed):
        """Flash packed kernel vs the quadratic block-diagonal reference
        on the same concatenated layout."""
        lens = _ragged_lens(seed, l_max=33)
        t, hq, hkv, d = sum(lens), 4, 2, 16
        q = _rand((t, hq, d), seed + 1)
        k = _rand((t, hkv, d), seed + 101)
        v = _rand((t, hkv, d), seed + 201)
        seg = _seg_ids(lens)
        got = packed_flash_attention(q, k, v, seg, use_kernel=True)
        want = packed_flash_attention(q, k, v, seg, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"lens={lens}")

    def test_ref_is_block_diagonal(self):
        """The reference itself: perturbing one segment's keys must not
        change any other segment's output (oracle sanity)."""
        lens = [3, 1, 5]
        t, h, d = sum(lens), 2, 8
        q, k, v = (_rand((h, t, d), 7), _rand((h, t, d), 8),
                   _rand((h, t, d), 9))
        seg = _seg_ids(lens)
        base = packed_prefill_ref(q, k, v, seg, sm_scale=d ** -0.5)
        k2 = k.at[:, 3:4].add(100.0)       # clobber segment 1's only key
        v2 = v.at[:, 3:4].add(-50.0)
        pert = packed_prefill_ref(q, k2, v2, seg, sm_scale=d ** -0.5)
        np.testing.assert_array_equal(np.asarray(base[:, :3]),
                                      np.asarray(pert[:, :3]))
        np.testing.assert_array_equal(np.asarray(base[:, 4:]),
                                      np.asarray(pert[:, 4:]))
        assert not np.allclose(np.asarray(base[:, 3]),
                               np.asarray(pert[:, 3]))


# ---------------------------------------------------------------------------
# serve-level: packed == padded through the whole engine
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _engine(paged: bool):
    """One engine per backend, shared across examples — serve() takes
    prefill_mode/prefill_chunk per call, so jit caches amortize."""
    cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
    if paged:
        cfg = cfg.replace(attn_impl="flash", kv_page_size=8)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(cfg, params, max_batch=2)


def _prompts(seed, vocab, l_max=12):
    lens = _ragged_lens(seed, l_max=l_max)
    rng = np.random.default_rng(seed + 1000)
    prompts = [list(map(int, rng.integers(1, vocab - 1, size=ln)))
               for ln in lens]
    budgets = [int(b) for b in rng.integers(2, 7, size=len(lens))]
    return prompts, budgets


class TestServeParity:
    @given(st.integers(0, 6))
    @settings(max_examples=7, deadline=None)
    def test_packed_token_identical_to_padded(self, seed):
        """Random ragged mixtures (len-1 and bucket-max rows included):
        the packed scheduler's emitted tokens == the padded scheduler's,
        on both KV backends. (Backend loop lives inside the example so
        the property decorator composes with the fallback shim.)"""
        for paged in (False, True):
            eng = _engine(paged)
            prompts, budgets = _prompts(seed, eng.cfg.vocab_size)
            pad = eng.serve(prompts, budgets, prefill_mode="padded")
            got = eng.serve(prompts, budgets, prefill_mode="packed")
            assert got == pad, (paged, prompts, budgets)

    @pytest.mark.parametrize("paged", [False, True])
    def test_chunk_size_invariance(self, paged):
        """Chunked prefill must not change a single emitted token, for
        chunk ∈ {1, 7, page, smax} on both backends (whole-prompt packed
        call is the baseline)."""
        eng = _engine(paged)
        prompts, budgets = _prompts(3, eng.cfg.vocab_size, l_max=16)
        base = eng.serve(prompts, budgets, prefill_mode="packed",
                         prefill_chunk=0)
        smax = max(len(p) for p in prompts) + max(budgets)
        for chunk in (1, 7, 8, smax):
            got = eng.serve(prompts, budgets, prefill_mode="packed",
                            prefill_chunk=chunk)
            assert got == base, (chunk, prompts, budgets)

    def test_packed_matches_solo_generate(self):
        """Packed continuous batching vs one-request generate(): the
        end-to-end admission → prefill → decode chain is exact."""
        eng = _engine(False)
        prompts, budgets = _prompts(5, eng.cfg.vocab_size)
        served = eng.serve(prompts, budgets, prefill_mode="packed",
                           prefill_chunk=4)
        for p, bud, got in zip(prompts, budgets, served):
            solo = eng.generate([p], max_new_tokens=bud)[0]
            assert got == solo, (p, got, solo)

    def test_no_pad_tokens_charged(self):
        """The packed scheduler's stats must account every prompt token
        exactly once, and the per-call padding (bucket rounding only) must
        stay below the padded scheduler's rectangle."""
        eng = _engine(False)
        prompts, budgets = _prompts(2, eng.cfg.vocab_size, l_max=16)
        eng.serve(prompts, budgets, prefill_mode="packed")
        stats = eng.serve_stats
        total = sum(len(p) for p in prompts)
        assert stats["prompt_tokens"] == total
        # padded admission charges max_batch * T_max per wave; packed pays
        # bucket-rounded total tokens — strictly less on a ragged mix
        t_max = max(len(p) for p in prompts)
        assert stats["packed_prefill_tokens"] < len(prompts) * t_max * 2
        assert all(len(t) == b for t, b in
                   zip(eng.serve(prompts, budgets), budgets))

    def test_ttft_recorded(self):
        """serve_stats carries a TTFT sample per request (used by the
        packed-prefill benchmark's jitter sweep)."""
        eng = _engine(False)
        prompts, budgets = _prompts(4, eng.cfg.vocab_size)
        eng.serve(prompts, budgets, prefill_mode="packed", prefill_chunk=4)
        ttft = eng.serve_stats["ttft_s"]
        assert len(ttft) == len(prompts)
        assert all(t > 0 for t in ttft)
