"""Sharding-rule policy tests (pure spec logic — no devices needed).

Guarantee checked here: every PartitionSpec produced for every assigned
architecture divides evenly on the production meshes, so the dry-run can
never fail on a divisibility error.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import SHAPES, RunConfig, TrainConfig
from repro.configs import ASSIGNED, get_config
from repro.dist import sharding as shd
from repro.models import registry

# spec-only "mesh": shape dict + axis names are all the rules consult
class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    def __hash__(self):
        return hash(tuple(self.shape.items()))


POD = _FakeMesh({"data": 16, "model": 16})
MULTI = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axsize(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check_divisible(tree_specs, tree_vals, mesh, where=""):
    flat_s = jax.tree_util.tree_flatten_with_path(
        tree_specs, is_leaf=lambda x: isinstance(x, P))[0]
    flat_v = jax.tree_util.tree_flatten_with_path(tree_vals)[0]
    specs = {"/".join(str(p) for p in path): s for path, s in flat_s}
    for path, leaf in flat_v:
        key = "/".join(str(p) for p in path)
        spec = specs.get(key, P())
        if not isinstance(spec, P) or not hasattr(leaf, "shape"):
            continue
        for dim, entry in zip(leaf.shape, tuple(spec)):
            n = _axsize(mesh, entry)
            assert dim % n == 0, (where, key, leaf.shape, spec)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [POD, MULTI], ids=["pod", "multipod"])
def test_param_specs_divide_for_full_configs(arch, mesh):
    cfg = get_config(arch)          # FULL config — abstract init only
    sds = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(sds, mesh, cfg)
    _check_divisible(specs, sds, mesh, where=arch)


def test_column_and_row_rules():
    cfg = get_config("olmo-1b")
    sds = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(sds, POD, cfg, fsdp_min_shard_elems=None)
    lyr = specs["layers"]
    assert tuple(lyr["attn"]["q_proj"]["w"]) == (None, None, "model")
    assert tuple(lyr["attn"]["o_proj"]["w"]) == (None, "model", None)
    assert tuple(lyr["mlp"]["wi"]["w"]) == (None, None, "model")
    assert tuple(lyr["mlp"]["wo"]["w"]) == (None, "model", None)
    assert tuple(specs["embed"]["table"]) == ("model", None)


def test_expert_rule_and_fsdp():
    cfg = get_config("kimi-k2-1t-a32b")
    sds = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(sds, POD, cfg)
    wi = tuple(specs["layers"]["moe"]["experts"]["wi"])
    # [L, E, d, f]: experts on model, FSDP data on a free dim
    assert wi[1] == "model"
    assert "data" in (wi[2], wi[3], wi[0])


def test_fsdp_disabled_keeps_small_replicated():
    cfg = get_config("yi-34b")      # rmsnorm => has replicated scale leaves
    sds = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(sds, POD, cfg, fsdp_min_shard_elems=None)
    scale = specs["layers"]["ln_attn"]["scale"]
    assert all(e is None for e in tuple(scale))
    # with FSDP on, big leaves gain a data axis; norms stay replicated
    specs_fsdp = shd.param_specs(sds, POD, cfg)
    wi = tuple(specs_fsdp["layers"]["mlp"]["wi"]["w"])
    assert any(e == "data" or (isinstance(e, tuple) and "data" in e)
               for e in wi)
    scale2 = specs_fsdp["layers"]["ln_attn"]["scale"]
    assert all(e is None for e in tuple(scale2))


def test_opt_state_specs_derivation():
    cfg = get_config("yi-34b")
    rc = RunConfig(model=cfg, train=TrainConfig(optimizer="adafactor"))
    from repro.train.loop import init_train_state
    sds = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), rc))
    pspecs = shd.param_specs(sds.params, POD, cfg)
    ospecs = shd.opt_state_specs_like(sds.opt_state, sds.params, pspecs, POD)
    _check_divisible(ospecs, sds.opt_state, POD, where="yi-opt")
    # factored stats follow the param's surviving axes
    wi_p = tuple(pspecs["layers"]["mlp"]["wi"]["w"])     # [L, d, f]
    vr = tuple(ospecs["s"]["layers"]["mlp"]["wi"]["w"]["vr"])  # [L, d]
    assert vr[:2] == wi_p[:2] or vr[1] in ("data", ("pod", "data"), None)


def test_cache_specs_match_cache_tree():
    for arch in ("qwen2.5-14b", "rwkv6-1.6b", "zamba2-1.2b"):
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda c=cfg: registry.init_cache(c, 128, 32768))
        specs = shd.cache_specs(cfg, POD, 128, 32768)
        assert set(specs) == set(sds)
        _check_divisible(specs, sds, POD, where=arch)


def test_batch_specs_partial_batch():
    cfg = get_config("olmo-1b")
    # batch=1 can't shard: falls back to replication, never errors
    s = shd.batch_specs(cfg, MULTI, 1, 128)
    assert tuple(s["tokens"])[0] is None
    # batch=32 on pod×data=32 shards fully
    s = shd.batch_specs(cfg, MULTI, 32, 128)
    assert tuple(s["tokens"])[0] == ("pod", "data")


def test_zero_spec_adds_data_axes():
    spec = shd.zero_spec(P(None, None, "model"), (48, 5120, 13824), POD)
    assert "data" in tuple(spec)
    # small leaves untouched
    assert tuple(shd.zero_spec(P(), (64,), POD)) == ()
