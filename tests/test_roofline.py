"""HLO analyzer: must agree with XLA cost_analysis on scan-free graphs and
correct it (trip-count multiplication) on scanned ones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (HW_V5E, model_flops_per_step,
                                     roofline_terms)
from repro.roofline.hlo import analyze_hlo_text


def _compile(fn, *sds):
    return jax.jit(fn).lower(*sds).compile()


def _cost(compiled) -> dict:
    """compiled.cost_analysis(): dict on current jax, [dict] on 0.4.x."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


SDS = jax.ShapeDtypeStruct


def test_matches_cost_analysis_scan_free():
    def f(x, w1, w2):
        return jnp.maximum(x @ w1, 0) @ w2

    c = _compile(f, SDS((64, 128), jnp.float32), SDS((128, 256), jnp.float32),
                 SDS((256, 64), jnp.float32))
    st = analyze_hlo_text(c.as_text())
    ca = _cost(c)
    assert st.flops == pytest.approx(ca["flops"], rel=0.05)


def test_scan_multiplies_by_trip_count():
    L = 8

    def layer(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(layer, x, ws)[0]

    def unrolled(x, ws):
        for i in range(L):
            x, _ = layer(x, ws[i])
        return x

    xs = SDS((32, 64), jnp.float32)
    ws = SDS((L, 64, 64), jnp.float32)
    st_scan = analyze_hlo_text(_compile(scanned, xs, ws).as_text())
    st_unroll = analyze_hlo_text(_compile(unrolled, xs, ws).as_text())
    assert st_scan.flops == pytest.approx(st_unroll.flops, rel=0.02)
    # and ~L× what cost_analysis reports for the scanned module
    ca = _compile(scanned, xs, ws).cost_analysis()
    assert st_scan.flops > 0.9 * L * 2 * 32 * 64 * 64


def test_nested_scan():
    def inner(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    def outer(x, ws):
        return jax.lax.scan(lambda c, w: (inner(c, w), None), x, ws)[0]

    xs = SDS((16, 32), jnp.float32)
    ws = SDS((3, 5, 32, 32), jnp.float32)   # 3 outer × 5 inner
    st = analyze_hlo_text(_compile(outer, xs, ws).as_text())
    want = 3 * 5 * 2 * 16 * 32 * 32
    assert st.flops == pytest.approx(want, rel=0.02)


def test_dot_general_batched_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    st = analyze_hlo_text(
        _compile(f, SDS((4, 8, 16), jnp.float32),
                 SDS((4, 16, 32), jnp.float32)).as_text())
    assert st.flops == pytest.approx(2 * 4 * 8 * 16 * 32, rel=0.01)


def test_bytes_match_cost_analysis_scan_free():
    def f(x, w):
        return x @ w

    c = _compile(f, SDS((128, 256), jnp.float32), SDS((256, 128), jnp.float32))
    st = analyze_hlo_text(c.as_text())
    ca = _cost(c)
    assert st.hbm_bytes == pytest.approx(ca["bytes accessed"], rel=0.1)


def test_roofline_terms_math():
    from repro.roofline.hlo import HloStats
    st = HloStats(flops=197e12, hbm_bytes=819e9,
                  collective_bytes={"all-reduce": 100e9},
                  collective_counts={"all-reduce": 1})
    t = roofline_terms(st, model_flops_per_device=197e12 / 2,
                       io_bytes_per_device=819e9 / 2)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.memory_unfused_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)   # 100e9 / (50e9*4/2)
    assert t.bottleneck in ("compute", "collective")
    assert t.roofline_fraction == pytest.approx(0.5)


def test_model_flops_per_step():
    assert model_flops_per_step(1_000_000, 2048, train=True) == \
        6 * 1_000_000 * 2048
    assert model_flops_per_step(1_000_000, 16, train=False) == \
        2 * 1_000_000 * 16


def test_collective_parse_from_psum_graph():
    """A hand-built shard_map psum must surface as all-reduce bytes.
    Runs in-process only if >1 device; otherwise exercises the text parser
    on a synthetic module."""
    text = """
HloModule test, num_partitions=4

ENTRY %main (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  ROOT %ar = f32[128,64]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%add
}
"""
    st = analyze_hlo_text(text)
    assert st.collective_bytes.get("all-reduce") == 128 * 64 * 4
    assert st.collective_counts.get("all-reduce") == 1


# ---------------------------------------------------------------------------
# dispatch cost model ↔ roofline terms: golden pins (DESIGN.md §13 pass 5)
# ---------------------------------------------------------------------------

class TestDispatchCostGolden:
    """Pin the dispatch registry's analytic (flops, bytes) terms to
    hand-computed golden values, and the route timing law to the same
    Hardware constants roofline/analysis.py publishes. The static
    verifier's monotonicity pass catches sign/shape bugs; these pins
    catch silent coefficient edits."""

    def _cost(self, domain, name, spec):
        from repro.kernels import dispatch
        return dispatch.routes_for(domain)[name].cost(spec)

    def test_xla_matmul_dense_golden(self):
        from repro.kernels.dispatch import OpSpec
        spec = OpSpec(domain="matmul", m=256, k=512, n=1024, itemsize=4)
        flops, nbytes = self._cost("matmul", "xla", spec)
        assert flops == 2 * 256 * 512 * 1024            # 268_435_456
        # A[M,K] + B[K,N] + C[M,N], f32, no epilogue round-trips
        assert nbytes == 4 * (256 * 512 + 512 * 1024 + 256 * 1024)

    def test_xla_matmul_epilogue_roundtrips(self):
        from repro.kernels.dispatch import OpSpec
        base = OpSpec(domain="matmul", m=64, k=128, n=128, itemsize=4)
        fused = OpSpec(domain="matmul", m=64, k=128, n=128, itemsize=4,
                       epilogue_ops=2)
        _, b0 = self._cost("matmul", "xla", base)
        _, b2 = self._cost("matmul", "xla", fused)
        # each unfused epilogue op re-reads + re-writes the f32 [M, N]
        assert b2 - b0 == 2 * 2 * 64 * 128 * 4

    def test_xla_matmul_packed_decompress_golden(self):
        from repro.kernels.dispatch import OpSpec
        spec = OpSpec(domain="matmul", m=8, k=512, n=512, itemsize=4,
                      packed=True, vals_itemsize=4)
        flops, nbytes = self._cost("matmul", "xla", spec)
        assert flops == 2 * 8 * 512 * 512
        nb = 512 // 8                                    # DBB 8-blocks
        packed_w = nb * 4 * 512 * 4 + nb * 512           # values + bitmask
        assert packed_w == 557056
        # x + out + compressed read + dense write + dense re-read
        assert nbytes == (8 * 512 * 4 + 8 * 512 * 4
                          + packed_w + 2 * 512 * 512 * 4)

    def test_attn_flash_vs_chunked_score_traffic(self):
        from repro.kernels.dispatch import OpSpec
        spec = OpSpec(domain="attention", m=256, k=64, n=256, itemsize=4,
                      batch=2, chunk=64, flash_active=True, float_ok=True)
        f_fl, b_fl = self._cost("attention", "attn_flash", spec)
        f_ch, b_ch = self._cost("attention", "attn_chunked", spec)
        assert f_fl == f_ch == 4 * 2 * 256 * 256 * 64   # 33_554_432
        assert b_fl == 2 * (2 * 256 * 64 + 2 * 256 * 64) * 4
        # chunked recomputes exactly one f32 score-tile pass
        assert b_ch - b_fl == 2 * 256 * 256 * 4

    def test_route_timing_is_roofline_law(self):
        """RouteDecision timing must be the roofline law under the same
        HW_V5E constants roofline/analysis.py exports — for every route
        decision over the verifier's default spec sweep."""
        from repro.analysis.dispatch_check import default_specs
        from repro.kernels import dispatch
        from repro.roofline.analysis import HW_V5E
        assert HW_V5E.peak_flops == 197e12 and HW_V5E.hbm_bw == 819e9
        seen = 0
        for domain, specs in default_specs().items():
            for spec in specs[::4]:
                for dec in dispatch.select(spec)[1]:
                    assert dec.compute_s == dec.flops / HW_V5E.peak_flops
                    assert dec.memory_s == dec.bytes / HW_V5E.hbm_bw
                    assert dec.cost_s == max(dec.compute_s, dec.memory_s)
                    seen += 1
        assert seen > 50
