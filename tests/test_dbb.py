"""DBB format invariants: projection, pack/unpack, footprint, STE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "fast", max_examples=25, deadline=None)
    hypothesis.settings.load_profile("fast")
except ModuleNotFoundError:      # bare container: deterministic fallback
    from _hyp_fallback import given, st

from repro.config import DbbConfig
from repro.core.dbb import (DbbWeight, dbb_footprint_bytes, dbb_mask,
                            dbb_project, dense_footprint_bytes, pack_dbb,
                            unpack_dbb, validate_dbb)
from repro.core.sparsity import (apply_dbb_to_tree, dbb_schedule_nnz,
                                 ste_dbb, tree_sparsity_report)


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


class TestMaskAndProject:
    def test_nnz_bound_holds(self):
        w = _rand((64, 16))
        m = dbb_mask(w, 8, 3)
        per_block = np.asarray(m).reshape(8, 8, 16).sum(axis=1)
        assert per_block.max() <= 3

    def test_keeps_largest_magnitude(self):
        w = jnp.array([[0.1], [5.0], [0.2], [4.0], [0.01], [3.0], [0.0],
                       [0.3]])
        m = np.asarray(dbb_mask(w, 8, 3))[:, 0]
        assert list(np.nonzero(m)[0]) == [1, 3, 5]

    def test_dense_backward_compat(self):
        """nnz == block must be the identity (paper: 'fully backwards
        compatible with dense models')."""
        w = _rand((32, 8))
        np.testing.assert_array_equal(dbb_project(w, 8, 8), w)

    def test_projection_idempotent(self):
        w = _rand((64, 32))
        p1 = dbb_project(w, 8, 4)
        p2 = dbb_project(p1, 8, 4)
        np.testing.assert_allclose(p1, p2, atol=0)

    @given(st.integers(1, 8), st.integers(1, 6), st.integers(1, 5))
    def test_property_nnz_bound(self, nnz, kb, n):
        block = 8
        nnz = min(nnz, block)
        w = np.asarray(
            jax.random.normal(jax.random.PRNGKey(kb * 7 + n), (kb * block, n)))
        m = np.asarray(dbb_mask(jnp.asarray(w), block, nnz))
        assert m.reshape(kb, block, n).sum(axis=1).max() <= nnz

    def test_bad_dims_raise(self):
        with pytest.raises(ValueError):
            dbb_mask(_rand((33, 4)), 8, 4)
        with pytest.raises(ValueError):
            dbb_mask(_rand((32, 4)), 8, 9)


class TestPackUnpack:
    @given(st.integers(0, 10), st.integers(1, 8))
    def test_roundtrip(self, seed, nnz):
        w = _rand((64, 24), seed)
        p = pack_dbb(w, 8, nnz)
        np.testing.assert_allclose(np.asarray(unpack_dbb(p)),
                                   np.asarray(dbb_project(w, 8, nnz)),
                                   rtol=1e-6)
        ok, msg = validate_dbb(p)
        assert ok, msg

    def test_roundtrip_sparse_input(self):
        """Blocks with fewer than nnz nonzeros pack canonically."""
        w = np.zeros((16, 4), np.float32)
        w[1, 0] = 2.0
        w[9, 2] = -3.0
        p = pack_dbb(jnp.asarray(w), 8, 4)
        np.testing.assert_allclose(np.asarray(unpack_dbb(p)), w)
        assert validate_dbb(p)[0]

    def test_bitmask_popcount_le_nnz(self):
        p = pack_dbb(_rand((128, 8)), 8, 4)
        bm = np.asarray(p.bitmask)
        pop = np.zeros_like(bm)
        for t in range(8):
            pop += (bm >> t) & 1
        assert pop.max() <= 4

    def test_footprint_matches_paper(self):
        """B=8, k=4, INT8: 62.5% of dense == the paper's 37.5% saving."""
        dense = dense_footprint_bytes(4096, 4096, 1)
        packed = dbb_footprint_bytes(4096, 4096, 8, 4, 1)
        assert packed / dense == pytest.approx(0.625)
        cfg = DbbConfig(block=8, nnz=4)
        assert cfg.weight_footprint_ratio == pytest.approx(0.625)


class TestSTE:
    def test_forward_is_projection(self):
        w = _rand((32, 8))
        np.testing.assert_allclose(np.asarray(ste_dbb(w, 8, 2)),
                                   np.asarray(dbb_project(w, 8, 2)))

    def test_gradient_is_straight_through(self):
        w = _rand((32, 8))
        g = jax.grad(lambda w: (ste_dbb(w, 8, 2) ** 2).sum())(w)
        # straight-through: dL/dw = dL/dw_proj exactly (identity jacobian)
        g_ref = 2 * dbb_project(w, 8, 2)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-6)

    def test_schedule_anneals(self):
        cfg = DbbConfig(enabled=True, block=8, nnz=4)
        ks = [dbb_schedule_nnz(cfg, s, start=10, ramp=20)
              for s in (0, 9, 10, 20, 30, 100)]
        assert ks[0] == ks[1] == 8
        assert ks[-1] == 4
        assert all(a >= b for a, b in zip(ks, ks[1:]))

    def test_apply_to_tree_respects_eligibility(self):
        cfg = DbbConfig(enabled=True, block=8, nnz=4, apply_to=("mlp",))
        tree = {"mlp": {"wi": {"w": _rand((64, 32))}},
                "attn": {"q_proj": {"w": _rand((64, 32))}},
                "norm": {"scale": jnp.ones((64,))}}
        out = apply_dbb_to_tree(tree, cfg)
        assert np.mean(np.asarray(out["mlp"]["wi"]["w"]) == 0) >= 0.49
        np.testing.assert_array_equal(out["attn"]["q_proj"]["w"],
                                      tree["attn"]["q_proj"]["w"])
        rep = tree_sparsity_report(out, cfg)
        assert any("mlp" in k for k in rep)
