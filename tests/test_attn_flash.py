"""Flash-attention kernel subsystem (DESIGN.md §10): parity matrix vs the
naive oracle (GQA/MQA × window × dtype × ragged), the structural
no-score-tensor trace assertion, VMEM-guard fallback, and the paged decode
kernel vs its gather-then-attend reference."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.attn.kernel import flash_prefill_pallas
from repro.kernels.attn.ops import (flash_attention, flash_ok,
                                    identity_block_table,
                                    paged_decode_attention)
from repro.kernels.attn.ref import flash_prefill_ref
from repro.models import attention as attn_mod
from repro.models import registry


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32).astype(dtype)


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 1e-4


class TestFlashPrefillKernel:
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (4, 1)])
    @pytest.mark.parametrize("window", [0, 64])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_parity_matrix(self, hq, hkv, window, dtype):
        """GQA/MQA × sliding-window × dtype against the quadratic oracle
        (which materializes the full score tensor — the contrast is the
        point)."""
        b, t, d = 2, 128, 32
        q = _rand((b, t, hq, d), 0, dtype)
        k = _rand((b, t, hkv, d), 1, dtype)
        v = _rand((b, t, hkv, d), 2, dtype)
        got = flash_attention(q, k, v, window=window, block_q=32,
                              block_kv=32)
        want = flash_attention(q, k, v, window=window, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=_tol(dtype), atol=_tol(dtype))

    def test_ragged_left_pad_parity(self):
        """Per-row start offsets (left-padded serving batch): flash must
        mask pad keys exactly like _mask_bias; valid rows bit-compare to
        the oracle."""
        b, t, hq, hkv, d = 3, 64, 4, 2, 16
        q, k, v = (_rand((b, t, hq, d), 3), _rand((b, t, hkv, d), 4),
                   _rand((b, t, hkv, d), 5))
        start = jnp.asarray([0, 7, 33], jnp.int32)
        got = flash_attention(q, k, v, start, block_q=16, block_kv=16)
        want = flash_attention(q, k, v, start, use_kernel=False)
        for i, s0 in enumerate([0, 7, 33]):
            np.testing.assert_allclose(
                np.asarray(got[i, s0:]), np.asarray(want[i, s0:]),
                rtol=1e-5, atol=1e-5)

    def test_softcap_and_unaligned_lengths(self):
        """Logit softcap (applied pre-mask, like _scores) + T not divisible
        by the block grid (ops-layer padding)."""
        b, t, hq, d = 1, 45, 2, 16
        q, k, v = (_rand((b, t, hq, d), 6), _rand((b, t, hq, d), 7),
                   _rand((b, t, hq, d), 8))
        got = flash_attention(q, k, v, softcap=30.0, block_q=16,
                              block_kv=16)
        want = flash_attention(q, k, v, softcap=30.0, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_kernel_matches_standalone_ref(self):
        """Head-major kernel entry point against ref (no ops wrapper)."""
        q = _rand((1, 2, 32, 16), 9)
        k = _rand((1, 2, 32, 16), 10)
        v = _rand((1, 2, 32, 16), 11)
        got = flash_prefill_pallas(q, k, v, sm_scale=0.25, block_q=16,
                                   block_kv=16, interpret=True)
        want = flash_prefill_ref(q, k, v, sm_scale=0.25)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_vmem_guard(self):
        assert flash_ok(128, 128, 128, 4)
        assert flash_ok(4096, 4096, 256, 2)
        # pathological head dim: even the minimal block pair blows VMEM
        assert not flash_ok(128, 128, 1 << 20, 4)


class TestPagedDecodeKernel:
    @pytest.mark.parametrize("g", [1, 2, 4])
    @pytest.mark.parametrize("window", [0, 5])
    def test_matches_gather_ref(self, g, window):
        """Block-table gather + online softmax == gather-then-attend."""
        b, hkv, d, pool, page, n_log = 2, 2, 16, 9, 4, 3
        q = _rand((b, hkv, g, d), 12)
        kp = _rand((pool, page, hkv, d), 13)
        vp = _rand((pool, page, hkv, d), 14)
        tab = jnp.asarray([[5, 1, 7], [8, 3, 0]], jnp.int32)
        lengths = jnp.asarray([9, 4], jnp.int32)
        start = jnp.asarray([2, 0], jnp.int32)
        got = paged_decode_attention(q, kp, vp, tab, lengths, start,
                                     window=window)
        want = paged_decode_attention(q, kp, vp, tab, lengths, start,
                                      window=window, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_identity_table_is_contiguous(self):
        """A contiguous [B, S, H, D] cache reshaped to pages under the
        identity table attends identically to the raw layout."""
        b, s, hkv, g, d, page = 2, 16, 2, 2, 16, 4
        n_log = s // page
        kc = _rand((b, s, hkv, d), 15)
        vc = _rand((b, s, hkv, d), 16)
        q = _rand((b, hkv, g, d), 17)
        lengths = jnp.asarray([10, 15], jnp.int32)
        start = jnp.zeros((b,), jnp.int32)
        tab = identity_block_table(b, n_log)
        kp = kc.reshape(b * n_log, page, hkv, d)
        vp = vc.reshape(b * n_log, page, hkv, d)
        got = paged_decode_attention(q, kp, vp, tab, lengths, start)
        want = paged_decode_attention(q, kp, vp, tab, lengths, start,
                                      use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model-level dispatch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestModelDispatch:
    def test_forward_flash_matches_default(self, small_lm):
        cfg, params = small_lm
        toks = jnp.asarray([[5, 17, 3, 250, 99, 7, 12, 2]], jnp.int32)
        h0, _ = registry.forward(params, cfg, {"tokens": toks})
        h1, _ = registry.forward(params, cfg.replace(attn_impl="flash"),
                                 {"tokens": toks})
        np.testing.assert_allclose(np.asarray(h0, np.float32),
                                   np.asarray(h1, np.float32),
                                   rtol=5e-4, atol=5e-4)

    def test_auto_routes_flash_on_pallas_route(self, small_lm):
        """attn_impl='auto' + gemm_impl='pallas' (single device) must pick
        the flash backend — naive stays the use_kernel=False oracle only."""
        cfg, _ = small_lm
        assert attn_mod._flash_backend(cfg.replace(gemm_impl="pallas"))
        assert attn_mod._flash_backend(cfg.replace(attn_impl="flash"))
        assert not attn_mod._flash_backend(cfg)          # xla route: auto off
        assert not attn_mod._flash_backend(
            cfg.replace(gemm_impl="pallas", attn_impl="chunked"))

    def test_ragged_prefill_flash_matches_naive(self, small_lm):
        """Left-padded ragged batch through the flash backend must match
        the naive ragged path on every non-pad position."""
        cfg, params = small_lm
        toks = jnp.asarray([[0, 0, 0, 5, 17, 3, 250, 99],
                            [9, 9, 9, 9, 1, 2, 7, 3]], jnp.int32)
        start = jnp.asarray([3, 0], jnp.int32)
        h0, c0 = registry.prefill(params, cfg, tokens=toks,
                                  cache=registry.init_cache(cfg, 2, 12),
                                  start=start)
        cfgf = cfg.replace(attn_impl="flash")
        h1, c1 = registry.prefill(params, cfgf, tokens=toks,
                                  cache=registry.init_cache(cfgf, 2, 12),
                                  start=start)
        np.testing.assert_allclose(np.asarray(h0[0, 3:], np.float32),
                                   np.asarray(h1[0, 3:], np.float32),
                                   rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(np.asarray(h0[1], np.float32),
                                   np.asarray(h1[1], np.float32),
                                   rtol=5e-4, atol=5e-4)

    def test_guard_falls_back_to_chunked(self, small_lm, monkeypatch):
        """When the VMEM guard rejects the call, attn_impl='flash' must
        degrade to the XLA paths, not crash."""
        cfg, params = small_lm
        # the VMEM guard lives in the dispatch registry's attn_flash route
        # (DESIGN.md §11), which reads flash_ok from the attn ops module
        monkeypatch.setattr("repro.kernels.attn.ops.flash_ok",
                            lambda *a, **k: False)
        toks = jnp.asarray([[5, 17, 3, 250]], jnp.int32)
        h0, _ = registry.forward(params, cfg, {"tokens": toks})
        h1, _ = registry.forward(params, cfg.replace(attn_impl="flash"),
                                 {"tokens": toks})
        np.testing.assert_allclose(np.asarray(h0, np.float32),
                                   np.asarray(h1, np.float32),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# structural: the score tensor never materializes
# ---------------------------------------------------------------------------

class TestNoScoreTensor:
    B, T, HQ, HKV, D = 2, 256, 4, 2, 32

    def _peak(self, cfg):
        from repro.analysis.materialize import max_intermediate_elems
        q = jnp.zeros((self.B, self.T, self.HQ, self.D))
        k = jnp.zeros((self.B, self.T, self.HKV, self.D))
        v = jnp.zeros((self.B, self.T, self.HKV, self.D))
        pos = jnp.arange(self.T)[None, :]
        return max_intermediate_elems(
            lambda *a: attn_mod._attention_core(*a, cfg), q, k, v, pos)

    def test_flash_never_materializes_scores(self, small_lm):
        """Trace-time assertion via the shared repro.analysis walker: no
        intermediate in the flash route is as large as the [B, Hq, T, T]
        score tensor; the naive oracle (control) materializes exactly
        that."""
        cfg, _ = small_lm
        score_elems = self.B * self.HQ * self.T * self.T
        flash_max = self._peak(cfg.replace(attn_impl="flash"))
        naive_max = self._peak(cfg.replace(attn_impl="naive"))
        assert flash_max < score_elems, (
            f"flash route materialized a {flash_max}-element tensor "
            f"(score tensor would be {score_elems})")
        assert naive_max >= score_elems     # control: oracle really does
