"""Unified kernel dispatch (DESIGN.md §11): golden route table, forced-route
parity, override precedence, and the grep-clean model-layer contract."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.core.dbb import pack_dbb
from repro.kernels import dispatch

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _chosen(decisions):
    [name] = [d.name for d in decisions if d.chosen]
    return name


def _rand(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    if jnp.dtype(dtype) == jnp.int8:
        return jnp.asarray(rng.integers(-20, 21, shape), jnp.int8)
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# golden route table
# ---------------------------------------------------------------------------

class TestGoldenRouteTable:
    # (m, k, n, dtype, packed, pallas, kwargs) -> expected route
    CASES = [
        # decode regime: skinny weight-streaming kernels
        (1,   256,  512, jnp.float32, False, True, {}, "skinny_sta"),
        (4,   256,  512, jnp.float32, False, True, {}, "skinny_sta"),
        (32,  256,  512, jnp.bfloat16, False, True, {}, "skinny_sta"),
        (8,   256,  512, jnp.int8,   False, True, {}, "skinny_sta"),
        (16,  256,  512, jnp.float32, True,  True, {}, "skinny_dbb"),
        (8,   256,  512, jnp.int8,   True,  True, {}, "skinny_dbb"),
        # prefill/train regime: M-tiled kernels
        (128, 256,  512, jnp.float32, False, True, {}, "sta"),
        (512, 256,  512, jnp.bfloat16, False, True, {}, "sta"),
        (256, 256,  512, jnp.float32, True,  True, {}, "dbb_packed"),
        (256, 256,  512, jnp.int8,   True,  True, {}, "dbb_packed"),
        # above the skinny gate but tiny: M-tiled still ties-and-wins
        (48,  256,  512, jnp.float32, False, True, {}, "sta"),
        # pinned block shapes opt out of skinny (legacy wrapper contract)
        (4,   256,  512, jnp.float32, False, True, {"pinned": True}, "sta"),
        # head GEMV hint: stream when skinny fits, XLA above the gate
        (4,   256, 8192, jnp.float32, False, True, {"gemv": True},
         "skinny_sta"),
        (48,  256, 8192, jnp.float32, False, True, {"gemv": True}, "xla"),
        # XLA route family (gemm_impl="xla" / live mesh)
        (4,   256,  512, jnp.float32, False, False, {}, "xla"),
        (256, 256,  512, jnp.float32, False, False, {}, "xla"),
        # packed weight but K not divisible by the DBB block: no DBB route
        (4,   252,  512, jnp.float32, True,  True, {}, "xla"),
    ]

    @pytest.mark.parametrize(
        "m,k,n,dtype,packed,pallas,kw,expected",
        CASES, ids=[c[-1] + f"_m{c[0]}k{c[1]}n{c[2]}" for c in CASES])
    def test_expected_route(self, m, k, n, dtype, packed, pallas, kw,
                            expected):
        decs = dispatch.explain("matmul", m=m, k=k, n=n, dtype=dtype,
                                packed=packed, pallas=pallas, **kw)
        assert _chosen(decs) == expected, dispatch.format_table(decs)

    def test_conv_routes(self):
        geom = dict(conv_geom=(2, 16, 16, 64, 3, 3, 1))
        decs = dispatch.explain("conv", m=2 * 16 * 16, k=3 * 3 * 64, n=128,
                                pallas=True, **geom)
        assert _chosen(decs) == "conv_sta"
        decs = dispatch.explain("conv", m=2 * 16 * 16, k=3 * 3 * 64, n=128,
                                packed=True, pallas=True, **geom)
        assert _chosen(decs) == "conv_dbb"
        decs = dispatch.explain("conv", m=2 * 16 * 16, k=3 * 3 * 64, n=128,
                                pallas=False, **geom)
        assert _chosen(decs) == "conv_xla"

    def test_conv_explain_without_geom(self):
        """explain('conv') without conv_geom must return a table (kernel
        routes inapplicable with a clear reason), not crash unpacking."""
        decs = dispatch.explain("conv", m=512, k=576, n=128, pallas=True)
        assert _chosen(decs) == "conv_xla"
        by = {d.name: d for d in decs}
        assert "conv_geom" in by["conv_sta"].reason

    def test_attention_routes(self):
        flash_cfg = ModelConfig(gemm_impl="pallas", dtype="float32")
        xla_cfg = ModelConfig(gemm_impl="xla")
        assert _chosen(dispatch.explain("attention", m=64, k=64, n=64,
                                        cfg=flash_cfg)) == "attn_flash"
        # flash off, short sequence: naive (chunked defers below 2 chunks)
        assert _chosen(dispatch.explain("attention", m=64, k=64, n=64,
                                        cfg=xla_cfg)) == "attn_naive"
        # flash off, long divisible sequence: chunked
        assert _chosen(dispatch.explain("attention", m=4096, k=64, n=4096,
                                        cfg=xla_cfg)) == "attn_chunked"
        # ragged per-row ladders exclude chunked
        decs = dispatch.explain("attention", m=4096, k=64, n=4096,
                                cfg=xla_cfg, ragged=True)
        assert _chosen(decs) == "attn_naive"

    def test_packed_route_charged_at_total_tokens(self):
        """Ragged 8:1 max:median mix (DESIGN.md §12): the packed
        cu_seqlens route is costed at the batch's real token count, while
        the padded cost model charges the B×T_max rectangle — on this mix
        the rectangle mis-ranks the same traffic by > 2×. Padded routes
        must also refuse the packed spec outright (block-diagonal masking
        is not optional)."""
        flash_cfg = ModelConfig(gemm_impl="pallas", dtype="float32")
        lens = [512] + [64] * 7
        total, b, t_max = sum(lens), len(lens), max(lens)
        packed = dispatch.explain("attention", m=total, k=64, n=total,
                                  cfg=flash_cfg, packed_seq=True)
        assert _chosen(packed) == "attn_packed_flash"
        by = {d.name: d for d in packed}
        for name in ("attn_flash", "attn_chunked", "attn_naive"):
            assert not by[name].applicable
            assert "packed" in by[name].reason
        # charged at total_tokens, not a padded rectangle
        assert by["attn_packed_flash"].flops == 4.0 * total * total * 64
        padded = dispatch.explain("attention", m=t_max, k=64, n=t_max,
                                  cfg=flash_cfg, batch=b)
        assert _chosen(padded) == "attn_flash"
        cost_packed = by["attn_packed_flash"].cost_s
        cost_padded = next(d for d in padded if d.chosen).cost_s
        assert cost_padded > 2.0 * cost_packed

    def test_decode_routes(self):
        flash_cfg = ModelConfig(gemm_impl="pallas", dtype="float32",
                                num_heads=4, num_kv_heads=4)
        assert dispatch.decode_attention_route(
            flash_cfg, group=1, head_dim=64, itemsize=4, page=8,
            smax=64) == "attn_decode_flash"
        # ring caches and unaligned pages fall back to the XLA softmax
        assert dispatch.decode_attention_route(
            flash_cfg, group=1, head_dim=64, itemsize=4, page=8, smax=64,
            ring=True) == "attn_decode_xla"
        assert dispatch.decode_attention_route(
            flash_cfg, group=1, head_dim=64, itemsize=4, page=8,
            smax=60) == "attn_decode_xla"
        xla_cfg = ModelConfig(gemm_impl="xla")
        assert dispatch.decode_attention_route(
            xla_cfg, group=1, head_dim=64, itemsize=4, page=8,
            smax=64) == "attn_decode_xla"

    def test_explain_reports_cost_terms(self):
        decs = dispatch.explain("matmul", m=4, k=256, n=512, pallas=True)
        assert {d.name for d in decs} == {"xla", "sta", "skinny_sta",
                                          "dbb_packed", "skinny_dbb",
                                          "dbb_packed_w4",
                                          "skinny_dbb_w4"}
        for d in decs:
            assert d.flops > 0 and d.bytes > 0
            assert d.cost_s == pytest.approx(max(d.compute_s, d.memory_s))
            if not d.applicable:
                assert d.reason
        # at M=4 both pad to the sublane: bytes tie and priority picks
        # skinny; the compressed weight stream strictly beats dense bytes
        by = {d.name: d for d in decs}
        assert by["skinny_sta"].bytes <= by["sta"].bytes
        assert by["skinny_dbb"].bytes < by["skinny_sta"].bytes
        # formatting smoke
        assert "skinny_sta" in dispatch.format_table(decs)


# ---------------------------------------------------------------------------
# forced-route parity: every applicable route computes the same thing
# ---------------------------------------------------------------------------

class TestForcedRouteParity:
    SHAPES = [(4, 64, 128), (17, 128, 256), (64, 64, 128)]

    @pytest.mark.parametrize("m,k,n", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
    def test_dense_routes_match_auto(self, m, k, n, dtype):
        x = _rand((m, k), dtype, 0)
        w = _rand((k, n), dtype, 1)
        bias = jnp.asarray(np.random.default_rng(2).standard_normal(n),
                           jnp.float32)
        kw = dict(act="relu", pallas=True)
        auto = np.asarray(dispatch.matmul(x, w, bias, **kw))
        decs = dispatch.explain("matmul", m=m, k=k, n=n, dtype=dtype,
                                pallas=True)
        forced_names = [d.name for d in decs if d.applicable]
        assert "xla" in forced_names
        for name in forced_names:
            got = np.asarray(dispatch.matmul(x, w, bias, route=name, **kw))
            if jnp.dtype(dtype) == jnp.int8:
                np.testing.assert_array_equal(got, auto, err_msg=name)
            else:
                np.testing.assert_allclose(got, auto, rtol=2e-5, atol=2e-5,
                                           err_msg=name)

    @pytest.mark.parametrize("m", [4, 64])
    def test_packed_routes_match_auto(self, m):
        k, n = 128, 256
        x = _rand((m, k), jnp.float32, 0)
        w = np.asarray(_rand((k, n), jnp.float32, 1))
        p = pack_dbb(jnp.asarray(w), 8, 4)
        bias = jnp.ones((n,), jnp.float32)
        auto = np.asarray(dispatch.matmul(x, p, bias, act="relu",
                                          pallas=True))
        decs = dispatch.explain("matmul", m=m, k=k, n=n, packed=True,
                                pallas=True)
        for name in [d.name for d in decs if d.applicable]:
            got = np.asarray(dispatch.matmul(x, p, bias, act="relu",
                                             pallas=True, route=name))
            np.testing.assert_allclose(got, auto, rtol=2e-5, atol=2e-5,
                                       err_msg=name)

    def test_int8_scaled_packed_routes_match_auto(self):
        """INT8 deployment format (quantized values + per-channel scale):
        the forced xla route must keep the scale for the int32 epilogue,
        not dequantize-and-truncate the weights back to int8."""
        from repro.core.dbb import DbbWeight
        from repro.core.quant import quantize_weight

        k, n = 128, 256
        x = _rand((4, k), jnp.int8, 0)
        qw = quantize_weight(np.asarray(_rand((k, n), jnp.float32, 1)))
        p0 = pack_dbb(qw.q, 8, 4)
        p = DbbWeight(values=p0.values.astype(jnp.int8), indices=p0.indices,
                      bitmask=p0.bitmask, scale=qw.scale, block=8, nnz=4,
                      k_dim=k)
        auto = np.asarray(dispatch.matmul(x, p, pallas=True))
        decs = dispatch.explain("matmul", m=4, k=k, n=n, dtype=jnp.int8,
                                packed=True, pallas=True)
        for name in [d.name for d in decs if d.applicable]:
            got = np.asarray(dispatch.matmul(x, p, pallas=True, route=name))
            np.testing.assert_array_equal(got, auto, err_msg=name)

    def test_conv_routes_match_auto(self):
        x = _rand((2, 8, 8, 16), jnp.float32, 0)
        w = _rand((3 * 3 * 16, 64), jnp.float32, 1)
        bias = jnp.ones((64,), jnp.float32)
        auto = np.asarray(dispatch.conv(x, w, bias, kh=3, kw=3, act="relu"))
        for name in ("conv_sta", "conv_xla"):
            got = np.asarray(dispatch.conv(x, w, bias, kh=3, kw=3,
                                           act="relu", route=name))
            np.testing.assert_allclose(got, auto, rtol=2e-5, atol=2e-5,
                                       err_msg=name)

    def test_caller_scale_folds_into_packed_routes(self):
        """A caller-supplied scale must reach the DBB kernels' epilogue
        (folded into the packed weight's scale), not be silently dropped."""
        m, k, n = 4, 128, 256
        x = _rand((m, k), jnp.float32, 0)
        p = pack_dbb(jnp.asarray(_rand((k, n), jnp.float32, 1)), 8, 4)
        scale = jnp.full((n,), 2.0, jnp.float32)
        want = np.asarray(dispatch.matmul(x, p, scale=scale, route="xla"))
        got = np.asarray(dispatch.matmul(x, p, scale=scale, pallas=True))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_inapplicable_forced_route_raises(self):
        x = _rand((4, 64), jnp.float32, 0)
        w = _rand((64, 128), jnp.float32, 1)
        with pytest.raises(ValueError, match="rejected"):
            dispatch.matmul(x, w, route="dbb_packed", pallas=True)


# ---------------------------------------------------------------------------
# override precedence: env var > kernel_routes > auto
# ---------------------------------------------------------------------------

class TestOverrides:
    def test_env_force_route(self, monkeypatch):
        monkeypatch.setenv(dispatch.FORCE_ROUTE_ENV, "xla")
        decs = dispatch.explain("matmul", m=4, k=256, n=512, pallas=True)
        assert _chosen(decs) == "xla"
        assert [d.forced for d in decs if d.chosen] == [True]

    def test_env_force_per_domain(self, monkeypatch):
        monkeypatch.setenv(dispatch.FORCE_ROUTE_ENV,
                           "matmul=sta,attention=attn_naive")
        assert _chosen(dispatch.explain("matmul", m=4, k=256, n=512,
                                        pallas=True)) == "sta"
        cfg = ModelConfig(gemm_impl="pallas", dtype="float32")
        assert _chosen(dispatch.explain("attention", m=64, k=64, n=64,
                                        cfg=cfg)) == "attn_naive"
        # other domains keep auto
        assert _chosen(dispatch.explain("conv", m=512, k=576, n=128,
                                        pallas=True,
                                        conv_geom=(2, 16, 16, 64, 3, 3, 1))
                       ) == "conv_sta"

    def test_cfg_kernel_routes(self):
        cfg = ModelConfig(gemm_impl="pallas",
                          kernel_routes=(("matmul", "xla"),))
        decs = dispatch.explain("matmul", m=4, k=256, n=512, cfg=cfg,
                                pallas=True)
        assert _chosen(decs) == "xla"

    def test_env_beats_cfg(self, monkeypatch):
        monkeypatch.setenv(dispatch.FORCE_ROUTE_ENV, "matmul=skinny_sta")
        cfg = ModelConfig(gemm_impl="pallas",
                          kernel_routes=(("matmul", "xla"),))
        decs = dispatch.explain("matmul", m=4, k=256, n=512, cfg=cfg,
                                pallas=True)
        assert _chosen(decs) == "skinny_sta"

    def test_rejected_force_falls_back_with_warning(self, monkeypatch):
        monkeypatch.setenv(dispatch.FORCE_ROUTE_ENV, "matmul=skinny_sta")
        dispatch._warned_forced.clear()
        with pytest.warns(UserWarning, match="falling back to auto"):
            # m=64 is outside the skinny gate -> guard rejects the force
            decs = dispatch.explain("matmul", m=64, k=256, n=512,
                                    pallas=True)
        assert _chosen(decs) == "sta"

    def test_bare_env_typo_warns(self, monkeypatch):
        monkeypatch.setenv(dispatch.FORCE_ROUTE_ENV, "skiny_sta")
        dispatch._warned_forced.clear()
        with pytest.warns(UserWarning, match="names no registered route"):
            decs = dispatch.explain("matmul", m=4, k=256, n=512,
                                    pallas=True)
        assert _chosen(decs) == "skinny_sta"     # auto still runs

    def test_cnn_kernel_routes_respected(self):
        """cnn_apply threads cfg into the conv domain, so kernel_routes
        pins reach it (numerics identical — the oracle route)."""
        from repro.configs import get_config
        from repro.models import registry
        from repro.models.cnn import cnn_apply

        cfg = get_config("convnet-dbb", smoke=True)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, cfg.cnn_img, cfg.cnn_img, cfg.cnn_in_ch))
        y0 = cnn_apply(params, cfg, x, matmul="sta")
        cfg_pin = cfg.replace(kernel_routes=(("conv", "conv_xla"),
                                             ("matmul", "xla")))
        y1 = cnn_apply(params, cfg_pin, x, matmul="sta")
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-4, atol=2e-4)

    def test_explain_attention_int8_matches_runtime(self):
        """explain() must not report flash for integer-dtype attention
        specs the runtime routes to the XLA paths."""
        cfg = ModelConfig(gemm_impl="pallas", dtype="float32")
        decs = dispatch.explain("attention", m=64, k=64, n=64,
                                dtype=jnp.int8, cfg=cfg)
        assert _chosen(decs) != "attn_flash"

    def test_forced_env_end_to_end_parity(self, monkeypatch):
        x = _rand((4, 64), jnp.float32, 0)
        w = _rand((64, 128), jnp.float32, 1)
        base = np.asarray(dispatch.matmul(x, w, pallas=True))
        monkeypatch.setenv(dispatch.FORCE_ROUTE_ENV, "matmul=xla")
        forced = np.asarray(dispatch.matmul(x, w, pallas=True))
        np.testing.assert_allclose(forced, base, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# model-layer integration
# ---------------------------------------------------------------------------

class TestModelLayerIntegration:
    def test_kernel_routes_thread_through_model(self):
        """A config-pinned xla route changes nothing numerically for the
        model forward (the registry guarantees route interchangeability)."""
        from repro.configs import get_config
        from repro.models import registry

        cfg = get_config("olmo-1b", smoke=True).replace(
            remat="none", gemm_impl="pallas")
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray([[5, 17, 3, 250, 99, 7, 12, 2]], jnp.int32)
        h_auto, _ = registry.forward(params, cfg, {"tokens": toks})
        cfg_pin = cfg.replace(kernel_routes=(("matmul", "xla"),))
        h_pin, _ = registry.forward(params, cfg_pin, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(h_auto), np.asarray(h_pin),
                                   rtol=2e-4, atol=2e-4)

    def test_moe_fused_experts_match_einsum(self):
        from repro.configs import get_config
        from repro.models import registry

        cfg = get_config("arctic-480b", smoke=True).replace(remat="none")
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray([[5, 17, 3, 250, 99, 7, 12, 2]], jnp.int32)
        h_xla, _ = registry.forward(params, cfg, {"tokens": toks})
        h_pal, _ = registry.forward(
            params, cfg.replace(gemm_impl="pallas"), {"tokens": toks})
        np.testing.assert_allclose(np.asarray(h_xla), np.asarray(h_pal),
                                   rtol=2e-3, atol=2e-3)

    def test_grep_clean_model_layer(self):
        """Acceptance contract: no direct kernel-subsystem imports outside
        the kernel package — all kernel selection flows through dispatch
        (DESIGN.md §11). Delegates to the repo-wide import-layering pass
        of the static verifier, which covers every repro/ module (the old
        grep here only saw models/ + core/dbb_linear.py)."""
        from repro.analysis import layering
        checked, violations = layering.check(os.path.dirname(SRC))
        assert checked > 0
        assert not violations, "\n".join(
            f"[{v.code}] {v.subject}: {v.message}" for v in violations)
