"""Training substrate: optimizers, compression, checkpointing, convergence,
fault tolerance."""
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, ShapeSpec, TrainConfig
from repro.configs import get_config
from repro.data.pipeline import SyntheticCNN, SyntheticLM, make_pipeline
from repro.launch.train import train_loop
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (PreemptionGuard, StragglerMonitor,
                                         retry_step)
from repro.train.grad_compress import compress_grads, init_ef_state
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import (clip_by_global_norm, global_norm,
                                   lr_schedule, make_optimizer)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quadratic_losses(opt_name, steps=60, lr=0.1):
    cfg = TrainConfig(optimizer=opt_name, learning_rate=lr, warmup_steps=2,
                      steps=steps, weight_decay=0.0)
    init, update = make_optimizer(cfg)
    target = jnp.array([[1.0, -2.0], [3.0, 0.5]])
    # nonzero init: adafactor's relative step scales with RMS(param)
    params = {"w": jnp.full((2, 2), 0.5)}
    state = init(params)
    losses = []
    for s in range(steps):
        loss, grads = jax.value_and_grad(
            lambda p: ((p["w"] - target) ** 2).sum())(params)
        ups, state = update(grads, state, params, jnp.asarray(s))
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, ups)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("opt", ["adamw", "adafactor", "sgd"])
def test_optimizer_converges_on_quadratic(opt):
    losses = _quadratic_losses(opt)
    assert losses[-1] < 0.05 * losses[0], (opt, losses[0], losses[-1])


def test_adafactor_state_is_factored():
    cfg = TrainConfig(optimizer="adafactor")
    init, _ = make_optimizer(cfg)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = init(params)
    assert st["s"]["w"]["vr"].shape == (64,)
    assert st["s"]["w"]["vc"].shape == (32,)
    assert st["s"]["b"]["v"].shape == (64,)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(250.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_warmup_cosine():
    cfg = TrainConfig(learning_rate=1.0, warmup_steps=10, steps=100)
    f = lr_schedule(cfg)
    assert float(f(jnp.asarray(0))) < 0.2
    assert float(f(jnp.asarray(10))) == pytest.approx(1.0, rel=0.1)
    assert float(f(jnp.asarray(99))) < 0.2


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_bf16_compression_roundtrip():
    g = {"w": jnp.array([1.0, 1e-3, 256.5])}
    out, _ = compress_grads(g, None, "bf16")
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=1e-2)


def test_int8_error_feedback_compensates():
    """With EF the *accumulated* applied gradient tracks the true sum even
    though each step quantizes aggressively."""
    rng = np.random.default_rng(0)
    true = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    ef = init_ef_state({"w": true}, "int8_ef")
    applied = jnp.zeros_like(true)
    for s in range(20):
        sent, ef = compress_grads({"w": true}, ef, "int8_ef")
        applied = applied + sent["w"]
    np.testing.assert_allclose(np.asarray(applied) / 20, np.asarray(true),
                               atol=np.abs(true).max() / 100)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("olmo-1b", smoke=True)
    rc = RunConfig(model=cfg, train=TrainConfig())
    state = init_train_state(jax.random.PRNGKey(0), rc)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state)
    template = init_train_state(jax.random.PRNGKey(1), rc)
    restored, meta = ckpt.restore(d, template)
    assert meta["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_pruned(tmp_path):
    d = str(tmp_path / "ck")
    state = {"w": jnp.arange(8.0)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, state, keep_last=2)
    assert ckpt.available_steps(d) == [4, 5]
    assert ckpt.latest_step(d) == 5
    # template mismatch is rejected, not silently mis-restored
    with pytest.raises(ValueError):
        ckpt.restore(d, {"w": jnp.zeros((8,)), "extra": jnp.zeros((2,))})


def test_resume_is_bit_exact(tmp_path):
    """10 straight steps == 5 steps + checkpoint + restore + 5 steps."""
    cfg = get_config("lenet5-dbb", smoke=True)
    shape = ShapeSpec("t", 16, 8, "train")

    def run(steps, ckdir=None, resume=False):
        rc = RunConfig(model=cfg, train=TrainConfig(
            steps=steps, learning_rate=1e-2, log_every=1,
            checkpoint_dir=ckdir or "", checkpoint_every=0, seed=3))
        return train_loop(rc, shape, log=lambda *_: None)

    s_straight, _ = run(10)
    d = str(tmp_path / "ck")
    rc5 = RunConfig(model=cfg, train=TrainConfig(
        steps=5, learning_rate=1e-2, checkpoint_dir=d, seed=3, log_every=1))
    s5, _ = train_loop(rc5, shape, log=lambda *_: None)
    ckpt.save(d, 5, s5)
    rc10 = RunConfig(model=cfg, train=TrainConfig(
        steps=10, learning_rate=1e-2, checkpoint_dir=d, seed=3, log_every=1))
    s_resumed, _ = train_loop(rc10, shape, log=lambda *_: None)
    for a, b in zip(jax.tree_util.tree_leaves(s_straight.params),
                    jax.tree_util.tree_leaves(s_resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# convergence (end-to-end loop)
# ---------------------------------------------------------------------------

def test_cnn_training_converges():
    cfg = get_config("convnet-dbb", smoke=True)
    rc = RunConfig(model=cfg, train=TrainConfig(
        steps=30, learning_rate=3e-3, log_every=1, dbb_prune_start=10,
        dbb_prune_ramp=10))
    shape = ShapeSpec("t", 16, 32, "train")
    state, hist = train_loop(rc, shape, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8
    assert hist[-1]["nnz"] == cfg.dbb.nnz        # anneal reached the bound


def test_lm_training_converges():
    cfg = get_config("olmo-1b", smoke=True)
    rc = RunConfig(model=cfg, train=TrainConfig(
        steps=25, learning_rate=1e-3, log_every=1))
    shape = ShapeSpec("t", 32, 8, "train")
    state, hist = train_loop(rc, shape, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_microbatched_grads_match_full_batch():
    cfg = get_config("olmo-1b", smoke=True)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (4, 16), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab_size),
             "loss_mask": jnp.ones((4, 16), jnp.float32)}
    outs = {}
    for m in (1, 2):
        rc = RunConfig(model=cfg, train=TrainConfig(microbatches=m))
        state = init_train_state(jax.random.PRNGKey(0), rc)
        new_state, metrics = jax.jit(make_train_step(rc))(state, batch)
        outs[m] = (new_state, metrics)
    np.testing.assert_allclose(float(outs[1][1]["loss"]),
                               float(outs[2][1]["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(outs[1][0].params),
                    jax.tree_util.tree_leaves(outs[2][0].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_skippable():
    cfg = get_config("olmo-1b", smoke=True)
    shape = ShapeSpec("t", 32, 8, "train")
    p1 = SyntheticLM(cfg, shape, seed=5)
    p2 = SyntheticLM(cfg, shape, seed=5)
    for s in (0, 3, 100):       # stateless: arbitrary order, same data
        np.testing.assert_array_equal(p1.batch_at(s)["tokens"],
                                      p2.batch_at(s)["tokens"])
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_pipeline_host_sharding_partitions_batch():
    cfg = get_config("olmo-1b", smoke=True)
    shape = ShapeSpec("t", 16, 8, "train")
    full = SyntheticLM(cfg, shape, seed=9, host_index=0, host_count=1)
    parts = [SyntheticLM(cfg, shape, seed=9, host_index=i, host_count=4)
             for i in range(4)]
    sizes = [p.batch_at(0)["tokens"].shape[0] for p in parts]
    assert sizes == [2, 2, 2, 2]
    # hosts draw disjoint streams (host index enters the seed)
    assert not np.array_equal(parts[0].batch_at(0)["tokens"],
                              parts[1].batch_at(0)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_config("olmo-1b", smoke=True)
    shape = ShapeSpec("t", 32, 4, "train")
    b = SyntheticLM(cfg, shape, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["loss_mask"][:, -1].sum() == 0


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_preemption_guard_catches_sigterm():
    with PreemptionGuard(signals=(signal.SIGUSR1,)) as g:
        assert not g.should_stop
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert g.should_stop


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(threshold=2.0, warmup=3)
    flagged = [m.update(i, 0.1) for i in range(10)]
    assert not any(flagged)
    assert m.update(10, 0.5)
    assert m.straggler_steps == 1
    # outlier did not poison the mean
    assert m.mean_step_time == pytest.approx(0.1, rel=0.05)


def test_retry_step_retries_then_succeeds():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return 42

    assert retry_step(flaky, retries=3, backoff_s=0.0) == 42
    assert len(calls) == 3


def test_retry_step_exhausts():
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(RuntimeError("x")),
                   retries=1, backoff_s=0.0)
