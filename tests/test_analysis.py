"""Static kernel-contract verifier (repro.analysis) — DESIGN.md §13.

Three layers of coverage:

  * unit tests of the pass primitives (revisit detection, the jaxpr
    walker, the hermetic route selector);
  * the repo's own contracts/registry/source tree must be clean — the
    same verdict CI's lint job enforces;
  * each known-bad fixture under tests/fixtures/ must make its pass
    fail with the expected violation code (and leave every other pass
    quiet), including end-to-end through the CLI with a JSON report.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import bounds, dispatch_check, layering, races, vmem
from repro.analysis.contracts import (BlockDecl, KernelContract,
                                      all_contracts)
from repro.analysis.materialize import (assert_no_intermediate_larger_than,
                                        max_intermediate_elems, repo_checks,
                                        run_checks)

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "fixtures")
SRC_ROOT = os.path.abspath(os.path.join(HERE, "..", "src"))


def _codes(violations):
    return {v.code for v in violations}


# ---------------------------------------------------------------------------
# pass primitives
# ---------------------------------------------------------------------------

class TestPrimitives:
    def test_ignored_dims_finds_revisit(self):
        blk = BlockDecl("out", (8, 128), lambda i, kk: (i, 0), (32, 128), 4)
        assert races.ignored_dims(blk, (4, 4)) == {1}

    def test_ignored_dims_none_when_all_used(self):
        blk = BlockDecl("out", (8, 128), lambda i, kk: (i, kk), (32, 512), 4)
        assert races.ignored_dims(blk, (4, 4)) == set()

    def test_walker_sees_through_jit(self):
        import jax
        import jax.numpy as jnp
        big = jax.jit(lambda x: (x[:, None, :] * x[None, :, :]).sum(0))
        x = jnp.ones((16, 16), jnp.float32)
        assert max_intermediate_elems(big, x) >= 16 * 16 * 16

    def test_assert_helper_raises_and_returns_peak(self):
        import jax.numpy as jnp
        x = jnp.ones((8, 8), jnp.float32)
        peak = assert_no_intermediate_larger_than(
            lambda x: x + 1.0, x, max_elems=1000)
        assert 0 < peak < 1000
        with pytest.raises(AssertionError, match="materialized"):
            assert_no_intermediate_larger_than(
                lambda x: x + 1.0, x, max_elems=8)

    def test_hermetic_selector_matches_dispatch(self):
        """The dispatch pass replays select()'s auto path; both must name
        the same winner on real registry + real specs."""
        from repro.kernels import dispatch
        for domain, specs in dispatch_check.default_specs().items():
            table = dispatch.routes_for(domain)
            for spec in specs[:8]:
                want, _ = dispatch.select(spec)
                assert dispatch_check._auto_select(table, spec) == want


# ---------------------------------------------------------------------------
# the repo itself is clean (CI's lint verdict, in-process)
# ---------------------------------------------------------------------------

class TestRepoClean:
    @pytest.fixture(scope="class")
    def contracts(self):
        return all_contracts()

    def test_contract_passes_clean(self, contracts):
        assert len(contracts) >= 15
        for check in (vmem.check_contracts, races.check_contracts,
                      bounds.check_contracts):
            n, violations = check(contracts)
            assert n == len(contracts)
            assert not violations, "\n".join(
                f"[{v.code}] {v.subject}: {v.message}" for v in violations)

    def test_headroom_constants_clean(self):
        n, violations = vmem.check_headroom_constants(SRC_ROOT)
        assert n > 0
        assert not violations, "\n".join(v.subject for v in violations)

    def test_layering_clean(self):
        n, violations = layering.check(SRC_ROOT)
        assert n > 0
        assert not violations, "\n".join(v.subject for v in violations)

    def test_dispatch_registry_clean(self):
        from repro.kernels import dispatch
        routes = {d: dispatch.routes_for(d) for d in dispatch.DOMAINS}
        n, violations = dispatch_check.check_registry(
            routes, dispatch_check.default_specs())
        assert n > 0
        assert not violations, "\n".join(
            f"[{v.code}] {v.subject}: {v.message}" for v in violations)

    def test_materialization_claims_hold(self):
        n, violations = run_checks(repo_checks())
        assert n == 3
        assert not violations, "\n".join(
            f"[{v.code}] {v.subject}: {v.message}" for v in violations)


# ---------------------------------------------------------------------------
# known-bad fixtures: each pass catches its bug class
# ---------------------------------------------------------------------------

_FIXTURE_EXPECT = [
    ("bad_vmem.py", "vmem", {"vmem-overflow", "dead-headroom"}),
    ("bad_quant.py", "vmem", {"vmem-overflow"}),
    ("bad_race.py", "races", {"race", "unguarded-accumulation"}),
    ("bad_sample.py", "races", {"race"}),
    ("bad_bounds.py", "bounds", {"oob", "overlapping-write"}),
    ("bad_materialize.py", "materialize", {"materialized"}),
    ("bad_dispatch.py", "dispatch",
     {"unreachable", "shadowed", "non-monotone-cost"}),
]


class TestKnownBadFixtures:
    @pytest.mark.parametrize("fname,pass_name,expect",
                             _FIXTURE_EXPECT,
                             ids=[f[0] for f in _FIXTURE_EXPECT])
    def test_fixture_fails_its_pass(self, fname, pass_name, expect):
        from repro.analysis import lint
        report = lint.run(contracts_module=os.path.join(FIXTURES, fname))
        assert not report["ok"]
        target = report["passes"][pass_name]
        got = {v["code"] for v in target["violations"]}
        assert expect <= got, f"{pass_name} reported {got}, want {expect}"
        # the defect is isolated: every other pass is quiet or skipped
        for name, p in report["passes"].items():
            if name != pass_name:
                assert not p["violations"], (name, p["violations"])

    def test_cli_nonzero_exit_and_json(self, tmp_path):
        """End-to-end: the CLI exits 1 on a known-bad fixture and names
        the violation in the JSON artifact."""
        out = tmp_path / "report.json"
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis.lint", "--quiet",
             "--contracts", os.path.join(FIXTURES, "bad_bounds.py"),
             "--json", str(out)],
            env=env, capture_output=True, text=True, timeout=300)
        assert proc.returncode == 1, proc.stderr
        report = json.loads(out.read_text())
        codes = {v["code"] for p in report["passes"].values()
                 for v in p["violations"]}
        assert {"oob", "overlapping-write"} <= codes
