"""Self-speculative decode (DESIGN.md §15) property suite.

  * the rejection-sampling acceptance rule against a per-row python
    reference (same counter-RNG draws, loop-wise accept/resample);
  * temperature-0 speculation is bit-identical to plain greedy decode
    through the full engine, on both KV backends and under TP;
  * sampled speculative streams are seed-reproducible across chunk
    sizes, and the paged KV backend's rollback of rejected tokens'
    cache writes is bit-equal to the contiguous backend;
  * host-side gating: top-k/top-p requests disable speculation with a
    warning and serve the plain sampled path.
"""
import json
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings

    hypothesis.settings.register_profile(
        "fast", max_examples=10, deadline=None)
    hypothesis.settings.load_profile("fast")
except ModuleNotFoundError:      # bare container: deterministic fallback
    from _hyp_fallback import given, settings, st

from repro.configs import get_config
from repro.kernels.sample import (NEG_INF, SALT_ACCEPT, SALT_RESAMPLE,
                                  gumbel_noise, probs_from_logits,
                                  uniform_noise)
from repro.models import registry
from repro.serve.engine import ServeEngine
from repro.serve.sampling import SamplingParams, speculative_accept_state

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# acceptance rule vs a loop-wise reference rejection sampler
# ---------------------------------------------------------------------------

def _state(b, v, temp=0.0, seed0=0, step0=0):
    return {"temp": jnp.full((b,), temp, jnp.float32),
            "top_k": jnp.zeros((b,), jnp.int32),
            "top_p": jnp.ones((b,), jnp.float32),
            "rep": jnp.ones((b,), jnp.float32),
            "pres": jnp.zeros((b,), jnp.float32),
            "freq": jnp.zeros((b,), jnp.float32),
            "seed": jnp.arange(b, dtype=jnp.int32) + seed0,
            "step": jnp.full((b,), step0, jnp.int32),
            "counts": jnp.zeros((b, v), jnp.int32)}


def _reference_accept(draft_tok, p, q, seed, step):
    """Leviathan-et-al. rejection sampling, one row and one position at a
    time, drawing the SAME counter-RNG streams the vectorized rule uses:
    accept d_i iff u_i < p_i[d_i]/q_i[d_i]; first rejection resamples
    from norm(max(p - q, 0)) via gumbel-argmax over log residual; a
    fully-accepted draft draws the bonus from p_k (residual with q := 0).
    """
    b, k = draft_tok.shape
    v = p.shape[-1]
    emit = np.zeros((b, k + 1), np.int64)
    n_emit = np.zeros((b,), np.int64)
    cols = jnp.arange(v, dtype=jnp.int32)
    for r in range(b):
        n_acc = 0
        for i in range(k):
            u = float(uniform_noise(jnp.int32(seed[r]),
                                    jnp.int32(step[r] + i),
                                    jnp.int32(0), SALT_ACCEPT))
            d = int(draft_tok[r, i])
            if u < p[r, i, d] / max(q[r, i, d], 1e-30):
                emit[r, i] = d
                n_acc += 1
            else:
                break
        q_row = q[r, n_acc] if n_acc < k else np.zeros((v,), p.dtype)
        resid = np.maximum(p[r, n_acc] - q_row, 0.0)
        logr = np.where(resid > 0, np.log(np.maximum(resid, 1e-30)),
                        np.float32(NEG_INF))
        g = np.asarray(gumbel_noise(jnp.int32(seed[r]),
                                    jnp.int32(step[r] + n_acc),
                                    cols, SALT_RESAMPLE))
        emit[r, n_acc] = int(np.argmax(logr + g))
        n_emit[r] = n_acc + 1
    return emit, n_emit


class TestAcceptanceRule:
    def _logits(self, seed, b, k, v):
        kk = jax.random.PRNGKey(seed)
        dl = jax.random.normal(kk, (b, k, v), jnp.float32) * 2.0
        vl = jax.random.normal(jax.random.fold_in(kk, 1),
                               (b, k + 1, v), jnp.float32) * 2.0
        return dl, vl

    def test_temp0_identical_models_accept_everything(self):
        b, k, v = 3, 4, 32
        dl, vl = self._logits(0, 0, k, v)[0], None
        dl = jax.random.normal(jax.random.PRNGKey(0), (b, k, v))
        vl = jnp.concatenate(
            [dl, jax.random.normal(jax.random.PRNGKey(1), (b, 1, v))],
            axis=1)
        draft = jnp.argmax(dl, -1).astype(jnp.int32)
        emit, n = speculative_accept_state(draft, dl, vl, _state(b, v))
        emit, n = np.asarray(emit), np.asarray(n)
        assert (n == k + 1).all()
        assert (emit[:, :k] == np.asarray(draft)).all()
        assert (emit[:, k] == np.asarray(jnp.argmax(vl[:, k], -1))).all()

    @given(st.integers(0, 3))
    def test_temp0_first_divergence_truncates(self, j):
        """Force the verify argmax to differ from the draft at position
        j: exactly j drafts are accepted and the emitted token at j is
        the full model's greedy choice."""
        b, k, v = 2, 4, 32
        dl, _ = self._logits(7 + j, b, k, v)
        draft = jnp.argmax(dl, -1).astype(jnp.int32)
        other = (np.asarray(draft[:, j]) + 1) % v
        vln = np.array(jnp.concatenate([dl, dl[:, :1]], axis=1))
        vln[np.arange(b), j, other] = 50.0     # new verify argmax at j
        emit, n = speculative_accept_state(
            draft, dl, jnp.asarray(vln), _state(b, v))
        emit, n = np.asarray(emit), np.asarray(n)
        assert (n == j + 1).all()
        assert (emit[:, :j] == np.asarray(draft)[:, :j]).all()
        assert (emit[np.arange(b), j] == other).all()

    @given(st.integers(0, 12))
    def test_matches_reference_rejection_sampler(self, seed):
        b, k, v = 4, 3, 24
        temp = 0.8
        dl, vl = self._logits(seed + 20, b, k, v)
        s = _state(b, v, temp=temp, seed0=seed * 13, step0=seed % 5)
        # drafts need not come from q for the rule itself to be
        # well-defined — any token ids exercise accept/reject paths
        draft = jax.random.randint(jax.random.PRNGKey(seed), (b, k), 0, v)
        draft = draft.astype(jnp.int32)
        emit, n = speculative_accept_state(draft, dl, vl, s)
        emit, n = np.asarray(emit), np.asarray(n)

        bc = lambda x: x.reshape(b, 1, 1)
        counts = s["counts"][:, None]
        p = np.asarray(probs_from_logits(
            vl, counts, bc(s["temp"]), bc(s["rep"]), bc(s["pres"]),
            bc(s["freq"])))
        q = np.asarray(probs_from_logits(
            dl, counts, bc(s["temp"]), bc(s["rep"]), bc(s["pres"]),
            bc(s["freq"])))
        ref_emit, ref_n = _reference_accept(
            np.asarray(draft), p, q, np.asarray(s["seed"]),
            np.asarray(s["step"]))
        assert (n == ref_n).all()
        for r in range(b):
            assert (emit[r, :n[r]] == ref_emit[r, :n[r]]).all()

    def test_n_emit_bounds(self):
        b, k, v = 4, 3, 24
        dl, vl = self._logits(99, b, k, v)
        draft = jnp.argmax(dl, -1).astype(jnp.int32)
        _, n = speculative_accept_state(draft, dl, vl,
                                        _state(b, v, temp=1.2))
        n = np.asarray(n)
        assert ((n >= 1) & (n <= k + 1)).all()


# ---------------------------------------------------------------------------
# engine-level: spec streams vs plain streams
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cfg():
    return get_config("olmo-1b", smoke=True).replace(remat="none")


@pytest.fixture(scope="module")
def params(cfg):
    return registry.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(9)
    return [list(rng.integers(2, 500, size=n)) for n in (5, 3, 6, 4)]


class TestSpecEngine:
    def test_spec_temp0_bit_identical_to_greedy(self, cfg, params,
                                                prompts):
        eng = ServeEngine(cfg, params, max_batch=4, fetch_chunk=4)
        greedy = eng.generate(prompts, max_new_tokens=8)
        spec = eng.generate(
            prompts, max_new_tokens=8,
            sampling=[SamplingParams() for _ in prompts], draft_k=2)
        assert spec == greedy

    def test_spec_stream_reproducible_across_chunks(self, cfg, params,
                                                    prompts):
        sp = [SamplingParams(temperature=0.8, seed=23 + i)
              for i in range(len(prompts))]
        outs = []
        for chunk in (4, 3):
            eng = ServeEngine(cfg, params, max_batch=4, fetch_chunk=chunk)
            outs.append(eng.generate(prompts, max_new_tokens=8,
                                     sampling=sp, draft_k=2))
        assert outs[0] == outs[1]

    def test_paged_backend_bit_equal_contiguous(self, cfg, params):
        """Paged serve (with rejected-token rollback) must emit the same
        speculative streams as the contiguous cache."""
        rng = np.random.default_rng(11)
        prompts = [list(rng.integers(2, 500, size=4)) for _ in range(5)]
        sp = [SamplingParams(temperature=0.7, seed=31 + i)
              for i in range(5)]
        pcfg = cfg.replace(gemm_impl="pallas", attn_impl="flash")
        cont = ServeEngine(pcfg, params, max_batch=2, fetch_chunk=4)
        paged = ServeEngine(pcfg.replace(kv_page_size=8), params,
                            max_batch=2, fetch_chunk=4)
        a = cont.serve(prompts, 8, sampling=sp, draft_k=2)
        b = paged.serve(prompts, 8, sampling=sp, draft_k=2)
        assert a == b
        assert cont.serve_stats["spec_steps"] > 0

    def test_serve_acceptance_stats_recorded(self, cfg, params, prompts):
        eng = ServeEngine(cfg, params, max_batch=2, fetch_chunk=4)
        eng.serve(prompts, 8,
                  sampling=[SamplingParams(temperature=0.6, seed=i)
                            for i in range(len(prompts))], draft_k=2)
        st_ = eng.serve_stats
        assert st_["spec_steps"] > 0
        # 1..k+1 tokens per speculative step, by construction
        assert st_["spec_steps"] <= st_["spec_emitted"] \
            <= 3 * st_["spec_steps"]

    def test_top_k_request_gates_speculation_with_warning(self, cfg,
                                                          params,
                                                          prompts):
        eng = ServeEngine(cfg, params, max_batch=4, fetch_chunk=4)
        sp = [SamplingParams(temperature=0.8, top_k=4, seed=i)
              for i in range(len(prompts))]
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            spec = eng.generate(prompts, max_new_tokens=8, sampling=sp,
                                draft_k=2)
        assert any("speculative decode disabled" in str(x.message)
                   for x in w)
        plain = eng.generate(prompts, max_new_tokens=8, sampling=sp)
        assert spec == plain


# ---------------------------------------------------------------------------
# TP parity (subprocess-spawned virtual mesh)
# ---------------------------------------------------------------------------

def _run(body: str, devices: int = 2, timeout: int = 900) -> dict:
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
import sys, json
sys.path.insert(0, {_SRC!r})
import jax, jax.numpy as jnp
import numpy as np
{body}
print("JSON::" + json.dumps(out))
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    for line in r.stdout.splitlines():
        if line.startswith("JSON::"):
            return json.loads(line[len("JSON::"):])
    raise AssertionError(f"no JSON in output: {r.stdout[-2000:]}")


def test_tp_spec_temp0_matches_greedy():
    out = _run("""
from repro.configs import get_config
from repro.dist.mesh_ctx import use_mesh
from repro.launch.mesh import make_smoke_mesh
from repro.models import registry
from repro.serve.engine import ServeEngine
from repro.serve.sampling import SamplingParams

cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
params = registry.init_params(jax.random.PRNGKey(0), cfg)
prompts = [[5, 6, 7, 8], [9, 10, 11], [12, 13, 14, 15, 16]]
mesh = make_smoke_mesh(data=1, model=2)
with use_mesh(mesh):
    eng = ServeEngine(cfg, params, max_batch=4, fetch_chunk=4)
    tp_greedy = eng.generate(prompts, max_new_tokens=8)
    tp_spec = eng.generate(prompts, max_new_tokens=8,
                           sampling=[SamplingParams() for _ in prompts],
                           draft_k=2)
single = ServeEngine(cfg, params, max_batch=4, fetch_chunk=4)
ref = single.generate(prompts, max_new_tokens=8)
out = {"spec_eq_greedy": tp_spec == tp_greedy,
       "tp_eq_single": tp_greedy == ref}
""")
    assert out["spec_eq_greedy"], \
        "TP speculative temp-0 diverged from TP greedy"
    assert out["tp_eq_single"], "TP greedy diverged from single-device"
