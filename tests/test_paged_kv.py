"""Paged KV cache serving (DESIGN.md §10): allocator invariants, paged
decode bit-equivalence with the contiguous flash cache under mid-stream
admission/retirement, page recycling under pool pressure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import registry
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import (DUMMY_PAGE, PageAllocator, init_paged_cache,
                                  pages_needed)


class TestPageAllocator:
    def test_alloc_free_roundtrip(self):
        a = PageAllocator(8)                 # pages 1..7 usable
        assert a.free_pages == 7
        got = a.alloc(3)
        assert len(got) == 3 and DUMMY_PAGE not in got
        assert a.free_pages == 4 and a.used_pages == 3
        a.free(got)
        assert a.free_pages == 7

    def test_exhaustion_defers(self):
        a = PageAllocator(4)
        assert a.alloc(3) is not None
        assert a.alloc(1) is None            # nothing left: caller defers
        assert a.free_pages == 0

    def test_dummy_never_handed_out(self):
        a = PageAllocator(16)
        seen = a.alloc(15)
        assert a.alloc(1) is None
        assert DUMMY_PAGE not in seen and len(set(seen)) == 15

    def test_pages_needed(self):
        assert pages_needed(8, 8, 8) == 2    # prompt fills p0, decode p1
        assert pages_needed(8, 9, 8) == 3
        assert pages_needed(3, 1, 8) == 1
        assert pages_needed(0, 1, 8) == 1


def test_init_paged_cache_shapes():
    cfg = get_config("olmo-1b", smoke=True)
    c = init_paged_cache(cfg, n_slots=3, pool_pages=9, page=8, n_log=4)
    L, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    assert c["k_pages"].shape == (L, 9, 8, hkv, hd)
    assert c["block_table"].shape == (3, 4)
    assert c["block_table"].dtype == jnp.int32
    assert c["length"].shape == (3,) and c["start"].shape == (3,)


# ---------------------------------------------------------------------------
# serving parity: the ragged continuous-batching suite, paged vs contiguous
# ---------------------------------------------------------------------------

PROMPTS = [[5, 17, 3], [9, 9, 9, 9, 1, 2], [42, 7, 13, 250, 99],
           [4, 8], [100, 200, 300]]
BUDGETS = [6, 3, 8, 5, 4]


@pytest.fixture(scope="module")
def flash_lm():
    """Flash backend + page-8 decode tiles — both engines below run the
    SAME decode kernel in the same page-visit order; only the physical
    page layout differs, which is what makes the comparison bit-exact."""
    cfg = get_config("olmo-1b", smoke=True).replace(
        remat="none", attn_impl="flash", kv_page_size=8)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def paged_outputs(flash_lm):
    cfg, params = flash_lm
    eng = ServeEngine(cfg, params, max_batch=2, fetch_chunk=3)
    outs = eng.serve(PROMPTS, max_new_tokens=BUDGETS)
    return eng, outs


class TestPagedServing:
    def test_bit_identical_to_contiguous(self, flash_lm, paged_outputs):
        """More requests than slots (mid-stream admission + retirement):
        the paged scheduler must emit exactly the contiguous flash
        engine's tokens — same kernel, identity block table vs real block
        table."""
        cfg, params = flash_lm
        _, out_paged = paged_outputs
        eng_c = ServeEngine(cfg, params, max_batch=2, fetch_chunk=3,
                            paged=False)
        out_contig = eng_c.serve(PROMPTS, max_new_tokens=BUDGETS)
        assert out_paged == out_contig

    def test_page_recycling_under_pool_pressure(self, flash_lm,
                                                paged_outputs):
        """A pool too small to hold every admitted request forces deferred
        admissions and page recycling; emitted tokens must not change
        (recycled pages carry no ghost state — the admission scatter
        overwrites every logical page)."""
        cfg, params = flash_lm
        _, out_ref = paged_outputs
        eng = ServeEngine(cfg, params, max_batch=4, fetch_chunk=3,
                          kv_pool_pages=4)     # 3 usable pages
        outs = eng.serve(PROMPTS, max_new_tokens=BUDGETS)
        assert outs == out_ref
        assert eng.serve_stats["deferred_admissions"] > 0
        assert eng.serve_stats["peak_active"] <= 2

    def test_occupancy_beats_contiguous_slots(self, flash_lm):
        """Mixed short/long workload: smax (and so the contiguous per-slot
        reserve) is driven by the longest budget, while short requests use
        a fraction of it in pages. A pool holding the HBM of 2 contiguous
        slots must admit MORE than 2 concurrent rows — the occupancy win
        the benchmark quantifies — with bit-identical tokens."""
        cfg, params = flash_lm
        budgets = [20, 3, 3, 3, 3]
        # smax buckets to 32 → 4 pages/slot; 2 contiguous slots = 8 pages.
        # long request: ceil((8+20)/8) = 4 pages; short: ceil((8+3)/8) = 2.
        eng = ServeEngine(cfg, params, max_batch=8, fetch_chunk=3,
                          kv_pool_pages=9)
        outs = eng.serve(PROMPTS, max_new_tokens=budgets)
        eng_c = ServeEngine(cfg, params, max_batch=2, fetch_chunk=3,
                            paged=False)
        assert outs == eng_c.serve(PROMPTS, max_new_tokens=budgets)
        assert eng.serve_stats["peak_active"] > 2

    def test_page_not_dividing_bucket_stays_bit_identical(self, flash_lm):
        """A page size that does not divide the power-of-two smax bucket:
        serve() must page-align smax for BOTH schedulers, or the
        contiguous engine silently drops to the XLA softmax decode while
        the paged engine runs the kernel (latent bit-identity break)."""
        cfg, params = flash_lm
        cfg12 = cfg.replace(kv_page_size=12)         # 12 ∤ 16-slot bucket
        prompts, budgets = PROMPTS[:3], BUDGETS[:3]
        out_p = ServeEngine(cfg12, params, max_batch=2, fetch_chunk=3
                            ).serve(prompts, max_new_tokens=budgets)
        out_c = ServeEngine(cfg12, params, max_batch=2, fetch_chunk=3,
                            paged=False).serve(prompts,
                                               max_new_tokens=budgets)
        assert out_p == out_c

    def test_sub_sublane_page_rejected(self, flash_lm):
        """Pages below 8 slots put the two schedulers on different
        numeric paths (the contiguous gate rejects them) — refuse
        up front."""
        cfg, params = flash_lm
        eng = ServeEngine(cfg.replace(kv_page_size=4), params, max_batch=2)
        with pytest.raises(ValueError, match="minimum page"):
            eng.serve([[5, 17, 3]], max_new_tokens=2)

    def test_pinned_oracle_falls_back_to_contiguous(self, flash_lm):
        """--attn-backend naive + --kv-page-size is honored, not silently
        overridden: the paged branch would decode through the flash kernel
        unconditionally, so serve() must fall back to the contiguous
        scheduler (which respects the oracle) with a warning."""
        cfg, params = flash_lm
        cfgn = cfg.replace(attn_impl="naive")        # kv_page_size still 8
        eng = ServeEngine(cfgn, params, max_batch=2)
        with pytest.warns(UserWarning, match="contiguous"):
            out = eng.serve([[5, 17, 3]], max_new_tokens=3)
        ref = ServeEngine(cfgn, params, max_batch=2, paged=False).serve(
            [[5, 17, 3]], max_new_tokens=3)
        assert out == ref

    def test_oversized_page_rejected(self, flash_lm):
        """kv_page_size is user config: a page whose KV tile cannot fit
        the decode kernel's VMEM budget must be refused at pool
        construction, not fail in the lowering mid-serving."""
        cfg, params = flash_lm
        big = cfg.replace(kv_page_size=1 << 20)
        eng = ServeEngine(big, params, max_batch=2)
        with pytest.raises(ValueError, match="VMEM"):
            eng.serve([[5, 17, 3]], max_new_tokens=2)

    def test_pool_too_small_raises(self, flash_lm):
        cfg, params = flash_lm
        eng = ServeEngine(cfg, params, max_batch=2, kv_pool_pages=2)
        with pytest.raises(RuntimeError, match="pages"):
            eng.serve([[5, 17, 3]], max_new_tokens=30)

    def test_paged_decode_step_cache_contract(self, flash_lm):
        """transformer.decode_step's paged branch: advances length, keeps
        table/start, scatters the new token into the owning page only."""
        cfg, params = flash_lm
        page, n_log = 8, 2
        cache = init_paged_cache(cfg, 2, 5, page, n_log)
        cache["block_table"] = jnp.asarray([[1, 3], [2, 4]], jnp.int32)
        cache["length"] = jnp.asarray([2, 9], jnp.int32)
        before_k = np.asarray(cache["k_pages"])
        h, c2 = registry.decode_step(params, cfg, jnp.asarray([7, 8]), cache)
        assert h.shape[:2] == (2, 1)
        np.testing.assert_array_equal(np.asarray(c2["length"]),
                                      np.asarray([3, 10]))
        np.testing.assert_array_equal(np.asarray(c2["block_table"]),
                                      np.asarray(cache["block_table"]))
        after_k = np.asarray(c2["k_pages"])
        changed = np.where(np.any(after_k != before_k, axis=(0, 3, 4)))
        # row 0 writes slot 2 of phys page 1; row 1 slot 1 of phys page 4
        assert set(zip(changed[0].tolist(), changed[1].tolist())) == {
            (1, 2), (4, 1)}
