"""Decode fast path (DESIGN.md §9): skinny weight-streaming kernels,
packed-weight streaming decode, and continuous-batching serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dbb import dbb_project, pack_dbb
from repro.kernels.autotune import m_bucket
from repro.kernels.dbb_gemm.ops import dbb_gemm, dbb_gemm_packed
from repro.kernels.dbb_gemm.ref import dbb_gemm_ref
from repro.kernels.epilogue import Epilogue
from repro.kernels.skinny import (SKINNY_M_MAX, dbb_gemm_skinny_pallas,
                                  skinny_ok, sta_gemm_skinny_pallas)
from repro.kernels.sta_gemm.ops import sta_gemm
from repro.kernels.sta_gemm.ref import sta_gemm_ref


def _rand(shape, seed, dtype):
    k = jax.random.PRNGKey(seed)
    if dtype == jnp.int8:
        return jax.random.randint(k, shape, -127, 128, jnp.int32).astype(
            jnp.int8)
    return jax.random.normal(k, shape, jnp.float32).astype(dtype)


class TestSkinnySta:
    """Skinny dispatch happens inside the public sta_gemm for M ≤ 32."""

    @pytest.mark.parametrize("m", [1, 3, 8, 17, 32])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_matches_oracle(self, m, dtype):
        k, n = 256, 72                       # ragged N: padding path
        x = _rand((m, k), 0, dtype)
        w = _rand((k, n), 1, dtype)
        got = sta_gemm(x, w)
        want = sta_gemm_ref(x, w)
        assert got.dtype == want.dtype
        if dtype == jnp.int8:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        else:
            rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=rtol, atol=rtol)

    @pytest.mark.parametrize("act", ["none", "silu", "relu"])
    def test_fused_epilogue(self, act):
        m, k, n = 4, 256, 72
        x = _rand((m, k), 2, jnp.float32)
        w = _rand((k, n), 3, jnp.float32)
        bias = _rand((n,), 4, jnp.float32)
        scale = jnp.linspace(0.25, 1.5, n)
        got = sta_gemm(x, w, bias, scale, act=act)
        want = sta_gemm(x, w, bias, scale, act=act, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_direct_kernel_matches_tiled(self):
        """The skinny kernel itself (resident A, N-major grid) must equal
        the M-tiled kernel on an aligned shape."""
        from repro.kernels.sta_gemm.kernel import sta_gemm_pallas
        x = _rand((8, 256), 5, jnp.float32)
        w = _rand((256, 256), 6, jnp.float32)
        got = sta_gemm_skinny_pallas(x, w, block_k=128, block_n=128,
                                     interpret=True)
        want = sta_gemm_pallas(x, w, block_m=8, block_k=128, block_n=128,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_int8_requant_store(self):
        """INT8 requant through the skinny store is bit-exact vs the
        hand-computed round/clip (same contract as the tiled kernel)."""
        x = _rand((8, 128), 6, jnp.int8)
        w = _rand((128, 128), 7, jnp.int8)
        s = jnp.float32(2e-3)
        got = sta_gemm(x, w, scale=s, act="relu", out_dtype=jnp.int8)
        assert got.dtype == jnp.int8
        acc = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        want = jnp.clip(jnp.round(jnp.maximum(
            acc.astype(jnp.float32) * s, 0)), -127, 127).astype(jnp.int8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_dispatch_boundary(self):
        assert skinny_ok(1, 4096, 4)
        assert skinny_ok(SKINNY_M_MAX, 4096, 4)
        assert not skinny_ok(SKINNY_M_MAX + 1, 4096, 4)
        # a resident row-block that cannot fit VMEM opts out
        assert not skinny_ok(32, 1 << 22, 4)

    def test_pinned_blocks_still_supported(self):
        """Caller-pinned block shapes opt out of skinny dispatch and keep
        the tiled kernel contract."""
        x = _rand((8, 256), 8, jnp.float32)
        w = _rand((256, 128), 9, jnp.float32)
        got = sta_gemm(x, w, block_m=8, block_k=128, block_n=128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)


class TestSkinnyDbb:
    @pytest.mark.parametrize("m", [1, 8, 32])
    @pytest.mark.parametrize("block,nnz", [(8, 4), (8, 2), (16, 4)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
    def test_matches_oracle(self, m, block, nnz, dtype):
        k, n = 256, 128
        x = _rand((m, k), 0, dtype)
        w = _rand((k, n), 1, jnp.float32)
        p = pack_dbb(w, block, nnz)
        vals = p.values.astype(dtype)
        got = dbb_gemm(x, vals, p.bitmask, block=block, nnz=nnz)
        want = dbb_gemm_ref(x, vals, p.bitmask.astype(jnp.int32),
                            block=block, nnz=nnz)
        if dtype == jnp.int8:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        else:
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)

    def test_packed_with_scale_bias_act(self):
        """Per-channel scale + bias + act fused into the skinny epilogue."""
        m, k, n = 4, 256, 128
        x = _rand((m, k), 2, jnp.float32)
        w = _rand((k, n), 3, jnp.float32)
        scale = jnp.linspace(0.5, 2.0, n)
        bias = _rand((n,), 4, jnp.float32)
        p = pack_dbb(w, 8, 4, scale=scale)
        got = dbb_gemm_packed(x, p, bias, act="relu")
        want = jnp.maximum(
            (x @ dbb_project(w, 8, 4)) * scale[None, :] + bias[None, :], 0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_direct_kernel_matches_tiled(self):
        from repro.kernels.dbb_gemm.kernel import dbb_gemm_pallas
        w = _rand((256, 128), 5, jnp.float32)
        x = _rand((8, 256), 6, jnp.float32)
        p = pack_dbb(w, 8, 4)
        mask = p.bitmask.astype(jnp.int32)
        got = dbb_gemm_skinny_pallas(x, p.values, mask, block=8, nnz=4,
                                     block_k=128, block_n=128,
                                     interpret=True)
        want = dbb_gemm_pallas(x, p.values, mask, block=8, nnz=4,
                               block_m=8, block_k=128, block_n=128,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestSkinnyCandidates:
    def test_bm_fixed_and_unique(self):
        from repro.core.sta import LANE, SUBLANE, VMEM_BYTES
        from repro.kernels.autotune import skinny_candidate_block_shapes

        cands = skinny_candidate_block_shapes(17, 2048, 512, itemsize=4)
        assert cands
        assert len(set(cands)) == len(cands)      # no duplicate timings
        for bm, bk, bn in cands:
            assert bm == 24                        # round_up(17, SUBLANE)
            assert bk % LANE == 0 and bn % LANE == 0
            kp = -(-2048 // bk) * bk
            assert (bm * kp + bk * bn) * 4 + bm * bn * 4 <= VMEM_BYTES // 2

    def test_align_k_honored(self):
        from repro.kernels.autotune import skinny_candidate_block_shapes

        cands = skinny_candidate_block_shapes(8, 768, 256, itemsize=1,
                                              align_k=384)
        assert all(bk % 384 == 0 for _, bk, _ in cands)


class TestMBucket:
    def test_buckets(self):
        assert m_bucket(1) == 8 and m_bucket(8) == 8
        assert m_bucket(9) == 16 and m_bucket(32) == 32
        assert m_bucket(33) == 64 and m_bucket(512) == 512
        assert m_bucket(513) == 1024 and m_bucket(1500) == 1536

    def test_decode_prefill_separate_same_bucket_shared(self, tmp_path):
        """M=1..8 share one cache entry; decode and prefill shapes don't."""
        from repro.kernels import autotune
        path = str(tmp_path / "autotune.json")
        autotune.clear_memory_cache()
        calls = []

        def mk(shape):
            def fn():
                calls.append(shape)
                return jnp.zeros(())
            return fn

        a = autotune.autotune_block_shape(
            "k", 1, 128, 128, jnp.float32, mk,
            candidates=[(8, 128, 128)], repeats=1, path=path)
        n_after_first = len(calls)
        b = autotune.autotune_block_shape(
            "k", 8, 128, 128, jnp.float32, mk,
            candidates=[(8, 128, 128)], repeats=1, path=path)
        assert a == b and len(calls) == n_after_first   # shared bucket
        autotune.autotune_block_shape(
            "k", 512, 128, 128, jnp.float32, mk,
            candidates=[(8, 128, 128)], repeats=1, path=path)
        assert len(calls) > n_after_first               # prefill: own entry
        import json
        assert len(json.load(open(path))) == 2


@pytest.fixture(scope="module")
def packed_lm():
    from repro.configs import get_config
    from repro.core.dbb_linear import pack_tree
    from repro.core.sparsity import apply_dbb_to_tree
    from repro.models import registry

    cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
    dbb = cfg.dbb.__class__(enabled=True, block=8, nnz=4)
    cfg = cfg.replace(dbb=dbb)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    proj = apply_dbb_to_tree(params, dbb, straight_through=False)
    packed = pack_tree(proj, dbb)
    return cfg, proj, packed


class TestPackedStreamingDecode:
    def test_decode_token_parity(self, packed_lm):
        """Pallas streaming decode on packed weights == XLA decode on the
        DBB-projected dense weights, token for token."""
        from repro.models import registry
        from repro.serve.engine import make_decode_step

        cfg, proj, packed = packed_lm
        cfgp = cfg.replace(gemm_impl="pallas")
        tok = jnp.asarray([7])
        c1 = registry.init_cache(cfg, 1, 8)
        c2 = registry.init_cache(cfgp, 1, 8)
        n1, _ = jax.jit(make_decode_step(cfg))(proj, c1, tok)
        n2, _ = jax.jit(make_decode_step(cfgp))(packed, c2, tok)
        assert int(n1[0]) == int(n2[0])

    def test_no_dense_materialization(self, packed_lm):
        """Tracing the streaming decode step must never expand a packed
        layer weight to dense — checked two ways: every dense expand goes
        through decompress_xla (which counts trace-time calls), and the
        shared repro.analysis jaxpr walker proves no traced intermediate
        has the dense [K, N] shape of any packed weight (the XLA
        decompress route, the control, traces exactly those)."""
        from repro.analysis.materialize import trace_avals
        from repro.core import dbb_linear
        from repro.core.dbb import DbbWeight
        from repro.models import registry
        from repro.serve.engine import make_decode_step

        cfg, _, packed = packed_lm
        tok = jnp.asarray([7], jnp.int32)

        def calls(route_cfg):
            cache = registry.init_cache(route_cfg, 1, 8)
            before = dbb_linear.DECOMPRESS_STATS["calls"]
            jax.eval_shape(make_decode_step(route_cfg), packed, cache, tok)
            return dbb_linear.DECOMPRESS_STATS["calls"] - before

        assert calls(cfg.replace(gemm_impl="pallas")) == 0
        assert calls(cfg.replace(gemm_impl="xla")) > 0   # control

        # dense shapes bigger than one [LANE, LANE] streaming tile — a
        # single tile is the kernel's legitimate VMEM unit and is
        # indistinguishable by shape from a dense expand of a tile-sized
        # layer
        from repro.core.sta import LANE
        dense_shapes = {
            (leaf.k_dim, leaf.n_dim)
            for leaf in jax.tree_util.tree_leaves(
                packed, is_leaf=lambda x: isinstance(x, DbbWeight))
            if isinstance(leaf, DbbWeight)
            and leaf.k_dim * leaf.n_dim > LANE * LANE}

        def traced_dense(route_cfg):
            cache = registry.init_cache(route_cfg, 1, 8)
            avals = trace_avals(make_decode_step(route_cfg), packed,
                                cache, tok)
            return dense_shapes & {tuple(a.shape) for a in avals}

        hit = traced_dense(cfg.replace(gemm_impl="pallas"))
        assert not hit, (
            f"pallas decode step traced dense weight-shaped "
            f"intermediates: {sorted(hit)}")
        assert traced_dense(cfg.replace(gemm_impl="xla"))   # control

    def test_prefill_parity(self, packed_lm):
        """The streaming fast path covers prefill too (same layer blocks):
        packed Pallas prefill hidden ≈ dense XLA prefill hidden."""
        from repro.models import registry

        cfg, proj, packed = packed_lm
        toks = jnp.asarray([[5, 17, 3, 250]], jnp.int32)
        h_d, _ = registry.prefill(proj, cfg, tokens=toks,
                                  cache=registry.init_cache(cfg, 1, 8))
        cfgp = cfg.replace(gemm_impl="pallas")
        h_p, _ = registry.prefill(packed, cfgp, tokens=toks,
                                  cache=registry.init_cache(cfgp, 1, 8))
        np.testing.assert_allclose(np.asarray(h_p, np.float32),
                                   np.asarray(h_d, np.float32),
                                   rtol=2e-3, atol=2e-3)

    def test_packed_engine_generate_parity(self, packed_lm):
        """End-to-end: the packed streaming engine generates the same
        tokens as the projected-dense XLA engine."""
        from repro.serve.engine import ServeEngine

        cfg, proj, packed = packed_lm
        out_d = ServeEngine(cfg, proj, max_batch=2).generate(
            [[5, 17, 3, 250]], max_new_tokens=3)[0]
        out_p = ServeEngine(cfg.replace(gemm_impl="pallas"), packed,
                            max_batch=2).generate(
            [[5, 17, 3, 250]], max_new_tokens=3)[0]
        assert out_d == out_p

    def test_engine_strips_diagnostic_indices(self, packed_lm):
        """ServeEngine drops the int32 indices plane from device-resident
        packed leaves (diagnostics only — 4 B/value, 4x the int8
        payload). `pack_tree` output is already stripped; a hand-packed
        tree (pack_dbb keeps indices for validate_dbb) must not carry
        the plane into the engine's resident params either."""
        import dataclasses

        from repro.core.dbb import DbbWeight
        from repro.serve.engine import ServeEngine

        cfg, _, packed = packed_lm
        is_dbb = lambda x: isinstance(x, DbbWeight)  # noqa: E731
        with_idx = jax.tree_util.tree_map(
            lambda l: dataclasses.replace(
                l, indices=jnp.zeros(l.values.shape, jnp.int32))
            if is_dbb(l) else l, packed, is_leaf=is_dbb)
        host_leaves = [l for l in jax.tree_util.tree_leaves(
            with_idx, is_leaf=is_dbb) if is_dbb(l)]
        assert host_leaves and all(l.indices is not None
                                   for l in host_leaves)
        eng = ServeEngine(cfg.replace(gemm_impl="pallas"), with_idx,
                          max_batch=2)
        eng_leaves = [l for l in jax.tree_util.tree_leaves(
            eng.params, is_leaf=is_dbb) if is_dbb(l)]
        assert eng_leaves and all(l.indices is None for l in eng_leaves)
        # the caller's tree is untouched (host-side diagnostics keep it)
        assert all(l.indices is not None for l in host_leaves)


@pytest.fixture(scope="module")
def packed_lm_w4():
    from repro.configs import get_config
    from repro.core.dbb_linear import pack_tree
    from repro.core.sparsity import apply_dbb_to_tree
    from repro.models import registry

    cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
    dbb = cfg.dbb.__class__(enabled=True, block=8, nnz=4,
                            weight_bits=4, quant_group=64)
    cfg = cfg.replace(dbb=dbb)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    proj = apply_dbb_to_tree(params, dbb, straight_through=False)
    packed = pack_tree(proj, dbb)
    return cfg, packed


class TestW4StreamingDecode:
    def test_all_leaves_pack_w4(self, packed_lm_w4):
        from repro.core.dbb import DbbWeight

        _, packed = packed_lm_w4
        leaves = [l for l in jax.tree_util.tree_leaves(
            packed, is_leaf=lambda x: isinstance(x, DbbWeight))
            if isinstance(l, DbbWeight)]
        assert leaves and all(l.bits == 4 for l in leaves)

    def test_decode_token_parity(self, packed_lm_w4):
        """Pallas w4 streaming decode == XLA w4-decompress decode on the
        same packed tree (identical dequantized weights), token for
        token."""
        from repro.models import registry
        from repro.serve.engine import make_decode_step

        cfg, packed = packed_lm_w4
        cfgp = cfg.replace(gemm_impl="pallas")
        tok = jnp.asarray([7])
        n1, _ = jax.jit(make_decode_step(cfg))(
            packed, registry.init_cache(cfg, 1, 8), tok)
        n2, _ = jax.jit(make_decode_step(cfgp))(
            packed, registry.init_cache(cfgp, 1, 8), tok)
        assert int(n1[0]) == int(n2[0])

    def test_no_dense_or_int8_materialization(self, packed_lm_w4):
        """The w4 trace claim is stronger than the int8 one: neither the
        dense [K, N] weight NOR the int8-expanded [K/B·nnz, N] slot
        plane may appear as a traced HBM intermediate — the nibble
        plane expands only inside kernel VMEM."""
        from repro.analysis.materialize import trace_avals
        from repro.core import dbb_linear
        from repro.core.dbb import DbbWeight
        from repro.core.sta import LANE
        from repro.models import registry
        from repro.serve.engine import make_decode_step

        cfg, packed = packed_lm_w4
        tok = jnp.asarray([7], jnp.int32)

        def calls(route_cfg):
            cache = registry.init_cache(route_cfg, 1, 8)
            before = dbb_linear.DECOMPRESS_STATS["calls"]
            jax.eval_shape(make_decode_step(route_cfg), packed, cache,
                           tok)
            return dbb_linear.DECOMPRESS_STATS["calls"] - before

        assert calls(cfg.replace(gemm_impl="pallas")) == 0
        assert calls(cfg.replace(gemm_impl="xla")) > 0   # control

        leaves = [l for l in jax.tree_util.tree_leaves(
            packed, is_leaf=lambda x: isinstance(x, DbbWeight))
            if isinstance(l, DbbWeight)]
        banned = (
            {(l.k_dim, l.n_dim) for l in leaves
             if l.k_dim * l.n_dim > LANE * LANE}
            | {(l.k_dim // l.block * l.nnz, l.n_dim) for l in leaves
               if l.k_dim // l.block * l.nnz * l.n_dim > LANE * LANE})

        def traced(route_cfg):
            cache = registry.init_cache(route_cfg, 1, 8)
            avals = trace_avals(make_decode_step(route_cfg), packed,
                                cache, tok)
            return banned & {tuple(a.shape) for a in avals}

        hit = traced(cfg.replace(gemm_impl="pallas"))
        assert not hit, (
            f"w4 decode step traced dense/int8-expanded weight-shaped "
            f"intermediates: {sorted(hit)}")
        assert traced(cfg.replace(gemm_impl="xla"))      # control

    def test_engine_generate_runs(self, packed_lm_w4):
        """End-to-end smoke: the w4 streaming engine decodes; greedy
        tokens match the XLA w4 engine (same dequantized weights)."""
        from repro.serve.engine import ServeEngine

        cfg, packed = packed_lm_w4
        out_x = ServeEngine(cfg, packed, max_batch=2).generate(
            [[5, 17, 3, 250]], max_new_tokens=3)[0]
        out_p = ServeEngine(cfg.replace(gemm_impl="pallas"), packed,
                            max_batch=2).generate(
            [[5, 17, 3, 250]], max_new_tokens=3)[0]
        assert out_x == out_p


@pytest.fixture(scope="module")
def small_lm():
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestContinuousBatching:
    def test_midstream_admission_matches_solo(self, small_lm):
        """More requests than slots: late requests are admitted into slots
        freed mid-stream and must decode token-identically to solo."""
        from repro.serve.engine import ServeEngine

        cfg, params = small_lm
        eng = ServeEngine(cfg, params, max_batch=2, fetch_chunk=3)
        prompts = [[5, 17, 3], [9, 9, 9, 9, 1, 2], [42, 7, 13, 250, 99],
                   [4, 8], [100, 200, 300]]
        budgets = [6, 3, 8, 5, 4]
        served = eng.serve(prompts, max_new_tokens=budgets)
        for p, bud, got in zip(prompts, budgets, served):
            solo = eng.generate([p], max_new_tokens=bud)[0]
            assert got == solo, (p, got, solo)

    def test_scalar_budget_and_order(self, small_lm):
        from repro.serve.engine import ServeEngine

        cfg, params = small_lm
        eng = ServeEngine(cfg, params, max_batch=4)
        prompts = [[5, 17, 3], [9, 9, 9, 9, 1, 2]]
        served = eng.serve(prompts, max_new_tokens=4)
        batched = eng.generate(prompts, max_new_tokens=4)
        assert served == batched

    def test_generate_chunk_size_invariant(self, small_lm):
        """Chunked device-side fetch must not change the emitted tokens."""
        from repro.serve.engine import ServeEngine

        cfg, params = small_lm
        prompts = [[5, 17, 3], [9, 9, 9, 9, 1, 2]]
        outs = [ServeEngine(cfg, params, max_batch=2, fetch_chunk=fc)
                .generate(prompts, max_new_tokens=7) for fc in (1, 3, 8)]
        assert outs[0] == outs[1] == outs[2]

    def test_decode_unperturbed_by_concurrent_chunk_prefill(self, small_lm):
        """Mid-stream chunked prefill (DESIGN.md §12): a decoding row's
        tokens must be bit-identical whether or not another slot is
        chunk-prefilling a long prompt between its decode steps."""
        from repro.serve.engine import ServeEngine

        cfg, params = small_lm
        eng = ServeEngine(cfg, params, max_batch=2)
        short = [5, 17, 3]
        long = [int(x) % 200 + 2 for x in range(24)]
        # chunk=4: the short prompt admits whole in the first packed call
        # and starts decoding while the long prompt still owes five
        # continuation chunks — every decode step interleaves with one.
        both = eng.serve([short, long], max_new_tokens=[8, 4],
                         prefill_mode="packed", prefill_chunk=4)
        assert both[0] == eng.generate([short], max_new_tokens=8)[0]
        assert both[1] == eng.generate([long], max_new_tokens=4)[0]

    def test_ssm_falls_back_to_waves(self):
        from repro.configs import get_config
        from repro.models import registry
        from repro.serve.engine import ServeEngine

        cfg = get_config("rwkv6-1.6b", smoke=True)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, max_batch=2)
        with pytest.warns(UserWarning, match="continuous batching"):
            out = eng.serve([[4, 8, 15], [16, 23], [42]],
                            max_new_tokens=[3, 2, 4])
        assert [len(o) for o in out] == [3, 2, 4]


class TestGreedyFromHidden:
    def test_skinny_route_matches_xla(self, small_lm):
        from repro.serve.engine import greedy_from_hidden

        cfg, params = small_lm
        h = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model))
        w = jax.random.normal(jax.random.PRNGKey(2),
                              (cfg.d_model, cfg.vocab_size))
        np.testing.assert_array_equal(
            np.asarray(greedy_from_hidden(h, w, impl="pallas")),
            np.asarray(greedy_from_hidden(h, w, impl="xla")))

    def test_large_batch_falls_back(self, small_lm):
        """B > SKINNY_M_MAX: the head GEMV goes to XLA instead of being
        padded into STA tiles."""
        from repro.serve.engine import greedy_from_hidden

        cfg, _ = small_lm
        h = jax.random.normal(jax.random.PRNGKey(3), (48, 1, cfg.d_model))
        w = jax.random.normal(jax.random.PRNGKey(4),
                              (cfg.d_model, cfg.vocab_size))
        np.testing.assert_array_equal(
            np.asarray(greedy_from_hidden(h, w, impl="pallas")),
            np.asarray(greedy_from_hidden(h, w, impl="xla")))
