"""INT4 groupwise DBB weight streaming (DESIGN.md §16).

Format invariants (nibble pack/unpack, footprint math across bit
widths), kernel bit-exactness against the XLA decompress reference on
both w4 routes, dispatch registry behavior (route selection, halved
weight-bytes roofline, int8-activation rejection), and the serving-tree
integration (pack_tree w4 leaves + per-leaf INT8 fallback).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "fast", max_examples=25, deadline=None)
    hypothesis.settings.load_profile("fast")
except ModuleNotFoundError:      # bare container: deterministic fallback
    from _hyp_fallback import given, st

from repro.core.dbb import (INT4_MAX, dbb_footprint_bytes,
                            dense_footprint_bytes, pack_dbb,
                            pack_nibbles, unpack_dbb, unpack_nibbles,
                            validate_dbb)


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


class TestNibblePlane:
    @given(st.integers(0, 20), st.integers(1, 8), st.integers(1, 6))
    def test_roundtrip(self, seed, rows2, n):
        q = np.asarray(jax.random.randint(
            jax.random.PRNGKey(seed), (2 * rows2, n), -INT4_MAX,
            INT4_MAX + 1), np.int8)
        packed = pack_nibbles(jnp.asarray(q))
        assert packed.shape == (rows2, n) and packed.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(unpack_nibbles(packed)),
                                      q)

    def test_full_int4_range(self):
        q = jnp.asarray(np.arange(-8, 8, dtype=np.int8).reshape(16, 1))
        np.testing.assert_array_equal(
            np.asarray(unpack_nibbles(pack_nibbles(q))), np.asarray(q))

    def test_odd_rows_rejected(self):
        with pytest.raises(ValueError):
            pack_nibbles(jnp.zeros((3, 4), jnp.int8))


class TestW4Format:
    @given(st.integers(0, 10), st.integers(1, 4))
    def test_pack_unpack_quant_error_bound(self, seed, gb):
        """unpack(pack(w, bits=4)) equals the groupwise INT4 fake-quant
        of the kept positions: error <= scale/2 per group, zeros exact
        where the (quantized) projection dropped a row."""
        group = 8 * gb
        w = _rand((2 * group, 16), seed)
        p = pack_dbb(w, 8, 4, bits=4, group=group)
        assert p.bits == 4 and p.group == group
        assert p.values.dtype == jnp.int8
        assert p.values.shape == (2 * group // 8 * 4 // 2, 16)
        assert p.scale.shape == (2, 16)
        deq = np.asarray(unpack_dbb(p))
        scale = np.asarray(p.scale)
        # every kept position is within half an INT4 LSB of the dense w
        kept = deq != 0
        err = np.abs(deq - np.asarray(w))
        bound = np.repeat(scale, group, axis=0) * 0.5 + 1e-7
        assert np.all(err[kept] <= bound[kept])
        ok, msg = validate_dbb(p)
        assert ok, msg

    def test_bad_dims_raise(self):
        with pytest.raises(ValueError):
            pack_dbb(_rand((64, 8)), 8, 4, bits=4, group=12)   # % block
        with pytest.raises(ValueError):
            pack_dbb(_rand((64, 8)), 8, 4, bits=4, group=48)   # K % group
        with pytest.raises(ValueError):
            pack_dbb(_rand((8, 8)), 8, 1, bits=4, group=8)     # odd slots
        with pytest.raises(ValueError):
            pack_dbb(_rand((64, 8)), 8, 4, bits=5)

    def test_caller_scale_rejected(self):
        with pytest.raises(ValueError):
            pack_dbb(_rand((64, 8)), 8, 4, bits=4, group=64,
                     scale=jnp.ones((8,)))


class TestFootprint:
    @pytest.mark.parametrize("block,nnz", [(8, 4), (8, 2), (16, 8)])
    @pytest.mark.parametrize("group", [64, 128])
    def test_w4_math(self, block, nnz, group):
        k, n = 1024, 512
        b4 = dbb_footprint_bytes(k, n, block, nnz, itemsize=1,
                                 bits=4, group=group)
        vals = (k // block * nnz + 1) // 2 * n
        mask = k // block * n * ((block + 7) // 8)
        scales = k // group * n * 4
        assert b4 == vals + mask + scales

    def test_int4_under_int8_under_dense(self):
        k, n = 2048, 2048
        dense = dense_footprint_bytes(k, n, 1)
        b8 = dbb_footprint_bytes(k, n, 8, 4, 1)
        b4 = dbb_footprint_bytes(k, n, 8, 4, 1, bits=4, group=128)
        assert b4 < b8 < dense
        # B=8/nnz=4/G=128: 0.25 values + 0.125 mask + 0.03125 scales
        assert b4 / dense == pytest.approx(0.40625)
        assert b8 / b4 == pytest.approx(0.625 / 0.40625)

    def test_config_ratio_matches_format(self):
        from repro.config import DbbConfig
        cfg = DbbConfig(block=8, nnz=4, weight_bits=4, quant_group=128)
        assert cfg.weight_footprint_ratio == pytest.approx(0.40625)


class TestW4Kernels:
    @pytest.mark.parametrize("m,k,n,group", [
        (8, 256, 256, 128),      # skinny route, group nests in K tile
        (8, 256, 256, 256),      # group spans two K tiles
        (64, 256, 384, 64),      # M-tiled route
        (5, 200, 130, 8),        # ragged M/N padding, K padded to group
    ])
    def test_matches_xla_decompress(self, m, k, n, group):
        """Pallas w4 streaming == dense GEMM against the XLA-decompressed
        reference weight — the decompress itself is bit-exact, so the
        only difference is f32 accumulation order."""
        from repro.kernels.dbb_gemm.ops import dbb_gemm_packed

        w = _rand((k, n), seed=m)
        p = pack_dbb(w, 8, 4, bits=4, group=group)
        x = _rand((m, k), seed=m + 1)
        y = dbb_gemm_packed(x, p)
        y_ref = x @ unpack_dbb(p)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_decompress_ref_bit_exact(self):
        """decompress_w4_ref == unpack_dbb on the bitmask plane — the
        XLA oracle the kernel tests and serving decompress both use."""
        from repro.kernels.dbb_gemm.ref import decompress_w4_ref

        p = pack_dbb(_rand((256, 64)), 8, 4, bits=4, group=64)
        ref = decompress_w4_ref(p.values, p.bitmask.astype(jnp.int32),
                                p.scale, block=8, nnz=4, group=64)
        np.testing.assert_array_equal(np.asarray(ref),
                                      np.asarray(unpack_dbb(p)))

    def test_fused_epilogue(self):
        """bias/act fuse on the w4 route exactly like the int8 route."""
        from repro.kernels.dbb_gemm.ops import dbb_gemm_packed

        p = pack_dbb(_rand((256, 128)), 8, 4, bits=4, group=128)
        x = _rand((8, 256), 1)
        bias = _rand((128,), 2)
        y = dbb_gemm_packed(x, p, bias, act="relu")
        y_ref = jnp.maximum(x @ unpack_dbb(p) + bias[None, :], 0.0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


class TestW4Dispatch:
    def _explain(self, m, **kw):
        from repro.kernels import dispatch
        kw.setdefault("dtype", "float32")
        return dispatch.explain("matmul", m=m, k=256, n=512,
                                packed=True, pallas=True, **kw)

    def test_w4_routes_selected(self):
        chosen = [d.name for d in self._explain(8, bits=4, group=128)
                  if d.chosen]
        assert chosen == ["skinny_dbb_w4"]
        chosen = [d.name for d in self._explain(256, bits=4, group=128)
                  if d.chosen]
        assert chosen == ["dbb_packed_w4"]

    def test_int8_routes_reject_w4_and_vice_versa(self):
        ds = {d.name: d for d in self._explain(8, bits=4, group=128)}
        assert not ds["skinny_dbb"].applicable
        assert not ds["dbb_packed"].applicable
        ds = {d.name: d for d in self._explain(8)}
        assert not ds["skinny_dbb_w4"].applicable
        assert not ds["dbb_packed_w4"].applicable

    def test_w4_halves_weight_bytes(self):
        d8 = {d.name: d for d in self._explain(8)}["skinny_dbb"]
        d4 = {d.name: d for d in self._explain(8, bits=4, group=128)
              }["skinny_dbb_w4"]
        assert 0 < d4.weight_bytes < d8.weight_bytes
        # values plane halves; mask and [K/G, N] scales ride on top
        k, n = 256, 512
        assert d4.weight_bytes == pytest.approx(
            k // 8 * 4 * n * 0.5 + k // 8 * n + k // 128 * n * 4)
        assert d4.cost_s < d8.cost_s

    def test_int8_activations_rejected(self):
        ds = {d.name: d for d in self._explain(8, bits=4, group=128,
                                               dtype="int8")}
        assert not ds["skinny_dbb_w4"].applicable
        assert not ds["dbb_packed_w4"].applicable

    def test_weight_bytes_column_in_table(self):
        from repro.kernels import dispatch
        table = dispatch.format_table(self._explain(8, bits=4, group=128))
        assert "wbytes" in table.splitlines()[0]

    def test_xla_route_executes_w4(self):
        from repro.kernels import dispatch
        p = pack_dbb(_rand((256, 64)), 8, 4, bits=4, group=128)
        x = _rand((4, 256), 3)
        y = dispatch.matmul(x, p, pallas=False)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(x @ unpack_dbb(p)),
                                   rtol=1e-5, atol=1e-5)


class TestW4Tree:
    def test_pack_tree_w4_with_fallback(self):
        """Leaves whose K dim fits the group pack at 4 bits; the rest
        fall back to the INT8/float format per-leaf."""
        from repro.config import DbbConfig
        from repro.core.dbb import DbbWeight
        from repro.core.dbb_linear import (decompress_xla, pack_tree,
                                           tree_footprint_bytes)

        cfg = DbbConfig(enabled=True, block=8, nnz=4,
                        apply_to=("mlp",), weight_bits=4,
                        quant_group=128)
        tree = {"mlp": {"wi": {"w": _rand((128, 64))},
                        "wo": {"w": _rand((72, 64))}}}   # 72 % 128 != 0
        out = pack_tree(tree, cfg)
        wi, wo = out["mlp"]["wi"]["w"], out["mlp"]["wo"]["w"]
        assert isinstance(wi, DbbWeight) and wi.bits == 4
        assert wi.indices is None
        assert isinstance(wo, DbbWeight) and wo.bits == 8
        # footprint counts the nibble plane at 1 byte per 2 values
        got = tree_footprint_bytes({"w": wi})
        assert got == dbb_footprint_bytes(128, 64, 8, 4, 1,
                                          bits=4, group=128)
        # XLA decompress reproduces unpack_dbb exactly
        np.testing.assert_array_equal(np.asarray(decompress_xla(wi)),
                                      np.asarray(unpack_dbb(wi)))

    def test_validate_reports_stripped_indices(self):
        p = pack_dbb(_rand((64, 8)), 8, 4, bits=4, group=64)
        import dataclasses
        stripped = dataclasses.replace(p, indices=None)
        ok, msg = validate_dbb(stripped)
        assert not ok and "stripped" in msg

    def test_conv_front_door_decompresses_w4(self):
        """conv never consumes the nibble plane: the front door expands
        w4 leaves to dense before the conv kernels see them."""
        from repro.kernels import dispatch

        k, n = 72, 16                    # 3x3x8 patch dim
        w = _rand((k, n))
        p = pack_dbb(w, 8, 4, bits=4, group=8)
        x = _rand((2, 8, 8, 8), 1)
        w4d = jnp.reshape(unpack_dbb(p), (3, 3, 8, n))
        y = dispatch.conv(x, p, kh=3, kw=3, stride=1, padding="SAME")
        y_ref = jax.lax.conv_general_dilated(
            x, w4d, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
