"""Configuration system for the STA/DBB reproduction framework.

A single dataclass family covers every assigned architecture. Configs are
plain frozen dataclasses so they hash, compare, and round-trip through the
CLI (`--arch <id> --shape <id>`); `repro.configs` registers one builder per
architecture id.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


# ---------------------------------------------------------------------------
# DBB (density-bound block) — the paper's sparse format
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DbbConfig:
    """Density-bound block sparsity config (paper §IV-A).

    block:     block length B along the contraction (K) dimension (paper: 8).
    nnz:       density bound k — max non-zeros per block (paper sweet spot: 4).
    enabled:   master switch; dense models run with enabled=False.
    apply_to:  which weight families get DBB'd. Attention score/value matmuls
               are activation×activation and are never DBB'd (weights only).
    weight_bits: value-plane width for `pack_tree`. 8 = the paper's INT8/
               float deployment; 4 nibble-packs the surviving values with
               groupwise scales (DESIGN.md §16) on every leaf whose K
               divides quant_group (others stay 8-bit packed).
    quant_group: scale-group length G along dense K for weight_bits=4
               (must be a multiple of block).
    """
    block: int = 8
    nnz: int = 4
    enabled: bool = False
    apply_to: Tuple[str, ...] = ("mlp", "attn_proj", "expert")
    weight_bits: int = 8
    quant_group: int = 128

    @property
    def density(self) -> float:
        return self.nnz / self.block

    @property
    def weight_footprint_ratio(self) -> float:
        """Compressed bytes / dense bytes for INT8 weights (paper: 62.5%).

        Per block of B INT8 values: k value bytes + ceil(B/8) bitmask bytes.
        weight_bits=4 halves the value term and adds 4 scale bytes per
        G-group (37.5% + 4/G of dense at B=8/k=4 — DESIGN.md §16).
        """
        mask_bytes = (self.block + 7) // 8
        if self.weight_bits == 4 and self.quant_group > 0:
            return ((self.nnz * 0.5 + mask_bytes) / self.block
                    + 4.0 / self.quant_group)
        return (self.nnz + mask_bytes) / self.block


# ---------------------------------------------------------------------------
# STA tensor-PE geometry (paper §III-B) — drives Pallas block shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StaConfig:
    """A×B×C tensor-PE geometry mapped onto Pallas GEMM tiling.

    The paper's A×B×C_MxN: M×N systolic grid of tensor PEs, each an A×C array
    of B-input dot-product units. On TPU this becomes block tiling:
      bm = A * m_tiles, bk = B * k_unroll, bn = C * n_tiles
    with the accumulator tile output-stationary in VMEM scratch.
    """
    a: int = 4
    b: int = 8
    c: int = 4
    # Pallas block shape (bm, bk, bn) for the GEMM kernels; MXU-aligned.
    block_m: int = 128
    block_k: int = 128
    block_n: int = 128

    def macs_per_pe(self) -> int:
        return self.a * self.b * self.c


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuantConfig:
    enabled: bool = False
    weight_dtype: str = "int8"      # int8 symmetric per-channel
    accumulator_dtype: str = "int32"


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoeConfig:
    num_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # Arctic has a dense residual MLP in parallel with the MoE FFN.
    dense_residual_ff: int = 0
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    impl: str = "auto"  # auto | dense | ep  (dense one-hot vs expert-parallel)


@dataclass(frozen=True)
class SsmConfig:
    state_size: int = 64           # mamba2 N / rwkv head size
    head_dim: int = 64
    expand: int = 2                # d_inner = expand * d_model (mamba2)
    conv_width: int = 4            # mamba2 local conv
    chunk: int = 128               # chunked-scan block length
    # zamba2: one shared attention block applied every `shared_period` layers
    shared_period: int = 6
    shared_window: int = 4096      # sliding window for shared attn at long ctx


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense_lm"   # dense_lm | moe_lm | rwkv6 | zamba2 | vlm_lm | audio_lm | cnn
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0               # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 8192
    # layer details
    norm: str = "rmsnorm"           # rmsnorm | layernorm | nonparam_ln (olmo)
    act: str = "silu"               # silu (swiglu) | gelu (geglu/gelu-mlp)
    mlp_gated: bool = True
    qkv_bias: bool = False          # qwen2.5 uses QKV bias
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rope: bool = True
    # modality frontends (stubs): number of prefix embedding positions
    prefix_embed_len: int = 0       # paligemma: 256 SigLIP patches
    embeds_input: bool = False      # musicgen/paligemma: frontend supplies embeds
    # sub-configs
    moe: MoeConfig = field(default_factory=MoeConfig)
    ssm: SsmConfig = field(default_factory=SsmConfig)
    dbb: DbbConfig = field(default_factory=DbbConfig)
    sta: StaConfig = field(default_factory=StaConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # GEMM routing: "xla" = plain matmuls (GSPMD-shardable, default);
    # "pallas" = hot GEMMs go through the STA/DBB Pallas kernels with the
    # fused bias/activation/requant epilogue (DESIGN.md §7) — on a single
    # device, or per-shard inside the TP serving wrap's shard_map bodies
    # (DESIGN.md §14), where every operand is shard-local and the kernels
    # apply unchanged. Only *global* GSPMD graphs under a live mesh still
    # fall back to "xla" (the kernels are not GSPMD-partitionable).
    gemm_impl: str = "xla"
    # kernel route overrides (DESIGN.md §11): (domain, route) pairs pinning
    # a `kernels.dispatch` registry route per domain, e.g.
    # (("matmul", "skinny_sta"), ("attention", "attn_naive")). Tuple-of-
    # pairs (not a dict) so the frozen config stays hashable. Precedence:
    # REPRO_FORCE_ROUTE env var > kernel_routes > auto (guard + roofline
    # cost). A pinned route whose guard rejects an op falls back to auto
    # with a warning — overrides pick among legal kernels, never bypass
    # correctness guards.
    kernel_routes: Tuple[Tuple[str, str], ...] = ()
    remat: str = "auto"             # auto | none | full — auto picks by size
    # distribution: "tp" = tensor-parallel over the model axis;
    # "dp" = the model axis joins batch parallelism (params replicated +
    # ZeRO/FSDP) — the right layout for d_model <~ 2048 where TP boundary
    # collectives dwarf the per-shard compute (§Perf iteration 12)
    parallel: str = "tp"
    # attention backend (DESIGN.md §10):
    # "flash"   = fused Pallas flash kernel (online softmax, no [B,H,T,T]
    #             score tensor); floats only. Single device or per-shard
    #             under the TP serving wrap (DESIGN.md §14).
    # "chunked" = blocked XLA path with running-softmax combine.
    # "naive"   = quadratic oracle (full score bias materialized).
    # "auto"    = flash when the Pallas route is active (gemm_impl="pallas"
    #             on one device or inside a TP shard body), else
    #             chunked/naive by sequence length.
    attn_impl: str = "auto"         # auto | naive | chunked | flash
    attn_chunk: int = 1024
    sliding_window: int = 0         # 0 = full causal
    attn_logit_softcap: float = 0.0
    # paged KV cache (DESIGN.md §10): page size in cache slots. 0 keeps the
    # contiguous per-slot cache; > 0 lets ServeEngine.serve() admit requests
    # by pages actually used (block-table decode) instead of reserving
    # max_len per slot, and sizes the flash decode kernel's KV tiles.
    kv_page_size: int = 0
    # cnn family (paper's own models)
    cnn_channels: Tuple[int, ...] = ()
    cnn_kernel: int = 3
    cnn_classes: int = 10
    cnn_img: int = 32
    cnn_in_ch: int = 3

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "rwkv6"

    @property
    def supports_long_context(self) -> bool:
        """long_500k eligibility: SSM / hybrid families only (DESIGN.md §4)."""
        return self.family in ("rwkv6", "zamba2")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        if self.family == "cnn":
            n, cin, k = 0, self.cnn_in_ch, self.cnn_kernel
            for cout in self.cnn_channels:
                n += cin * cout * k * k + cout
                cin = cout
            img = self.cnn_img // (2 ** len(self.cnn_channels))
            n += cin * img * img * self.cnn_classes
            return n
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.family == "rwkv6":
            # r,k,v,g,o projections + decay lora + channel mix (approx., see models/rwkv6.py)
            per_layer = 5 * d * d + 2 * d * f + 2 * d * 96
        else:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            attn = q + kv + o
            if self.family in ("moe_lm",):
                ff = self.moe.num_experts * (3 if self.mlp_gated else 2) * d * f
                ff += d * self.moe.num_experts  # router
                if self.moe.dense_residual_ff:
                    ff += (3 if self.mlp_gated else 2) * d * self.moe.dense_residual_ff
            else:
                ff = (3 if self.mlp_gated else 2) * d * f
            per_layer = attn + ff
            if self.family == "zamba2":
                di = self.ssm.expand * d
                mamba = d * 2 * di + di * d + di * (self.ssm.conv_width + 3)
                per_layer = mamba + ff // max(1, self.num_layers)  # rough; exact in model
        return n + self.num_layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if self.family != "moe_lm" or not self.moe.num_experts:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        dense = self.param_count()
        per_expert = (3 if self.mlp_gated else 2) * d * f
        inactive = (self.moe.num_experts - self.moe.top_k) * per_expert * L
        return dense - inactive


# ---------------------------------------------------------------------------
# Input shapes (the four assigned cells per arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not model.supports_long_context:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{model.name} is a pure full-attention arch (skip per brief)")
    return True, ""


# ---------------------------------------------------------------------------
# Mesh / training / serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1            # grad-accumulation microbatches (scan)
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"         # adamw | adafactor | sgd
    grad_compress: str = "none"      # none | bf16 | int8_ef
    seed: int = 0
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    log_every: int = 10
    # DBB pruning schedule
    dbb_prune_start: int = 0
    dbb_prune_ramp: int = 0          # steps to ramp density bound down


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq_len: int = 2048
    prefill_chunk: int = 512
    eos_id: int = 1
    temperature: float = 0.0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)
