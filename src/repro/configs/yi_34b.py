"""yi-34b [dense] — llama-architecture GQA [arXiv:2403.04652]."""
from repro.config import DbbConfig, ModelConfig

ARCH = "yi-34b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense_lm",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=20480, vocab_size=64000,
        norm="rmsnorm", act="silu", mlp_gated=True, qkv_bias=False,
        rope=True, rope_theta=5_000_000.0,
        dbb=DbbConfig(enabled=True, block=8, nnz=4),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32", remat="none",
    )
