"""zamba2-1.2b [hybrid] — Mamba2 backbone + one shared attention block
[arXiv:2411.15242]. Runs long_500k (Mamba2 state + sliding-window shared
attention)."""
from repro.config import DbbConfig, ModelConfig, SsmConfig

ARCH = "zamba2-1.2b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="zamba2",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        norm="rmsnorm", act="gelu", mlp_gated=True, rope=True,
        ssm=SsmConfig(state_size=64, head_dim=64, expand=2, conv_width=4,
                      chunk=128, shared_period=6, shared_window=4096),
        dbb=DbbConfig(enabled=True, block=8, nnz=4),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=512, dtype="float32", remat="none",
        ssm=SsmConfig(state_size=16, head_dim=32, expand=2, conv_width=4,
                      chunk=16, shared_period=2, shared_window=64),
    )
