"""rwkv6-1.6b [ssm] — "Finch", attention-free, data-dependent decay
[arXiv:2404.05892]. Runs long_500k (O(1) state)."""
from repro.config import DbbConfig, ModelConfig, SsmConfig

ARCH = "rwkv6-1.6b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="rwkv6",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=7168, vocab_size=65536,
        norm="layernorm", act="relu",   # squared-relu channel mix (in-model)
        mlp_gated=False, rope=False,
        ssm=SsmConfig(head_dim=64, chunk=32),
        dbb=DbbConfig(enabled=True, block=8, nnz=4),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32", remat="none",
        ssm=SsmConfig(head_dim=64, chunk=16),
    )
