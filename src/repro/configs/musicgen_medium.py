"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284]. The EnCodec frontend is a STUB per the brief:
``input_specs()`` supplies precomputed frame embeddings (embeds_input=True);
the backbone is the standard MusicGen decoder (MHA, LayerNorm, GeLU MLP)."""
from repro.config import DbbConfig, ModelConfig

ARCH = "musicgen-medium"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="audio_lm",
        num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
        d_ff=6144, vocab_size=2048,
        norm="layernorm", act="gelu", mlp_gated=False, qkv_bias=False,
        rope=True,                      # positional mechanism (adaptation:
        embeds_input=True,              # sinusoidal → RoPE, DESIGN.md §2)
        dbb=DbbConfig(enabled=True, block=8, nnz=4),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=256, dtype="float32", remat="none",
    )
