"""Architecture registry: ``--arch <id>`` → ModelConfig.

Each module defines ``ARCH`` (the public id), ``full()`` (the exact published
config from the brief) and ``smoke()`` (a reduced same-family config that runs
a forward/train step on CPU). `get_config` is the single lookup used by the
launchers, the dry-run, tests and benchmarks.
"""
from __future__ import annotations

from typing import Dict, List

from repro.config import ModelConfig, SHAPES, ShapeSpec, shape_applicable

from repro.configs import (
    arctic_480b,
    convnet_dbb,
    kimi_k2_1t,
    lenet5_dbb,
    musicgen_medium,
    olmo_1b,
    paligemma_3b,
    qwen2_5_14b,
    rwkv6_1b6,
    starcoder2_15b,
    yi_34b,
    zamba2_1b2,
)

__all__ = ["ARCHS", "ASSIGNED", "get_config", "arch_ids", "SHAPES",
           "ShapeSpec", "shape_applicable"]

_MODULES = (
    qwen2_5_14b, olmo_1b, yi_34b, starcoder2_15b, musicgen_medium,
    rwkv6_1b6, zamba2_1b2, paligemma_3b, arctic_480b, kimi_k2_1t,
    convnet_dbb, lenet5_dbb,
)

ARCHS: Dict[str, object] = {m.ARCH: m for m in _MODULES}

# The ten assigned LM-family architectures (40 dry-run cells); the CNN
# configs are the paper's own models, exercised by the Table I/Fig. 4 paths.
ASSIGNED: List[str] = [
    "qwen2.5-14b", "olmo-1b", "yi-34b", "starcoder2-15b", "musicgen-medium",
    "rwkv6-1.6b", "zamba2-1.2b", "paligemma-3b", "arctic-480b",
    "kimi-k2-1t-a32b",
]


def arch_ids() -> List[str]:
    return list(ARCHS)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = ARCHS[arch]
    return mod.smoke() if smoke else mod.full()
