"""qwen2.5-14b [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5 family]."""
from repro.config import DbbConfig, ModelConfig

ARCH = "qwen2.5-14b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense_lm",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=13824, vocab_size=152064,
        norm="rmsnorm", act="silu", mlp_gated=True, qkv_bias=True,
        rope=True, rope_theta=1_000_000.0,
        dbb=DbbConfig(enabled=True, block=8, nnz=4),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32", remat="none",
    )
