"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base]. dense_residual_ff=4864 mirrors the
expert hidden size (gives the published ~480B total)."""
from repro.config import DbbConfig, ModelConfig, MoeConfig

ARCH = "arctic-480b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe_lm",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        norm="rmsnorm", act="silu", mlp_gated=True, qkv_bias=False,
        rope=True,
        moe=MoeConfig(num_experts=128, top_k=2, capacity_factor=1.25,
                      dense_residual_ff=4864),
        dbb=DbbConfig(enabled=True, block=8, nnz=4,
                      apply_to=("mlp", "attn_proj", "expert")),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, dtype="float32", remat="none",
        moe=MoeConfig(num_experts=8, top_k=2, capacity_factor=1.25,
                      dense_residual_ff=128),
    )
