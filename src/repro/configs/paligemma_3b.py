"""paligemma-3b [vlm] — SigLIP + Gemma backbone [arXiv:2407.07726].
The SigLIP vision tower is a STUB per the brief: ``input_specs()`` supplies
256 precomputed patch embeddings as a prefix (prefix_embed_len)."""
from repro.config import DbbConfig, ModelConfig

ARCH = "paligemma-3b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm_lm",
        num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
        head_dim=256, d_ff=16384, vocab_size=257216,
        norm="rmsnorm", act="gelu", mlp_gated=True, qkv_bias=False,
        tie_embeddings=True, rope=True,
        prefix_embed_len=256,
        dbb=DbbConfig(enabled=True, block=8, nnz=4),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512, prefix_embed_len=16,
        dtype="float32", remat="none",
    )
