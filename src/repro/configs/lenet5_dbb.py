"""LeNet-5 (MNIST, Table I row 1) as an im2col-GEMM CNN with DBB weights."""
from repro.config import DbbConfig, ModelConfig, QuantConfig

ARCH = "lenet5-dbb"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="cnn",
        cnn_channels=(6, 16), cnn_kernel=5, cnn_classes=10,
        cnn_img=28, cnn_in_ch=1, dtype="float32", param_dtype="float32",
        dbb=DbbConfig(enabled=True, block=8, nnz=2,   # Table I: 25% NNZ
                      apply_to=("conv",)),
        quant=QuantConfig(enabled=True),
    )


def smoke() -> ModelConfig:
    return full().replace(cnn_channels=(4, 8), cnn_img=16)
