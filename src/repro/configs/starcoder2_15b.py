"""starcoder2-15b [dense] — GQA kv=4, RoPE, LayerNorm + plain GeLU MLP with
biases [arXiv:2402.19173]."""
from repro.config import DbbConfig, ModelConfig

ARCH = "starcoder2-15b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense_lm",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
        d_ff=24576, vocab_size=49152,
        norm="layernorm", act="gelu", mlp_gated=False, qkv_bias=True,
        rope=True, rope_theta=100_000.0, sliding_window=4096,
        dbb=DbbConfig(enabled=True, block=8, nnz=4),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=1, d_ff=512,
        vocab_size=512, sliding_window=0, dtype="float32", remat="none",
    )
