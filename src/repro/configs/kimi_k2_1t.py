"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 + one
shared expert (expressed as dense_residual_ff) [paper-table; unverified]."""
from repro.config import DbbConfig, ModelConfig, MoeConfig

ARCH = "kimi-k2-1t-a32b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe_lm",
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        head_dim=112, d_ff=2048, vocab_size=163840,
        norm="rmsnorm", act="silu", mlp_gated=True, qkv_bias=False,
        rope=True,
        moe=MoeConfig(num_experts=384, top_k=8, capacity_factor=1.25,
                      dense_residual_ff=2048),
        dbb=DbbConfig(enabled=True, block=8, nnz=4,
                      apply_to=("mlp", "attn_proj", "expert")),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=512, dtype="float32", remat="none",
        moe=MoeConfig(num_experts=8, top_k=2, capacity_factor=1.25,
                      dense_residual_ff=128),
    )
