"""The paper's own 5-layer ConvNet (CIFAR10, Table I row 2), convs lowered to
GEMM via im2col so DBB runs along the GEMM contraction dim."""
from repro.config import DbbConfig, ModelConfig, QuantConfig

ARCH = "convnet-dbb"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="cnn",
        cnn_channels=(64, 128, 256), cnn_kernel=3, cnn_classes=10,
        cnn_img=32, cnn_in_ch=3, dtype="float32", param_dtype="float32",
        dbb=DbbConfig(enabled=True, block=8, nnz=2,   # Table I: 25% NNZ
                      apply_to=("conv",)),
        quant=QuantConfig(enabled=True),
    )


def smoke() -> ModelConfig:
    return full().replace(cnn_channels=(16, 32), cnn_img=16)
