"""olmo-1b [dense] — non-parametric LayerNorm, MHA, tied embeddings
[arXiv:2402.00838]."""
from repro.config import DbbConfig, ModelConfig

ARCH = "olmo-1b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense_lm",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=50304,
        norm="nonparam_ln", act="silu", mlp_gated=True, qkv_bias=False,
        tie_embeddings=True, rope=True,
        dbb=DbbConfig(enabled=True, block=8, nnz=4),
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=512, dtype="float32", remat="none",
    )
