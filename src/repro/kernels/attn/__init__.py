"""Flash-style fused attention kernels + paged-KV decode (DESIGN.md §10)."""
from repro.kernels.attn.ops import (DEFAULT_PAGE, PACKED_PAD_SEG,
                                    flash_attention, flash_ok,
                                    identity_block_table,
                                    packed_flash_attention,
                                    paged_decode_attention, paged_decode_ok)

__all__ = ["flash_attention", "packed_flash_attention",
           "paged_decode_attention", "flash_ok", "paged_decode_ok",
           "identity_block_table", "DEFAULT_PAGE", "PACKED_PAD_SEG"]
