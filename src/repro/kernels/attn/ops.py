"""Public wrappers for the flash-attention kernels (DESIGN.md §10).

`flash_attention` takes the model layout (``q [B, T, Hq, D]``,
``k/v [B, S, Hkv, D]``), transposes to the kernel's head-major layout,
pads T/S to the block grid (padded KV slots sit at absolute positions
``>= S`` and are causally unreachable from any real query; padded query
rows are sliced off), and dispatches. Block shapes default to a VMEM-aware
heuristic; with ``REPRO_AUTOTUNE=1`` the measured autotuner picks them
under the ``attn_flash`` op tag with `m_bucket()`-bucketed T keys (decode
and prefill sequence lengths never share an entry, mirroring the GEMM
wrappers).

`flash_ok` is the VMEM guard: callers (``models.attention``) fall back to
the chunked XLA path when even the smallest legal block pair would not
fit — the kernel never partially materializes.

`paged_decode_attention` wraps the block-table decode kernel; a contiguous
cache is served by the same wrapper through an identity block table
(`identity_block_table`), which is what makes paged-vs-contiguous decode
bit-identical: one kernel, one page-visit order, only the physical page
layout differs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sta import SUBLANE
from repro.kernels.attn.kernel import (flash_prefill_packed_pallas,
                                       flash_prefill_pallas,
                                       paged_decode_pallas)
from repro.kernels.attn.ref import (flash_prefill_ref, packed_prefill_ref,
                                    paged_decode_ref)
from repro.kernels.common import (KERNEL_VMEM_BUDGET, default_interpret,
                                  round_up)

__all__ = ["flash_attention", "packed_flash_attention",
           "paged_decode_attention", "flash_ok", "paged_decode_ok",
           "identity_block_table", "DEFAULT_PAGE", "PACKED_PAD_SEG"]

# segment-id sentinel for packed-batch padding tokens: larger than any real
# segment, so pad rows match nothing and the non-decreasing block-skip
# invariant holds (DESIGN.md §12)
PACKED_PAD_SEG = 2 ** 30

# default KV page size (slots) when the config leaves kv_page_size unset —
# one f32 page of 64 slots × 128 head dim is half an MXU tile per head
DEFAULT_PAGE = 64


def _footprint(bq: int, bkv: int, d: int, itemsize: int) -> int:
    """Prefill VMEM working set: q/k/v tiles + score tile + (m, l, acc)
    f32 scratch."""
    return ((bq * d + 2 * bkv * d) * itemsize
            + bq * bkv * 4 + bq * d * 4 + 2 * bq * 128 * 4)


def _heuristic_blocks(t: int, s: int, d: int, itemsize: int
                      ) -> Tuple[int, int]:
    bq = min(128, round_up(max(t, 1), SUBLANE))
    bkv = min(128, round_up(max(s, 1), SUBLANE))
    while (_footprint(bq, bkv, d, itemsize) > KERNEL_VMEM_BUDGET
           and bkv > SUBLANE):
        bkv //= 2
    while (_footprint(bq, bkv, d, itemsize) > KERNEL_VMEM_BUDGET
           and bq > SUBLANE):
        bq //= 2
    return bq, bkv


def flash_ok(t: int, s: int, d: int, itemsize: int) -> bool:
    """Whether the flash kernel applies: the minimal legal block pair fits
    the VMEM budget (it always does for transformer head dims; a pathologic
    head_dim opts back into the chunked XLA path)."""
    return _footprint(SUBLANE, SUBLANE, d, itemsize) <= KERNEL_VMEM_BUDGET


def paged_decode_ok(page: int, d: int, itemsize: int) -> bool:
    """VMEM guard for the decode kernel: the page is its KV tile size, and
    unlike the prefill blocks it comes straight from user config
    (``kv_page_size`` / ``--kv-page-size``), so an oversized page must be
    rejected up front (contiguous decode falls back to the XLA path; the
    paged engine refuses at pool construction) rather than failing in the
    Mosaic lowering mid-serving. Budgeted at the worst-case resident query
    block (SKINNY_M_MAX rows)."""
    from repro.kernels.common import SKINNY_M_MAX
    return _footprint(round_up(SKINNY_M_MAX, SUBLANE), page, d,
                      itemsize) <= KERNEL_VMEM_BUDGET


def _autotuned_blocks(t: int, s: int, d: int, dtype, window: int,
                      softcap: float, interpret: bool, measure: bool
                      ) -> Tuple[int, int]:
    """Measured (block_q, block_kv) under the ``attn_flash`` op tag.
    Candidates are the heuristic choice and its half/double neighborhood,
    VMEM-filtered; (bq, d, bkv) triples reuse the GEMM cache machinery
    (m = T is bucketed, so decode-shaped and prefill-shaped calls keep
    distinct entries)."""
    import numpy as np

    from repro.kernels import autotune

    itemsize = np.dtype(dtype).itemsize
    bq0, bkv0 = _heuristic_blocks(t, s, d, itemsize)
    cands = []
    for fq in (1.0, 0.5, 2.0):
        for fkv in (1.0, 0.5, 2.0):
            bq = max(SUBLANE, min(int(bq0 * fq), round_up(max(t, 1), SUBLANE)))
            bkv = max(SUBLANE, min(int(bkv0 * fkv),
                                   round_up(max(s, 1), SUBLANE)))
            bq, bkv = round_up(bq, SUBLANE), round_up(bkv, SUBLANE)
            c = (bq, d, bkv)
            if c not in cands and _footprint(bq, bkv, d, itemsize) \
                    <= KERNEL_VMEM_BUDGET:
                cands.append(c)
    if not cands:
        cands = [(bq0, d, bkv0)]

    def make_fn(shape):
        bq, _, bkv = shape
        rng = np.random.default_rng(0)
        tp, sp = round_up(t, bq), round_up(s, bkv)
        q = jnp.asarray(rng.standard_normal((1, 1, tp, d)), dtype)
        k = jnp.asarray(rng.standard_normal((1, 1, sp, d)), dtype)
        v = jnp.asarray(rng.standard_normal((1, 1, sp, d)), dtype)
        return lambda: flash_prefill_pallas(
            q, k, v, sm_scale=1.0 / math.sqrt(d), window=window,
            softcap=softcap, block_q=bq, block_kv=bkv, interpret=interpret)

    name = "attn_flash" + ("_interp" if interpret else "")
    tag = f"w{1 if window > 0 else 0}+sc{1 if softcap > 0 else 0}"
    bq, _, bkv = autotune.autotune_block_shape(
        name, t, d, s, dtype, make_fn, epilogue_tag=tag,
        candidates=cands, itemsize=itemsize, measure=measure)
    return bq, bkv


def flash_attention(
    q: jax.Array,                 # [B, T, Hq, D] (model layout)
    k: jax.Array,                 # [B, S, Hkv, D]
    v: jax.Array,                 # [B, S, Hkv, D]
    start: Optional[jax.Array] = None,    # [B] int32 — first real key slot
    *,
    q_offset: Optional[jax.Array] = None,  # [B] int32 — abs pos of q row 0
    sm_scale: Optional[float] = None,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 0,
    block_kv: int = 0,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    autotune: Optional[bool] = None,
) -> jax.Array:
    """Causal flash attention, model layout in/out ([B, T, Hq, D]).

    start [B]: absolute index of the first real key per row (left-padded
    ragged batches, DESIGN.md §5); keys below it are masked and queries
    below it produce garbage rows the caller already ignores. The mask is
    _mask_bias's qpos/kpos convention in absolute coordinates.

    q_offset [B]: absolute key-slot position of query row 0 — lets a
    chunked-prefill continuation (T chunk rows, S cache slots, DESIGN.md
    §12) reuse the same kernel; defaults to 0 (self-attention prefill).
    """
    b, t, hq, d = q.shape
    s_len = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    if interpret is None:
        interpret = default_interpret()
    start2 = (None if start is None
              else jnp.asarray(start, jnp.int32).reshape(b, 1))
    qoff2 = (None if q_offset is None
             else jnp.asarray(q_offset, jnp.int32).reshape(b, 1))
    qh = jnp.moveaxis(q, 2, 1)                          # [B, Hq, T, D]
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    if not use_kernel:
        o = flash_prefill_ref(qh, kh, vh, start2, qoff2, sm_scale=sm_scale,
                              window=window, softcap=softcap)
        return jnp.moveaxis(o, 1, 2)

    if block_q and block_kv:
        bq, bkv = block_q, block_kv
    else:
        if autotune is None:
            from repro.kernels.autotune import autotune_enabled
            autotune = autotune_enabled()
        if autotune:
            measure = not isinstance(q, jax.core.Tracer)
            bq, bkv = _autotuned_blocks(t, s_len, d, q.dtype, window,
                                        softcap, interpret, measure)
        else:
            bq, bkv = _heuristic_blocks(t, s_len, d, q.dtype.itemsize)
    tp, sp = round_up(t, bq), round_up(s_len, bkv)
    if tp != t:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    if sp != s_len:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, sp - s_len), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, sp - s_len), (0, 0)))
    o = flash_prefill_pallas(qh, kh, vh, start2, qoff2, sm_scale=sm_scale,
                             window=window, softcap=softcap, block_q=bq,
                             block_kv=bkv, interpret=interpret)
    return jnp.moveaxis(o[:, :, :t], 1, 2)


def packed_flash_attention(
    q: jax.Array,                 # [T, Hq, D] — packed model layout
    k: jax.Array,                 # [T, Hkv, D]
    v: jax.Array,                 # [T, Hkv, D]
    seg_ids: jax.Array,           # [T] int32, non-decreasing segment ids
    *,
    sm_scale: Optional[float] = None,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 0,
    block_kv: int = 0,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Block-diagonal-causal flash attention over a PACKED ragged batch
    (DESIGN.md §12): T = total tokens of all concatenated requests,
    ``seg_ids[t]`` names the owning request. No query crosses a segment
    boundary and no pad row reaches a GEMM with real weight — pad tokens
    are re-labelled `PACKED_PAD_SEG` here, so even caller-supplied pad ids
    can't collide with a real segment. Returns [T, Hq, D] in q.dtype;
    rows whose mask is empty (padding) hold garbage the caller never
    gathers."""
    t, hq, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    if interpret is None:
        interpret = default_interpret()
    seg_ids = jnp.asarray(seg_ids, jnp.int32).reshape(1, t)
    qh = jnp.moveaxis(q, 1, 0)                          # [Hq, T, D]
    kh = jnp.moveaxis(k, 1, 0)
    vh = jnp.moveaxis(v, 1, 0)
    if not use_kernel:
        o = packed_prefill_ref(qh, kh, vh, seg_ids[0], sm_scale=sm_scale,
                               window=window, softcap=softcap)
        return jnp.moveaxis(o, 0, 1)

    if block_q and block_kv:
        bq, bkv = block_q, block_kv
    else:
        bq, bkv = _heuristic_blocks(t, t, d, q.dtype.itemsize)
        bq = bkv = min(bq, bkv)    # one padded T must serve both grids
    lcm = bq * bkv // math.gcd(bq, bkv)
    tp = round_up(t, lcm)
    if tp != t:
        pad = ((0, 0), (0, tp - t), (0, 0))
        qh, kh, vh = jnp.pad(qh, pad), jnp.pad(kh, pad), jnp.pad(vh, pad)
        seg_ids = jnp.pad(seg_ids, ((0, 0), (0, tp - t)),
                          constant_values=PACKED_PAD_SEG)
    o = flash_prefill_packed_pallas(qh, kh, vh, seg_ids, sm_scale=sm_scale,
                                    window=window, softcap=softcap,
                                    block_q=bq, block_kv=bkv,
                                    interpret=interpret)
    return jnp.moveaxis(o[:, :t], 0, 1)


def identity_block_table(b: int, n_log: int) -> jax.Array:
    """Block table mapping row ``b``'s logical page ``j`` to physical page
    ``b * n_log + j`` — a contiguous [B, S, H, D] cache reshaped to
    [B · n_log, page, H, D] is exactly this layout."""
    return (jnp.arange(b, dtype=jnp.int32)[:, None] * n_log
            + jnp.arange(n_log, dtype=jnp.int32)[None, :])


def paged_decode_attention(
    q: jax.Array,                 # [B, Hkv, G, D]
    k_pages: jax.Array,           # [P, page, Hkv, D]
    v_pages: jax.Array,           # [P, page, Hkv, D]
    block_table: jax.Array,       # [B, n_log] int32
    lengths: jax.Array,           # [B] int32
    start: Optional[jax.Array] = None,    # [B] int32
    *,
    sm_scale: Optional[float] = None,
    window: int = 0,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> jax.Array:
    """One-token decode over a paged (or identity-table contiguous) KV
    cache. Query rows (the GQA group, G ≤ 32 — `skinny_ok` gates upstream)
    pad to the sublane quantum; pad rows are sliced off."""
    b, hkv, g, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    if interpret is None:
        interpret = default_interpret()
    if start is None:
        start = jnp.zeros((b,), jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    if not use_kernel:
        return paged_decode_ref(q, k_pages, v_pages, block_table, lengths,
                                start, sm_scale=sm_scale, window=window,
                                softcap=softcap)
    gp = round_up(g, SUBLANE)
    qp = q if gp == g else jnp.pad(q, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    o = paged_decode_pallas(qp, k_pages, v_pages,
                            jnp.asarray(block_table, jnp.int32), lengths,
                            start, sm_scale=sm_scale, window=window,
                            softcap=softcap, interpret=interpret)
    return o[:, :, :g]
