"""Flash-style fused attention Pallas kernels (DESIGN.md §10).

Attention is two chained GEMMs (QKᵀ and PV) around a softmax; the paper's
thesis — blocked operand reuse inside a tiled datapath (STA §III) — applies
to it exactly as to the MLP GEMMs. These kernels keep the whole
score→softmax→context chain on-chip:

* **prefill** (`flash_prefill_pallas`): blocks over the KV sequence with an
  *online softmax* — running (m, l, acc) statistics live in VMEM scratch
  across the KV grid dimension, so the ``[B, H, T, S]`` score tensor never
  exists in HBM (or anywhere: only one ``[block_q, block_kv]`` tile is ever
  live). Causal + sliding-window + left-pad masking uses the same
  qpos/kpos offset convention as ``models.attention._mask_bias``: logical
  positions are ``absolute - start[b]``, and since both q and k shift by
  the same per-row ``start``, the causal/window structure is invariant in
  absolute coordinates — only the pad mask (``kpos >= 0`` ⇔
  ``k_abs >= start[b]``) depends on it. Blocks entirely above the causal
  diagonal or entirely outside the window are skipped (`pl.when`).

* **decode** (`paged_decode_pallas`): M = GQA group size query rows
  (M ≤ 32 — the skinny regime, `kernels.common.skinny_ok`) stay resident
  while KV streams through the K loop in fixed-size **pages** gathered via
  a per-row **block table** (scalar-prefetched, so the table lookup drives
  the DMA index map — the physical page layout in HBM is arbitrary). A
  contiguous cache is the special case of an identity block table, which
  is how `decode_attention_apply` reuses this kernel (DESIGN.md §10).

Numerics match the chunked XLA path in `models.attention`: scores
accumulate in f32 on the MXU (operands stay in storage dtype), the
optional logit softcap applies before masking, probabilities are cast to
the V storage dtype for the PV matmul with f32 accumulation, and the
final normalization divides by ``max(l, 1e-30)``.

Shape contract (pad at the ops layer):
    prefill: q [B, Hq, T, D], k/v [B, Hkv, S, D], start [B, 1] int32,
             T % block_q == 0, S % block_kv == 0, Hq % Hkv == 0
    decode:  q [B, Hkv, G, D], k/v pages [P, page, Hkv, D],
             block table [B, n_log] int32, lengths/start [B] int32
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import CompilerParams, pltpu

__all__ = ["flash_prefill_pallas", "flash_prefill_packed_pallas",
           "paged_decode_pallas", "NEG_INF"]

NEG_INF = -1e30          # same sentinel as models.attention._mask_bias
_L_EPS = 1e-30           # matches the chunked path's combine guard


def _softcap(s: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(s / cap) if cap > 0 else s


def _online_update(s, v, m_ref, l_ref, acc_ref):
    """One online-softmax step: fold the masked score tile ``s`` [M, Skv]
    and value tile ``v`` [Skv, D] into the running (m, l, acc) scratch."""
    m_prev = m_ref[:, :1]                               # [M, 1]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)                     # [M, 1]
    p = jnp.exp(s - m_cur)                              # [M, Skv]
    l_cur = l_ref[:, :1] * alpha + p.sum(axis=-1, keepdims=True)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _flash_prefill_kernel(q_ref, k_ref, v_ref, start_ref, qoff_ref, o_ref,
                          m_ref, l_ref, acc_ref, *, n_kv: int, block_q: int,
                          block_kv: int, sm_scale: float, window: int,
                          softcap: float, out_dtype):
    i = pl.program_id(2)
    j = pl.program_id(3)
    qoff = qoff_ref[0, 0]        # chunked-prefill continuation offset (§12)
    qi0 = qoff + i * block_q
    kj0 = j * block_kv

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block skip: any (qpos, kpos) pair alive ⇔ kj_min <= qi_max (causal,
    # start-invariant in absolute coordinates), kj_max inside the window,
    # and kj_max past the row's left padding (fully-pad blocks of a ragged
    # batch contribute nothing — the alpha washout would discard them)
    run = kj0 <= qi0 + block_q - 1
    run &= kj0 + block_kv - 1 >= start_ref[0, 0]
    if window > 0:
        run &= kj0 + block_kv - 1 > qi0 - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0]                                 # [bq, D]
        k = k_ref[0, 0]                                 # [bkv, D]
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        s = _softcap(s, softcap)
        qi = qi0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kj = kj0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kj <= qi) & (kj >= start_ref[0, 0])
        if window > 0:
            mask &= kj > qi - window
        s = jnp.where(mask, s, NEG_INF)
        _online_update(s, v_ref[0, 0], m_ref, l_ref, acc_ref)

    @pl.when(j == n_kv - 1)
    def _store():
        l = jnp.maximum(l_ref[:, :1], _L_EPS)
        o_ref[0, 0] = (acc_ref[...] / l).astype(out_dtype)


def flash_prefill_pallas(
    q: jax.Array,                 # [B, Hq, T, D]
    k: jax.Array,                 # [B, Hkv, S, D]
    v: jax.Array,                 # [B, Hkv, S, D]
    start: Optional[jax.Array] = None,    # [B, 1] int32, first real key slot
    q_offset: Optional[jax.Array] = None,  # [B, 1] int32, abs pos of q row 0
    *,
    sm_scale: float,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Causal (+ sliding window, + left-pad) flash attention over a full
    sequence. Returns o [B, Hq, T, D] in q.dtype.

    q_offset [B, 1] (optional): absolute key-slot position of query row 0 —
    the chunked-prefill continuation case (DESIGN.md §12), where a chunk of
    queries at absolute positions ``offset .. offset+T-1`` attends a cache
    of S >= offset+T key slots. Zero (the default) is the ordinary
    self-attention prefill where row index == absolute position."""
    b, hq, t, d = q.shape
    _, hkv, s_len, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    assert t % block_q == 0 and s_len % block_kv == 0, (
        f"(T={t}, S={s_len}) not divisible by blocks "
        f"({block_q},{block_kv}); pad at the ops layer")
    if start is None:
        start = jnp.zeros((b, 1), jnp.int32)
    if q_offset is None:
        q_offset = jnp.zeros((b, 1), jnp.int32)
    n_q, n_kv = t // block_q, s_len // block_kv

    kernel = functools.partial(
        _flash_prefill_kernel, n_kv=n_kv, block_q=block_q,
        block_kv=block_kv, sm_scale=sm_scale, window=window,
        softcap=softcap, out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, i, j: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, i, j: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1), lambda bb, h, i, j: (bb, 0)),
            pl.BlockSpec((1, 1), lambda bb, h, i, j: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 128), jnp.float32),    # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),      # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, start, q_offset)


# ---------------------------------------------------------------------------
# packed (cu_seqlens) prefill
# ---------------------------------------------------------------------------

def _packed_online_update(s, mask, v, m_ref, l_ref, acc_ref):
    """Online-softmax step with an explicit probability mask. The packed
    kernel needs it because a computed block can be *fully* masked for some
    real query rows (a key block that only covers earlier segments): with
    m still at NEG_INF, ``exp(s - m) = exp(0) = 1`` would silently count
    every masked key. Zeroing p through the mask keeps those rows exact;
    the plain prefill kernel never hits this (the first computed block
    always holds key slot ``start``, valid for every real row)."""
    m_prev = m_ref[:, :1]                               # [M, 1]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)                     # [M, 1]
    p = jnp.exp(s - m_cur) * mask.astype(jnp.float32)   # [M, Skv]
    l_cur = l_ref[:, :1] * alpha + p.sum(axis=-1, keepdims=True)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _flash_packed_kernel(q_ref, k_ref, v_ref, segq_ref, segk_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, n_kv: int, block_q: int,
                         block_kv: int, sm_scale: float, window: int,
                         softcap: float, out_dtype):
    i = pl.program_id(1)
    j = pl.program_id(2)
    qi0 = i * block_q
    kj0 = j * block_kv

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block skip: causal in absolute packed coordinates (a later segment's
    # keys always sit at higher absolute positions, so forward cross-
    # segment blocks fall out with the diagonal), plus the segment bound —
    # a key block wholly in earlier segments than every query row of this
    # block contributes nothing (segment ids are non-decreasing along the
    # packed axis, so the block extremes decide)
    run = kj0 <= qi0 + block_q - 1
    run &= segk_ref[0, block_kv - 1] >= segq_ref[0, 0]
    if window > 0:
        run &= kj0 + block_kv - 1 > qi0 - window

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                    # [bq, D]
        k = k_ref[0]                                    # [bkv, D]
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        s = _softcap(s, softcap)
        qi = qi0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kj = kj0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # block-diagonal mask from the row offsets: same segment + causal
        # (within a segment both positions shift by the same cu_seqlens
        # offset, so absolute comparisons ARE the logical causal/window
        # structure — the plain kernel's convention, DESIGN.md §12)
        mask = (kj <= qi) & (segq_ref[0][:, None] == segk_ref[0][None, :])
        if window > 0:
            mask &= kj > qi - window
        s = jnp.where(mask, s, NEG_INF)
        _packed_online_update(s, mask, v_ref[0], m_ref, l_ref, acc_ref)

    @pl.when(j == n_kv - 1)
    def _store():
        l = jnp.maximum(l_ref[:, :1], _L_EPS)
        o_ref[0] = (acc_ref[...] / l).astype(out_dtype)


def flash_prefill_packed_pallas(
    q: jax.Array,                 # [Hq, T, D] — packed tokens, head-major
    k: jax.Array,                 # [Hkv, T, D]
    v: jax.Array,                 # [Hkv, T, D]
    seg_ids: jax.Array,           # [1, T] int32, non-decreasing segment ids
    *,
    sm_scale: float,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """cu_seqlens-aware flash prefill over a PACKED ragged batch
    (DESIGN.md §12): T is the total token count of all concatenated
    requests, ``seg_ids[t]`` names the request owning packed position t
    (non-decreasing; padding tokens carry a sentinel id larger than every
    real segment). Masking is block-diagonal-causal — no query ever
    attends a key of another request. Returns o [Hq, T, D] in q.dtype."""
    hq, t, d = q.shape
    hkv = k.shape[0]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    assert t % block_q == 0 and t % block_kv == 0, (
        f"T={t} not divisible by blocks ({block_q},{block_kv}); "
        "pad at the ops layer")
    assert seg_ids.shape == (1, t), (seg_ids.shape, t)
    n_q, n_kv = t // block_q, t // block_kv

    kernel = functools.partial(
        _flash_packed_kernel, n_kv=n_kv, block_q=block_q,
        block_kv=block_kv, sm_scale=sm_scale, window=window,
        softcap=softcap, out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        grid=(hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, i, j: (h // g, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, i, j: (h // g, j, 0)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (0, i)),
            pl.BlockSpec((1, block_kv), lambda h, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 128), jnp.float32),    # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),      # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, seg_ids, seg_ids)


# ---------------------------------------------------------------------------
# decode (paged KV)
# ---------------------------------------------------------------------------

def _paged_decode_kernel(tab_ref, len_ref, start_ref, q_ref, k_ref, v_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, n_log: int,
                         page: int, sm_scale: float, window: int,
                         softcap: float, out_dtype):
    bb = pl.program_id(0)
    j = pl.program_id(2)
    length = len_ref[bb]                                # current token's slot

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # page skip: any valid slot ⇔ page start <= length (causal), page end
    # past the row's left padding, and, with a window, page end inside it
    run = j * page <= length
    run &= (j + 1) * page - 1 >= start_ref[bb]
    if window > 0:
        run &= (j + 1) * page - 1 > length - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0]                                 # [G, D]
        k = k_ref[0, :, 0]                              # [page, D]
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        s = _softcap(s, softcap)
        kk = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kk <= length) & (kk >= start_ref[bb])
        if window > 0:
            mask &= kk > length - window
        s = jnp.where(mask, s, NEG_INF)
        _online_update(s, v_ref[0, :, 0], m_ref, l_ref, acc_ref)

    @pl.when(j == n_log - 1)
    def _store():
        l = jnp.maximum(l_ref[:, :1], _L_EPS)
        o_ref[0, 0] = (acc_ref[...] / l).astype(out_dtype)


def paged_decode_pallas(
    q: jax.Array,                 # [B, Hkv, G, D] — one token, grouped heads
    k_pages: jax.Array,           # [P, page, Hkv, D] physical page pool
    v_pages: jax.Array,           # [P, page, Hkv, D]
    block_table: jax.Array,       # [B, n_log] int32: logical → physical page
    lengths: jax.Array,           # [B] int32 — absolute slot of the new token
    start: jax.Array,             # [B] int32 — first real (non-pad) slot
    *,
    sm_scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = False,
) -> jax.Array:
    """One-token decode attention over a paged KV cache. The block table is
    scalar-prefetched so it drives the KV page DMA index map: logical page
    ``j`` of row ``b`` is fetched from physical page ``block_table[b, j]``.
    Returns o [B, Hkv, G, D] in q.dtype. The new token's K/V must already
    be scattered into the pool (slot ``lengths[b]``)."""
    b, hkv, g, d = q.shape
    _, page, hkv2, _ = k_pages.shape
    assert hkv2 == hkv, (k_pages.shape, q.shape)
    n_log = block_table.shape[1]

    kernel = functools.partial(
        _paged_decode_kernel, n_log=n_log, page=page, sm_scale=sm_scale,
        window=window, softcap=softcap, out_dtype=q.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, n_log),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bb, h, j, tab, ln, st: (bb, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bb, h, j, tab, ln, st: (tab[bb, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bb, h, j, tab, ln, st: (tab[bb, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, h, j, tab, ln, st: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),          # running max m
            pltpu.VMEM((g, 128), jnp.float32),          # running sum l
            pltpu.VMEM((g, d), jnp.float32),            # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, lengths, start, q, k_pages, v_pages)
