"""KernelContract declarations for the attention kernels
(`flash_prefill_pallas`, `flash_prefill_packed_pallas`,
`paged_decode_pallas`) — DESIGN.md §13.

All three share the flash discipline: the output block's index map
ignores the KV grid dim (revisited once per KV block), with running
(m, l, acc) scratch guarded by first/last-visit ``pl.when``. The score
tile ``[bq, bkv]`` is a kernel-body intermediate, not a BlockSpec, so
it rides in ``extra_vmem_bytes`` — the same term `_footprint` charges.
The paged decode contract closes its KV index maps over a concrete
identity block table, mirroring the scalar-prefetch indirection.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET, SUBLANE
from repro.kernels.attn.ops import (_heuristic_blocks, flash_ok,
                                    paged_decode_ok)
from repro.kernels.common import round_up, skinny_ok

__all__ = ["contracts"]


def _flash(b: int, hq: int, hkv: int, t: int, s: int, d: int,
           itemsize: int = 4) -> KernelContract:
    bq, bkv = _heuristic_blocks(t, s, d, itemsize)
    tp, sp = round_up(t, bq), round_up(s, bkv)
    grid = (b, hq, tp // bq, sp // bkv)
    g = hq // hkv
    return KernelContract(
        name=f"attn_flash[b{b} h{hq}/{hkv} t{t} s{s} d{d}]",
        route="attn_flash", domain="attention",
        grid=grid,
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"),
        inputs=(
            BlockDecl("q", (1, 1, bq, d),
                      lambda bb, h, i, j: (bb, h, i, 0), (b, hq, tp, d),
                      itemsize),
            BlockDecl("k", (1, 1, bkv, d),
                      lambda bb, h, i, j: (bb, h // g, j, 0),
                      (b, hkv, sp, d), itemsize),
            BlockDecl("v", (1, 1, bkv, d),
                      lambda bb, h, i, j: (bb, h // g, j, 0),
                      (b, hkv, sp, d), itemsize),
            BlockDecl("start", (1, 1), lambda bb, h, i, j: (bb, 0),
                      (b, 1), 4),
            BlockDecl("q_offset", (1, 1), lambda bb, h, i, j: (bb, 0),
                      (b, 1), 4),
        ),
        outputs=(BlockDecl("out", (1, 1, bq, d),
                           lambda bb, h, i, j: (bb, h, i, 0),
                           (b, hq, tp, d), itemsize),),
        scratch=(ScratchDecl("m", (bq, 128), 4),
                 ScratchDecl("l", (bq, 128), 4),
                 ScratchDecl("acc", (bq, d), 4)),
        acc_dims=(3,), guarded_init=True, guarded_store=True,
        vmem_budget=KERNEL_VMEM_BUDGET,
        extra_vmem_bytes=bq * bkv * 4,      # score tile (kernel body)
        admitted=flash_ok(t, s, d, itemsize),
        vmem_reject=not flash_ok(t, s, d, itemsize))


def _packed(hq: int, hkv: int, t: int, d: int, itemsize: int = 4
            ) -> KernelContract:
    bq, bkv = _heuristic_blocks(t, t, d, itemsize)
    bq = bkv = min(bq, bkv)
    tp = round_up(t, bq)
    grid = (hq, tp // bq, tp // bkv)
    g = hq // hkv
    return KernelContract(
        name=f"attn_packed_flash[h{hq}/{hkv} t{t} d{d}]",
        route="attn_packed_flash", domain="attention",
        grid=grid,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        inputs=(
            BlockDecl("q", (1, bq, d), lambda h, i, j: (h, i, 0),
                      (hq, tp, d), itemsize),
            BlockDecl("k", (1, bkv, d), lambda h, i, j: (h // g, j, 0),
                      (hkv, tp, d), itemsize),
            BlockDecl("v", (1, bkv, d), lambda h, i, j: (h // g, j, 0),
                      (hkv, tp, d), itemsize),
            BlockDecl("seg_q", (1, bq), lambda h, i, j: (0, i), (1, tp), 4),
            BlockDecl("seg_k", (1, bkv), lambda h, i, j: (0, j), (1, tp), 4),
        ),
        outputs=(BlockDecl("out", (1, bq, d), lambda h, i, j: (h, i, 0),
                           (hq, tp, d), itemsize),),
        scratch=(ScratchDecl("m", (bq, 128), 4),
                 ScratchDecl("l", (bq, 128), 4),
                 ScratchDecl("acc", (bq, d), 4)),
        acc_dims=(2,), guarded_init=True, guarded_store=True,
        vmem_budget=KERNEL_VMEM_BUDGET,
        extra_vmem_bytes=bq * bkv * 4,
        admitted=flash_ok(t, t, d, itemsize),
        vmem_reject=not flash_ok(t, t, d, itemsize))


def _paged(b: int, hkv: int, g: int, d: int, page: int, n_log: int,
           itemsize: int = 4) -> KernelContract:
    gp = round_up(g, SUBLANE)
    n_phys = b * n_log                      # identity table's pool size
    tab = (np.arange(b, dtype=np.int32)[:, None] * n_log
           + np.arange(n_log, dtype=np.int32)[None, :])

    def kv_map(bb, h, j):
        return (int(tab[bb, j]), 0, h, 0)

    ok = paged_decode_ok(page, d, itemsize) and skinny_ok(g, d, itemsize)
    return KernelContract(
        name=f"attn_decode[b{b} h{hkv} g{g} d{d} p{page}x{n_log}]",
        route="attn_decode_flash", domain="attn_decode",
        grid=(b, hkv, n_log),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        inputs=(
            BlockDecl("q", (1, 1, gp, d), lambda bb, h, j: (bb, h, 0, 0),
                      (b, hkv, gp, d), itemsize),
            BlockDecl("k_pages", (1, page, 1, d), kv_map,
                      (n_phys, page, hkv, d), itemsize),
            BlockDecl("v_pages", (1, page, 1, d), kv_map,
                      (n_phys, page, hkv, d), itemsize),
        ),
        outputs=(BlockDecl("out", (1, 1, gp, d),
                           lambda bb, h, j: (bb, h, 0, 0),
                           (b, hkv, gp, d), itemsize),),
        scratch=(ScratchDecl("m", (gp, 128), 4),
                 ScratchDecl("l", (gp, 128), 4),
                 ScratchDecl("acc", (gp, d), 4)),
        acc_dims=(2,), guarded_init=True, guarded_store=True,
        vmem_budget=KERNEL_VMEM_BUDGET,
        extra_vmem_bytes=gp * page * 4,     # score tile
        admitted=ok, vmem_reject=not ok,
        notes="KV index maps close over an identity block table "
              "(scalar-prefetch indirection)")


def contracts() -> List[KernelContract]:
    return [
        _flash(2, 4, 2, 256, 256, 64),                 # GQA prefill
        _flash(1, 8, 8, 2048, 2048, 128),              # long MHA prefill
        _flash(2, 4, 2, 256, 256, 1 << 17),            # rejected: huge D
        _packed(4, 2, 1024, 64),                       # cu_seqlens batch
        _paged(2, 2, 4, 64, 64, 8),                    # paged decode
        _paged(2, 2, 4, 128, 1 << 15, 2),              # rejected: huge page
    ]
