"""jnp oracles for the flash-attention kernels (DESIGN.md §10).

The prefill oracle materializes the full ``[B, Hq, T, S]`` score tensor and
runs a plain softmax — exactly what the flash kernel must never do — so
fused/unfused parity is a real structural check. The decode oracle gathers
the paged pool back into a contiguous [B, S, Hkv, D] cache through the
block table and reuses the same quadratic math.

Mask convention is `models.attention._mask_bias`'s, expressed in absolute
key/query slots: valid ⇔ ``k_abs <= q_abs`` ∧ ``k_abs >= start[b]``
(∧ ``k_abs > q_abs - window``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.attn.kernel import NEG_INF

__all__ = ["flash_prefill_ref", "packed_prefill_ref", "paged_decode_ref",
           "gather_pages"]


def _softcap(s: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(s / cap) if cap > 0 else s


def flash_prefill_ref(
    q: jax.Array,                 # [B, Hq, T, D]
    k: jax.Array,                 # [B, Hkv, S, D]
    v: jax.Array,                 # [B, Hkv, S, D]
    start: Optional[jax.Array] = None,    # [B, 1] int32
    q_offset: Optional[jax.Array] = None,  # [B, 1] int32
    *,
    sm_scale: float,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Quadratic reference: full score tensor + plain softmax. ``q_offset``
    shifts query row 0 to that absolute key slot (chunked-prefill
    continuation, DESIGN.md §12)."""
    b, hq, t, d = q.shape
    hkv, s_len = k.shape[1], k.shape[2]
    g = hq // hkv
    if start is None:
        start = jnp.zeros((b, 1), jnp.int32)
    if q_offset is None:
        q_offset = jnp.zeros((b, 1), jnp.int32)
    kg = jnp.repeat(k, g, axis=1)                       # [B, Hq, S, D]
    vg = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q, kg,
                   preferred_element_type=jnp.float32) * sm_scale
    s = _softcap(s, softcap)
    qi = (jnp.arange(t)[None, :] + q_offset)[:, None, :, None]
    kj = jnp.arange(s_len)[None, None, None, :]
    mask = (kj <= qi) & (kj >= start[:, None, :, None])
    if window > 0:
        mask &= kj > qi - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhts,bhsd->bhtd", p.astype(v.dtype), vg,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def packed_prefill_ref(
    q: jax.Array,                 # [Hq, T, D] — packed tokens, head-major
    k: jax.Array,                 # [Hkv, T, D]
    v: jax.Array,                 # [Hkv, T, D]
    seg_ids: jax.Array,           # [T] int32, non-decreasing segment ids
    *,
    sm_scale: float,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Quadratic oracle for the packed (cu_seqlens) prefill kernel
    (DESIGN.md §12): block-diagonal-causal mask — a query attends a key iff
    they share a segment id and the key's packed position is not later.
    Within a segment both positions carry the same cu_seqlens offset, so
    absolute comparisons reproduce the per-request causal/window ladder."""
    hq, t, d = q.shape
    hkv = k.shape[0]
    g = hq // hkv
    seg_ids = jnp.asarray(seg_ids, jnp.int32).reshape(t)
    kg = jnp.repeat(k, g, axis=0)                       # [Hq, T, D]
    vg = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("htd,hsd->hts", q, kg,
                   preferred_element_type=jnp.float32) * sm_scale
    s = _softcap(s, softcap)
    qi = jnp.arange(t)[:, None]
    kj = jnp.arange(t)[None, :]
    mask = (kj <= qi) & (seg_ids[:, None] == seg_ids[None, :])
    if window > 0:
        mask &= kj > qi - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (padding sentinels) get a uniform softmax over
    # NEG_INF scores — garbage the caller never gathers
    o = jnp.einsum("hts,hsd->htd", p.astype(v.dtype), vg,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def gather_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """[P, page, H, D] pool + [B, n_log] table → contiguous [B, S, H, D]."""
    b, n_log = block_table.shape
    _, page, h, d = pages.shape
    gathered = pages[block_table]                       # [B, n_log, page, H, D]
    return gathered.reshape(b, n_log * page, h, d)


def paged_decode_ref(
    q: jax.Array,                 # [B, Hkv, G, D]
    k_pages: jax.Array,           # [P, page, Hkv, D]
    v_pages: jax.Array,           # [P, page, Hkv, D]
    block_table: jax.Array,       # [B, n_log] int32
    lengths: jax.Array,           # [B] int32
    start: jax.Array,             # [B] int32
    *,
    sm_scale: float,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Gather-then-attend reference for the paged decode kernel."""
    b, hkv, g, d = q.shape
    k = gather_pages(k_pages, block_table)              # [B, S, Hkv, D]
    v = gather_pages(v_pages, block_table)
    s = jnp.einsum("bhgd,bshd->bhgs", q, k,
                   preferred_element_type=jnp.float32) * sm_scale
    s = _softcap(s, softcap)
    kk = jnp.arange(k.shape[1])[None, :]
    valid = (kk <= lengths[:, None]) & (kk >= start[:, None])
    if window > 0:
        valid &= kk > (lengths[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)
