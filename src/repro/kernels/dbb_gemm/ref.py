"""Pure-jnp oracle for the DBB GEMM kernel: decompress densely, then matmul,
then the same fused epilogue the kernel applies in VMEM."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dbb import DbbWeight, unpack_dbb, unpack_nibbles
from repro.kernels.common import acc_dtype_for
from repro.kernels.epilogue import Epilogue, apply_epilogue, default_out_dtype

__all__ = ["dbb_gemm_ref", "decompress_ref", "decompress_w4_ref"]


def decompress_ref(values: jax.Array, bitmask: jax.Array, *,
                   block: int, nnz: int) -> jax.Array:
    """Dense [K, N] from (values [K/B*k, N], bitmask [K/B, N])."""
    nb, n = bitmask.shape
    v = values.reshape(nb, nnz, n)
    pos = jnp.arange(block)                                    # [B]
    bit = (bitmask[:, None, :] >> pos[None, :, None]) & 1      # [nb, B, n]
    below_mask = (jnp.uint32(1) << pos.astype(jnp.uint32)) - 1
    below = bitmask[:, None, :].astype(jnp.uint32) & below_mask[None, :, None]
    # rank = popcount(below): below has < 32 bits set, use bit-sum
    rank = jnp.zeros_like(below, dtype=jnp.int32)
    for t in range(block):
        rank = rank + ((below >> t) & 1).astype(jnp.int32)
    slot = jnp.clip(rank, 0, nnz - 1)
    gathered = jnp.take_along_axis(v, slot, axis=1)            # [nb, B, n]
    dense = jnp.where(bit == 1, gathered, jnp.zeros_like(gathered))
    return dense.reshape(nb * block, n)


def decompress_w4_ref(values: jax.Array, bitmask: jax.Array,
                      gscale: jax.Array, *, block: int, nnz: int,
                      group: int) -> jax.Array:
    """Dense f32 ``[K, N]`` from the nibble-packed INT4 plane: sign-extend
    the nibbles (``values [K/B·k/2, N] int8``), bitmask-rank decompress,
    then dequantize with the groupwise ``gscale [K//G, N]`` (DESIGN.md
    §16). The XLA oracle for the w4 kernel routes."""
    v8 = unpack_nibbles(values)                               # [K/B·k, N]
    dense = decompress_ref(v8, bitmask, block=block, nnz=nnz)
    k_dim, n = dense.shape
    grouped = dense.astype(jnp.float32).reshape(k_dim // group, group, n)
    return (grouped * gscale[:, None, :]).reshape(k_dim, n)


def dbb_gemm_ref(x: jax.Array, values: jax.Array, bitmask: jax.Array, *,
                 block: int, nnz: int,
                 epilogue: Epilogue = Epilogue(),
                 bias: Optional[jax.Array] = None,
                 scale: Optional[jax.Array] = None,
                 out_dtype=None) -> jax.Array:
    acc = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)
    w = decompress_ref(values, bitmask, block=block, nnz=nnz).astype(x.dtype)
    y = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc)
    return apply_epilogue(y, epilogue, out_dtype, bias=bias, scale=scale)


def dbb_gemm_ref_from_packed(x: jax.Array, p: DbbWeight,
                             out_dtype=None) -> jax.Array:
    """Oracle via core.dbb.unpack_dbb (independent decompression path)."""
    w = unpack_dbb(p).astype(x.dtype)
    acc = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = acc if x.dtype == jnp.int8 else x.dtype
    y = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc)
    return y.astype(out_dtype)
