"""Jit'd public wrappers for DBB GEMM.

`dbb_gemm_packed` consumes a `core.dbb.DbbWeight` (the framework's stored
format); `dbb_gemm` takes raw (values, bitmask). Both pad M to the block
grid and fall back to the oracle when `use_kernel=False`.

Shape contract (DESIGN.md §2): for a dense weight ``W[K, N]`` and DBB
geometry (B=block, k=nnz),
    values  [K/B · k, N]  surviving values, slot-major per block
                          (row kb·k + s holds slot s of block kb)
    bitmask [K/B, N]      bit ``pos`` set ⇔ dense row kb·B + pos kept
K and N must already be block-aligned — weights are packed offline, and
every assigned architecture's matmul dims are multiples of 128.

The fused epilogue (bias / activation / scale, DESIGN.md §7) runs inside
the kernel's final-K store; `dbb_gemm_packed` folds the per-out-channel
quant scale of the packed weight into that epilogue, so dequantization no
longer costs a second pass over the [M, N] output in HBM.

Like `sta_gemm`, the public wrapper is a plain function that resolves the
block shape (measured autotuning needs concrete operands — inside an
enclosing jit the tuner degrades to cache lookup + heuristic) and then
dispatches to the inner jit'd implementation.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dbb import DbbWeight
from repro.kernels.common import (acc_dtype_for, coerce_bias_scale,
                                  default_interpret, pad_cols, round_up,
                                  skinny_dispatch)
from repro.kernels.dbb_gemm.kernel import dbb_gemm_pallas
from repro.kernels.dbb_gemm.ref import dbb_gemm_ref, decompress_w4_ref
from repro.kernels.epilogue import (Epilogue, apply_epilogue, as_row,
                                    default_out_dtype)

__all__ = ["dbb_gemm", "dbb_gemm_packed"]


def _skinny_kernel():
    # deferred: skinny.kernel imports dbb_gemm.kernel (shared VMEM
    # decompress), so a module-level import here would be order-dependent
    # (whichever of sta_gemm/dbb_gemm loads first would hit the partially
    # initialized sibling)
    from repro.kernels.skinny.kernel import dbb_gemm_skinny_pallas
    return dbb_gemm_skinny_pallas


@functools.partial(
    jax.jit,
    static_argnames=("act", "block", "nnz", "block_m", "block_k", "block_n",
                     "out_dtype", "interpret", "use_kernel", "skinny",
                     "bits", "group"))
def _dbb_gemm_impl(x, values, bitmask, bias, scale, gscale=None, *, act,
                   block, nnz, block_m, block_k, block_n, out_dtype,
                   interpret, use_kernel, skinny=False, bits=8, group=0):
    epilogue = Epilogue(act=act, has_bias=bias is not None,
                        has_scale=scale is not None)
    *batch, k_dim = x.shape
    n = values.shape[1]
    x2 = x.reshape(-1, k_dim)
    m = x2.shape[0]
    mask_i32 = bitmask.astype(jnp.int32)
    bias_r = as_row(bias, n) if bias is not None else None
    scale_r = as_row(scale, n) if scale is not None else None

    if not use_kernel:
        if bits == 4:
            w = decompress_w4_ref(values, mask_i32, gscale, block=block,
                                  nnz=nnz, group=group).astype(x2.dtype)
            acc = jax.lax.dot_general(
                x2, w, dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=acc_dtype_for(x2.dtype))
            od = out_dtype or default_out_dtype(x2.dtype, epilogue)
            y = apply_epilogue(acc, epilogue, od, bias=bias_r, scale=scale_r)
        else:
            y = dbb_gemm_ref(x2, values, mask_i32, block=block, nnz=nnz,
                             epilogue=epilogue, bias=bias_r, scale=scale_r,
                             out_dtype=out_dtype)
        return y.reshape(*batch, n)

    assert k_dim % block == 0, (k_dim, block)
    bm = min(block_m, round_up(m, 8))
    bk = max(block, block_k // block * block)   # floor-align K tile to B
    bn = min(block_n, round_up(n, 128))
    if bits == 4 and bk % group != 0 and group % bk != 0:
        bk = group          # force K tile / scale group to nest
    # pad every axis to its block grid: M rows (zeros), K by whole DBB
    # blocks (zero value-rows + zero mask-rows), N by zero columns
    mp = round_up(m, 8) if skinny else round_up(m, bm)
    # w4 padding must keep kp a whole number of scale groups too
    kp = round_up(k_dim, max(bk, group) if bits == 4 else bk)
    np_ = round_up(n, bn)
    nb, nbp = k_dim // block, kp // block
    xp = x2 if (mp, kp) == (m, k_dim) else jnp.pad(
        x2, ((0, mp - m), (0, kp - k_dim)))
    vp, mp_arr = values, mask_i32
    if nbp != nb:
        pad_rows = (nbp - nb) * nnz // 2 if bits == 4 else (nbp - nb) * nnz
        vp = jnp.pad(vp, ((0, pad_rows), (0, 0)))
        mp_arr = jnp.pad(mp_arr, ((0, nbp - nb), (0, 0)))
    vp = pad_cols(vp, np_ - n)
    mp_arr = pad_cols(mp_arr, np_ - n)
    bias_r = pad_cols(bias_r, np_ - n)
    scale_r = pad_cols(scale_r, np_ - n)
    gs = None
    if bits == 4:
        gs = gscale
        gr = k_dim // group
        if kp // group != gr:            # padded groups dequant zeros: ×1
            gs = jnp.pad(gs, ((0, kp // group - gr), (0, 0)),
                         constant_values=1.0)
        gs = pad_cols(gs, np_ - n)
    w4_kw = dict(bits=bits, group=group, gscale=gs) if bits == 4 else {}
    if skinny:
        # decode fast path (DESIGN.md §9): resident activations, the
        # compressed values/bitmask stream through the K loop
        y = _skinny_kernel()(xp, vp, mp_arr, bias_r, scale_r,
                                   epilogue=epilogue, block=block, nnz=nnz,
                                   block_k=bk, block_n=bn,
                                   out_dtype=out_dtype, interpret=interpret,
                                   **w4_kw)
    else:
        y = dbb_gemm_pallas(xp, vp, mp_arr, bias_r, scale_r,
                            epilogue=epilogue, block=block, nnz=nnz,
                            block_m=bm, block_k=bk, block_n=bn,
                            out_dtype=out_dtype, interpret=interpret,
                            **w4_kw)
    return y[:m, :n].reshape(*batch, n)


def dbb_gemm(
    x: jax.Array,          # [..., K]
    values: jax.Array,     # [K//B * k, N]
    bitmask: jax.Array,    # [K//B, N] integer
    bias: Optional[jax.Array] = None,    # [N] f32 — fused epilogue
    scale: Optional[jax.Array] = None,   # scalar/[N] f32 — fused epilogue
    *,
    act: str = "none",
    block: int = 8,
    nnz: int = 4,
    block_m: int = 0,          # 0 = unpinned (heuristic or autotuner)
    block_k: int = 0,
    block_n: int = 0,
    out_dtype=None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    autotune: Optional[bool] = None,
    skinny: Optional[bool] = None,
    bits: int = 8,
    group: int = 0,
    gscale: Optional[jax.Array] = None,
) -> jax.Array:
    """DBB structured-sparse GEMM: ``x @ unpack(values, bitmask)``.

    ``skinny`` overrides the automatic skinny-vs-M-tiled choice (the
    dispatch registry resolves routes up front; None keeps the legacy
    in-wrapper auto dispatch for direct callers).

    Shapes (DESIGN.md §2): ``x [..., K]``; ``values [K/B·k, N]`` slot-major
    compressed non-zeros; ``bitmask [K/B, N]`` integer, bit ``pos`` set ⇔
    dense row kb·B + pos kept. K must divide by ``block``; M and N pad to
    the block grid. ``bias``/``scale``/``act`` fuse into the kernel's
    final-K store exactly as in `sta_gemm`.

    ``bits=4`` (DESIGN.md §16): ``values`` is nibble-packed
    ``[K/B·k/2, N] int8`` and ``gscale [K//G, N]`` the groupwise dequant
    scales, applied at the in-VMEM decompress step (they vary along K, so
    they cannot ride the [1, N] epilogue ``scale``, which stays available
    for requant).
    """
    if interpret is None:
        interpret = default_interpret()
    bias, scale = coerce_bias_scale(bias, scale)
    bm0, bk0, bn0 = block_m or 128, block_k or 128, block_n or 128
    if bits == 4:
        assert gscale is not None, "bits=4 needs the groupwise gscale plane"
        autotune = False   # tuner synthesizes int8/f32 operand sets only
    if not use_kernel:
        skinny = False
    if use_kernel:
        *batch, k_dim = x.shape
        m = math.prod(batch) if batch else 1
        if skinny is None:
            # decode fast path (DESIGN.md §9): GEMV-shaped calls stream the
            # compressed weight through the skinny kernel; pinned blocks
            # opt out (the dispatch layer passes an explicit choice)
            skinny = skinny_dispatch(m, k_dim, x.dtype.itemsize,
                                     block_m, block_k, block_n)
        if autotune is None:
            # caller-pinned block shapes win over the tuner (0-sentinel
            # convention, mirrors sta_gemm)
            from repro.kernels.autotune import autotune_enabled
            autotune = (not (block_m or block_k or block_n)
                        and autotune_enabled())
        if autotune:
            epi = Epilogue(act=act, has_bias=bias is not None,
                           has_scale=scale is not None)
            measure = not isinstance(x, jax.core.Tracer)
            bm0, bk0, bn0 = _autotuned_shape(
                m, k_dim, values.shape[1], x.dtype, epi, out_dtype,
                interpret, block=block, nnz=nnz, measure=measure,
                skinny=skinny)
    return _dbb_gemm_impl(x, values, bitmask, bias, scale, gscale, act=act,
                          block=block, nnz=nnz, block_m=bm0, block_k=bk0,
                          block_n=bn0, out_dtype=out_dtype,
                          interpret=interpret, use_kernel=use_kernel,
                          skinny=skinny, bits=bits, group=group)


def _autotuned_shape(m, k_dim, n, dtype, epilogue, out_dtype, interpret,
                     *, block, nnz, measure, skinny=False):
    """Measured (bm, bk, bn) for the DBB kernel (bk also B-aligned); skinny
    calls tune the compressed-stream tiles of the skinny kernel under
    their own op tag."""
    import numpy as np
    from repro.core.sta import LANE
    from repro.kernels import autotune

    align_k = LANE * block // math.gcd(LANE, block)

    def make_fn(shape):
        bm, bk, bn = shape
        mp = round_up(m, 8) if skinny else round_up(m, bm)
        kp = round_up(k_dim, bk)
        np_ = round_up(n, bn)
        rng = np.random.default_rng(0)
        if np.dtype(dtype) == np.int8:
            x = jnp.asarray(rng.integers(-127, 128, (mp, kp)), jnp.int8)
            vals = jnp.asarray(
                rng.integers(-127, 128, (kp // block * nnz, np_)), jnp.int8)
        else:
            x = jnp.asarray(rng.standard_normal((mp, kp)), dtype)
            vals = jnp.asarray(
                rng.standard_normal((kp // block * nnz, np_)), dtype)
        mask = jnp.full((kp // block, np_), (1 << nnz) - 1, jnp.int32)
        bias = jnp.zeros((1, np_), jnp.float32) if epilogue.has_bias else None
        scale = jnp.ones((1, np_), jnp.float32) if epilogue.has_scale else None
        if skinny:
            return lambda: _skinny_kernel()(
                x, vals, mask, bias, scale, epilogue=epilogue, block=block,
                nnz=nnz, block_k=bk, block_n=bn,
                out_dtype=out_dtype, interpret=interpret)
        return lambda: dbb_gemm_pallas(
            x, vals, mask, bias, scale, epilogue=epilogue, block=block,
            nnz=nnz, block_m=bm, block_k=bk, block_n=bn,
            out_dtype=out_dtype, interpret=interpret)

    tag = f"{epilogue.tag()}>{jnp.dtype(out_dtype).name if out_dtype else 'auto'}"
    name = (f"dbb_gemm_skinny_b{block}k{nnz}" if skinny
            else f"dbb_gemm_b{block}k{nnz}") + ("_interp" if interpret else "")
    itemsize = np.dtype(dtype).itemsize
    cands = (autotune.skinny_candidate_block_shapes(
        m, k_dim, n, itemsize=itemsize, align_k=align_k) if skinny else None)
    return autotune.autotune_block_shape(
        name, m, k_dim, n, dtype, make_fn, epilogue_tag=tag,
        candidates=cands,
        itemsize=itemsize, align_k=align_k, measure=measure)


def dbb_gemm_packed(x: jax.Array, p: DbbWeight,
                    bias: Optional[jax.Array] = None, *,
                    act: str = "none", out_dtype=None,
                    interpret: Optional[bool] = None,
                    use_kernel: bool = True, **block_kw) -> jax.Array:
    """GEMM against a packed DbbWeight.

    The per-out-channel quant scale (if any) is *fused into the kernel
    epilogue* together with the optional bias and activation — the
    pre-dequant [M, N] accumulator never round-trips through HBM.

    ``bits=4`` leaves route their groupwise ``[K//G, N]`` scale plane to
    the kernels' dequant step instead (it varies along K); any caller
    scale folded into ``p.scale`` upstream rides along multiplicatively.
    """
    if p.bits == 4:
        y = dbb_gemm(x, p.values, p.bitmask, bias, None,
                     act=act, block=p.block, nnz=p.nnz,
                     out_dtype=out_dtype, interpret=interpret,
                     use_kernel=use_kernel, bits=4, group=p.group,
                     gscale=p.scale, **block_kw)
        return y
    scale = p.scale
    y = dbb_gemm(x, p.values, p.bitmask, bias, scale,
                 act=act, block=p.block, nnz=p.nnz,
                 out_dtype=out_dtype, interpret=interpret,
                 use_kernel=use_kernel, **block_kw)
    return y
