"""Jit'd public wrappers for DBB GEMM.

`dbb_gemm_packed` consumes a `core.dbb.DbbWeight` (the framework's stored
format); `dbb_gemm` takes raw (values, bitmask). Both pad M to the block
grid and fall back to the oracle when `use_kernel=False`.

K and N must already be block-aligned — weights are packed offline, and
every assigned architecture's matmul dims are multiples of 128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dbb import DbbWeight
from repro.kernels.common import default_interpret, round_up
from repro.kernels.dbb_gemm.kernel import dbb_gemm_pallas
from repro.kernels.dbb_gemm.ref import dbb_gemm_ref

__all__ = ["dbb_gemm", "dbb_gemm_packed"]


@functools.partial(
    jax.jit,
    static_argnames=("block", "nnz", "block_m", "block_k", "block_n",
                     "out_dtype", "interpret", "use_kernel"))
def dbb_gemm(
    x: jax.Array,          # [..., K]
    values: jax.Array,     # [K//B * k, N]
    bitmask: jax.Array,    # [K//B, N] integer
    *,
    block: int = 8,
    nnz: int = 4,
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    out_dtype=None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    *batch, k_dim = x.shape
    n = values.shape[1]
    x2 = x.reshape(-1, k_dim)
    m = x2.shape[0]
    mask_i32 = bitmask.astype(jnp.int32)

    if not use_kernel:
        y = dbb_gemm_ref(x2, values, mask_i32, block=block, nnz=nnz,
                         out_dtype=out_dtype)
        return y.reshape(*batch, n)

    assert k_dim % block == 0, (k_dim, block)
    bm = min(block_m, round_up(m, 8))
    bk = min(round_up(block_k, block) // block * block, block_k) or block
    bk = max(block, bk // block * block)
    bn = min(block_n, round_up(n, 128))
    # pad every axis to its block grid: M rows (zeros), K by whole DBB
    # blocks (zero value-rows + zero mask-rows), N by zero columns
    mp = round_up(m, bm)
    kp = round_up(k_dim, bk)
    np_ = round_up(n, bn)
    nb, nbp = k_dim // block, kp // block
    xp = x2 if (mp, kp) == (m, k_dim) else jnp.pad(
        x2, ((0, mp - m), (0, kp - k_dim)))
    vp, mp_arr = values, mask_i32
    if nbp != nb:
        vp = jnp.pad(vp, ((0, (nbp - nb) * nnz), (0, 0)))
        mp_arr = jnp.pad(mp_arr, ((0, nbp - nb), (0, 0)))
    if np_ != n:
        vp = jnp.pad(vp, ((0, 0), (0, np_ - n)))
        mp_arr = jnp.pad(mp_arr, ((0, 0), (0, np_ - n)))
    y = dbb_gemm_pallas(xp, vp, mp_arr, block=block, nnz=nnz,
                        block_m=bm, block_k=bk, block_n=bn,
                        out_dtype=out_dtype, interpret=interpret)
    return y[:m, :n].reshape(*batch, n)


def dbb_gemm_packed(x: jax.Array, p: DbbWeight, *, out_dtype=None,
                    interpret: Optional[bool] = None,
                    use_kernel: bool = True, **block_kw) -> jax.Array:
    """GEMM against a packed DbbWeight; applies the per-channel quant scale."""
    y = dbb_gemm(x, p.values, p.bitmask, block=p.block, nnz=p.nnz,
                 out_dtype=out_dtype, interpret=interpret,
                 use_kernel=use_kernel, **block_kw)
    if p.scale is not None:
        y = (y.astype(jnp.float32) * p.scale).astype(
            out_dtype if out_dtype is not None else y.dtype)
    return y
