"""DBB structured-sparse GEMM Pallas kernel (paper §IV, STA-DBB).

TPU adaptation (DESIGN.md §2): the STA-DBB hardware feeds each dot unit the
``k`` non-zero weights plus a bitmask, and *muxes* the matching activations.
The MXU has no muxes, so the exploitable win on TPU is **HBM bandwidth**: the
weight stream stays DBB-compressed in HBM — `values [K/B·k, N]` + one mask
byte per block, 62.5% of dense bytes at k=4/B=8 — and is decompressed
*inside the kernel* in VMEM right before the MXU dot. Decode-time GEMMs are
memory-bound, so the compression moves the dominant roofline term directly.

The decompression is the paper's mux, inverted: for dense block position
``pos``, the source slot is ``rank(pos) = popcount(mask & ((1<<pos)-1))`` and
the value is kept iff bit ``pos`` is set. Everything is unrolled over the
static block geometry (B, k), so the kernel body is pure VPU select/add ops
followed by a single MXU dot per tile.

Accumulation is output-stationary in VMEM scratch across the K grid
dimension, identical to the dense STA kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import CompilerParams, acc_dtype_for, pltpu, popcount_u32
from repro.kernels.epilogue import Epilogue, apply_epilogue, default_out_dtype

__all__ = ["dbb_gemm_pallas"]


def _decompress_tile(vals, mask, *, block: int, nnz: int):
    """Expand a compressed weight tile to dense.

    vals: [nb * nnz, bn]  (slot-major per block: rows kb*nnz + s)
    mask: [nb, bn] int32 bitmask, bit pos set ⇔ dense position kept
    returns: [nb * block, bn] dense tile
    """
    nb_nnz, bn = vals.shape
    nb = nb_nnz // nnz
    v = vals.reshape(nb, nnz, bn)
    rows = []
    for pos in range(block):
        bit = (mask >> pos) & 1                        # [nb, bn]
        below = mask & ((1 << pos) - 1)
        rank = popcount_u32(below, pos) if pos else jnp.zeros_like(mask)
        val_at_rank = jnp.zeros_like(v[:, 0, :])
        for s in range(min(nnz, pos + 1)):
            val_at_rank = jnp.where(rank == s, v[:, s, :], val_at_rank)
        rows.append(jnp.where(bit == 1, val_at_rank,
                              jnp.zeros_like(val_at_rank)))
    dense = jnp.stack(rows, axis=1)                    # [nb, block, bn]
    return dense.reshape(nb * block, bn)


def _expand_nibbles(packed):
    """Sign-extend a nibble-packed int8 tile ``[r/2, bn] → [r, bn]``:
    packed row i holds compressed row 2i (low nibble, ``(p << 4) >> 4``)
    and row 2i+1 (high nibble, ``p >> 4``) — pure VPU shift arithmetic,
    the in-kernel mirror of `core.dbb.unpack_nibbles`."""
    r2, bn = packed.shape
    lo = jnp.right_shift(jnp.left_shift(packed, 4), 4)
    hi = jnp.right_shift(packed, 4)
    return jnp.stack([lo, hi], axis=1).reshape(r2 * 2, bn)


def _dequant_tile(vals, mask, gscale, *, block: int, nnz: int):
    """w4 decompress-tile step: expand the nibble plane to int8, bitmask-
    rank decompress to the dense [bk, bn] tile, then dequantize with the
    per-group scales ``gscale [gpt, bn]`` (gpt groups cover the K tile).
    All in VMEM — neither the int8-expanded nor the dense weight ever
    exists in HBM."""
    w = _decompress_tile(_expand_nibbles(vals), mask, block=block, nnz=nnz)
    bk, bn = w.shape
    gpt = gscale.shape[0]
    w = w.astype(jnp.float32).reshape(gpt, bk // gpt, bn) * gscale[:, None, :]
    return w.reshape(bk, bn)


def _dbb_gemm_kernel(x_ref, v_ref, m_ref, *refs, n_k: int, block: int,
                     nnz: int, out_dtype, epilogue: Epilogue,
                     bits: int = 8):
    refs = list(refs)
    gs_ref = refs.pop(0) if bits == 4 else None
    bias_ref = refs.pop(0) if epilogue.has_bias else None
    scale_ref = refs.pop(0) if epilogue.has_scale else None
    o_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if bits == 4:
        w = _dequant_tile(v_ref[...], m_ref[...], gs_ref[...],
                          block=block, nnz=nnz)
    else:
        w = _decompress_tile(v_ref[...], m_ref[...], block=block, nnz=nnz)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w.astype(x_ref.dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = apply_epilogue(
            acc_ref[...], epilogue, out_dtype,
            bias=bias_ref[...] if bias_ref is not None else None,
            scale=scale_ref[...] if scale_ref is not None else None)


def dbb_gemm_pallas(
    x: jax.Array,          # [M, K]
    values: jax.Array,     # [K//B * k, N] compressed non-zeros (slot-major)
    bitmask: jax.Array,    # [K//B, N] int32 (low `block` bits used)
    bias: jax.Array = None,    # [1, N] f32 (epilogue.has_bias)
    scale: jax.Array = None,   # [1, N] f32 (epilogue.has_scale)
    *,
    epilogue: Epilogue = Epilogue(),
    block: int = 8,
    nnz: int = 4,
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    out_dtype=None,
    interpret: bool = False,
    bits: int = 8,
    group: int = 0,
    gscale: jax.Array = None,  # [K//G, N] f32 (bits=4 only)
) -> jax.Array:
    """``x @ unpack(values, bitmask)`` with on-chip DBB decompression and an
    optional fused bias/activation/requant epilogue in the final-K store.

    Shape contract (DESIGN.md §2): for dense contraction dim K and DBB
    geometry (B=block, k=nnz), the weight stream is
        values  [K/B · k, N]  slot-major (row kb·k + s = slot s of block kb)
        bitmask [K/B, N]      int32, bit ``pos`` set ⇔ dense row
                              kb·B + pos is kept
    K must divide by block_k and block_k by B, so every K tile covers whole
    DBB blocks.

    ``bits=4`` (DESIGN.md §16): ``values`` is the nibble-packed plane
    ``[K/B·k/2, N] int8`` and ``gscale [K//G, N]`` the groupwise dequant
    scales; the kernel streams the packed plane, sign-extends + dequantizes
    at the decompress-tile step, so neither the int8-expanded nor the dense
    weight ever exists in HBM. Requires float activations and block_k and
    group to nest (block_k % group == 0 or group % block_k == 0).
    """
    m, k_dim = x.shape
    kc, n = values.shape
    nb_total = k_dim // block
    assert k_dim % block_k == 0 and block_k % block == 0
    assert m % block_m == 0 and n % block_n == 0

    acc_dtype = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)
    n_k = k_dim // block_k
    nb_tile = block_k // block            # blocks per K tile
    bkc = nb_tile * nnz                   # compressed rows per K tile

    operands = [x, values, bitmask]
    if bits == 4:
        assert kc == nb_total * nnz // 2, (values.shape, k_dim, block, nnz)
        assert bkc % 2 == 0, (block_k, block, nnz)
        assert x.dtype != jnp.int8, "w4 dequantizes in VMEM: float x only"
        assert group > 0 and (block_k % group == 0 or group % block_k == 0)
        assert gscale is not None and gscale.shape == (k_dim // group, n)
        vals_spec = pl.BlockSpec((bkc // 2, block_n),
                                 lambda i, j, kk: (kk, j))
    else:
        assert kc == nb_total * nnz, (values.shape, k_dim, block, nnz)
        vals_spec = pl.BlockSpec((bkc, block_n), lambda i, j, kk: (kk, j))
    assert bitmask.shape == (nb_total, n), bitmask.shape
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        vals_spec,
        pl.BlockSpec((nb_tile, block_n), lambda i, j, kk: (kk, j)),
    ]
    if bits == 4:
        # gpt scale rows cover one K tile; when the group spans several K
        # tiles (gdiv of them), successive kk revisit the same scale row.
        gpt = max(block_k // group, 1)
        gdiv = max(group // block_k, 1)
        operands.append(gscale)
        in_specs.append(pl.BlockSpec((gpt, block_n),
                                     lambda i, j, kk: (kk // gdiv, j)))
    row_spec = pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j))
    if epilogue.has_bias:
        assert bias is not None and bias.shape == (1, n), (
            "bias must be [1, N]", None if bias is None else bias.shape, n)
        operands.append(bias)
        in_specs.append(row_spec)
    if epilogue.has_scale:
        assert scale is not None and scale.shape == (1, n), (
            "scale must be [1, N]", None if scale is None else scale.shape, n)
        operands.append(scale)
        in_specs.append(row_spec)

    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_dbb_gemm_kernel, n_k=n_k, block=block,
                               nnz=nnz, out_dtype=out_dtype,
                               epilogue=epilogue, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
