from repro.kernels.dbb_gemm.ops import dbb_gemm, dbb_gemm_packed
from repro.kernels.dbb_gemm.ref import dbb_gemm_ref

__all__ = ["dbb_gemm", "dbb_gemm_packed", "dbb_gemm_ref"]
