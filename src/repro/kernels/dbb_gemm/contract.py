"""KernelContract declarations for the M-tiled DBB GEMM
(`dbb_gemm_pallas`) — DESIGN.md §13.

Same grid and accumulation discipline as the dense STA kernel; the
weight operands are the compressed stream (values ``[K/B·nnz, N]``
slot-major + bitmask ``[K/B, N]``), and the kernel body decompresses
one dense ``[bk, bn]`` tile in VMEM per K step — declared here as
``extra_vmem_bytes`` so the budget pass sees what the BlockSpecs alone
don't show.
"""
from __future__ import annotations

from typing import List

from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET
from repro.kernels.common import round_up

__all__ = ["contracts"]


def _instance(m: int, k: int, n: int, *, block: int = 8, nnz: int = 4,
              itemsize: int = 4, bits: int = 8, group: int = 0
              ) -> KernelContract:
    bm, bk, bn = min(128, round_up(m, 8)), 128, 128
    mp, np_ = round_up(m, bm), round_up(n, bn)
    admitted = k % block == 0 and k % bk == 0
    if bits == 4:
        admitted = admitted and group > 0 and k % group == 0
    kp = round_up(k, bk)
    grid = (mp // bm, np_ // bn, kp // bk)
    nb_tile = bk // block
    bkc = nb_tile * nnz
    nb_total = kp // block

    inputs = [BlockDecl("x", (bm, bk), lambda i, j, kk: (i, kk), (mp, kp),
                        itemsize)]
    if bits == 4:
        gpt = max(bk // group, 1)      # scale groups covered per K tile
        gdiv = max(group // bk, 1)
        inputs += [
            # nibble plane: two compressed rows per streamed byte row
            BlockDecl("values", (bkc // 2, bn), lambda i, j, kk: (kk, j),
                      (nb_total * nnz // 2, np_), 1),
            BlockDecl("bitmask", (nb_tile, bn), lambda i, j, kk: (kk, j),
                      (nb_total, np_), 4),
            BlockDecl("gscale", (gpt, bn),
                      lambda i, j, kk: (kk // gdiv, j),
                      (kp // group, np_), 4),
        ]
        # expansion chain per K step (DESIGN.md §16): unpacked int8
        # slots + dense int8 tile + dequantized f32 tile
        extra = bkc * bn + bk * bn + bk * bn * 4
    else:
        inputs += [
            BlockDecl("values", (bkc, bn), lambda i, j, kk: (kk, j),
                      (nb_total * nnz, np_), itemsize),
            BlockDecl("bitmask", (nb_tile, bn), lambda i, j, kk: (kk, j),
                      (nb_total, np_), 4),
        ]
        extra = bk * bn * itemsize     # decompressed dense weight tile

    kind = "dbb_packed_w4" if bits == 4 else "dbb_packed"
    return KernelContract(
        name=f"dbb_gemm[m{m} k{k} n{n} B{block} z{nnz} b{bits}]",
        route=kind, domain="matmul",
        grid=grid,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        inputs=tuple(inputs),
        outputs=(BlockDecl("out", (bm, bn), lambda i, j, kk: (i, j),
                           (mp, np_), 4),),
        scratch=(ScratchDecl("acc", (bm, bn), 4),),
        acc_dims=(2,), guarded_init=True, guarded_store=True,
        vmem_budget=KERNEL_VMEM_BUDGET,
        extra_vmem_bytes=extra,
        admitted=admitted, vmem_reject=False,
        notes="" if admitted else f"K={k} not divisible by block {block}")


def contracts() -> List[KernelContract]:
    return [
        _instance(256, 512, 512),
        _instance(64, 1024, 256),
        _instance(128, 252, 256),      # guard-rejected: K % block != 0
        # nibble-plane prefill-shaped instances (DESIGN.md §16)
        _instance(256, 1024, 512, bits=4, group=128),
        _instance(64, 512, 256, bits=4, group=256),
    ]
