"""Pallas TPU kernels for the paper's compute hot-spots.

sta_gemm:  dense Tensor-PE-tiled GEMM (output-stationary VMEM accumulation).
dbb_gemm:  DBB structured-sparse GEMM with on-chip bitmask decompression.
conv_gemm: implicit-GEMM convolution — the im2col patch tile is gathered
           in-kernel from the NHWC activation block in VMEM, never
           materialized in HBM (DESIGN.md §8); dense and DBB variants.
skinny:    skinny-M (decode-shaped, M ≤ 32) weight-streaming variants of
           sta_gemm/dbb_gemm — resident activation block, N-major grid,
           compressed DBB stream decompressed in VMEM (DESIGN.md §9). The
           ops wrappers dispatch to these automatically for small M.
attn:      flash-style fused attention (DESIGN.md §10) — prefill with
           online softmax over KV blocks (the [B,H,T,S] score tensor
           never materializes) and a paged decode kernel whose KV pages
           are gathered through a scalar-prefetched block table; a
           contiguous cache is the identity-table special case.
epilogue:  fused bias/activation/requant applied in the final-K store of
           all kernels (DESIGN.md §7).
autotune:  measured block/tile-shape selection with a persistent on-disk
           cache (DESIGN.md §7) — conv and skinny shapes key under their
           own op tags, with M bucketed so decode (M=1-32) and prefill
           (M=512+) shapes never share an entry.
dispatch:  the one route registry + roofline-informed selection over all
           of the above (DESIGN.md §11). Model layers call
           `dispatch.matmul` / `dispatch.conv` / `dispatch.attention`
           instead of importing kernel subsystems directly.
"""
from repro.kernels.epilogue import Epilogue, apply_epilogue

__all__ = ["Epilogue", "apply_epilogue", "decompress_ref",
           "decompress_w4_ref"]


def __getattr__(name):
    # lazy re-export: `repro.core.dbb_linear` consumes the DBB decompress
    # oracles through the package root (kernel-subsystem imports live only
    # here and in dispatch.py); eager import would cycle through
    # core/__init__ ↔ kernels.dbb_gemm at package-init time.
    if name in ("decompress_ref", "decompress_w4_ref"):
        from repro.kernels.dbb_gemm import ref
        return getattr(ref, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
