"""Pallas TPU kernels for the paper's compute hot-spots.

sta_gemm:  dense Tensor-PE-tiled GEMM (output-stationary VMEM accumulation).
dbb_gemm:  DBB structured-sparse GEMM with on-chip bitmask decompression.
epilogue:  fused bias/activation/requant applied in the final-K store of
           both kernels (DESIGN.md §7).
autotune:  measured (bm, bk, bn) block-shape selection with a persistent
           on-disk cache (DESIGN.md §7).
"""
from repro.kernels.epilogue import Epilogue, apply_epilogue

__all__ = ["Epilogue", "apply_epilogue"]
