"""Pallas TPU kernels for the paper's compute hot-spots.

sta_gemm: dense Tensor-PE-tiled GEMM (output-stationary VMEM accumulation).
dbb_gemm: DBB structured-sparse GEMM with on-chip bitmask decompression.
"""
