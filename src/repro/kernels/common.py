"""Shared Pallas kernel utilities (TPU target, interpret-mode on CPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sta import KERNEL_VMEM_BUDGET, SUBLANE, VMEM_BYTES

try:  # jax >= 0.7 name
    from jax.experimental.pallas import tpu as pltpu
    CompilerParams = pltpu.CompilerParams
except AttributeError:  # pragma: no cover - older naming
    from jax.experimental.pallas import tpu as pltpu
    CompilerParams = pltpu.TPUCompilerParams  # type: ignore[attr-defined]

__all__ = ["pltpu", "CompilerParams", "on_cpu", "default_interpret",
           "cdiv", "round_up", "popcount_u32", "acc_dtype_for",
           "SKINNY_M_MAX", "skinny_ok", "skinny_dispatch",
           "coerce_bias_scale", "pad_cols",
           "KERNEL_VMEM_BUDGET", "SKINNY_RESIDENT_BUDGET"]


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def default_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode on this CPU container."""
    return on_cpu()


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def popcount_u32(x: jax.Array, bits: int) -> jax.Array:
    """Population count via an unrolled shift-and-add (Pallas-safe: no
    dependence on lax.population_count lowering inside Mosaic)."""
    out = jnp.zeros_like(x)
    for t in range(bits):
        out = out + ((x >> t) & 1)
    return out


def coerce_bias_scale(bias, scale):
    """Epilogue contract (DESIGN.md §7): bias/scale rows are f32 no matter
    what dtype the caller's params are stored in (bf16 model trees hand
    over bf16 biases) — coerce at the wrapper boundary, before jit/tuning
    sees the operand, so one compiled kernel serves every param dtype.
    The single shared copy of the coercion all three GEMM-family ops
    wrappers (sta_gemm / dbb_gemm / conv_gemm) apply."""
    if bias is not None:
        bias = jnp.asarray(bias, jnp.float32)
    if scale is not None:
        scale = jnp.asarray(scale, jnp.float32)
    return bias, scale


def pad_cols(a, extra: int):
    """Zero-pad the last dim of a 2-D operand — weights / bias / scale /
    bitmask all share the N-padding treatment (shared shape policy)."""
    if a is None or extra == 0:
        return a
    return jnp.pad(a, ((0, 0), (0, extra)))


def acc_dtype_for(operand_dtype) -> jnp.dtype:
    """Accumulator dtype on the PE datapath: INT32 for INT8 operands
    (the paper's datapath), f32 otherwise."""
    if operand_dtype == jnp.int8:
        return jnp.dtype(jnp.int32)
    return jnp.dtype(jnp.float32)


# ---------------------------------------------------------------------------
# skinny (decode-shaped) dispatch guard — shared by the GEMM ops wrappers
# and the flash-attention decode kernel's M-gate (DESIGN.md §9/§10)
# ---------------------------------------------------------------------------

# Dispatch cap: decode/serving batches. Above this the M-tiled kernels win
# (the resident A block would crowd out weight streaming double-buffers).
SKINNY_M_MAX = 32

# Named headroom fractions (DESIGN.md §13). KERNEL_VMEM_BUDGET bounds a
# kernel's whole single-buffered working set (defined next to VMEM_BYTES in
# core.sta; re-exported here as the guards' import surface).
# SKINNY_RESIDENT_BUDGET bounds just the grid-constant resident [M, K]
# block of the skinny kernels: a quarter of VMEM, so the streamed weight
# tiles keep their double buffers even at the largest admitted K. The
# analysis verifier asserts the dispatch guards agree with these constants
# (repro.analysis.vmem), so don't respell them as VMEM_BYTES // n literals.
SKINNY_RESIDENT_BUDGET = VMEM_BYTES // 4


def skinny_ok(m: int, k: int, itemsize: int) -> bool:
    """Whether the resident-row-block (skinny) regime applies: M small
    enough and the full padded [M, K] block fits comfortably in VMEM next
    to the streamed operand's double buffers. Used for the skinny GEMM
    kernels (K = d_model) and as the attn decode kernel's M-gate
    (M = GQA group size, K = head_dim)."""
    if m > SKINNY_M_MAX:
        return False
    mp = round_up(max(m, 1), SUBLANE)
    kp = round_up(max(k, 1), 128)
    return mp * kp * itemsize <= SKINNY_RESIDENT_BUDGET


def skinny_dispatch(m: int, k: int, itemsize: int, *pinned) -> bool:
    """The guard both GEMM ops wrappers share: GEMV-shaped call (skinny
    regime) AND no caller-pinned block shape (a nonzero pinned block opts
    out of automatic skinny dispatch)."""
    return not any(pinned) and skinny_ok(m, k, itemsize)
