"""Shared Pallas kernel utilities (TPU target, interpret-mode on CPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

try:  # jax >= 0.7 name
    from jax.experimental.pallas import tpu as pltpu
    CompilerParams = pltpu.CompilerParams
except AttributeError:  # pragma: no cover - older naming
    from jax.experimental.pallas import tpu as pltpu
    CompilerParams = pltpu.TPUCompilerParams  # type: ignore[attr-defined]

__all__ = ["pltpu", "CompilerParams", "on_cpu", "default_interpret",
           "cdiv", "round_up", "popcount_u32", "acc_dtype_for"]


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def default_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode on this CPU container."""
    return on_cpu()


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def popcount_u32(x: jax.Array, bits: int) -> jax.Array:
    """Population count via an unrolled shift-and-add (Pallas-safe: no
    dependence on lax.population_count lowering inside Mosaic)."""
    out = jnp.zeros_like(x)
    for t in range(bits):
        out = out + ((x >> t) & 1)
    return out


def acc_dtype_for(operand_dtype) -> jnp.dtype:
    """Accumulator dtype on the PE datapath: INT32 for INT8 operands
    (the paper's datapath), f32 otherwise."""
    if operand_dtype == jnp.int8:
        return jnp.dtype(jnp.int32)
    return jnp.dtype(jnp.float32)
