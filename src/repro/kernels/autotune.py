"""Measured (bm, bk, bn) block-shape autotuner with a persistent cache
(DESIGN.md §7).

`core.sta.choose_block_shape` is an analytical prior: it honors MXU/VREG
alignment and the VMEM footprint model but never looks at the clock. This
module turns it into a *measured* choice: generate a small candidate
neighborhood around the heuristic (half/double each block dim), drop
everything that violates alignment or the VMEM budget, time each survivor
on the real kernel, and memoize the winner.

Cache key: (kernel, bucket(M), K, N, dtype, epilogue-tag, backend). M is
*bucketed* (`m_bucket`): decode steps walk M through 1..32 as the serving
batch fills and prefill sees 512+, and keying on the exact M would re-tune
(and re-store) a near-identical kernel for every batch size. Buckets are
powers of two up to 512, then multiples of 512 — so decode (M=1-32) and
prefill (M=512+) shapes land in distinct entries and never fight over one
cached block shape, while all batch sizes inside one bucket share the
measurement. Skinny decode kernels additionally key under their own op tag
("sta_gemm_skinny", "dbb_gemm_skinny_*"). Results persist
in a JSON table (default ``~/.cache/repro/autotune.json``, override with
``REPRO_AUTOTUNE_CACHE``) so the sweep cost is paid once per shape per
machine. Set ``REPRO_AUTOTUNE=1`` to let the GEMM wrappers consult the
autotuner instead of the static heuristic; without the env var (and without
an explicit ``autotune=True``) behaviour is unchanged.

Measurement happens eagerly at trace time — the wrappers call in with
concrete (M, K, N), the tuner runs the candidate kernels on synthetic
operands outside the enclosing jit, and only the winning static shape is
baked into the traced computation.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import StaConfig
from repro.core.sta import (KERNEL_VMEM_BUDGET, LANE, SUBLANE,
                            choose_block_shape)

__all__ = [
    "autotune_enabled", "cache_path", "candidate_block_shapes",
    "skinny_candidate_block_shapes", "autotune_block_shape",
    "clear_memory_cache", "m_bucket",
]

BlockShape = Tuple[int, int, int]

# in-memory layer over the on-disk table; maps cache-file path -> table
_MEM: Dict[str, Dict[str, List[int]]] = {}


def autotune_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "0").lower() not in (
        "", "0", "false", "no")


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


def clear_memory_cache() -> None:
    _MEM.clear()


def _load(path: str) -> Dict[str, List[int]]:
    if path not in _MEM:
        table: Dict[str, List[int]] = {}
        try:
            with open(path) as f:
                table = {k: list(map(int, v)) for k, v in json.load(f).items()}
        except (OSError, ValueError):
            pass
        _MEM[path] = table
    return _MEM[path]


def _save(path: str, table: Dict[str, List[int]]) -> None:
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(table, f, indent=0, sort_keys=True)
        os.replace(tmp, path)          # atomic: a crash never corrupts
    except OSError:
        pass                           # cache is an optimization, never fatal


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def m_bucket(m: int) -> int:
    """Bucket M for cache keys: powers of two from 8 up to 512, then
    multiples of 512. Decode (M=1-32) and prefill (M=512+) land in distinct
    entries; batch sizes inside one bucket share a measurement."""
    m = max(m, 1)
    b = 8
    while b < m and b < 512:
        b *= 2
    return b if m <= b else _round_up(m, 512)


def _footprint(bm: int, bk: int, bn: int, itemsize: int) -> int:
    """Same VMEM working-set model as choose_block_shape: two operand tiles
    plus the f32/int32 accumulator tile."""
    return (bm * bk + bk * bn) * itemsize + bm * bn * 4


def candidate_block_shapes(m: int, k: int, n: int,
                           cfg: Optional[StaConfig] = None,
                           itemsize: int = 2,
                           align_k: int = LANE,
                           max_candidates: int = 8) -> List[BlockShape]:
    """Heuristic choice + its half/double neighborhood, constraint-filtered.

    align_k: extra K-tile alignment (the DBB kernel needs bk % B == 0 on top
    of the LANE quantum; pass lcm(LANE, B) — callers pass LANE for dense).
    Constraints: bm % SUBLANE == 0, bn % LANE == 0, bk % align_k == 0,
    footprint ≤ VMEM/2, no block larger than the padded problem dim.
    """
    cfg = cfg or StaConfig()
    base = choose_block_shape(m, k, n, cfg, itemsize=itemsize)
    mp = _round_up(max(m, 1), SUBLANE)
    kp = _round_up(max(k, 1), align_k)
    np_ = _round_up(max(n, 1), LANE)

    def clamp(v: int, quantum: int, hi: int) -> int:
        return max(quantum, min(_round_up(v, quantum), _round_up(hi, quantum)))

    bm0, bk0, bn0 = base
    cands: List[BlockShape] = []
    for fm in (1.0, 0.5, 2.0):
        for fk in (1.0, 0.5, 2.0):
            for fn in (1.0, 0.5, 2.0):
                bm = clamp(int(bm0 * fm), SUBLANE, mp)
                bk = clamp(int(bk0 * fk), align_k, kp)
                bn = clamp(int(bn0 * fn), LANE, np_)
                c = (bm, bk, bn)
                if c in cands:
                    continue
                if _footprint(bm, bk, bn, itemsize) > KERNEL_VMEM_BUDGET:
                    continue
                cands.append(c)
    if not cands:                       # over-constrained: trust the prior
        cands = [base]
    return cands[:max_candidates]


def skinny_candidate_block_shapes(m: int, k: int, n: int,
                                  itemsize: int = 2,
                                  align_k: int = LANE,
                                  max_candidates: int = 8
                                  ) -> List[BlockShape]:
    """Candidates for the skinny weight-streaming kernels (DESIGN.md §9).

    bm is not a free dimension there — the whole padded [mp, K] activation
    block is resident — so candidates vary only (bk, bn) around the
    heuristic prior, and the VMEM filter uses the skinny working set:
    resident A block + streamed weight tile + accumulator.
    """
    cfg = StaConfig()
    mp = _round_up(max(m, 1), SUBLANE)
    np_ = _round_up(max(n, 1), LANE)
    _, bk0, bn0 = choose_block_shape(m, k, n, cfg, itemsize=itemsize)

    def clamp(v: int, quantum: int, hi: int) -> int:
        return max(quantum, min(_round_up(v, quantum), _round_up(hi, quantum)))

    cands: List[BlockShape] = []
    for fk in (1.0, 0.5, 2.0, 4.0):     # weight stream: deeper K tiles too
        for fn in (1.0, 0.5, 2.0):
            bk = clamp(int(bk0 * fk), align_k, max(k, 1))
            bn = clamp(int(bn0 * fn), LANE, np_)
            c = (mp, bk, bn)
            if c in cands:
                continue
            kp = _round_up(max(k, 1), bk)
            if (mp * kp + bk * bn) * itemsize + mp * bn * 4 \
                    > KERNEL_VMEM_BUDGET:
                continue
            cands.append(c)
    if not cands:
        cands = [(mp, clamp(bk0, align_k, max(k, 1)), clamp(bn0, LANE, np_))]
    return cands[:max_candidates]


def _measure(fn: Callable[[], object], repeats: int = 3) -> float:
    """Best-of-N wall time of fn(), compile/warmup excluded."""
    import jax
    jax.block_until_ready(fn())         # warmup (compile / first trace)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_block_shape(
    kernel_name: str,
    m: int, k: int, n: int, dtype,
    make_fn: Callable[[BlockShape], Callable[[], object]],
    *,
    epilogue_tag: str = "none",
    candidates: Optional[Sequence[BlockShape]] = None,
    cfg: Optional[StaConfig] = None,
    itemsize: int = 2,
    align_k: int = LANE,
    repeats: int = 3,
    path: Optional[str] = None,
    measure: bool = True,
) -> BlockShape:
    """Return the fastest measured (bm, bk, bn) for this GEMM shape.

    make_fn(shape) must return a zero-arg callable that runs the kernel once
    with that block shape (on synthetic operands) and returns its output.
    Winners are memoized in memory and on disk; a cache hit never measures.

    measure=False (caller is inside a jit trace, where kernels can't
    execute): cache lookup only — a miss returns the analytical prior and
    caches nothing, so a later eager call can still tune the shape.
    """
    import jax
    path = path or cache_path()
    key = "|".join(str(p) for p in (
        kernel_name, m_bucket(m), k, n, np.dtype(dtype).name, epilogue_tag,
        jax.default_backend()))
    table = _load(path)
    hit = table.get(key)
    if hit is not None:
        return tuple(hit)  # type: ignore[return-value]

    if candidates is None:
        candidates = candidate_block_shapes(
            m, k, n, cfg, itemsize=itemsize, align_k=align_k)
    if not measure:
        return candidates[0]            # the choose_block_shape prior
    best_shape, best_t = candidates[0], float("inf")
    for shape in candidates:
        try:
            t = _measure(make_fn(shape), repeats=repeats)
        except Exception:               # a candidate the backend rejects
            continue
        if t < best_t:
            best_shape, best_t = shape, t
    if best_t == float("inf"):
        # every candidate failed to run: fall back to the analytical prior
        # and do NOT cache — caching would pin a known-failing shape until
        # the user deletes the file
        return best_shape
    table[key] = list(best_shape)
    _save(path, table)
    return best_shape
