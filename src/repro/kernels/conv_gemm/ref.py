"""Pure-jnp oracles for the implicit-GEMM conv kernels.

The reference is the *explicit* lowering the kernel replaces: materialize
the im2col patch matrix, run a dense (or DBB-decompressed) matmul with the
kernel's accumulation semantics, then the identical `apply_epilogue`.
`im2col` is the canonical patch-matrix builder for the whole repo —
`models/cnn.py` re-exports it — so the kernel's in-VMEM gather and the
explicit path share one K-ordering definition (spatial-major (i·kw+j),
channel-minor).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import acc_dtype_for
from repro.kernels.dbb_gemm.ref import decompress_ref
from repro.kernels.epilogue import Epilogue, apply_epilogue, default_out_dtype

__all__ = ["im2col", "conv_gemm_ref", "conv_gemm_dbb_ref"]


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           pad: str = "SAME") -> jax.Array:
    """x: [B, H, W, C] -> patches [B, Ho, Wo, kh*kw*C]."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches yields channel-major [C*kh*kw]; reorder to
    # [kh*kw*C] so K blocks run over spatial-then-channel (any fixed order
    # works for DBB; this matches the conv weight layout [kh*kw*C, N]).
    b, ho, wo, ckk = patches.shape
    c = x.shape[-1]
    patches = patches.reshape(b, ho, wo, c, kh * kw)
    patches = jnp.moveaxis(patches, -2, -1)
    return patches.reshape(b, ho, wo, kh * kw * c)


def conv_gemm_ref(x: jax.Array, w: jax.Array, *,
                  kh: int, kw: int, stride: int = 1, padding: str = "SAME",
                  epilogue: Epilogue = Epilogue(),
                  bias: Optional[jax.Array] = None,
                  scale: Optional[jax.Array] = None,
                  out_dtype=None) -> jax.Array:
    """Explicit im2col + GEMM oracle: [B, H, W, C] × [kh*kw*C, N] →
    [B, Ho, Wo, N], same accumulation dtype and epilogue as the kernel."""
    acc = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)
    cols = im2col(x, kh, kw, stride, padding)          # [B, Ho, Wo, K]
    b, ho, wo, kdim = cols.shape
    y = jax.lax.dot_general(
        cols.reshape(b * ho * wo, kdim), w.astype(x.dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc)
    y = apply_epilogue(y, epilogue, out_dtype, bias=bias, scale=scale)
    return y.reshape(b, ho, wo, w.shape[1])


def conv_gemm_dbb_ref(x: jax.Array, values: jax.Array, bitmask: jax.Array, *,
                      kh: int, kw: int, stride: int = 1,
                      padding: str = "SAME", block: int = 8, nnz: int = 4,
                      epilogue: Epilogue = Epilogue(),
                      bias: Optional[jax.Array] = None,
                      scale: Optional[jax.Array] = None,
                      out_dtype=None) -> jax.Array:
    """DBB oracle: decompress the weight stream densely, then the explicit
    im2col + GEMM path."""
    w = decompress_ref(values, bitmask.astype(jnp.int32), block=block,
                       nnz=nnz)
    return conv_gemm_ref(x, w, kh=kh, kw=kw, stride=stride, padding=padding,
                         epilogue=epilogue, bias=bias, scale=scale,
                         out_dtype=out_dtype)
