from repro.kernels.conv_gemm.ops import (conv_gemm, conv_gemm_dbb,
                                         conv_gemm_packed)

__all__ = ["conv_gemm", "conv_gemm_dbb", "conv_gemm_packed"]
