"""Implicit-GEMM convolution Pallas kernels (fused im2col in-kernel).

The paper lowers every conv layer to GEMM via im2col, and `models/cnn.py`
used to do that literally: materialize the patch matrix
``[B·Ho·Wo, kh·kw·C]`` in HBM (a kh·kw× activation blowup — 9× for 3×3)
and feed it to `sta_gemm`/`dbb_gemm`. Hardware im2col units (SPOTS,
arXiv:2107.13386) build the patch stream *inside* the systolic pipeline
instead; this kernel is the TPU analogue: the K-loop of the GEMM gathers
the ``(kh, kw, C)`` patch tile directly from the NHWC activation block in
VMEM, so the im2col tensor never exists in HBM (DESIGN.md §8).

Decomposition (DESIGN.md §8):

    out[b, oh, ow, n] = Σ_{i,j,c} x_pad[b, oh·s+i, ow·s+j, c] · w[(i·kw+j)·C+c, n]

    grid = (B, Ho/th, N/bn, kh)       th output rows per M tile, bm = th·Wo
    K step i (one kernel ROW offset, kw·C contraction columns):
      slab  = x[0, i + t0·s : i + t0·s + (th-1)·s + 1 : s, :, :]   # th rows
      patch = stack_j slab[:, j : j+(Wo-1)·s+1 : s, :]             # [th,Wo,kw,C]
      acc  += patch.reshape(th·Wo, kw·C) @ w_tile                  # MXU dot

The patch gather is a dynamic-start row slice plus kw static shifted
column slices of the VMEM-resident image block — no HBM gather, no
scatter. K ordering matches `conv_gemm.ref.im2col` exactly: spatial-major
(i·kw+j), channel-minor, so the weight matrix is the same ``[kh·kw·C, N]``
layout the explicit-im2col path consumes, and DBB 8×1 blocks run along it.
A K tile covers whole DBB blocks whenever ``(kw·C) % B == 0`` (the ops
layer enforces this for the packed variant).

The whole padded image ``[Hp, Wp, C]`` rides in VMEM as one block (mobile
CNN images are small: 32·32·512·4B = 2 MiB); the accumulator tile is
output-stationary scratch across the kh K steps, identical to the dense
STA kernel, and the shared `Epilogue` (bias/act/requant) runs on the final
K store.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import CompilerParams, acc_dtype_for, pltpu
from repro.kernels.dbb_gemm.kernel import _decompress_tile
from repro.kernels.epilogue import Epilogue, apply_epilogue, default_out_dtype

__all__ = ["conv_gemm_pallas", "conv_gemm_dbb_pallas"]


def _gather_patch_tile(x_ref, *, th: int, wo: int, kw: int, stride: int):
    """In-kernel im2col of one M×K tile: [th·wo, kw·C] patch rows for the
    current (image-row tile, kernel-row offset) grid step.

    x_ref block is the whole padded image [1, Hp, Wp, C]; the row slab is a
    dynamic-start slice (start depends on grid ids), the kw column shifts
    are static strided slices of the loaded slab."""
    ih = pl.program_id(1)                  # output-row tile index
    ki = pl.program_id(3)                  # kernel row offset i ∈ [0, kh)
    rows = (th - 1) * stride + 1
    r0 = ih * (th * stride) + ki
    slab = x_ref[0, pl.ds(r0, rows)]       # [rows, Wp, C]
    if stride > 1:
        slab = slab[::stride]              # [th, Wp, C]
    cols = (wo - 1) * stride + 1
    parts = [slab[:, j:j + cols:stride, :] for j in range(kw)]
    patch = jnp.stack(parts, axis=2)       # [th, wo, kw, C]
    c = patch.shape[-1]
    return patch.reshape(th * wo, kw * c)  # K order: j-major, c-minor


def _accumulate(acc_ref, patch, w):
    acc_ref[...] += jax.lax.dot_general(
        patch, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)


def _store_epilogue(o_ref, acc_ref, bias_ref, scale_ref, *, epilogue,
                    out_dtype, th: int, wo: int):
    y = apply_epilogue(
        acc_ref[...], epilogue, out_dtype,
        bias=bias_ref[...] if bias_ref is not None else None,
        scale=scale_ref[...] if scale_ref is not None else None)
    o_ref[...] = y.reshape(1, th, wo, y.shape[-1])


def _conv_gemm_kernel(x_ref, w_ref, *refs, kh: int, kw: int, stride: int,
                      th: int, wo: int, out_dtype, epilogue: Epilogue):
    refs = list(refs)
    bias_ref = refs.pop(0) if epilogue.has_bias else None
    scale_ref = refs.pop(0) if epilogue.has_scale else None
    o_ref, acc_ref = refs
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    patch = _gather_patch_tile(x_ref, th=th, wo=wo, kw=kw, stride=stride)
    _accumulate(acc_ref, patch, w_ref[...])

    @pl.when(ki == kh - 1)
    def _store():
        _store_epilogue(o_ref, acc_ref, bias_ref, scale_ref,
                        epilogue=epilogue, out_dtype=out_dtype, th=th, wo=wo)


def _conv_gemm_dbb_kernel(x_ref, v_ref, m_ref, *refs, kh: int, kw: int,
                          stride: int, th: int, wo: int, block: int, nnz: int,
                          out_dtype, epilogue: Epilogue):
    """DBB variant: the weight K tile arrives compressed (values + bitmask)
    and is expanded in VMEM right before the dot — identical decompression
    to the dbb_gemm kernel, so the weight stream stays at the packed 62.5%
    of dense bytes end-to-end (cf. S2TA, arXiv:2107.07983)."""
    refs = list(refs)
    bias_ref = refs.pop(0) if epilogue.has_bias else None
    scale_ref = refs.pop(0) if epilogue.has_scale else None
    o_ref, acc_ref = refs
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    patch = _gather_patch_tile(x_ref, th=th, wo=wo, kw=kw, stride=stride)
    w = _decompress_tile(v_ref[...], m_ref[...], block=block, nnz=nnz)
    _accumulate(acc_ref, patch, w.astype(patch.dtype))

    @pl.when(ki == kh - 1)
    def _store():
        _store_epilogue(o_ref, acc_ref, bias_ref, scale_ref,
                        epilogue=epilogue, out_dtype=out_dtype, th=th, wo=wo)


def _conv_specs(b: int, hp: int, wp: int, c: int, hot: int, wo: int,
                np_: int, th: int, bn: int, kh: int, epilogue: Epilogue,
                bias, scale):
    """Shared grid/spec plumbing for both variants (x, out, bias, scale)."""
    grid = (b, hot // th, np_ // bn, kh)
    x_spec = pl.BlockSpec((1, hp, wp, c), lambda bb, ih, jn, ki: (bb, 0, 0, 0))
    out_spec = pl.BlockSpec((1, th, wo, bn),
                            lambda bb, ih, jn, ki: (bb, ih, 0, jn))
    row_spec = pl.BlockSpec((1, bn), lambda bb, ih, jn, ki: (0, jn))
    extra_ops, extra_specs = [], []
    if epilogue.has_bias:
        assert bias is not None and bias.shape == (1, np_), (
            "bias must be [1, N]", None if bias is None else bias.shape, np_)
        extra_ops.append(bias)
        extra_specs.append(row_spec)
    if epilogue.has_scale:
        assert scale is not None and scale.shape == (1, np_), (
            "scale must be [1, N]", None if scale is None else scale.shape,
            np_)
        extra_ops.append(scale)
        extra_specs.append(row_spec)
    return grid, x_spec, out_spec, extra_ops, extra_specs


def conv_gemm_pallas(
    x: jax.Array,              # [B, Hp, Wp, C] spatially pre-padded NHWC
    w: jax.Array,              # [kh*kw*C, N] spatial-major, channel-minor
    bias: Optional[jax.Array] = None,    # [1, N] f32
    scale: Optional[jax.Array] = None,   # [1, N] f32
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    th: int,                   # output rows per M tile (bm = th * Wo)
    block_n: int = 128,
    epilogue: Epilogue = Epilogue(),
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Implicit-GEMM conv: returns [B, Hot, Wo, N] where Hot = the padded
    output-row count implied by Hp (the ops layer slices back to Ho).

    Contract: x is already padded so that Hp = (Hot-1)·stride + kh and
    Wp = (Wo-1)·stride + kw; N % block_n == 0; Hot % th == 0.
    """
    b, hp, wp, c = x.shape
    kdim, n = w.shape
    assert kdim == kh * kw * c, (w.shape, kh, kw, c)
    assert (hp - kh) % stride == 0 and (wp - kw) % stride == 0, (
        "pad spatial dims at the ops layer", x.shape, kh, kw, stride)
    hot = (hp - kh) // stride + 1
    wo = (wp - kw) // stride + 1
    assert hot % th == 0, (hot, th)
    assert n % block_n == 0, (n, block_n)
    acc_dtype = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)

    grid, x_spec, out_spec, extra_ops, extra_specs = _conv_specs(
        b, hp, wp, c, hot, wo, n, th, block_n, kh, epilogue, bias, scale)
    w_spec = pl.BlockSpec((kw * c, block_n), lambda bb, ih, jn, ki: (ki, jn))

    kernel = functools.partial(
        _conv_gemm_kernel, kh=kh, kw=kw, stride=stride, th=th, wo=wo,
        out_dtype=out_dtype, epilogue=epilogue)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, w_spec] + extra_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, hot, wo, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((th * wo, block_n), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w, *extra_ops)


def conv_gemm_dbb_pallas(
    x: jax.Array,              # [B, Hp, Wp, C] spatially pre-padded NHWC
    values: jax.Array,         # [kh*kw*C/B * k, N] compressed (slot-major)
    bitmask: jax.Array,        # [kh*kw*C/B, N] int32
    bias: Optional[jax.Array] = None,    # [1, N] f32
    scale: Optional[jax.Array] = None,   # [1, N] f32
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    th: int,
    block: int = 8,
    nnz: int = 4,
    block_n: int = 128,
    epilogue: Epilogue = Epilogue(),
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Implicit-GEMM conv against a DBB-compressed weight stream.

    Same contract as `conv_gemm_pallas` plus the DBB block geometry: the
    per-K-step contraction span is kw·C rows, which must cover whole DBB
    blocks — (kw·C) % block == 0 (the ops layer guards this).
    """
    b, hp, wp, c = x.shape
    kdim = kh * kw * c
    kc, n = values.shape
    nb_total = kdim // block
    assert kdim % block == 0 and (kw * c) % block == 0, (
        "K tile must cover whole DBB blocks", kh, kw, c, block)
    assert kc == nb_total * nnz, (values.shape, kdim, block, nnz)
    assert bitmask.shape == (nb_total, n), bitmask.shape
    assert (hp - kh) % stride == 0 and (wp - kw) % stride == 0, (
        "pad spatial dims at the ops layer", x.shape, kh, kw, stride)
    hot = (hp - kh) // stride + 1
    wo = (wp - kw) // stride + 1
    assert hot % th == 0, (hot, th)
    assert n % block_n == 0, (n, block_n)
    acc_dtype = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)

    nb_step = (kw * c) // block            # DBB blocks per K step
    grid, x_spec, out_spec, extra_ops, extra_specs = _conv_specs(
        b, hp, wp, c, hot, wo, n, th, block_n, kh, epilogue, bias, scale)
    v_spec = pl.BlockSpec((nb_step * nnz, block_n),
                          lambda bb, ih, jn, ki: (ki, jn))
    m_spec = pl.BlockSpec((nb_step, block_n),
                          lambda bb, ih, jn, ki: (ki, jn))

    kernel = functools.partial(
        _conv_gemm_dbb_kernel, kh=kh, kw=kw, stride=stride, th=th, wo=wo,
        block=block, nnz=nnz, out_dtype=out_dtype, epilogue=epilogue)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, v_spec, m_spec] + extra_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, hot, wo, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((th * wo, block_n), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, values, bitmask, *extra_ops)
