"""Jit'd public wrappers around the implicit-GEMM conv kernels.

`conv_gemm` consumes a dense weight matrix ``[kh·kw·C, N]`` (the exact
layout the explicit im2col path uses), `conv_gemm_dbb` the raw DBB stream
(values, bitmask), and `conv_gemm_packed` a `core.dbb.DbbWeight` with its
per-out-channel quant scale folded into the fused epilogue — mirroring
`sta_gemm` / `dbb_gemm` / `dbb_gemm_packed` one-for-one.

The wrappers own everything the kernel contract excludes: SAME/VALID pad
arithmetic (XLA semantics: lo = total//2), bottom-row padding so the
output-row count divides the row tile, N padding to the lane grid,
f32 coercion of the epilogue operands, and the oracle fallback
(``use_kernel=False`` → `conv_gemm_ref`, the explicit im2col + GEMM path).

Tile selection follows the GEMM wrappers' split: the public functions are
*plain* (they resolve (th, bn) eagerly — the measured autotuner needs
concrete operands) and dispatch to an inner jit'd impl with the tiles as
static args. The autotuner memoizes under its own op tag
(``conv_gemm`` / ``conv_gemm_dbb_b{B}k{k}``) keyed by the implied GEMM
shape (M = B·Ho·Wo, K = kh·kw·C, N) plus the conv geometry in the
epilogue tag, so conv entries never collide with plain GEMM entries.

VMEM guard: the kernel keeps one whole padded image resident per grid
step, which is the right trade for mobile-CNN activations (≤ a few MiB)
but not for arbitrary inputs — images whose block footprint exceeds the
VMEM budget silently take the oracle path instead (numerically identical,
just materialized).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dbb import DbbWeight
from repro.kernels.common import (KERNEL_VMEM_BUDGET, coerce_bias_scale,
                                  default_interpret, pad_cols, round_up)
from repro.kernels.conv_gemm.kernel import (conv_gemm_dbb_pallas,
                                            conv_gemm_pallas)
from repro.kernels.conv_gemm.ref import conv_gemm_dbb_ref, conv_gemm_ref
from repro.kernels.epilogue import Epilogue, as_row

__all__ = ["conv_gemm", "conv_gemm_dbb", "conv_gemm_packed", "out_spatial"]


def out_spatial(size: int, k: int, stride: int, padding: str
                ) -> Tuple[int, int, int]:
    """(out, pad_lo, pad_hi) for one spatial dim — XLA SAME/VALID rules."""
    if padding == "VALID":
        return max(0, (size - k) // stride + 1), 0, 0
    if padding != "SAME":
        raise ValueError(f"padding={padding!r} not in ('SAME', 'VALID')")
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return out, total // 2, total - total // 2


def _vmem_fits(hp: int, wp: int, c: int, kw: int, th: int, wo: int, bn: int,
               itemsize: int, dbb: bool = False) -> bool:
    """Image block + one weight K tile (+ its decompressed copy for the
    DBB variant) + accumulator + output tile."""
    w_tile = kw * c * bn * itemsize
    foot = (hp * wp * c * itemsize            # resident image
            + w_tile                          # weight K tile [kw·C, bn]
            + (w_tile if dbb else 0)          # in-VMEM decompressed dense
            + th * wo * bn * 4                # accumulator scratch
            + th * wo * bn * 4)               # output tile
    return foot <= KERNEL_VMEM_BUDGET


def _default_tiles(ho: int, wo: int) -> Tuple[int, int]:
    """th so the M tile th·Wo lands near 128 rows; bn = one lane tile."""
    th = max(1, min(ho, -(-128 // max(wo, 1))))
    return th, 128


def _synth(shape, dtype, rng) -> jax.Array:
    """Synthetic autotune operand matching the caller's dtype regime."""
    if jnp.dtype(dtype) == jnp.int8:
        return jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _pad_input(x: jax.Array, kh: int, kw: int, stride: int, padding: str,
               th: int) -> Tuple[jax.Array, int, int, int]:
    """Spatially pad/crop x to the kernel contract. Returns
    (xp [B, Hp, Wp, C], ho, wo, hot) with Hp = (hot-1)·s + kh and
    Wp = (wo-1)·s + kw; rows past ho are zero-padding (sliced off after)."""
    b, h, w, c = x.shape
    ho, pt, pb = out_spatial(h, kh, stride, padding)
    wo, pl_, pr = out_spatial(w, kw, stride, padding)
    hot = round_up(max(ho, 1), th)
    xp = jnp.pad(x, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    # crop VALID leftovers, then pad the bottom out to the row-tile grid
    xp = xp[:, :(ho - 1) * stride + kh, :(wo - 1) * stride + kw, :]
    extra = (hot - 1) * stride + kh - xp.shape[1]
    if extra > 0:
        xp = jnp.pad(xp, ((0, 0), (0, extra), (0, 0), (0, 0)))
    return xp, ho, wo, hot


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "act", "th", "bn",
                     "out_dtype", "interpret", "use_kernel"))
def _conv_gemm_impl(x, w, bias, scale, *, kh, kw, stride, padding, act, th,
                    bn, out_dtype, interpret, use_kernel):
    epilogue = Epilogue(act=act, has_bias=bias is not None,
                        has_scale=scale is not None)
    n = w.shape[1]
    bias_r = as_row(bias, n) if bias is not None else None
    scale_r = as_row(scale, n) if scale is not None else None

    if not use_kernel:
        return conv_gemm_ref(x, w, kh=kh, kw=kw, stride=stride,
                             padding=padding, epilogue=epilogue, bias=bias_r,
                             scale=scale_r, out_dtype=out_dtype)

    xp, ho, wo, hot = _pad_input(x, kh, kw, stride, padding, th)
    np_ = round_up(n, bn)
    wp = pad_cols(w, np_ - n)
    bias_r = pad_cols(bias_r, np_ - n)
    scale_r = pad_cols(scale_r, np_ - n)
    y = conv_gemm_pallas(xp, wp, bias_r, scale_r, kh=kh, kw=kw,
                         stride=stride, th=th, block_n=bn, epilogue=epilogue,
                         out_dtype=out_dtype, interpret=interpret)
    return y[:, :ho, :, :n]


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "act", "block", "nnz",
                     "th", "bn", "out_dtype", "interpret", "use_kernel"))
def _conv_gemm_dbb_impl(x, values, bitmask, bias, scale, *, kh, kw, stride,
                        padding, act, block, nnz, th, bn, out_dtype,
                        interpret, use_kernel):
    epilogue = Epilogue(act=act, has_bias=bias is not None,
                        has_scale=scale is not None)
    n = values.shape[1]
    mask_i32 = bitmask.astype(jnp.int32)
    bias_r = as_row(bias, n) if bias is not None else None
    scale_r = as_row(scale, n) if scale is not None else None

    if not use_kernel:
        return conv_gemm_dbb_ref(x, values, mask_i32, kh=kh, kw=kw,
                                 stride=stride, padding=padding, block=block,
                                 nnz=nnz, epilogue=epilogue, bias=bias_r,
                                 scale=scale_r, out_dtype=out_dtype)

    xp, ho, wo, hot = _pad_input(x, kh, kw, stride, padding, th)
    np_ = round_up(n, bn)
    vp = pad_cols(values, np_ - n)
    mp = pad_cols(mask_i32, np_ - n)
    bias_r = pad_cols(bias_r, np_ - n)
    scale_r = pad_cols(scale_r, np_ - n)
    y = conv_gemm_dbb_pallas(xp, vp, mp, bias_r, scale_r, kh=kh, kw=kw,
                             stride=stride, th=th, block=block, nnz=nnz,
                             block_n=bn, epilogue=epilogue,
                             out_dtype=out_dtype, interpret=interpret)
    return y[:, :ho, :, :n]


def _resolve_tiles(x, n: int, kh: int, kw: int, stride: int, padding: str,
                   epilogue: Epilogue, out_dtype, interpret: bool,
                   rows_per_tile: int, block_n: int, autotune,
                   kernel_tag: str, make_fn, dbb: bool = False
                   ) -> Tuple[int, int, bool]:
    """(th, bn, kernel_ok): measured or heuristic tiles + the VMEM guard.

    make_fn(shape=(th, ·, bn)) → zero-arg kernel runner on synthetic
    operands (the autotuner's measurement hook)."""
    b, h, w_dim, c = x.shape
    ho, pt, pb = out_spatial(h, kh, stride, padding)
    wo, _, _ = out_spatial(w_dim, kw, stride, padding)
    th0, bn0 = _default_tiles(ho, wo)
    th = rows_per_tile or th0
    bn = block_n or bn0
    itemsize = jnp.dtype(x.dtype).itemsize
    if autotune is None:
        from repro.kernels.autotune import autotune_enabled
        autotune = (not (rows_per_tile or block_n)) and autotune_enabled()
    if autotune:
        from repro.kernels import autotune as at
        kdim = kh * kw * c
        cands = []
        for tc in (th0, max(1, th0 // 2), min(ho, th0 * 2),
                   min(ho, th0 * 4)):
            for bnc in (128, 256):
                if bnc > round_up(n, 128):
                    continue
                cand = (tc, kw * c, bnc)
                hp_c = (round_up(ho, tc) - 1) * stride + kh
                wp_c = (wo - 1) * stride + kw
                if not _vmem_fits(hp_c, wp_c, c, kw, tc, wo, bnc, itemsize,
                                  dbb):
                    continue
                if cand not in cands:
                    cands.append(cand)
        if cands:
            tag = (f"conv{kh}x{kw}s{stride}p{padding[0]}wo{wo}|"
                   f"{epilogue.tag()}>"
                   f"{jnp.dtype(out_dtype).name if out_dtype else 'auto'}")
            measure = not isinstance(x, jax.core.Tracer)
            shape = at.autotune_block_shape(
                kernel_tag, b * ho * wo, kdim, n, x.dtype, make_fn,
                epilogue_tag=tag, candidates=cands, itemsize=itemsize,
                measure=measure)
            th, _, bn = shape
    th = max(1, min(th, max(ho, 1)))
    hp = (round_up(max(ho, 1), th) - 1) * stride + kh
    wp = (wo - 1) * stride + kw
    kernel_ok = _vmem_fits(hp, wp, c, kw, th, wo, bn, itemsize, dbb)
    return th, bn, kernel_ok


def conv_gemm(
    x: jax.Array,              # [B, H, W, C] NHWC
    w: jax.Array,              # [kh*kw*C, N] spatial-major, channel-minor
    bias: Optional[jax.Array] = None,    # [N] f32 — fused epilogue
    scale: Optional[jax.Array] = None,   # scalar/[N] f32 — fused epilogue
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    act: str = "none",
    rows_per_tile: int = 0,    # 0 = unpinned (heuristic or autotuner)
    block_n: int = 0,
    out_dtype=None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    autotune: Optional[bool] = None,
) -> jax.Array:
    """Implicit-GEMM convolution: ``conv2d(x, w) (+bias, act, requant)`` →
    [B, Ho, Wo, N], with the im2col patch matrix gathered in-kernel
    (DESIGN.md §8) — it never exists in HBM.

    ``w`` is the GEMM weight matrix of the explicit lowering
    ([kh·kw·C, N], spatial-major, channel-minor — `conv_gemm.ref.im2col`
    order); bias/scale/act fuse into the final-K store exactly as in
    `sta_gemm`. ``use_kernel=False`` runs the explicit im2col + GEMM
    oracle instead (the pre-PR-2 path).
    """
    if interpret is None:
        interpret = default_interpret()
    bias, scale = coerce_bias_scale(bias, scale)
    assert w.shape[0] == kh * kw * x.shape[-1], (w.shape, kh, kw, x.shape)
    th, bn, kernel_ok = 1, 128, False
    if use_kernel:
        epi = Epilogue(act=act, has_bias=bias is not None,
                       has_scale=scale is not None)
        n = w.shape[1]

        def make_fn(shape):
            tc, _, bnc = shape
            import numpy as np
            rng = np.random.default_rng(0)
            bias_s = jnp.zeros((n,), jnp.float32) if epi.has_bias else None
            scale_s = jnp.ones((n,), jnp.float32) if epi.has_scale else None
            return lambda: _conv_gemm_impl(
                _synth(x.shape, x.dtype, rng), _synth(w.shape, x.dtype, rng),
                bias_s, scale_s, kh=kh, kw=kw, stride=stride,
                padding=padding, act=act, th=tc, bn=bnc,
                out_dtype=out_dtype, interpret=interpret, use_kernel=True)

        th, bn, kernel_ok = _resolve_tiles(
            x, n, kh, kw, stride, padding, epi, out_dtype,
            interpret, rows_per_tile, block_n, autotune, "conv_gemm",
            make_fn)
    return _conv_gemm_impl(x, w, bias, scale, kh=kh, kw=kw, stride=stride,
                           padding=padding, act=act, th=th, bn=bn,
                           out_dtype=out_dtype, interpret=interpret,
                           use_kernel=use_kernel and kernel_ok)


def conv_gemm_dbb(
    x: jax.Array,              # [B, H, W, C] NHWC
    values: jax.Array,         # [kh*kw*C/B * k, N]
    bitmask: jax.Array,        # [kh*kw*C/B, N] integer
    bias: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    act: str = "none",
    block: int = 8,
    nnz: int = 4,
    rows_per_tile: int = 0,
    block_n: int = 0,
    out_dtype=None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    autotune: Optional[bool] = None,
) -> jax.Array:
    """Implicit-GEMM conv against the raw DBB weight stream — the weight
    bytes stay compressed in HBM and expand in VMEM per K tile.

    Kernel route requires (kw·C) % block == 0 (K steps cover whole DBB
    blocks — DESIGN.md §8); other geometries take the dense oracle."""
    if interpret is None:
        interpret = default_interpret()
    bias, scale = coerce_bias_scale(bias, scale)
    c = x.shape[-1]
    kdim = kh * kw * c
    assert bitmask.shape[0] * block == kdim, (bitmask.shape, kdim, block)
    blocks_ok = (kw * c) % block == 0
    th, bn, kernel_ok = 1, 128, False
    if use_kernel and blocks_ok:
        epi = Epilogue(act=act, has_bias=bias is not None,
                       has_scale=scale is not None)
        n = values.shape[1]

        def make_fn(shape):
            tc, _, bnc = shape
            import numpy as np
            rng = np.random.default_rng(0)
            ms = jnp.full(bitmask.shape, (1 << nnz) - 1, jnp.int32)
            bias_s = jnp.zeros((n,), jnp.float32) if epi.has_bias else None
            scale_s = jnp.ones((n,), jnp.float32) if epi.has_scale else None
            return lambda: _conv_gemm_dbb_impl(
                _synth(x.shape, x.dtype, rng),
                _synth(values.shape, values.dtype, rng), ms, bias_s, scale_s,
                kh=kh, kw=kw, stride=stride, padding=padding, act=act,
                block=block, nnz=nnz, th=tc, bn=bnc, out_dtype=out_dtype,
                interpret=interpret, use_kernel=True)

        th, bn, kernel_ok = _resolve_tiles(
            x, n, kh, kw, stride, padding, epi, out_dtype,
            interpret, rows_per_tile, block_n, autotune,
            f"conv_gemm_dbb_b{block}k{nnz}", make_fn, dbb=True)
    return _conv_gemm_dbb_impl(
        x, values, bitmask, bias, scale, kh=kh, kw=kw, stride=stride,
        padding=padding, act=act, block=block, nnz=nnz, th=th, bn=bn,
        out_dtype=out_dtype, interpret=interpret,
        use_kernel=use_kernel and blocks_ok and kernel_ok)


def conv_gemm_packed(x: jax.Array, p: DbbWeight,
                     bias: Optional[jax.Array] = None, *,
                     kh: int, kw: int, stride: int = 1,
                     padding: str = "SAME", act: str = "none",
                     out_dtype=None, interpret: Optional[bool] = None,
                     use_kernel: bool = True, **tile_kw) -> jax.Array:
    """Implicit-GEMM conv against a packed `DbbWeight` (k_dim = kh·kw·C).

    The per-out-channel quant scale (if any) fuses into the kernel epilogue
    with the optional bias and activation, exactly like `dbb_gemm_packed`.
    """
    assert p.k_dim == kh * kw * x.shape[-1], (p.k_dim, kh, kw, x.shape)
    assert p.bits != 4, ("conv kernels stream the INT8 DBB plane only; "
                         "dispatch.conv decompresses w4 leaves up front")
    return conv_gemm_dbb(x, p.values, p.bitmask, bias, p.scale,
                         kh=kh, kw=kw, stride=stride, padding=padding,
                         act=act, block=p.block, nnz=p.nnz,
                         out_dtype=out_dtype, interpret=interpret,
                         use_kernel=use_kernel, **tile_kw)
