"""KernelContract declarations for the implicit-GEMM conv kernels
(`conv_gemm_pallas` / `conv_gemm_dbb_pallas`) — DESIGN.md §13.

Grid (B, Hot/th, Np/bn, kh): the padded NHWC image block for one batch
row stays in VMEM across the kh K steps (its index map ignores every
grid dim but the batch), the weight K tile ``[kw·C, bn]`` streams per
kernel row, and the output tile accumulates over the kh dim. Admission
is the real `_vmem_fits` guard; a deliberately oversized image instance
pins the reject direction.
"""
from __future__ import annotations

from typing import List

from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET
from repro.kernels.common import round_up
from repro.kernels.conv_gemm.ops import _default_tiles, _vmem_fits, \
    out_spatial

__all__ = ["contracts"]


def _instance(b: int, h: int, w: int, c: int, kh: int, kw: int,
              stride: int, n: int, *, itemsize: int = 4,
              dbb: bool = False, block: int = 8, nnz: int = 4
              ) -> KernelContract:
    ho, _, _ = out_spatial(h, kh, stride, "SAME")
    wo, _, _ = out_spatial(w, kw, stride, "SAME")
    th, bn = _default_tiles(ho, wo)
    hot = round_up(ho, th)
    hp = (hot - 1) * stride + kh
    wp = (wo - 1) * stride + kw
    np_ = round_up(n, bn)
    grid = (b, hot // th, np_ // bn, kh)
    admitted = _vmem_fits(hp, wp, c, kw, th, wo, bn, itemsize, dbb)
    if dbb:
        admitted = admitted and (kw * c) % block == 0

    inputs = [BlockDecl("x", (1, hp, wp, c),
                        lambda bb, ih, jn, ki: (bb, 0, 0, 0),
                        (b, hp, wp, c), itemsize)]
    extra = 0
    if dbb:
        nb_step = kw * c // block
        nb_total = kh * nb_step
        inputs += [
            BlockDecl("values", (nb_step * nnz, bn),
                      lambda bb, ih, jn, ki: (ki, jn),
                      (nb_total * nnz, np_), itemsize),
            BlockDecl("bitmask", (nb_step, bn),
                      lambda bb, ih, jn, ki: (ki, jn), (nb_total, np_), 4),
        ]
        extra = kw * c * bn * itemsize  # decompressed dense K tile
    else:
        inputs.append(BlockDecl("w", (kw * c, bn),
                                lambda bb, ih, jn, ki: (ki, jn),
                                (kh * kw * c, np_), itemsize))

    kind = "conv_dbb" if dbb else "conv_sta"
    tag = f"b{b} {h}x{w}x{c} k{kh}x{kw} s{stride} n{n}"
    return KernelContract(
        name=f"{kind}[{tag}]", route=kind, domain="conv",
        grid=grid,
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"),
        inputs=tuple(inputs),
        outputs=(BlockDecl("out", (1, th, wo, bn),
                           lambda bb, ih, jn, ki: (bb, ih, 0, jn),
                           (b, hot, wo, np_), 4),),
        scratch=(ScratchDecl("acc", (th * wo, bn), 4),),
        acc_dims=(3,), guarded_init=True, guarded_store=True,
        vmem_budget=KERNEL_VMEM_BUDGET,
        extra_vmem_bytes=extra,
        admitted=admitted, vmem_reject=not admitted)


def contracts() -> List[KernelContract]:
    return [
        _instance(2, 16, 16, 16, 3, 3, 1, 32),        # smoke convnet block
        _instance(4, 32, 32, 32, 3, 3, 2, 64),        # strided downsample
        _instance(2, 16, 16, 16, 3, 3, 1, 32, dbb=True),
        _instance(1, 256, 256, 64, 3, 3, 1, 64),      # rejected: image > VMEM
    ]
