"""Skinny-M weight-streaming GEMM kernels (decode fast path, DESIGN.md §9)."""
from repro.kernels.skinny.kernel import (SKINNY_M_MAX, dbb_gemm_skinny_pallas,
                                         skinny_ok, sta_gemm_skinny_pallas)

__all__ = ["SKINNY_M_MAX", "skinny_ok", "sta_gemm_skinny_pallas",
           "dbb_gemm_skinny_pallas"]
