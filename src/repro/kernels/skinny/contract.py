"""KernelContract declarations for the skinny weight-streaming kernels
(`sta_gemm_skinny_pallas` / `dbb_gemm_skinny_pallas`) — DESIGN.md §13.

The decode-shaped regime: the whole padded activation block ``[mp, kp]``
is grid-constant (``resident``) while weight tiles stream over an
(N, K) grid; the output row block is revisited over the K dim. The
resident block is budgeted separately (`SKINNY_RESIDENT_BUDGET`,
VMEM/4) — exactly what `skinny_ok` enforces — and the contract set
includes both sides of that boundary so guard/constant drift in either
direction trips the vmem pass: the largest K that exactly fills the
budget (admitted) and one K tile beyond it (rejected).
"""
from __future__ import annotations

from typing import List

from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET, LANE, SUBLANE
from repro.kernels.common import SKINNY_RESIDENT_BUDGET, round_up, skinny_ok

__all__ = ["contracts"]


def _instance(m: int, k: int, n: int, *, itemsize: int = 4,
              dbb: bool = False, block: int = 8, nnz: int = 4,
              bits: int = 8, group: int = 0) -> KernelContract:
    mp = round_up(max(m, 1), SUBLANE)
    kp = round_up(max(k, 1), LANE)
    np_ = round_up(max(n, 1), LANE)
    bk, bn = LANE, LANE
    grid = (np_ // bn, kp // bk)
    admitted = skinny_ok(m, k, itemsize)
    if dbb:
        admitted = admitted and k % block == 0
    if bits == 4:
        admitted = admitted and group > 0 and k % group == 0

    inputs = [BlockDecl("x", (mp, kp), lambda j, kk: (0, 0), (mp, kp),
                        itemsize, resident=True)]
    extra = 0
    if dbb:
        nb_tile = bk // block
        nb_total = kp // block
        kc_tile = nb_tile * nnz        # compressed (int8-slot) rows/tile
        if bits == 4:
            gpt = max(bk // group, 1)  # scale groups covered per K tile
            gdiv = max(group // bk, 1)
            inputs += [
                # nibble plane: two compressed rows per streamed byte row
                BlockDecl("values", (kc_tile // 2, bn),
                          lambda j, kk: (kk, j),
                          (nb_total * nnz // 2, np_), 1),
                BlockDecl("bitmask", (nb_tile, bn), lambda j, kk: (kk, j),
                          (nb_total, np_), 4),
                BlockDecl("gscale", (gpt, bn),
                          lambda j, kk: (kk // gdiv, j),
                          (kp // group, np_), 4),
            ]
            # expansion chain per tile, all live in VMEM at the
            # decompress step: unpacked int8 slots + dense int8 tile +
            # dequantized f32 tile (DESIGN.md §16)
            extra = kc_tile * bn + bk * bn + bk * bn * 4
        else:
            inputs += [
                BlockDecl("values", (kc_tile, bn),
                          lambda j, kk: (kk, j), (nb_total * nnz, np_),
                          itemsize),
                BlockDecl("bitmask", (nb_tile, bn), lambda j, kk: (kk, j),
                          (nb_total, np_), 4),
            ]
            extra = bk * bn * itemsize  # decompressed dense weight tile
    else:
        inputs.append(BlockDecl("w", (bk, bn), lambda j, kk: (kk, j),
                                (kp, np_), itemsize))

    kind = ("skinny_dbb_w4" if bits == 4 else
            "skinny_dbb" if dbb else "skinny_sta")
    return KernelContract(
        name=f"{kind}[m{m} k{k} n{n} i{itemsize}]",
        route=kind, domain="matmul",
        grid=grid,
        dimension_semantics=("parallel", "arbitrary"),
        inputs=tuple(inputs),
        outputs=(BlockDecl("out", (mp, bn), lambda j, kk: (0, j),
                           (mp, np_), 4),),
        scratch=(ScratchDecl("acc", (mp, bn), 4),),
        acc_dims=(1,), guarded_init=True, guarded_store=True,
        vmem_budget=KERNEL_VMEM_BUDGET,
        resident_budget=SKINNY_RESIDENT_BUDGET,
        extra_vmem_bytes=extra,
        admitted=admitted, vmem_reject=not admitted)


def contracts() -> List[KernelContract]:
    # K that exactly fills the resident budget for mp = 8, f32 — and the
    # first K one lane-tile past it (rejected by skinny_ok)
    k_fit = SKINNY_RESIDENT_BUDGET // (SUBLANE * 4)
    return [
        _instance(1, 2048, 32000),                    # decode head GEMV
        _instance(8, 256, 1024),                      # GQA group GEMM
        _instance(32, 4096, 4096),                    # skinny cap
        _instance(8, k_fit, 256),                     # boundary: fits
        _instance(8, k_fit + LANE, 256),              # boundary: rejected
        _instance(8, 256, 1024, dbb=True),
        _instance(32, 2048, 512, dbb=True),
        # nibble-plane decode stream (DESIGN.md §16): group nests inside
        # the K tile (G=128 == bk) and spans multiple tiles (G=256)
        _instance(8, 2048, 8192, dbb=True, bits=4, group=128),
        _instance(32, 1024, 512, dbb=True, bits=4, group=256),
    ]
