"""Skinny-M weight-streaming GEMM kernels — the decode fast path
(DESIGN.md §9).

Decode GEMMs are GEMV-shaped: M = batch rows (1-32), K = d_model,
N = d_ff / vocab. They sit deep in the memory-bound regime, so wall time is
weight bytes / HBM bandwidth and the tiled kernels' M-grid machinery is pure
overhead. These kernels restructure the loop for that regime:

  * the whole [M, K] activation row-block is **resident in VMEM** for the
    kernel's lifetime (constant index map — fetched once, never re-read);
  * the grid is **N-major** with K innermost: only the weight stream moves,
    tile after tile, through the K loop — the TPU analogue of the paper's
    weight-stationary streaming for the bandwidth-bound regime
    (arXiv:2009.02381);
  * the DBB variant streams the *compressed* values + bitmask (62.5% of
    dense bytes at k=4/B=8) and decompresses in VMEM right before the MXU
    dot — the dense weight never exists anywhere, HBM included;
  * the shared bias/activation/requant epilogue (DESIGN.md §7) runs on the
    accumulator tile in the final-K store, identical to the tiled kernels.

Shape contract (pad at the ops layer):
    x [M, K] resident, M % SUBLANE == 0, M <= SKINNY_M_MAX after padding
    w [K, N] dense  or  values [K/B·k, N] + bitmask [K/B, N] compressed
    K % block_k == 0, N % block_n == 0 (and block_k % B == 0 for DBB)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sta import SUBLANE
from repro.kernels.common import (SKINNY_M_MAX, CompilerParams, acc_dtype_for,
                                  pltpu, round_up, skinny_ok)
from repro.kernels.dbb_gemm.kernel import _decompress_tile, _dequant_tile
from repro.kernels.epilogue import Epilogue, apply_epilogue, default_out_dtype

__all__ = ["SKINNY_M_MAX", "skinny_ok", "sta_gemm_skinny_pallas",
           "dbb_gemm_skinny_pallas"]


def _epilogue_store(o_ref, acc_ref, bias_ref, scale_ref, epilogue, out_dtype):
    o_ref[...] = apply_epilogue(
        acc_ref[...], epilogue, out_dtype,
        bias=bias_ref[...] if bias_ref is not None else None,
        scale=scale_ref[...] if scale_ref is not None else None)


def _sta_skinny_kernel(x_ref, w_ref, *refs, n_k: int, block_k: int,
                       out_dtype, epilogue: Epilogue):
    """One (j, k) grid step: acc[j] += x[:, k-tile] @ w[k, j]; the x ref is
    the whole resident [M, K] block, sliced per K step."""
    refs = list(refs)
    bias_ref = refs.pop(0) if epilogue.has_bias else None
    scale_ref = refs.pop(0) if epilogue.has_scale else None
    o_ref, acc_ref = refs
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[:, pl.ds(k * block_k, block_k)]
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...], dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _store():
        _epilogue_store(o_ref, acc_ref, bias_ref, scale_ref, epilogue,
                        out_dtype)


def sta_gemm_skinny_pallas(
    x: jax.Array,             # [M, K] — fully resident
    w: jax.Array,             # [K, N] — streamed
    bias: Optional[jax.Array] = None,    # [1, N] f32
    scale: Optional[jax.Array] = None,   # [1, N] f32
    *,
    epilogue: Epilogue = Epilogue(),
    block_k: int = 128,
    block_n: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Dense skinny ``x @ w``: resident activations, streamed weights,
    fused epilogue in the final-K store."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % SUBLANE == 0 and m <= round_up(SKINNY_M_MAX, SUBLANE), m
    assert k % block_k == 0 and n % block_n == 0, (
        f"shape ({m},{k},{n}) not divisible by blocks "
        f"({block_k},{block_n}); pad at the ops layer")
    acc_dtype = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)
    n_k = k // block_k

    operands = [x, w]
    in_specs = [
        pl.BlockSpec((m, k), lambda j, kk: (0, 0)),       # resident A
        pl.BlockSpec((block_k, block_n), lambda j, kk: (kk, j)),
    ]
    row_spec = pl.BlockSpec((1, block_n), lambda j, kk: (0, j))
    if epilogue.has_bias:
        assert bias is not None and bias.shape == (1, n), (
            "bias must be [1, N]", None if bias is None else bias.shape, n)
        operands.append(bias)
        in_specs.append(row_spec)
    if epilogue.has_scale:
        assert scale is not None and scale.shape == (1, n), (
            "scale must be [1, N]", None if scale is None else scale.shape, n)
        operands.append(scale)
        in_specs.append(row_spec)

    grid = (n // block_n, n_k)
    kernel = functools.partial(_sta_skinny_kernel, n_k=n_k, block_k=block_k,
                               out_dtype=out_dtype, epilogue=epilogue)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, block_n), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def _dbb_skinny_kernel(x_ref, v_ref, m_ref, *refs, n_k: int, block_k: int,
                       block: int, nnz: int, out_dtype, epilogue: Epilogue,
                       bits: int = 8):
    refs = list(refs)
    gs_ref = refs.pop(0) if bits == 4 else None
    bias_ref = refs.pop(0) if epilogue.has_bias else None
    scale_ref = refs.pop(0) if epilogue.has_scale else None
    o_ref, acc_ref = refs
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if bits == 4:
        w = _dequant_tile(v_ref[...], m_ref[...], gs_ref[...],
                          block=block, nnz=nnz)
    else:
        w = _decompress_tile(v_ref[...], m_ref[...], block=block, nnz=nnz)
    x = x_ref[:, pl.ds(k * block_k, block_k)]
    acc_ref[...] += jax.lax.dot_general(
        x, w.astype(x.dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _store():
        _epilogue_store(o_ref, acc_ref, bias_ref, scale_ref, epilogue,
                        out_dtype)


def dbb_gemm_skinny_pallas(
    x: jax.Array,          # [M, K] — fully resident
    values: jax.Array,     # [K//B * k, N] compressed non-zeros (slot-major)
    bitmask: jax.Array,    # [K//B, N] int32
    bias: Optional[jax.Array] = None,    # [1, N] f32
    scale: Optional[jax.Array] = None,   # [1, N] f32
    *,
    epilogue: Epilogue = Epilogue(),
    block: int = 8,
    nnz: int = 4,
    block_k: int = 128,
    block_n: int = 128,
    out_dtype=None,
    interpret: bool = False,
    bits: int = 8,
    group: int = 0,
    gscale: Optional[jax.Array] = None,  # [K//G, N] f32 (bits=4 only)
) -> jax.Array:
    """Skinny ``x @ unpack(values, bitmask)``: resident activations, the
    COMPRESSED weight stream moves through the K loop and is decompressed in
    VMEM per tile — no dense [K, N] weight exists at any point. ``bits=4``
    streams the nibble-packed plane (37.5% of dense INT8 bytes) and
    dequantizes with ``gscale`` at the decompress step (DESIGN.md §16)."""
    m, k_dim = x.shape
    kc, n = values.shape
    nb_total = k_dim // block
    assert m % SUBLANE == 0 and m <= round_up(SKINNY_M_MAX, SUBLANE), m
    assert k_dim % block_k == 0 and block_k % block == 0
    assert n % block_n == 0

    acc_dtype = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)
    n_k = k_dim // block_k
    nb_tile = block_k // block            # blocks per K tile
    bkc = nb_tile * nnz                   # compressed rows per K tile

    operands = [x, values, bitmask]
    if bits == 4:
        assert kc == nb_total * nnz // 2, (values.shape, k_dim, block, nnz)
        assert bkc % 2 == 0, (block_k, block, nnz)
        assert x.dtype != jnp.int8, "w4 dequantizes in VMEM: float x only"
        assert group > 0 and (block_k % group == 0 or group % block_k == 0)
        assert gscale is not None and gscale.shape == (k_dim // group, n)
        vals_spec = pl.BlockSpec((bkc // 2, block_n),
                                 lambda j, kk: (kk, j))
    else:
        assert kc == nb_total * nnz, (values.shape, k_dim, block, nnz)
        vals_spec = pl.BlockSpec((bkc, block_n), lambda j, kk: (kk, j))
    assert bitmask.shape == (nb_total, n), bitmask.shape
    in_specs = [
        pl.BlockSpec((m, k_dim), lambda j, kk: (0, 0)),   # resident A
        vals_spec,
        pl.BlockSpec((nb_tile, block_n), lambda j, kk: (kk, j)),
    ]
    if bits == 4:
        gpt = max(block_k // group, 1)
        gdiv = max(group // block_k, 1)
        operands.append(gscale)
        in_specs.append(pl.BlockSpec((gpt, block_n),
                                     lambda j, kk: (kk // gdiv, j)))
    row_spec = pl.BlockSpec((1, block_n), lambda j, kk: (0, j))
    if epilogue.has_bias:
        assert bias is not None and bias.shape == (1, n), (
            "bias must be [1, N]", None if bias is None else bias.shape, n)
        operands.append(bias)
        in_specs.append(row_spec)
    if epilogue.has_scale:
        assert scale is not None and scale.shape == (1, n), (
            "scale must be [1, N]", None if scale is None else scale.shape, n)
        operands.append(scale)
        in_specs.append(row_spec)

    grid = (n // block_n, n_k)
    kernel = functools.partial(_dbb_skinny_kernel, n_k=n_k, block_k=block_k,
                               block=block, nnz=nnz, out_dtype=out_dtype,
                               epilogue=epilogue, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, block_n), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
