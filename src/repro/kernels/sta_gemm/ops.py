"""Jit'd public wrapper around the STA GEMM kernel.

Handles batch dims, padding to block multiples, dtype policy, the fused
bias/activation/requant epilogue, and the CPU-interpret fallback. Block
shapes default to `core.sta.choose_block_shape` (the Tensor-PE geometry
prior); with ``REPRO_AUTOTUNE=1`` (or ``autotune=True``) the measured
autotuner in `kernels.autotune` picks them instead.

Decode dispatch (DESIGN.md §9): GEMV-shaped calls (M ≤ 32 after batch
flattening, no caller-pinned block shapes) route to the skinny
weight-streaming kernel in `kernels.skinny` — full activation row-block
resident in VMEM, N-major grid, weights streamed through the K loop — and
autotune under their own op tag with M-bucketed cache keys.

Structure note: `sta_gemm` itself is a *plain* function that resolves the
block shape, then dispatches to the inner jit'd `_sta_gemm_impl` with the
shape as static args. The tuner must run real kernels on the clock, which
is only possible with concrete (non-tracer) operands — when `sta_gemm` is
called inside an enclosing jit, the tuner degrades to a cache lookup and
the analytical prior (never a measurement, never a bogus cache write).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import StaConfig
from repro.core.sta import SUBLANE, choose_block_shape
from repro.kernels.common import (coerce_bias_scale, default_interpret,
                                  pad_cols, round_up, skinny_dispatch)
from repro.kernels.epilogue import Epilogue, as_row, default_out_dtype
from repro.kernels.skinny.kernel import sta_gemm_skinny_pallas
from repro.kernels.sta_gemm.kernel import sta_gemm_pallas
from repro.kernels.sta_gemm.ref import sta_gemm_ref

__all__ = ["sta_gemm"]


def _autotuned_shape(m: int, k: int, n: int, dtype, epilogue: Epilogue,
                     out_dtype, interpret: bool, cfg: StaConfig,
                     measure: bool, skinny: bool = False
                     ) -> Tuple[int, int, int]:
    """Measured block shape for this GEMM (memoized on disk). With
    measure=False (tracer operands) only the cache is consulted. Skinny
    (decode-shaped) calls tune the weight-stream tiles (bk, bn) of the
    skinny kernel under their own op tag."""
    import numpy as np
    from repro.kernels import autotune

    def make_fn(shape):
        bm, bk, bn = shape
        mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
        if skinny:
            mp = round_up(m, SUBLANE)
        rng = np.random.default_rng(0)
        if np.dtype(dtype) == np.int8:
            x = jnp.asarray(rng.integers(-127, 128, (mp, kp)), jnp.int8)
            w = jnp.asarray(rng.integers(-127, 128, (kp, np_)), jnp.int8)
        else:
            x = jnp.asarray(rng.standard_normal((mp, kp)), dtype)
            w = jnp.asarray(rng.standard_normal((kp, np_)), dtype)
        bias = jnp.zeros((1, np_), jnp.float32) if epilogue.has_bias else None
        scale = jnp.ones((1, np_), jnp.float32) if epilogue.has_scale else None
        if skinny:
            return lambda: sta_gemm_skinny_pallas(
                x, w, bias, scale, epilogue=epilogue, block_k=bk,
                block_n=bn, out_dtype=out_dtype, interpret=interpret)
        return lambda: sta_gemm_pallas(
            x, w, bias, scale, epilogue=epilogue, block_m=bm, block_k=bk,
            block_n=bn, out_dtype=out_dtype, interpret=interpret)

    # out_dtype changes the store bandwidth (int32 vs int8 requant) and
    # interpret-mode timings are meaningless for compiled runs — both key
    # the cache
    tag = f"{epilogue.tag()}>{jnp.dtype(out_dtype).name if out_dtype else 'auto'}"
    name = ("sta_gemm_skinny" if skinny else "sta_gemm") + (
        "_interp" if interpret else "")
    itemsize = np.dtype(dtype).itemsize
    cands = (autotune.skinny_candidate_block_shapes(m, k, n,
                                                    itemsize=itemsize)
             if skinny else None)
    return autotune.autotune_block_shape(
        name, m, k, n, dtype,
        make_fn, epilogue_tag=tag, candidates=cands, cfg=cfg,
        itemsize=itemsize, measure=measure)


@functools.partial(
    jax.jit,
    static_argnames=("act", "block_m", "block_k", "block_n", "out_dtype",
                     "interpret", "use_kernel", "skinny"))
def _sta_gemm_impl(x, w, bias, scale, *, act, block_m, block_k, block_n,
                   out_dtype, interpret, use_kernel, skinny=False):
    epilogue = Epilogue(act=act, has_bias=bias is not None,
                        has_scale=scale is not None)
    *batch, k = x.shape
    n = w.shape[1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    bias_r = as_row(bias, n) if bias is not None else None
    scale_r = as_row(scale, n) if scale is not None else None

    if not use_kernel:
        y = sta_gemm_ref(x2, w, epilogue=epilogue, bias=bias_r,
                         scale=scale_r, out_dtype=out_dtype)
        return y.reshape(*batch, n)

    bm, bk, bn = block_m, block_k, block_n
    mp = round_up(m, SUBLANE) if skinny else round_up(m, bm)
    kp, np_ = round_up(k, bk), round_up(n, bn)
    xp = jnp.pad(x2, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x2
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    bias_r = pad_cols(bias_r, np_ - n)
    scale_r = pad_cols(scale_r, np_ - n)
    if skinny:
        y = sta_gemm_skinny_pallas(xp, wp, bias_r, scale_r,
                                   epilogue=epilogue, block_k=bk, block_n=bn,
                                   out_dtype=out_dtype, interpret=interpret)
    else:
        y = sta_gemm_pallas(xp, wp, bias_r, scale_r, epilogue=epilogue,
                            block_m=bm, block_k=bk, block_n=bn,
                            out_dtype=out_dtype, interpret=interpret)
    y = y[:m, :n]
    return y.reshape(*batch, n)


def sta_gemm(
    x: jax.Array,                # [..., K]
    w: jax.Array,                # [K, N]
    bias: Optional[jax.Array] = None,    # [N] f32 — fused epilogue
    scale: Optional[jax.Array] = None,   # scalar/[N] f32 — fused epilogue
    *,
    act: str = "none",
    block_m: int = 0,
    block_k: int = 0,
    block_n: int = 0,
    out_dtype=None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    autotune: Optional[bool] = None,
    skinny: Optional[bool] = None,
) -> jax.Array:
    """Dense GEMM through the STA Pallas kernel (oracle fallback optional),
    with the bias/act/requant epilogue fused into the final-K store.

    ``skinny`` overrides the automatic skinny-vs-M-tiled choice (the
    dispatch registry in `kernels.dispatch` resolves routes up front and
    pins the kernel here; None keeps the legacy in-wrapper auto dispatch
    for direct callers).

    Shapes: ``x [..., K] · w [K, N] → [..., N]``; any dims/dtypes — batch
    dims flatten to M, ragged (M, K, N) pad to the block grid and slice
    back. ``bias [N]`` f32; ``scale`` scalar or [N] f32 (multiplies the raw
    accumulator — fold dequant × requant before the call). Output dtype
    policy per DESIGN.md §7: int8 operands → int32 (raw) or f32 (scaled)
    or int8 (explicit ``out_dtype`` ⇒ round+clip ±127); floats keep their
    dtype.
    """
    if interpret is None:
        interpret = default_interpret()
    bias, scale = coerce_bias_scale(bias, scale)
    bm, bk, bn = 128, 128, 128
    if not use_kernel:
        skinny = False
    if use_kernel:
        *batch, k = x.shape
        m = math.prod(batch) if batch else 1
        n = w.shape[1]
        if skinny is None:
            # decode fast path (DESIGN.md §9): GEMV-shaped calls go through
            # the skinny weight-streaming kernel; caller-pinned block
            # shapes opt out (the dispatch layer passes an explicit choice)
            skinny = skinny_dispatch(m, k, x.dtype.itemsize,
                                     block_m, block_k, block_n)
        cfg = StaConfig(block_m=block_m or 128, block_k=block_k or 128,
                        block_n=block_n or 128)
        if autotune is None:
            from repro.kernels.autotune import autotune_enabled
            autotune = (not (block_m or block_k or block_n)
                        and autotune_enabled())
        if autotune:
            epi = Epilogue(act=act, has_bias=bias is not None,
                           has_scale=scale is not None)
            measure = not isinstance(x, jax.core.Tracer)
            bm, bk, bn = _autotuned_shape(m, k, n, x.dtype, epi, out_dtype,
                                          interpret, cfg, measure,
                                          skinny=skinny)
        else:
            bm, bk, bn = choose_block_shape(m, k, n, cfg,
                                            itemsize=x.dtype.itemsize)
    return _sta_gemm_impl(x, w, bias, scale, act=act, block_m=bm,
                          block_k=bk, block_n=bn, out_dtype=out_dtype,
                          interpret=interpret, use_kernel=use_kernel,
                          skinny=skinny)
