"""Jit'd public wrapper around the STA GEMM kernel.

Handles batch dims, padding to block multiples, dtype policy, and the
CPU-interpret fallback. Block shapes default to `core.sta.choose_block_shape`
so the Tensor-PE geometry config drives the tiling.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import StaConfig
from repro.core.sta import choose_block_shape
from repro.kernels.common import default_interpret, round_up
from repro.kernels.sta_gemm.kernel import sta_gemm_pallas
from repro.kernels.sta_gemm.ref import sta_gemm_ref

__all__ = ["sta_gemm"]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "out_dtype",
                     "interpret", "use_kernel"))
def sta_gemm(
    x: jax.Array,                # [..., K]
    w: jax.Array,                # [K, N]
    *,
    block_m: int = 0,
    block_k: int = 0,
    block_n: int = 0,
    out_dtype=None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Dense GEMM through the STA Pallas kernel (oracle fallback optional)."""
    if interpret is None:
        interpret = default_interpret()
    *batch, k = x.shape
    n = w.shape[1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    if not use_kernel:
        y = sta_gemm_ref(x2, w, out_dtype=out_dtype)
        return y.reshape(*batch, n)

    cfg = StaConfig(block_m=block_m or 128, block_k=block_k or 128,
                    block_n=block_n or 128)
    bm, bk, bn = choose_block_shape(m, k, n, cfg,
                                    itemsize=x.dtype.itemsize)
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    xp = jnp.pad(x2, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x2
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    y = sta_gemm_pallas(xp, wp, block_m=bm, block_k=bk, block_n=bn,
                        out_dtype=out_dtype, interpret=interpret)
    y = y[:m, :n]
    return y.reshape(*batch, n)
