from repro.kernels.sta_gemm.ops import sta_gemm
from repro.kernels.sta_gemm.ref import sta_gemm_ref

__all__ = ["sta_gemm", "sta_gemm_ref"]
