"""STA dense GEMM Pallas kernel — the Tensor-PE array as VMEM tiling.

Paper mapping (DESIGN.md §2): the A×B×C @ M×N tensor-PE grid becomes a
(bm, bk, bn) block decomposition. The accumulator tile is *output-stationary*
in VMEM scratch across the K grid dimension — the TPU analogue of keeping
INT32 accumulators in place while INT8 operands shift through the array
(the paper's modified dataflow, §II). INT8 operands accumulate in INT32 via
``preferred_element_type``, exactly the SA/STA datapath.

Fused epilogue (DESIGN.md §7): on the final K step the optional
bias/activation/requant epilogue runs on the accumulator tile *in VMEM*
before the single store — the output never round-trips through HBM in its
pre-activation form. Bias and scale ride along as [1, N] operands blocked
to [1, bn] per output column tile.

Shape contract:
    x [M, K] · w [K, N] → out [M, N]
    bias, scale (optional): [1, N] f32, broadcast over rows.
    M % block_m == K % block_k == N % block_n == 0 (pad at the ops layer).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import CompilerParams, acc_dtype_for, pltpu
from repro.kernels.epilogue import Epilogue, apply_epilogue, default_out_dtype

__all__ = ["sta_gemm_pallas"]


def _sta_gemm_kernel(x_ref, w_ref, *refs, n_k: int, out_dtype,
                     epilogue: Epilogue):
    """One (i, j, k) grid step: acc[i,j] += x[i,k] @ w[k,j]; epilogue+store
    on the last k."""
    refs = list(refs)
    bias_ref = refs.pop(0) if epilogue.has_bias else None
    scale_ref = refs.pop(0) if epilogue.has_scale else None
    o_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = apply_epilogue(
            acc_ref[...], epilogue, out_dtype,
            bias=bias_ref[...] if bias_ref is not None else None,
            scale=scale_ref[...] if scale_ref is not None else None)


def sta_gemm_pallas(
    x: jax.Array,             # [M, K]
    w: jax.Array,             # [K, N]
    bias: Optional[jax.Array] = None,    # [1, N] f32
    scale: Optional[jax.Array] = None,   # [1, N] f32
    *,
    epilogue: Epilogue = Epilogue(),
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Dense ``x @ w`` with output-stationary VMEM accumulation and an
    optional fused bias/activation/requant epilogue in the final-K store."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        f"shape ({m},{k},{n}) not divisible by blocks "
        f"({block_m},{block_k},{block_n}); pad at the ops layer")
    acc_dtype = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)
    n_k = k // block_k

    operands = [x, w]
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    row_spec = pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j))
    if epilogue.has_bias:
        assert bias is not None and bias.shape == (1, n), (
            "bias must be [1, N]", None if bias is None else bias.shape, n)
        operands.append(bias)
        in_specs.append(row_spec)
    if epilogue.has_scale:
        assert scale is not None and scale.shape == (1, n), (
            "scale must be [1, N]", None if scale is None else scale.shape, n)
        operands.append(scale)
        in_specs.append(row_spec)

    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_sta_gemm_kernel, n_k=n_k, out_dtype=out_dtype,
                               epilogue=epilogue)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
