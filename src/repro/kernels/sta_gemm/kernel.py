"""STA dense GEMM Pallas kernel — the Tensor-PE array as VMEM tiling.

Paper mapping (DESIGN.md §2): the A×B×C @ M×N tensor-PE grid becomes a
(bm, bk, bn) block decomposition. The accumulator tile is *output-stationary*
in VMEM scratch across the K grid dimension — the TPU analogue of keeping
INT32 accumulators in place while INT8 operands shift through the array
(the paper's modified dataflow, §II). INT8 operands accumulate in INT32 via
``preferred_element_type``, exactly the SA/STA datapath.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import CompilerParams, acc_dtype_for, pltpu

__all__ = ["sta_gemm_pallas"]


def _sta_gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    """One (i, j, k) grid step: acc[i,j] += x[i,k] @ w[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def sta_gemm_pallas(
    x: jax.Array,             # [M, K]
    w: jax.Array,             # [K, N]
    *,
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Dense ``x @ w`` with output-stationary VMEM accumulation."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        f"shape ({m},{k},{n}) not divisible by blocks "
        f"({block_m},{block_k},{block_n}); pad at the ops layer")
    acc_dtype = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = acc_dtype if x.dtype == jnp.int8 else x.dtype
    n_k = k // block_k

    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_sta_gemm_kernel, n_k=n_k, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
