"""Pure-jnp oracle for the STA dense GEMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import acc_dtype_for

__all__ = ["sta_gemm_ref"]


def sta_gemm_ref(x: jax.Array, w: jax.Array, *, out_dtype=None) -> jax.Array:
    """``x @ w`` with the same accumulation semantics as the kernel:
    INT8×INT8→INT32 on the integer datapath, f32 accumulation otherwise."""
    acc = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = acc if x.dtype == jnp.int8 else x.dtype
    y = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc)
    return y.astype(out_dtype)
