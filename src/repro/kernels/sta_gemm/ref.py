"""Pure-jnp oracle for the STA dense GEMM kernel (fused epilogue included)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import acc_dtype_for
from repro.kernels.epilogue import Epilogue, apply_epilogue, default_out_dtype

__all__ = ["sta_gemm_ref"]


def sta_gemm_ref(x: jax.Array, w: jax.Array, *,
                 epilogue: Epilogue = Epilogue(),
                 bias: Optional[jax.Array] = None,
                 scale: Optional[jax.Array] = None,
                 out_dtype=None) -> jax.Array:
    """``x @ w`` with the same accumulation semantics as the kernel
    (INT8×INT8→INT32 on the integer datapath, f32 accumulation otherwise),
    followed by the identical `apply_epilogue` the kernel runs in VMEM."""
    acc = acc_dtype_for(x.dtype)
    if out_dtype is None:
        out_dtype = default_out_dtype(x.dtype, epilogue)
    y = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc)
    return apply_epilogue(y, epilogue, out_dtype, bias=bias, scale=scale)
