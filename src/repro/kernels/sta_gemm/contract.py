"""KernelContract declarations for the dense M-tiled STA GEMM
(`sta_gemm_pallas`) — see DESIGN.md §13 and `repro.analysis.contracts`.

Mirrors ``kernel.py`` 1:1: grid (M/bm, N/bn, K/bk); x and w stream by
block, bias/scale ride as [1, bn] rows, the output block is revisited
over the K grid dim with a ``pl.when(kk == 0)`` accumulator init and a
``pl.when(kk == n_k - 1)`` epilogue store.
"""
from __future__ import annotations

from typing import List

from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.config import StaConfig
from repro.core.sta import KERNEL_VMEM_BUDGET, choose_block_shape
from repro.kernels.common import round_up

__all__ = ["contracts"]


def _instance(m: int, k: int, n: int, itemsize: int,
              with_epilogue: bool) -> KernelContract:
    bm, bk, bn = choose_block_shape(m, k, n, StaConfig(), itemsize=itemsize)
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    grid = (mp // bm, np_ // bn, kp // bk)
    # the shrink loop's own footprint (operand tiles + f32 accumulator) —
    # the guard this contract is cross-checked against
    admitted = (bm * bk + bk * bn) * itemsize + bm * bn * 4 \
        <= KERNEL_VMEM_BUDGET

    inputs = [
        BlockDecl("x", (bm, bk), lambda i, j, kk: (i, kk), (mp, kp),
                  itemsize),
        BlockDecl("w", (bk, bn), lambda i, j, kk: (kk, j), (kp, np_),
                  itemsize),
    ]
    if with_epilogue:
        inputs += [
            BlockDecl("bias", (1, bn), lambda i, j, kk: (0, j), (1, np_), 4),
            BlockDecl("scale", (1, bn), lambda i, j, kk: (0, j), (1, np_), 4),
        ]
    tag = f"m{m} k{k} n{n} i{itemsize}" + (" ep" if with_epilogue else "")
    return KernelContract(
        name=f"sta_gemm[{tag}]", route="sta", domain="matmul",
        grid=grid,
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        inputs=tuple(inputs),
        outputs=(BlockDecl("out", (bm, bn), lambda i, j, kk: (i, j),
                           (mp, np_), 4),),
        scratch=(ScratchDecl("acc", (bm, bn), 4),),
        acc_dims=(2,), guarded_init=True, guarded_store=True,
        vmem_budget=KERNEL_VMEM_BUDGET,
        admitted=admitted, vmem_reject=not admitted)


def contracts() -> List[KernelContract]:
    return [
        _instance(256, 512, 1024, itemsize=4, with_epilogue=True),
        _instance(8, 256, 128, itemsize=4, with_epilogue=False),
        _instance(1024, 4096, 4096, itemsize=2, with_epilogue=True),
    ]
