"""Fused head-sampling kernels + the shared XLA reference sampler
(DESIGN.md §15)."""
from repro.kernels.sample.kernel import head_sample_fused_pallas
from repro.kernels.sample.ops import head_sample_fused
from repro.kernels.sample.ref import (NEG_INF, SALT_ACCEPT, SALT_RESAMPLE,
                                      SALT_TOKEN, apply_penalties,
                                      gumbel_noise, hash_u32,
                                      inv_temperature, mask_top_k,
                                      mask_top_p, probs_from_logits,
                                      sample_argmax, sample_logits,
                                      sample_scores, uniform_noise)

__all__ = [
    "head_sample_fused_pallas", "head_sample_fused",
    "NEG_INF", "SALT_TOKEN", "SALT_ACCEPT", "SALT_RESAMPLE",
    "hash_u32", "uniform_noise", "gumbel_noise", "apply_penalties",
    "inv_temperature", "mask_top_k", "mask_top_p", "sample_scores",
    "sample_argmax", "sample_logits", "probs_from_logits",
]
