"""KernelContract declarations for the fused sampling head
(`head_sample_fused_pallas`) — DESIGN.md §13/§15.

Same skinny weight-streaming regime as the decode GEMV: the padded
hidden block ``[mp, kp]`` is grid-constant (``resident``, budgeted by
`SKINNY_RESIDENT_BUDGET`) while weight and counts tiles stream over an
(N, K) grid. The difference from `skinny/contract.py` is the epilogue:
the per-row best (score, index) pair is a *running argmax carried
across N tiles*, so both outputs are revisited over **both** grid dims
— both must be ``"arbitrary"`` and both are declared ``acc_dims``.
The logits tile itself lives only in the VMEM accumulator; the
epilogue's score/global-id tiles are declared as ``extra_vmem_bytes``.

The instance set mirrors the dispatch guard's three rejection reasons:
the resident-budget boundary (largest K that exactly fills VMEM/4 —
admitted — and one lane-tile past it — vmem-rejected) plus a
lane-divisibility reject (``k % 128 != 0``), which is *not* a VMEM
reject and must not trip the dead-headroom check.
"""
from __future__ import annotations

from typing import List

from repro.analysis.contracts import BlockDecl, KernelContract, ScratchDecl
from repro.core.sta import KERNEL_VMEM_BUDGET, LANE, SUBLANE
from repro.kernels.common import SKINNY_RESIDENT_BUDGET, round_up, skinny_ok

__all__ = ["contracts"]

_F32 = 4


def _instance(m: int, k: int, n: int, *, itemsize: int = 4
              ) -> KernelContract:
    mp = round_up(max(m, 1), SUBLANE)
    kp = round_up(max(k, 1), LANE)
    np_ = round_up(max(n, 1), LANE)
    bk, bn = LANE, LANE
    grid = (np_ // bn, kp // bk)
    vmem_ok = skinny_ok(m, k, itemsize)
    lane_ok = k % bk == 0 and n % bn == 0

    row = lambda name: BlockDecl(name, (mp, 1), lambda j, kk: (0, 0),
                                 (mp, 1), 4)
    return KernelContract(
        name=f"head_sample_fused[m{m} k{k} n{n}]",
        route="head_sample_fused", domain="head_sample",
        grid=grid,
        # the running argmax reads its own prior value: every visit is a
        # read-modify-write of the (score, index) pair, so *both* dims
        # are sequential — unlike the plain skinny GEMM, N cannot be
        # "parallel" here
        dimension_semantics=("arbitrary", "arbitrary"),
        inputs=(
            BlockDecl("x", (mp, kp), lambda j, kk: (0, 0), (mp, kp),
                      itemsize, resident=True),
            BlockDecl("w", (bk, bn), lambda j, kk: (kk, j), (kp, np_),
                      itemsize),
            BlockDecl("counts", (mp, bn), lambda j, kk: (0, j),
                      (mp, np_), 4),
            row("temp"), row("rep"), row("pres"), row("freq"),
            row("seed"), row("step"), row("base"),
        ),
        outputs=(
            BlockDecl("best_score", (mp, 1), lambda j, kk: (0, 0),
                      (mp, 1), 4),
            BlockDecl("best_idx", (mp, 1), lambda j, kk: (0, 0),
                      (mp, 1), 4),
        ),
        scratch=(ScratchDecl("acc", (mp, bn), 4),),
        acc_dims=(0, 1), guarded_init=True, guarded_store=True,
        vmem_budget=KERNEL_VMEM_BUDGET,
        resident_budget=SKINNY_RESIDENT_BUDGET,
        # epilogue intermediates at k == n_k - 1: the penalized score
        # tile (f32) and the global-token-id tile (i32)
        extra_vmem_bytes=2 * mp * bn * _F32,
        admitted=vmem_ok and lane_ok,
        vmem_reject=not vmem_ok)


def contracts() -> List[KernelContract]:
    # K that exactly fills the resident budget for mp = 8, f32 — and the
    # first K one lane-tile past it (rejected by skinny_ok)
    k_fit = SKINNY_RESIDENT_BUDGET // (SUBLANE * _F32)
    return [
        _instance(1, 2048, 32000),          # decode head GEMV, full vocab
        _instance(8, 2048, 4000),           # TP-local vocab shard
        _instance(8, k_fit, 256),           # boundary: fits exactly
        _instance(8, k_fit + LANE, 256),    # boundary: vmem-rejected
        _instance(8, 192, 256),             # lane reject (not a vmem one)
    ]
