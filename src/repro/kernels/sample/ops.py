"""Shape-policy wrapper for the fused head-sample kernel.

Pads the batch rows to the sublane quantum (the same policy the skinny
GEMM wrapper applies), fills the pad rows with identity sampling params
(temperature 0, repetition 1, zero counts — so they run a harmless
argmax over zero logits), and unpads the scalar outputs. K/N
divisibility by the 128 tile is a dispatch-guard precondition, not
padded here: zero-padding the vocab dim would let a pad column win the
argmax.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sta import SUBLANE
from repro.kernels.common import default_interpret, round_up
from repro.kernels.sample.kernel import head_sample_fused_pallas

__all__ = ["head_sample_fused"]


def _col(a, b: int, pad: int, dtype, fill) -> jax.Array:
    out = jnp.asarray(a, dtype).reshape(b, 1)
    if pad:
        out = jnp.pad(out, ((0, pad), (0, 0)), constant_values=fill)
    return out


def head_sample_fused(
    h: jax.Array,        # [B, K] hidden rows
    w: jax.Array,        # [K, N] head weight (local vocab slice under TP)
    counts: jax.Array,   # [B, N] i32 output-token counts
    temp: jax.Array,     # [B] f32
    rep: jax.Array,      # [B] f32
    pres: jax.Array,     # [B] f32
    freq: jax.Array,     # [B] f32
    seed: jax.Array,     # [B] i32/u32 bit pattern
    step: jax.Array,     # [B] i32
    base=0,              # scalar: global vocab id of w's column 0
    *,
    block_k: int = 128,
    block_n: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """(best score [B] f32, sampled LOCAL index [B] i32)."""
    if interpret is None:
        interpret = default_interpret()
    b, _ = h.shape
    mp = round_up(max(b, 1), SUBLANE)
    pad = mp - b
    x = h.astype(jnp.float32)
    c = counts.astype(jnp.int32)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        c = jnp.pad(c, ((0, pad), (0, 0)))
    base_col = jnp.broadcast_to(
        jnp.asarray(base, jnp.int32).reshape(1, 1), (mp, 1))
    score, idx = head_sample_fused_pallas(
        x, w.astype(jnp.float32), c,
        _col(temp, b, pad, jnp.float32, 0.0),
        _col(rep, b, pad, jnp.float32, 1.0),
        _col(pres, b, pad, jnp.float32, 0.0),
        _col(freq, b, pad, jnp.float32, 0.0),
        _col(seed, b, pad, jnp.int32, 0),
        _col(step, b, pad, jnp.int32, 0),
        base_col,
        block_k=block_k, block_n=block_n, interpret=interpret)
    return score[:b, 0], idx[:b, 0]
