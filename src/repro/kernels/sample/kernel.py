"""Fused head-sample kernel: skinny head GEMV + penalty → temperature →
Gumbel-sample epilogue in one pass (DESIGN.md §15).

Structure is the skinny weight-streaming template
(`kernels/skinny/kernel.py`): the whole [M, K] hidden block is
VMEM-resident, weight tiles stream over an (N, K) grid with K innermost.
The difference is the output — instead of materialising [M, vocab]
logits in HBM, each final-K step runs the sampling epilogue on its
accumulator tile (penalties from the streamed counts tile, temperature
scale, counter-hash Gumbel noise at *global* vocab ids) and folds the
tile into the running (best score, best index) output pair. Only those
[M, 1] scalars are ever written out.

The kernel returns BOTH the winning score and the (local) index: under
vocab-parallel TP each shard runs it on its vocab slice (noise offset by
``base`` so draws are keyed to global ids) and the scalar pair feeds the
same all-gather max/argmax combine the greedy head uses — bit-exact with
a single-device run over the full row.

Both grid dims are "arbitrary": the running-argmax output is carried
across N tiles, so tiles must arrive in ascending-j order — which is
also what makes the strict ``>`` update reproduce ``jnp.argmax``'s
first-max tie-break exactly. Every epilogue op is shared with the XLA
reference sampler (`ref.sample_scores`), which is what the dispatch
guard's bit-exactness claim rests on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sta import SUBLANE
from repro.kernels.common import (SKINNY_M_MAX, CompilerParams,
                                  pltpu, round_up)
from repro.kernels.sample.ref import NEG_INF, SALT_TOKEN, sample_scores

__all__ = ["head_sample_fused_pallas"]


def _head_sample_kernel(x_ref, w_ref, c_ref, t_ref, rep_ref, pres_ref,
                        freq_ref, seed_ref, step_ref, base_ref,
                        ov_ref, oi_ref, acc_ref, *, n_k: int,
                        block_k: int, block_n: int):
    j = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when((j == 0) & (k == 0))
    def _init_best():
        ov_ref[...] = jnp.full_like(ov_ref, NEG_INF)
        oi_ref[...] = jnp.zeros_like(oi_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[:, pl.ds(k * block_k, block_k)]
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...], dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _sample_tile():
        m = acc_ref.shape[0]
        loc = j * block_n + jax.lax.broadcasted_iota(
            jnp.int32, (m, block_n), 1)
        score = sample_scores(
            acc_ref[...], c_ref[...], t_ref[...], rep_ref[...],
            pres_ref[...], freq_ref[...], seed_ref[...], step_ref[...],
            base_ref[...] + loc, salt=SALT_TOKEN)
        tile_best = jnp.max(score, axis=1, keepdims=True)
        tile_arg = jnp.argmax(score, axis=1).astype(jnp.int32)[:, None] \
            + j * block_n
        # Strict > keeps the earlier (lower-index) tile on ties — the
        # cross-tile analogue of argmax's first-max rule.
        better = tile_best > ov_ref[...]
        ov_ref[...] = jnp.where(better, tile_best, ov_ref[...])
        oi_ref[...] = jnp.where(better, tile_arg, oi_ref[...])


def head_sample_fused_pallas(
    x: jax.Array,        # [M, K] f32 hidden rows — fully resident
    w: jax.Array,        # [K, N] f32 head weight — streamed
    counts: jax.Array,   # [M, N] i32 output-token history counts
    temp: jax.Array,     # [M, 1] f32
    rep: jax.Array,      # [M, 1] f32
    pres: jax.Array,     # [M, 1] f32
    freq: jax.Array,     # [M, 1] f32
    seed: jax.Array,     # [M, 1] i32 per-row seed (bit pattern)
    step: jax.Array,     # [M, 1] i32 per-row emitted-token counter
    base: jax.Array,     # [M, 1] i32 global vocab id of column 0
    *,
    block_k: int = 128,
    block_n: int = 128,
    interpret: bool = False,
):
    """Returns (best score [M, 1] f32, sampled LOCAL index [M, 1] i32);
    the [M, N] logits never leave VMEM."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % SUBLANE == 0 and m <= round_up(SKINNY_M_MAX, SUBLANE), m
    assert k % block_k == 0 and n % block_n == 0, (
        f"shape ({m},{k},{n}) not divisible by blocks "
        f"({block_k},{block_n}); pad at the ops layer")
    assert counts.shape == (m, n), counts.shape
    for name, arr in (("temp", temp), ("rep", rep), ("pres", pres),
                      ("freq", freq), ("seed", seed), ("step", step),
                      ("base", base)):
        assert arr.shape == (m, 1), (name, arr.shape)
    n_k = k // block_k

    row_spec = pl.BlockSpec((m, 1), lambda j, kk: (0, 0))
    kernel = functools.partial(_head_sample_kernel, n_k=n_k,
                               block_k=block_k, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((m, k), lambda j, kk: (0, 0)),      # resident x
            pl.BlockSpec((block_k, block_n), lambda j, kk: (kk, j)),
            pl.BlockSpec((m, block_n), lambda j, kk: (0, j)),  # counts
            row_spec, row_spec, row_spec, row_spec,          # t/rep/pres/freq
            row_spec, row_spec, row_spec,                    # seed/step/base
        ],
        out_specs=(pl.BlockSpec((m, 1), lambda j, kk: (0, 0)),
                   pl.BlockSpec((m, 1), lambda j, kk: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((m, 1), jnp.float32),
                   jax.ShapeDtypeStruct((m, 1), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(x, w, counts, temp, rep, pres, freq, seed, step, base)
