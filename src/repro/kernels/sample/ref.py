"""Reference sampling math shared by the fused head-sample kernel and the
XLA fallback route (DESIGN.md §15).

Everything here is plain ``jnp`` so the exact same ops run inside the
Pallas kernel (interpret mode) and in the XLA reference sampler — that is
what makes the fused route *bit-exact* with the reference at a fixed key:

  * **Counter-based RNG.** A murmur-finalizer hash of
    ``(seed, step, global vocab index, salt)`` in uint32. Noise depends
    only on those four values — never on batch slot, chunk size, tile
    order, or TP shard layout — so sampled streams are reproducible
    across chunk sizes and across TP vs single-device runs by
    construction. Salt streams keep the token-sampling, acceptance, and
    resample draws independent.
  * **Penalty contract** (mirrors TensorRT-LLM's
    ``samplingPenaltyKernels``): repetition divides positive /
    multiplies negative logits of seen tokens, presence subtracts a
    flat penalty from seen tokens, frequency subtracts
    ``count * penalty``. "Seen" means present in the *output-token
    history* (``counts > 0``); the prompt is not penalised. All three
    are exact identities at their default values (1.0 / 0.0 / 0.0), so
    default sampling at temperature 0 is bit-identical to greedy.
  * **Gumbel-max sampling.** ``argmax(logits / T + gumbel)`` is a
    categorical draw from ``softmax(logits / T)``; at temperature 0 the
    noise is skipped entirely and the score *is* the penalised logit, so
    the argmax degenerates to greedy exactly (no ``0 * inf`` traps).

Uniforms are built as ``((h >> 9) + 0.5) * 2^-23`` — every intermediate
is exactly representable in f32, and the result lies strictly inside
``(0, 1)`` (min ``2^-24``, max ``1 - 2^-24``), so ``log(u)`` is finite
and acceptance ratios of exactly 0 / 1 behave deterministically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SALT_TOKEN", "SALT_ACCEPT", "SALT_RESAMPLE", "NEG_INF",
    "hash_u32", "uniform_noise", "gumbel_noise",
    "apply_penalties", "inv_temperature", "mask_top_k", "mask_top_p",
    "sample_scores", "sample_argmax", "sample_logits", "probs_from_logits",
]

# Same sentinel the attention masks use — finite, so arithmetic on masked
# lanes stays NaN-free.
NEG_INF = -1e30

# Independent noise streams (static Python ints, baked into the trace).
SALT_TOKEN = 0     # per-step token sampling (gumbel)
SALT_ACCEPT = 1    # speculative acceptance uniforms
SALT_RESAMPLE = 2  # residual-distribution resample (gumbel)

# np scalars, not jnp arrays: they bind as jaxpr literals, so the Pallas
# kernel can use these helpers without capturing traced constants.
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)


def _mix(h: jax.Array) -> jax.Array:
    """Murmur3 finalizer — full avalanche on a uint32."""
    h = h ^ (h >> np.uint32(16))
    h = h * _C1
    h = h ^ (h >> np.uint32(13))
    h = h * _C2
    h = h ^ (h >> np.uint32(16))
    return h


def hash_u32(seed: jax.Array, step: jax.Array, idx: jax.Array,
             salt: int) -> jax.Array:
    """Counter-based hash of (seed, step, idx, salt) → uint32.

    Inputs may be any mutually-broadcastable shapes; each is folded in
    through a full-avalanche mix so per-row seeds, per-row step counters
    and global vocab indices all decorrelate.
    """
    # the salt product folds on the host (masked python int — numpy scalar
    # wraparound would warn) and binds as one u32 literal
    h = _mix(seed.astype(jnp.uint32)
             + np.uint32((0x9E3779B9 * (salt + 1)) & 0xFFFFFFFF))
    h = _mix(h ^ step.astype(jnp.uint32))
    h = _mix(h ^ idx.astype(jnp.uint32))
    return h


def uniform_noise(seed, step, idx, salt: int) -> jax.Array:
    """Uniform f32 strictly inside (0, 1); every op exact in f32."""
    h = hash_u32(seed, step, idx, salt)
    return ((h >> np.uint32(9)).astype(jnp.float32) + np.float32(0.5)) \
        * np.float32(2.0 ** -23)


def gumbel_noise(seed, step, idx, salt: int) -> jax.Array:
    u = uniform_noise(seed, step, idx, salt)
    return -jnp.log(-jnp.log(u))


def apply_penalties(logits: jax.Array, counts: jax.Array, rep: jax.Array,
                    pres: jax.Array, freq: jax.Array) -> jax.Array:
    """TensorRT-LLM penalty contract, in place on (a tile of) logits.

    ``logits`` f32 and ``counts`` i32 share a shape ``[..., n]``;
    ``rep``/``pres``/``freq`` are per-row f32 broadcastable against them
    (``[B, 1]`` against ``[B, n]``). Defaults (1, 0, 0) are exact
    identities: ``x / 1 == x * 1 == x`` and ``x - 0 == x`` bit-exactly.
    """
    seen = counts > 0
    cf = counts.astype(logits.dtype)
    scaled = jnp.where(logits > 0, logits / rep, logits * rep)
    out = jnp.where(seen, scaled, logits)
    out = out - cf * freq
    out = out - jnp.where(seen, pres, jnp.zeros_like(pres))
    return out


def inv_temperature(temp: jax.Array) -> jax.Array:
    """1/T for T > 0, else 1 — no inf/NaN in either branch."""
    safe = jnp.where(temp > 0, temp, jnp.ones_like(temp))
    return jnp.where(temp > 0, 1.0 / safe, jnp.ones_like(temp))


def mask_top_k(logits: jax.Array, top_k: jax.Array) -> jax.Array:
    """Keep each row's top-k logits, mask the rest to NEG_INF.

    ``top_k`` [B] int32; values <= 0 disable the filter for that row.
    Needs the full row (global order statistic) — XLA route only.
    """
    v = logits.shape[-1]
    k = jnp.where(top_k > 0, top_k, v).astype(jnp.int32)
    desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    kth = jnp.take_along_axis(
        desc, jnp.clip(k - 1, 0, v - 1)[:, None], axis=-1)
    masked = jnp.where(logits >= kth, logits, jnp.float32(NEG_INF))
    return jnp.where((top_k > 0)[:, None], masked, logits)


def mask_top_p(logits: jax.Array, top_p: jax.Array) -> jax.Array:
    """Nucleus filter: keep the smallest prefix of the descending-prob
    row whose cumulative mass reaches top_p. ``top_p`` [B] f32; values
    >= 1 disable the filter for that row. XLA route only."""
    desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # A token stays if the mass *before* it is still under top_p.
    keep = (cum - probs) < top_p[:, None]
    kth = jnp.min(jnp.where(keep, desc, jnp.float32(jnp.inf)),
                  axis=-1, keepdims=True)
    masked = jnp.where(logits >= kth, logits, jnp.float32(NEG_INF))
    return jnp.where((top_p < 1.0)[:, None], masked, logits)


def sample_scores(logits, counts, temp, rep, pres, freq, seed, step,
                  idx, *, salt: int = SALT_TOKEN) -> jax.Array:
    """Penalty → temperature → gumbel score for (a tile of) logits.

    Per-row params arrive as ``[B, 1]``; ``idx`` holds the *global*
    vocab index of each column (``[B, n]`` or ``[1, n]``). This is the
    exact epilogue the fused kernel runs per N tile — the argmax of the
    full-row scores is the sampled token.
    """
    pen = apply_penalties(logits, counts, rep, pres, freq)
    inv_t = inv_temperature(temp)
    g = gumbel_noise(seed, step, idx, salt)
    return jnp.where(temp > 0, pen * inv_t + g, pen)


def sample_argmax(logits, counts, temp, rep, pres, freq, seed, step,
                  *, base=0, top_k=None, top_p=None,
                  use_tt: bool = False):
    """Full-row scores → (best score [B] f32, argmax [B] i32 LOCAL index).

    The XLA twin of the fused kernel's output pair: ``base`` offsets the
    noise counter to global vocab ids (vocab-parallel TP shards pass
    ``shard * v_local``), while the returned index stays local so the
    caller's combine adds the shard offset exactly once. ``use_tt`` is a
    *static* flag: when False no top-k/top-p code is traced at all, so
    default params at temperature 0 reduce to a plain argmax. When True
    the logits must be the full (unsharded) row — the nucleus masks are
    global order statistics.
    """
    b, v = logits.shape
    col = jnp.asarray(base, jnp.int32).reshape(-1, 1) \
        + jnp.arange(v, dtype=jnp.int32)[None, :]
    t = temp.reshape(b, 1)
    pen = apply_penalties(logits, counts, rep.reshape(b, 1),
                          pres.reshape(b, 1), freq.reshape(b, 1))
    if use_tt:
        pen = mask_top_k(pen, top_k)
        pen = mask_top_p(pen, top_p)
    inv_t = inv_temperature(t)
    g = gumbel_noise(seed.reshape(b, 1), step.reshape(b, 1), col,
                     SALT_TOKEN)
    score = jnp.where(t > 0, pen * inv_t + g, pen)
    return (jnp.max(score, axis=-1),
            jnp.argmax(score, axis=-1).astype(jnp.int32))


def sample_logits(logits, counts, temp, top_k, top_p, rep, pres, freq,
                  seed, step, *, use_tt: bool = False) -> jax.Array:
    """XLA reference sampler: [B, V] logits → [B] int32 token ids."""
    _, tok = sample_argmax(logits, counts, temp, rep, pres, freq, seed,
                           step, top_k=top_k, top_p=top_p, use_tt=use_tt)
    return tok


def probs_from_logits(logits, counts, temp, rep, pres, freq) -> jax.Array:
    """Post-penalty sampling distribution ``[..., V]`` for the
    speculative accept/reject rule.

    Rows with temperature 0 get a one-hot at the greedy argmax (first
    max, matching ``jnp.argmax``) instead of a softmax over ``x / 0``.
    ``temp``/``rep``/``pres``/``freq`` broadcast against the leading
    dims of ``logits`` (e.g. ``[B, 1, 1]`` against ``[B, T, V]``).
    """
    v = logits.shape[-1]
    pen = apply_penalties(logits, counts, rep, pres, freq)
    inv_t = inv_temperature(temp)
    soft = jax.nn.softmax(pen * inv_t, axis=-1)
    hard = jax.nn.one_hot(jnp.argmax(pen, axis=-1), v, dtype=soft.dtype)
    return jnp.where(temp > 0, soft, hard)
