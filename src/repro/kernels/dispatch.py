"""Unified kernel dispatch: one route registry + roofline-informed
selection across every GEMM / conv / attention subsystem (DESIGN.md §11).

After PRs 1-4 the repro had five parallel kernel subsystems (`sta_gemm`,
`dbb_gemm`, `skinny`, `conv_gemm`, `attn`) whose dispatch guards, padding
policy, and XLA fallbacks were re-implemented privately at every model
call site. This module is the single place where route decisions live:

  * a **registry** of `Route` entries per domain (``matmul`` / ``conv`` /
    ``attention`` / ``attn_decode``), each declaring an applicability
    *guard* (shape / dtype / VMEM — subsuming the scattered `skinny_ok` /
    `flash_ok` / pinned-block checks) and a *cost estimate* built from the
    same terms as `roofline/analysis.py`: FLOPs at the op's padded M/N/K
    against `Hardware.peak_flops`, bytes moved against `Hardware.hbm_bw`;
  * **front doors** `matmul` / `conv` / `attention` that run the chosen
    route with one shared shape policy (pad → run → unpad and f32
    bias/scale coercion live in the ops wrappers via `kernels.common`);
  * **overrides**: ``ModelConfig.kernel_routes`` pins a route per domain
    from config, and the ``REPRO_FORCE_ROUTE`` env var pins one globally
    (``skinny_sta`` or ``matmul=skinny_sta,conv=conv_xla``). A forced
    route whose guard rejects the op falls back to auto with a warning —
    forcing can change *which kernel* runs, never whether the op is legal;
  * `explain` returns the full ranked route table with per-route cost
    terms so tests, benchmarks and ``launch.serve`` logs can show *why* a
    route was chosen.

Selection rule: among applicable (non-deferred) routes pick the lowest
modeled cost; costs within ``COST_TIE_RTOL`` are a tie and the route with
the lower ``priority`` number (the more specialized kernel) wins. This
keeps the decision roofline-driven where the model can discriminate
(skinny vs M-tiled padding waste, compressed vs dense weight bytes,
fused vs round-tripped epilogues) and deterministic where it cannot.

Route selection runs at trace time on static shapes — inside a jit it is
resolved once per compiled shape, exactly like the old inline guards.
"""
from __future__ import annotations

import dataclasses
import math
import os
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dbb import DbbWeight
from repro.kernels.common import SKINNY_M_MAX, round_up, skinny_ok
from repro.roofline.analysis import HW_V5E, Hardware, collective_bw

__all__ = [
    "Route", "RouteDecision", "OpSpec", "register_route", "routes_for",
    "select", "explain", "format_table", "matmul", "conv", "attention",
    "head_sample", "decode_attention_route", "pallas_route_active",
    "flash_backend_active", "forced_route", "routes_from_cfg",
    "FORCE_ROUTE_ENV", "COST_TIE_RTOL", "DOMAINS",
]

FORCE_ROUTE_ENV = "REPRO_FORCE_ROUTE"
# Relative cost window treated as a tie (the roofline model is first-order;
# within it the more specialized kernel wins on priority).
COST_TIE_RTOL = 0.10

DOMAINS = ("matmul", "conv", "attention", "attn_decode", "head_sample")

_MASK_BYTES = 1          # DBB bitmask storage: 1 byte per 8-block
_F32 = 4


# ---------------------------------------------------------------------------
# op description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Static description of one op instance (everything guards and cost
    models may consult — plain ints/bools so specs hash and build at trace
    time).

    GEMM domains use (m, k, n) literally; attention maps T→m, D→k, S→n
    (and the decode domain G→m, D→k, Smax→n).
    """
    domain: str
    m: int
    k: int
    n: int
    itemsize: int = 4            # operand bytes (activations / q)
    out_itemsize: int = 4
    packed: bool = False         # weight is a DbbWeight
    block: int = 8               # DBB geometry (packed ops)
    nnz: int = 4
    vals_itemsize: int = 1       # packed value bytes (int8 deployment)
    bits: int = 8                # value-plane width (4 = nibble-packed)
    group: int = 0               # w4 scale group along dense K (bits=4)
    epilogue_ops: int = 0        # unfused bias/act/scale passes on XLA
    pallas: bool = False         # fused Pallas route family is active
    dense_fused: bool = True     # call site opted dense weights into kernels
    pinned: bool = False         # caller-pinned block shapes (no skinny)
    gemv: bool = False           # decode head GEMV: stream or stay on XLA
    float_ok: bool = True        # operand dtype the Pallas kernels accept
    # conv extras: (b, h, w, c, kh, kw, stride[, padding]) — padding
    # defaults to "SAME" for 7-tuple specs
    conv_geom: Tuple[Any, ...] = ()
    # attention extras
    ragged: bool = False
    chunk: int = 1024
    flash_active: bool = False
    # packed cu_seqlens batch: m/n are TOTAL tokens across the ragged batch,
    # not a per-row T — the padded-batch routes must not claim these
    packed_seq: bool = False
    # rows in a padded batch (the vmapped leading dim the per-row (t, s)
    # cost must scale by; packed specs keep batch=1 since m already IS the
    # whole batch's token count)
    batch: int = 1
    # decode extras
    page: int = 0
    ring: bool = False
    # head_sample extras: top-k/top-p active for some row — they are
    # global order statistics, which the streaming fused epilogue cannot
    # compute (the XLA sampler materializes the row and sorts)
    sample_tt: bool = False
    # TP sharding (DESIGN.md §14): tp > 1 costs the op as the per-shard
    # instance a TP shard_map body would run — row-parallel ops (those
    # paying a boundary collective) split K, everything else splits N.
    # ``collective`` names the boundary collective this op's block pays
    # ("all-reduce" / "reduce-scatter" / "all-gather"; "" = none, the
    # column-parallel mid-block default).
    tp: int = 1
    collective: str = ""


@dataclasses.dataclass(frozen=True)
class Route:
    """One registry entry: a named way to execute a domain's op."""
    name: str
    domain: str
    priority: int                             # tie-break (lower wins)
    guard: Callable[[OpSpec], str]            # "" = applicable, else reason
    cost: Callable[[OpSpec], Tuple[float, float]]   # (flops, bytes)
    defer: Optional[Callable[[OpSpec], bool]] = None  # soft demotion (auto only)
    describe: str = ""
    # weight-stream bytes this route is costed at (the compressed-traffic
    # column of explain tables); None = not a weight-streaming route
    wbytes: Optional[Callable[[OpSpec], float]] = None


@dataclasses.dataclass
class RouteDecision:
    """One row of the explain table."""
    name: str
    applicable: bool
    reason: str                  # why not applicable ("" if it is)
    flops: float
    bytes: float
    compute_s: float
    memory_s: float
    cost_s: float
    priority: int
    deferred: bool = False
    chosen: bool = False
    forced: bool = False
    weight_bytes: float = 0.0    # weight-stream traffic term (0 = n/a)
    # TP terms (0 / tp=1 outside a sharded costing, DESIGN.md §14)
    collective_bytes: float = 0.0
    collective_s: float = 0.0
    tp: int = 1
    mesh: str = ""               # mesh shape the table was costed for

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


_REGISTRY: Dict[str, Dict[str, Route]] = {d: {} for d in DOMAINS}


def register_route(route: Route) -> Route:
    _REGISTRY[route.domain][route.name] = route
    return route


def routes_for(domain: str) -> Dict[str, Route]:
    return dict(_REGISTRY[domain])


# ---------------------------------------------------------------------------
# route-family predicates (shared with models/common + models/attention)
# ---------------------------------------------------------------------------

def pallas_route_active(cfg) -> bool:
    """The fused Pallas route family: ``gemm_impl == "pallas"`` and either
    no live device mesh, or tracing inside a TP shard_map body (where
    every operand is the per-shard local array, so the kernels apply
    unchanged — DESIGN.md §14). A *global* GSPMD graph under a live mesh
    still keeps XLA: the kernels themselves are not GSPMD-partitionable;
    the serve engine re-enters them per-shard via `shard_tp_ctx`."""
    if cfg is None or cfg.gemm_impl != "pallas":
        return False
    from repro.dist.mesh_ctx import current_mesh, shard_tp
    return current_mesh() is None or shard_tp() > 0


def flash_backend_active(cfg) -> bool:
    """Whether the fused flash-attention kernel is the selected backend:
    explicit ``attn_impl="flash"``, or "auto" with the Pallas route
    active — the same single-device-or-per-shard predicate the GEMM
    kernels use (`pallas_route_active`)."""
    if cfg.attn_impl == "flash":
        from repro.dist.mesh_ctx import current_mesh, shard_tp
        return current_mesh() is None or shard_tp() > 0
    return cfg.attn_impl == "auto" and pallas_route_active(cfg)


# ---------------------------------------------------------------------------
# overrides: env var + ModelConfig.kernel_routes
# ---------------------------------------------------------------------------

def routes_from_cfg(cfg) -> Dict[str, str]:
    """``ModelConfig.kernel_routes`` ((domain, route) pairs — tuple-of-pairs
    so the frozen config stays hashable) as a dict."""
    if cfg is None or not getattr(cfg, "kernel_routes", ()):
        return {}
    return dict(cfg.kernel_routes)


def forced_route(domain: str, cfg_routes: Optional[Dict[str, str]] = None
                 ) -> Optional[str]:
    """Resolve the override for a domain. Precedence: ``REPRO_FORCE_ROUTE``
    env var > ``ModelConfig.kernel_routes`` > None (auto). The env var is
    either one bare route name (applied to whichever domain owns it) or a
    comma list of ``domain=route`` pairs. Read at trace time — inside a
    jit the value seen at first trace sticks for that compiled shape."""
    env = os.environ.get(FORCE_ROUTE_ENV, "").strip()
    if env:
        if "=" in env:
            for pair in env.split(","):
                d, _, r = pair.partition("=")
                if d.strip() == domain and r.strip():
                    return r.strip()
        elif env in _REGISTRY[domain]:
            return env
        elif not any(env in table for table in _REGISTRY.values()):
            # bare name matching NO domain is a typo, not a different
            # domain's route — surface it once instead of silently
            # measuring auto dispatch as if it were forced
            key = ("*", env)
            if key not in _warned_forced:
                _warned_forced.add(key)
                warnings.warn(
                    f"{FORCE_ROUTE_ENV}={env!r} names no registered route "
                    f"in any domain — ignoring the override", stacklevel=2)
    if cfg_routes:
        return cfg_routes.get(domain)
    return None


# ---------------------------------------------------------------------------
# selection core
# ---------------------------------------------------------------------------

def _collective_term(spec: OpSpec, hw: Hardware) -> Tuple[float, float]:
    """Boundary-collective cost of a TP-sharded op instance (0 for tp=1 /
    no declared collective). Counted bytes are the op's [M, N] output
    payload against the ICI collective bandwidth model in
    `roofline.analysis` — the same accounting `roofline_terms` applies to
    HLO collective ops, so explain tables and dry-run rooflines agree."""
    if spec.tp <= 1 or not spec.collective:
        return 0.0, 0.0
    payload = float(spec.m) * spec.n * spec.out_itemsize
    return payload, payload / collective_bw(spec.collective, hw)


def _decide(route: Route, spec: OpSpec, hw: Hardware) -> RouteDecision:
    reason = route.guard(spec)
    flops, nbytes = route.cost(spec)
    compute_s = flops / hw.peak_flops
    memory_s = nbytes / hw.hbm_bw
    # the collective term is route-independent (inside a shard every route
    # pays the same boundary psum); it is charged as a third pipe under
    # max() because the serve path issues it while the epilogue stores
    # (overlapped collectives, DESIGN.md §14) — the slowest pipe bounds.
    coll_b, coll_s = _collective_term(spec, hw)
    return RouteDecision(
        name=route.name, applicable=(reason == ""), reason=reason,
        flops=flops, bytes=nbytes, compute_s=compute_s, memory_s=memory_s,
        cost_s=max(compute_s, memory_s, coll_s), priority=route.priority,
        deferred=bool(route.defer and route.defer(spec)),
        collective_bytes=coll_b, collective_s=coll_s, tp=spec.tp,
        weight_bytes=float(route.wbytes(spec)) if route.wbytes else 0.0)


_warned_forced: set = set()


def select(spec: OpSpec, cfg_routes: Optional[Dict[str, str]] = None,
           hw: Hardware = HW_V5E) -> Tuple[str, List[RouteDecision]]:
    """Pick a route for ``spec``. Returns (route_name, ranked decisions).

    Forced routes (env / config) win when their guard passes; a rejected
    force warns once per (domain, route) and falls back to auto. Auto:
    lowest modeled cost among applicable, non-deferred routes, with
    priority breaking ties inside ``COST_TIE_RTOL``.
    """
    table = _REGISTRY[spec.domain]
    decisions = [_decide(r, spec, hw) for r in table.values()]
    by_name = {d.name: d for d in decisions}

    forced = forced_route(spec.domain, cfg_routes)
    chosen: Optional[str] = None
    if forced is not None:
        dec = by_name.get(forced)
        if dec is None or not dec.applicable:
            key = (spec.domain, forced)
            if key not in _warned_forced:
                _warned_forced.add(key)
                why = dec.reason if dec else "unknown route"
                warnings.warn(
                    f"forced route {forced!r} for domain {spec.domain!r} "
                    f"not applicable ({why}) — falling back to auto "
                    f"dispatch", stacklevel=2)
        else:
            dec.forced = True
            chosen = forced

    if chosen is None:
        cands = [d for d in decisions if d.applicable and not d.deferred]
        if not cands:
            cands = [d for d in decisions if d.applicable]
        assert cands, f"no applicable route in domain {spec.domain}"
        best_cost = min(d.cost_s for d in cands)
        tied = [d for d in cands
                if d.cost_s <= best_cost * (1.0 + COST_TIE_RTOL)]
        chosen = min(tied, key=lambda d: (d.priority, d.cost_s, d.name)).name

    by_name[chosen].chosen = True
    decisions.sort(key=lambda d: (not d.chosen, not d.applicable,
                                  d.cost_s, d.priority))
    return chosen, decisions


def explain(domain: str = "matmul", *, m: int, k: int, n: int,
            dtype=jnp.float32, packed: bool = False, cfg=None,
            pallas: Optional[bool] = None, hw: Hardware = HW_V5E,
            tp: Optional[int] = None, collective: str = "",
            **spec_kw) -> List[RouteDecision]:
    """Ranked route table for a hypothetical op — the introspection hook
    for tests, benchmarks and serve logs. ``pallas=None`` derives the
    route-family flag from ``cfg`` (False without one).

    ``tp=None`` derives the model-axis size from the live mesh (1 without
    one, and 1 inside a shard_map body — there the dims you pass are
    already per-shard local). With ``tp > 1`` the given dims are GLOBAL
    and the table costs the per-shard instance the TP serving path would
    run (row-parallel split of K when ``collective`` names a boundary
    collective, column split of N otherwise), with the collective-bytes
    term shown per route; the table header names the mesh it costed for.

    Pass ``epilogue_ops`` (count of bias/scale/act the real call fuses)
    when describing an actual dispatch — near the 10% tie window the
    unfused-epilogue HBM round-trips charged to the xla route can decide
    the winner, and a table built with a different epilogue than the call
    it describes can name a route the run never takes."""
    from repro.dist.mesh_ctx import current_mesh, shard_tp
    mesh = current_mesh()
    mesh_desc = ""
    if tp is None:
        tp = 1
        if shard_tp() > 0:
            mesh_desc = f"shard_map body (tp={shard_tp()}, local dims)"
        elif (mesh is not None and "model" in mesh.axis_names
                and (cfg is None or cfg.parallel != "dp")):
            tp = int(mesh.shape["model"])
    if tp > 1 and not mesh_desc:
        mesh_desc = (str(dict(mesh.shape)) if mesh is not None
                     else f"(model={tp})")
    if pallas is None:
        pallas = pallas_route_active(cfg)
        if not pallas and tp > 1 and cfg is not None \
                and cfg.gemm_impl == "pallas":
            # costing the per-shard instance: inside the shard_map body
            # the route family re-activates even though it is off in the
            # enclosing global graph
            pallas = True
    itemsize = jnp.dtype(dtype).itemsize
    spec_kw.setdefault("out_itemsize", itemsize)
    if domain in ("attention", "attn_decode", "head_sample"):
        # the attention + sampling kernels take floats only; the GEMM/conv
        # kernels also accept int8 — mirror the front doors' own float_ok
        # exactly or explain() would report routes the runtime never takes
        spec_kw.setdefault("float_ok",
                           jnp.issubdtype(jnp.dtype(dtype), jnp.floating))
    else:
        spec_kw.setdefault("float_ok",
                           jnp.issubdtype(jnp.dtype(dtype), jnp.floating)
                           or jnp.dtype(dtype) == jnp.int8)
    if domain in ("attention", "attn_decode"):
        fa = flash_backend_active(cfg) if cfg is not None else bool(pallas)
        if not fa and tp > 1 and cfg is not None and (
                cfg.attn_impl == "flash"
                or (cfg.attn_impl == "auto" and cfg.gemm_impl == "pallas")):
            fa = True           # per-shard instance re-activates flash too
        spec_kw.setdefault("flash_active", fa)
    if domain == "attention":
        spec_kw.setdefault("chunk", cfg.attn_chunk if cfg is not None
                           else 1024)
    spec = OpSpec(domain=domain, m=m, k=k, n=n, itemsize=itemsize,
                  packed=packed, pallas=bool(pallas), tp=int(tp),
                  collective=collective, **spec_kw)
    _, decisions = select(spec, routes_from_cfg(cfg), hw=hw)
    for d in decisions:
        d.mesh = mesh_desc
    return decisions


def format_table(decisions: List[RouteDecision]) -> str:
    """Compact fixed-width rendering of an explain() table for logs."""
    lines = []
    if decisions and (decisions[0].mesh or decisions[0].tp > 1):
        lines.append(f"costed for mesh {decisions[0].mesh or '?'} "
                     f"(model-axis tp={decisions[0].tp})")
    lines.append(f"{'route':<18} {'ok':<3} {'cost':>10} {'flops':>10} "
                 f"{'bytes':>10} {'wbytes':>9} {'coll':>9}  note")
    for d in decisions:
        mark = "*" if d.chosen else ("f" if d.forced else "")
        note = d.reason if not d.applicable else (
            "deferred" if d.deferred and not d.chosen else "")
        wb = f"{d.weight_bytes:>9.3g}" if d.weight_bytes else f"{'-':>9}"
        lines.append(
            f"{d.name:<18} {('y' + mark) if d.applicable else 'n':<3} "
            f"{d.cost_s * 1e6:>9.2f}u {d.flops:>10.3g} {d.bytes:>10.3g} "
            f"{wb} {d.collective_bytes:>9.3g}  {note}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# matmul domain
# ---------------------------------------------------------------------------

def _shard_dims(spec: OpSpec) -> Tuple[int, int, int]:
    """Per-shard local (m, k, n) of a TP-sharded GEMM (DESIGN.md §14):
    row-parallel ops (those declaring a reduction-boundary collective)
    split the contraction K across shards; everything else takes the
    column-parallel default and splits N. tp=1 passes dims through."""
    if spec.tp <= 1:
        return spec.m, spec.k, spec.n
    if spec.collective in ("all-reduce", "reduce-scatter"):
        return spec.m, max(spec.k // spec.tp, 1), spec.n
    return spec.m, spec.k, max(spec.n // spec.tp, 1)


def _mm_dims(spec: OpSpec, skinny: bool) -> Tuple[int, int, int]:
    """Padded (mp, kp, np) of the per-shard instance, mirroring the ops
    wrappers' block policy: the M-tiled kernels clamp bm to round_up(m, 8)
    below 128 (so small-M pads only to the sublane quantum), skinny pads
    M straight to the sublane."""
    m, k, n = _shard_dims(spec)
    if skinny:
        mp = round_up(max(m, 1), 8)
    else:
        bm = min(128, round_up(max(m, 1), 8))
        mp = round_up(max(m, 1), bm)
    return mp, round_up(max(k, 1), 128), round_up(max(n, 1), 128)


def _dense_w_bytes(spec: OpSpec, kp: int, np_: int) -> float:
    return kp * np_ * spec.itemsize


def _packed_w_bytes(spec: OpSpec) -> float:
    """Compressed weight stream: values + bitmask, the paper's 62.5%
    (the per-shard plane slice when the spec is TP-sharded). ``bits=4``
    halves the values term (two slots per byte) and adds the groupwise
    f32 scale plane — 37.5% of dense INT8 at B=8/k=4/G=128 (§16)."""
    _, k, n = _shard_dims(spec)
    nb = max(k // max(spec.block, 1), 1)
    if spec.bits == 4 and spec.group > 0:
        return (nb * spec.nnz * n * 0.5 + nb * n * _MASK_BYTES
                + max(k // spec.group, 1) * n * 4.0)
    return (nb * spec.nnz * n * spec.vals_itemsize
            + nb * n * _MASK_BYTES)


def _mm_xla_cost(spec: OpSpec) -> Tuple[float, float]:
    # per-shard dims for tp > 1: GSPMD shards the XLA matmul the same way
    # the shard_map body shards the kernels, so both route families are
    # costed at local shapes and the comparison stays honest on meshes
    m, k, n = _shard_dims(spec)
    flops = 2.0 * m * k * n
    nbytes = (m * k * spec.itemsize + m * n * spec.out_itemsize)
    if spec.packed:
        # decompress_xla: read compressed, write dense, matmul reads dense
        nbytes += _packed_w_bytes(spec) + 2 * k * n * spec.itemsize
    else:
        nbytes += k * n * spec.itemsize
    # every unfused epilogue op re-reads + re-writes the [M, N] output
    nbytes += 2.0 * m * n * spec.out_itemsize * spec.epilogue_ops
    return flops, nbytes


def _mm_kernel_cost(spec: OpSpec, *, skinny: bool, dbb: bool
                    ) -> Tuple[float, float]:
    mp, kp, np_ = _mm_dims(spec, skinny)
    flops = 2.0 * mp * kp * np_
    w = _packed_w_bytes(spec) if dbb else _dense_w_bytes(spec, kp, np_)
    nbytes = (mp * kp * spec.itemsize + w + mp * np_ * spec.out_itemsize)
    return flops, nbytes


def _tp_split_reason(spec: OpSpec) -> str:
    """Divisibility of the declared TP split (empty = clean). Row-parallel
    ops split K, column-parallel split N; a dim that doesn't divide tp
    has no per-shard kernel instance."""
    if spec.tp <= 1:
        return ""
    if spec.collective in ("all-reduce", "reduce-scatter"):
        if spec.k % spec.tp:
            return (f"unsupported axis split: K={spec.k} % tp={spec.tp} "
                    "!= 0 (row-parallel shard)")
    elif spec.n % spec.tp:
        return f"unsupported axis split: N={spec.n} % tp={spec.tp} != 0"
    return ""


def _guard_pallas_dense(spec: OpSpec) -> str:
    if spec.packed:
        return "weight is DBB-packed (dense STA kernel takes dense [K,N])"
    if not spec.pallas:
        return ("Pallas route not selected (gemm_impl != 'pallas', or a "
                "global GSPMD graph — per-shard shard_map bodies "
                "re-enable it)")
    if not spec.dense_fused:
        return "call site keeps dense weights on XLA (shardable/diff path)"
    if not spec.float_ok:
        return "operand dtype outside the kernel contract (f32/bf16/int8)"
    return _tp_split_reason(spec)


def _guard_sta(spec: OpSpec) -> str:
    r = _guard_pallas_dense(spec)
    if r:
        return r
    if spec.gemv:
        return "head GEMV: M-tiled padding gains nothing on [B,d]·[d,V]"
    return ""


def _guard_skinny_sta(spec: OpSpec) -> str:
    r = _guard_pallas_dense(spec)
    if r:
        return r
    if spec.pinned:
        return "caller-pinned block shapes opt out of skinny dispatch"
    _, k_loc, _ = _shard_dims(spec)
    if not skinny_ok(spec.m, k_loc, spec.itemsize):
        shard = "per-shard " if spec.tp > 1 else ""
        return (f"outside the skinny regime (M ≤ {SKINNY_M_MAX} and "
                f"{shard}resident [M,K] ≤ VMEM/4)")
    return ""


def _guard_packed_base(spec: OpSpec) -> str:
    """Shared admission for every packed-weight kernel route (both value-
    plane widths): format present, route family on, block divisibility,
    clean TP split."""
    if not spec.packed:
        return "weight is dense (DBB kernels take values+bitmask)"
    if not spec.pallas:
        return ("Pallas route not selected (gemm_impl != 'pallas', or a "
                "global GSPMD graph — per-shard shard_map bodies "
                "re-enable it)")
    if spec.k % max(spec.block, 1) != 0:
        return f"K={spec.k} not divisible by the DBB block {spec.block}"
    r = _tp_split_reason(spec)
    if r:
        return r
    _, k_loc, _ = _shard_dims(spec)
    if k_loc % max(spec.block, 1) != 0:
        return (f"per-shard K={k_loc} not divisible by the DBB block "
                f"{spec.block} (tp={spec.tp} splits inside a block)")
    return ""


def _guard_pallas_packed(spec: OpSpec) -> str:
    r = _guard_packed_base(spec)
    if r:
        return r
    if spec.bits == 4:
        return ("values plane is nibble-packed INT4 (the w4 routes "
                "stream it)")
    return ""


def _skinny_reason(spec: OpSpec) -> str:
    if spec.pinned:
        return "caller-pinned block shapes opt out of skinny dispatch"
    _, k_loc, _ = _shard_dims(spec)
    if not skinny_ok(spec.m, k_loc, spec.itemsize):
        shard = "per-shard " if spec.tp > 1 else ""
        return (f"outside the skinny regime (M ≤ {SKINNY_M_MAX} and "
                f"{shard}resident [M,K] ≤ VMEM/4)")
    return ""


def _guard_skinny_dbb(spec: OpSpec) -> str:
    return _guard_pallas_packed(spec) or _skinny_reason(spec)


def _guard_pallas_packed_w4(spec: OpSpec) -> str:
    r = _guard_packed_base(spec)
    if r:
        return r
    if spec.bits != 4:
        return "values plane is INT8 (w4 routes take the nibble plane)"
    if spec.itemsize == 1:
        return ("int8 activations: the w4 dequantized tile is float "
                "(float x only)")
    if spec.group <= 0 or spec.group % max(spec.block, 1) != 0:
        return (f"scale group {spec.group} must be a positive multiple "
                f"of the DBB block {spec.block}")
    _, k_loc, _ = _shard_dims(spec)
    if k_loc % spec.group != 0:
        shard = "per-shard " if spec.tp > 1 else ""
        return (f"{shard}K={k_loc} not divisible by the scale group "
                f"{spec.group}")
    return ""


def _guard_skinny_dbb_w4(spec: OpSpec) -> str:
    return _guard_pallas_packed_w4(spec) or _skinny_reason(spec)


def _xla_w_bytes(spec: OpSpec) -> float:
    _, k, n = _shard_dims(spec)
    if spec.packed:
        # decompress_xla: read compressed, write + re-read dense
        return _packed_w_bytes(spec) + 2.0 * k * n * spec.itemsize
    return float(k) * n * spec.itemsize


register_route(Route(
    name="xla", domain="matmul", priority=9,
    guard=lambda s: "",
    cost=_mm_xla_cost,
    wbytes=_xla_w_bytes,
    describe="plain XLA matmul (GSPMD-shardable, differentiable); packed "
             "weights decompress transiently in-graph"))

register_route(Route(
    name="sta", domain="matmul", priority=1,
    guard=_guard_sta,
    cost=lambda s: _mm_kernel_cost(s, skinny=False, dbb=False),
    wbytes=lambda s: _dense_w_bytes(s, *_mm_dims(s, False)[1:]),
    describe="M-tiled dense STA Pallas kernel, fused epilogue"))

register_route(Route(
    name="skinny_sta", domain="matmul", priority=0,
    guard=_guard_skinny_sta,
    cost=lambda s: _mm_kernel_cost(s, skinny=True, dbb=False),
    wbytes=lambda s: _dense_w_bytes(s, *_mm_dims(s, True)[1:]),
    describe="skinny weight-streaming STA kernel (resident [M,K] rows)"))

register_route(Route(
    name="dbb_packed", domain="matmul", priority=1,
    guard=_guard_pallas_packed,
    cost=lambda s: _mm_kernel_cost(s, skinny=False, dbb=True),
    wbytes=_packed_w_bytes,
    describe="M-tiled DBB kernel: compressed weight stream, VMEM "
             "decompress, scale folded into the epilogue"))

register_route(Route(
    name="skinny_dbb", domain="matmul", priority=0,
    guard=_guard_skinny_dbb,
    cost=lambda s: _mm_kernel_cost(s, skinny=True, dbb=True),
    wbytes=_packed_w_bytes,
    describe="skinny DBB kernel: resident rows, compressed stream"))

register_route(Route(
    name="dbb_packed_w4", domain="matmul", priority=1,
    guard=_guard_pallas_packed_w4,
    cost=lambda s: _mm_kernel_cost(s, skinny=False, dbb=True),
    wbytes=_packed_w_bytes,
    describe="M-tiled DBB kernel, nibble-packed INT4 stream (~half the "
             "weight bytes) + groupwise dequant in VMEM (§16)"))

register_route(Route(
    name="skinny_dbb_w4", domain="matmul", priority=0,
    guard=_guard_skinny_dbb_w4,
    cost=lambda s: _mm_kernel_cost(s, skinny=True, dbb=True),
    wbytes=_packed_w_bytes,
    describe="skinny DBB kernel, INT4 nibble stream + groupwise dequant "
             "— the decode weight-bandwidth floor (§16)"))


def _epilogue_ops(bias, scale, act: str) -> int:
    return int(bias is not None) + int(scale is not None) + int(act != "none")


def matmul(x: jax.Array, w, bias=None, scale=None, *, act: str = "none",
           out_dtype=None, cfg=None, pallas: Optional[bool] = None,
           dense_fused: bool = True, gemv: bool = False,
           route: Optional[str] = None, use_kernel: bool = True,
           block_m: int = 0, block_k: int = 0, block_n: int = 0
           ) -> jax.Array:
    """The one front door for every model-layer GEMM:
    ``act(scale * (x @ w) + bias)`` where ``w`` is a dense ``[K, N]`` array
    or a packed `DbbWeight`, routed through the registry.

    cfg:          supplies ``gemm_impl`` (route family), ``kernel_routes``
                  overrides, and nothing else.
    pallas:       explicit route-family flag for callers without a config
                  (`dbb_linear_apply(impl=...)`); None derives from cfg.
    dense_fused:  whether this call site opts dense weights into the fused
                  Pallas kernels (attention projections keep False — their
                  dense path stays on the shardable/differentiable XLA
                  matmul, DESIGN.md §11).
    gemv:         decode head-GEMV hint: stream through the skinny kernel
                  or stay on XLA; never pad into M tiles.
    route:        explicit route name (wins over env/config overrides —
                  the benchmark/test forcing hook).
    use_kernel=False short-circuits to the XLA route (oracle fallbacks).
    """
    packed = isinstance(w, DbbWeight)
    if pallas is None:
        pallas = pallas_route_active(cfg)
    *batch, k_dim = x.shape
    m = math.prod(batch) if batch else 1
    if packed:
        k_w, n = w.k_dim, w.values.shape[-1]
        if k_w != k_dim:
            # Inside a TP shard_map body the packed planes arrive as
            # per-shard local slices but the static aux ``k_dim`` still
            # holds the global contraction (shard_map shards arrays, not
            # static fields). The row-parallel layout splits whole
            # K-blocks across shards, so the local bitmask rebuilds it.
            k_local = w.bitmask.shape[-2] * w.block
            if k_local == k_dim:
                w = dataclasses.replace(w, k_dim=k_local)
                k_w = k_local
        vals_itemsize = jnp.dtype(w.values.dtype).itemsize
        block, nnz = w.block, w.nnz
        bits, group = w.bits, w.group
    else:
        k_w, n = w.shape
        vals_itemsize, block, nnz = 1, 8, 4
        bits, group = 8, 0
    assert k_dim == k_w, (x.shape, k_w)
    eff_out = jnp.dtype(out_dtype).itemsize if out_dtype is not None \
        else x.dtype.itemsize
    spec = OpSpec(
        domain="matmul", m=m, k=k_dim, n=n,
        itemsize=x.dtype.itemsize, out_itemsize=eff_out,
        packed=packed, block=block, nnz=nnz, vals_itemsize=vals_itemsize,
        bits=bits, group=group,
        epilogue_ops=_epilogue_ops(bias, scale if not packed else None, act),
        pallas=bool(pallas) and use_kernel, dense_fused=dense_fused,
        pinned=bool(block_m or block_k or block_n), gemv=gemv,
        float_ok=(jnp.issubdtype(x.dtype, jnp.floating)
                  or x.dtype == jnp.int8))
    if route is not None:
        dec = _decide(_REGISTRY["matmul"][route], spec, HW_V5E)
        if not dec.applicable:
            raise ValueError(f"route {route!r} rejected this op: "
                             f"{dec.reason}")
        name = route
    else:
        name, _ = select(spec, routes_from_cfg(cfg))

    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n)
    if name in ("sta", "skinny_sta"):
        from repro.kernels.sta_gemm.ops import sta_gemm
        return sta_gemm(x, w.astype(x.dtype), bias, scale, act=act,
                        out_dtype=out_dtype, skinny=(name == "skinny_sta"),
                        **kw)
    if name in ("dbb_packed", "skinny_dbb", "dbb_packed_w4",
                "skinny_dbb_w4"):
        from repro.kernels.dbb_gemm.ops import dbb_gemm_packed
        if scale is not None:
            # fold a caller-supplied scale into the packed weight's
            # epilogue scale — dbb_gemm_packed consumes only w.scale, and
            # dropping the operand here would silently diverge from the
            # xla route (scales are multiplicative, so folding is exact;
            # on w4 leaves the [K//G, N] plane broadcasts against [N])
            s = jnp.asarray(scale, jnp.float32)
            w = dataclasses.replace(
                w, scale=s if w.scale is None else w.scale * s)
        return dbb_gemm_packed(
            x, w, bias, act=act, out_dtype=out_dtype,
            skinny=(name in ("skinny_dbb", "skinny_dbb_w4")), **kw)
    return _matmul_xla(x, w, bias, scale, act=act, out_dtype=out_dtype)


def _matmul_xla(x, w, bias, scale, *, act, out_dtype):
    """The XLA route, numerically identical to the pre-dispatch model-layer
    fallbacks: float operands keep the legacy storage-dtype bias add; int8
    operands run the kernels' exact epilogue (int32 accumulate → f32
    scale/bias → round/clip) so forced-route parity holds bit-for-bit."""
    import dataclasses as _dc

    from repro.kernels.epilogue import Epilogue, apply_act, apply_epilogue
    if isinstance(w, DbbWeight):
        from repro.core.dbb_linear import decompress_xla
        if w.bits == 4:
            # w4: the [K//G, N] scales vary along K, so there is no int8
            # epilogue folding — dequantize fully (f32); int8 activations
            # upcast (no int8×w4 requant datapath exists anywhere)
            w = decompress_xla(w)
            if x.dtype == jnp.int8:
                x = x.astype(w.dtype)
        elif x.dtype == jnp.int8 and w.scale is not None:
            # INT8 deployment: the quant scale must survive to the int32
            # epilogue — decompress_xla(dtype=int8) would dequantize to
            # f32 and truncate back to int8, destroying the weights.
            # Decompress the raw int8 values and fold the scale into the
            # epilogue operand instead (the DBB kernels' exact datapath).
            scale = (w.scale if scale is None
                     else jnp.asarray(scale, jnp.float32) * w.scale)
            w = decompress_xla(_dc.replace(w, scale=None))
        else:
            w = decompress_xla(w, dtype=x.dtype)    # scale already applied
    if x.dtype == jnp.int8:
        acc = jnp.matmul(x, w.astype(jnp.int8),
                         preferred_element_type=jnp.int32)
        spec = Epilogue(act=act, has_bias=bias is not None,
                        has_scale=scale is not None)
        from repro.kernels.epilogue import default_out_dtype
        od = out_dtype if out_dtype is not None else default_out_dtype(
            x.dtype, spec)
        return apply_epilogue(acc, spec, od, bias=bias, scale=scale)
    y = x @ w.astype(x.dtype)
    if scale is not None:
        y = (y.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)
             ).astype(y.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    y = apply_act(y, act)
    return y.astype(out_dtype) if out_dtype is not None else y


# ---------------------------------------------------------------------------
# conv domain (implicit-GEMM convolution, DESIGN.md §8)
# ---------------------------------------------------------------------------

def _conv_padded_geom(spec: OpSpec) -> Tuple[int, int, int, int, int]:
    b, h, w_dim, c, kh, kw, stride = spec.conv_geom[:7]
    pad = spec.conv_geom[7] if len(spec.conv_geom) > 7 else "SAME"
    from repro.kernels.conv_gemm.ops import _default_tiles, out_spatial
    ho, _, _ = out_spatial(h, kh, stride, pad)
    wo, _, _ = out_spatial(w_dim, kw, stride, pad)
    th, _ = _default_tiles(ho, wo)
    hp = (round_up(max(ho, 1), th) - 1) * stride + kh
    wp = (wo - 1) * stride + kw
    return ho, wo, th, hp, wp


def _conv_kernel_cost(spec: OpSpec, dbb: bool) -> Tuple[float, float]:
    kp, np_ = round_up(spec.k, 128), round_up(spec.n, 128)
    flops = 2.0 * spec.m * kp * np_
    w_bytes = _packed_w_bytes(spec) if dbb else kp * np_ * spec.itemsize
    if len(spec.conv_geom) < 7:
        # geometry-free spec (explain() without conv_geom): approximate
        # the resident-image term with the implied GEMM's activation
        # reads; the guard already marks these routes inapplicable
        img_bytes = float(spec.m) * spec.k * spec.itemsize
    else:
        b, _, _, c = spec.conv_geom[:4]
        _, _, _, hp, wp = _conv_padded_geom(spec)
        img_bytes = b * hp * wp * c * spec.itemsize  # resident image blocks
    nbytes = img_bytes + w_bytes + spec.m * np_ * spec.out_itemsize
    return flops, nbytes


def _conv_xla_cost(spec: OpSpec) -> Tuple[float, float]:
    flops = 2.0 * spec.m * spec.k * spec.n
    w_bytes = (_packed_w_bytes(spec) + spec.k * spec.n * spec.itemsize
               if spec.packed else spec.k * spec.n * spec.itemsize)
    # the explicit path writes AND re-reads the materialized [M, K] im2col
    nbytes = (spec.m * spec.k * spec.itemsize       # image gather reads
              + 2.0 * spec.m * spec.k * spec.itemsize
              + w_bytes + spec.m * spec.n * spec.out_itemsize
              + 2.0 * spec.m * spec.n * spec.out_itemsize
              * spec.epilogue_ops)
    return flops, nbytes


def _conv_vmem_ok(spec: OpSpec, dbb: bool) -> bool:
    from repro.kernels.conv_gemm.ops import _vmem_fits
    _, wo, th, hp, wp = _conv_padded_geom(spec)
    c, kw = spec.conv_geom[3], spec.conv_geom[5]
    return _vmem_fits(hp, wp, c, kw, th, wo, 128, spec.itemsize, dbb)


def _guard_conv_sta(spec: OpSpec) -> str:
    if spec.packed:
        return "weight is DBB-packed"
    if not spec.pallas:
        return "implicit-GEMM kernels not selected (use_kernel=False)"
    if len(spec.conv_geom) < 7:
        return ("conv_geom=(b, h, w, c, kh, kw, stride[, padding]) "
                "required (the VMEM guard needs the image geometry)")
    if not _conv_vmem_ok(spec, dbb=False):
        return "resident image block exceeds the VMEM budget"
    return ""


def _guard_conv_dbb(spec: OpSpec) -> str:
    if not spec.packed:
        return "weight is dense"
    if spec.bits == 4:
        return ("conv kernels stream the INT8 DBB plane only (w4 is the "
                "decode GEMM format; conv decompresses it up front)")
    if not spec.pallas:
        return "implicit-GEMM kernels not selected (use_kernel=False)"
    if len(spec.conv_geom) < 7:
        return ("conv_geom=(b, h, w, c, kh, kw, stride[, padding]) "
                "required (the VMEM guard needs the image geometry)")
    c, kw = spec.conv_geom[3], spec.conv_geom[5]
    if (kw * c) % max(spec.block, 1) != 0:
        return (f"kw·C = {kw * c} not divisible by the DBB block "
                f"{spec.block} (K steps must cover whole blocks)")
    if not _conv_vmem_ok(spec, dbb=True):
        return "resident image block exceeds the VMEM budget"
    return ""


register_route(Route(
    name="conv_xla", domain="conv", priority=9,
    guard=lambda s: "",
    cost=_conv_xla_cost,
    describe="explicit im2col + GEMM oracle (materialized patch matrix)"))

register_route(Route(
    name="conv_sta", domain="conv", priority=0,
    guard=_guard_conv_sta,
    cost=lambda s: _conv_kernel_cost(s, dbb=False),
    describe="implicit-GEMM dense kernel: im2col gathered in VMEM"))

register_route(Route(
    name="conv_dbb", domain="conv", priority=0,
    guard=_guard_conv_dbb,
    cost=lambda s: _conv_kernel_cost(s, dbb=True),
    describe="implicit-GEMM DBB kernel: compressed weight stream"))


def conv(x: jax.Array, w, bias=None, *, kh: int, kw: int, stride: int = 1,
         padding: str = "SAME", act: str = "none", out_dtype=None,
         cfg=None, route: Optional[str] = None, use_kernel: bool = True,
         **tile_kw) -> jax.Array:
    """Front door for conv-as-GEMM: ``conv2d(x, w) (+bias, act)`` with
    ``w`` a dense ``[kh·kw·C, N]`` GEMM weight or a packed `DbbWeight`.
    The implied GEMM is M = B·Ho·Wo, K = kh·kw·C, N. ``use_kernel=False``
    pins the explicit im2col oracle (the conv_xla route)."""
    from repro.kernels.conv_gemm.ops import out_spatial
    packed = isinstance(w, DbbWeight)
    if packed and w.bits == 4:
        # conv kernels stream the INT8 plane only — w4 is a decode-GEMM
        # format. Decompress once (XLA) and take the dense routes rather
        # than silently mis-reading the nibble plane as int8 slots.
        from repro.core.dbb import unpack_dbb
        w = unpack_dbb(w).astype(x.dtype)
        packed = False
    b, h, w_dim, c = x.shape
    ho, _, _ = out_spatial(h, kh, stride, padding)
    wo, _, _ = out_spatial(w_dim, kw, stride, padding)
    if packed:
        n = w.values.shape[-1]
        block, nnz = w.block, w.nnz
        vals_itemsize = jnp.dtype(w.values.dtype).itemsize
    else:
        n = w.shape[1]
        block, nnz, vals_itemsize = 8, 4, 1
    spec = OpSpec(
        domain="conv", m=b * ho * wo, k=kh * kw * c, n=n,
        itemsize=x.dtype.itemsize, out_itemsize=x.dtype.itemsize,
        packed=packed, block=block, nnz=nnz, vals_itemsize=vals_itemsize,
        epilogue_ops=_epilogue_ops(bias, None, act),
        pallas=use_kernel,
        conv_geom=(b, h, w_dim, c, kh, kw, stride, padding),
        float_ok=(jnp.issubdtype(x.dtype, jnp.floating)
                  or x.dtype == jnp.int8))
    if route is not None:
        dec = _decide(_REGISTRY["conv"][route], spec, HW_V5E)
        if not dec.applicable:
            raise ValueError(f"route {route!r} rejected this op: "
                             f"{dec.reason}")
        name = route
    else:
        name, _ = select(spec, routes_from_cfg(cfg))

    from repro.kernels.conv_gemm.ops import conv_gemm, conv_gemm_packed
    kernel = name != "conv_xla"
    if packed:
        return conv_gemm_packed(x, w, bias, kh=kh, kw=kw, stride=stride,
                                padding=padding, act=act,
                                out_dtype=out_dtype, use_kernel=kernel,
                                **tile_kw)
    return conv_gemm(x, w, bias, kh=kh, kw=kw, stride=stride,
                     padding=padding, act=act, out_dtype=out_dtype,
                     use_kernel=kernel, **tile_kw)


# ---------------------------------------------------------------------------
# attention domain (full-sequence core, DESIGN.md §10)
# ---------------------------------------------------------------------------

def _guard_attn_flash(spec: OpSpec) -> str:
    if spec.packed_seq:
        return "packed cu_seqlens batch (block-diagonal masking required)"
    if not spec.flash_active:
        return ("flash backend not selected (attn_impl/gemm_impl pin the "
                "XLA paths, or a global GSPMD graph — per-shard shard_map "
                "bodies re-enable it)")
    if not spec.float_ok:
        return "non-float operands"
    from repro.kernels.attn.ops import flash_ok
    if not flash_ok(spec.m, spec.n, spec.k, spec.itemsize):
        return "smallest legal (bq, bkv) block pair exceeds VMEM"
    return ""


def _guard_attn_chunked(spec: OpSpec) -> str:
    if spec.packed_seq:
        return "packed cu_seqlens batch (block-diagonal masking required)"
    if spec.ragged:
        return "ragged per-row positions (chunked masks assume one ladder)"
    if spec.m != spec.n:
        return "not a self-attention full-sequence call (T != S)"
    if spec.n % max(spec.chunk, 1) != 0:
        return f"S={spec.n} not divisible by attn_chunk={spec.chunk}"
    return ""


def _attn_cost(spec: OpSpec, score_passes: float) -> Tuple[float, float]:
    # per-row (t, s) work × the padded batch rows. Packed specs carry the
    # whole batch's token count in m with batch=1, which is exactly what
    # makes their roofline honest: total_tokens · s_visible instead of
    # B · T_max² (DESIGN.md §12)
    t, s, d, b = spec.m, spec.n, spec.k, max(spec.batch, 1)
    flops = 4.0 * b * t * s * d
    nbytes = b * ((2 * t * d + 2 * s * d) * spec.itemsize
                  + score_passes * t * s * _F32)
    return flops, nbytes


register_route(Route(
    name="attn_flash", domain="attention", priority=0,
    guard=_guard_attn_flash,
    cost=lambda s: _attn_cost(s, 0.0),
    describe="fused Pallas flash kernel: online softmax, no score tensor"))

register_route(Route(
    name="attn_chunked", domain="attention", priority=1,
    guard=_guard_attn_chunked,
    # one recomputed score-tile pass; deferred below 2 chunks where the
    # unrolled-scan overhead beats the naive path's extra score traffic
    cost=lambda s: _attn_cost(s, 1.0),
    defer=lambda s: s.n <= 2 * s.chunk,
    describe="blocked XLA path with running-softmax combine"))

register_route(Route(
    name="attn_naive", domain="attention", priority=2,
    guard=lambda s: ("packed cu_seqlens batch (block-diagonal masking "
                     "required)" if s.packed_seq else ""),
    cost=lambda s: _attn_cost(s, 2.0),
    describe="quadratic oracle (full [T,S] score bias materialized)"))


def _guard_attn_packed_flash(spec: OpSpec) -> str:
    if not spec.packed_seq:
        return "not a packed cu_seqlens batch"
    if not spec.flash_active:
        return ("flash backend not selected (attn_impl/gemm_impl pin the "
                "XLA paths, or a global GSPMD graph — per-shard shard_map "
                "bodies re-enable it)")
    if not spec.float_ok:
        return "non-float operands"
    from repro.kernels.attn.ops import flash_ok
    if not flash_ok(spec.m, spec.n, spec.k, spec.itemsize):
        return "smallest legal (bq, bkv) block pair exceeds VMEM"
    return ""


register_route(Route(
    name="attn_packed_flash", domain="attention", priority=0,
    guard=_guard_attn_packed_flash,
    cost=lambda s: _attn_cost(s, 0.0),
    describe="cu_seqlens flash kernel: block-diagonal-causal over packed "
             "total_tokens, zero pad rows"))

register_route(Route(
    name="attn_packed_ref", domain="attention", priority=3,
    guard=lambda s: ("" if s.packed_seq else "not a packed cu_seqlens "
                     "batch"),
    cost=lambda s: _attn_cost(s, 2.0),
    describe="quadratic packed oracle (full [T,T] segment-mask score "
             "tensor)"))

_ATTN_IMPL_ROUTE = {"flash": "attn_flash", "chunked": "attn_chunked",
                    "naive": "attn_naive"}
# packed calls have no chunked implementation: anything but flash drops to
# the quadratic packed oracle
_PACKED_IMPL_ROUTE = {"flash": "attn_packed_flash",
                      "chunked": "attn_packed_ref",
                      "naive": "attn_packed_ref"}
# a kernel_routes pin on a padded route carries its intent (kernel vs XLA)
# to the packed variant instead of tripping the forced-route warning
_ATTN_TO_PACKED = {"attn_flash": "attn_packed_flash",
                   "attn_chunked": "attn_packed_ref",
                   "attn_naive": "attn_packed_ref"}


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              positions: jax.Array, cfg, ragged: bool = False) -> jax.Array:
    """Front door for full-sequence attention dispatch (flash / chunked /
    naive) on projected q/k/v in model layout. Replaces the old
    `models.attention._attention_core` inline guard chain; the route
    implementations stay in `models.attention`."""
    from repro.models import attention as A
    t, s = q.shape[1], k.shape[1]
    spec = OpSpec(
        domain="attention", m=t, k=q.shape[-1], n=s,
        itemsize=q.dtype.itemsize, out_itemsize=q.dtype.itemsize,
        ragged=ragged, chunk=cfg.attn_chunk, batch=q.shape[0],
        flash_active=flash_backend_active(cfg),
        float_ok=jnp.issubdtype(q.dtype, jnp.floating))
    cfg_routes = dict(routes_from_cfg(cfg))
    # attn_impl is the config-level override for this domain (kept for
    # compatibility; kernel_routes["attention"] wins if both are set)
    if cfg.attn_impl in _ATTN_IMPL_ROUTE:
        cfg_routes.setdefault("attention", _ATTN_IMPL_ROUTE[cfg.attn_impl])
    name, _ = select(spec, cfg_routes)

    if name == "attn_flash":
        from repro.kernels.attn import flash_attention
        return flash_attention(
            q, k, v, A._start_from_positions(positions, q.shape[0]),
            window=cfg.sliding_window, softcap=cfg.attn_logit_softcap)
    if ragged:          # per-row ladders: only flash and naive mask them
        return A._naive_attention(q, k, v, positions, positions, cfg)
    if name == "attn_chunked":
        return A._chunked_causal_attention(q, k, v, cfg, cfg.attn_chunk)
    pos1d = positions[0] if positions.ndim > 1 else positions
    return A._naive_attention(q, k, v, pos1d, pos1d, cfg)


# a continuation chunk is not a full-sequence call (T != S, per-row offset
# ladder): the chunked path has no implementation for it, so a chunked pin
# degrades to naive rather than warning every trace
_CHUNK_IMPL_ROUTE = {"flash": "attn_flash", "chunked": "attn_naive",
                     "naive": "attn_naive"}


def chunk_attention_route(cfg, *, t: int, s: int, d: int, itemsize: int,
                          floating: bool = True) -> str:
    """Route gate for a chunked-prefill continuation (DESIGN.md §12): T
    chunk queries at an absolute offset against one row's S cache slots.
    Flash serves it through ``q_offset``; everything else drops to the
    naive qpos/kpos mask."""
    spec = OpSpec(domain="attention", m=t, k=d, n=s, itemsize=itemsize,
                  out_itemsize=itemsize, ragged=True, chunk=cfg.attn_chunk,
                  flash_active=flash_backend_active(cfg), float_ok=floating)
    cfg_routes = dict(routes_from_cfg(cfg))
    if cfg_routes.get("attention") == "attn_chunked":
        cfg_routes["attention"] = "attn_naive"
    if cfg.attn_impl in _CHUNK_IMPL_ROUTE:
        cfg_routes.setdefault("attention", _CHUNK_IMPL_ROUTE[cfg.attn_impl])
    name, _ = select(spec, cfg_routes)
    return name


def packed_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     seg_ids: jax.Array, cfg) -> jax.Array:
    """Front door for packed (cu_seqlens) prefill attention: ``q/k/v
    [1, T, H, D]`` where T is the ragged batch's TOTAL token count and
    ``seg_ids [T]`` names the owning request per packed position
    (DESIGN.md §12). The spec charges m = total_tokens with batch=1 — the
    honest roofline the padded route table can't express."""
    from repro.kernels.attn import packed_flash_attention
    t = q.shape[1]
    spec = OpSpec(
        domain="attention", m=t, k=q.shape[-1], n=t,
        itemsize=q.dtype.itemsize, out_itemsize=q.dtype.itemsize,
        packed_seq=True, chunk=cfg.attn_chunk,
        flash_active=flash_backend_active(cfg),
        float_ok=jnp.issubdtype(q.dtype, jnp.floating))
    cfg_routes = dict(routes_from_cfg(cfg))
    if cfg_routes.get("attention") in _ATTN_TO_PACKED:
        cfg_routes["attention"] = _ATTN_TO_PACKED[cfg_routes["attention"]]
    if cfg.attn_impl in _PACKED_IMPL_ROUTE:
        cfg_routes.setdefault("attention", _PACKED_IMPL_ROUTE[cfg.attn_impl])
    name, _ = select(spec, cfg_routes)
    o = packed_flash_attention(
        q[0], k[0], v[0], seg_ids, window=cfg.sliding_window,
        softcap=cfg.attn_logit_softcap,
        use_kernel=(name == "attn_packed_flash"))
    return o[None]


# ---------------------------------------------------------------------------
# attn_decode domain (single-token decode against the KV cache)
# ---------------------------------------------------------------------------

def _guard_decode_flash(spec: OpSpec) -> str:
    if spec.ring:
        return "ring-buffer (sliding-window) cache layout"
    if not spec.flash_active:
        return ("flash backend not selected (attn_impl/gemm_impl pin the "
                "XLA paths, or a global GSPMD graph — per-shard shard_map "
                "bodies re-enable it)")
    if not spec.float_ok:
        return "non-float operands"
    if not skinny_ok(spec.m, spec.k, spec.itemsize):
        return (f"GQA group {spec.m} exceeds the resident-query gate "
                f"(SKINNY_M_MAX={SKINNY_M_MAX})")
    if spec.page < 8:
        return f"page {spec.page} below the 8-slot sublane quantum"
    if spec.n % max(spec.page, 1) != 0:
        return f"cache length {spec.n} not a multiple of page {spec.page}"
    from repro.kernels.attn.ops import paged_decode_ok
    if not paged_decode_ok(spec.page, spec.k, spec.itemsize):
        return "KV page tile exceeds the decode kernel's VMEM budget"
    return ""


register_route(Route(
    name="attn_decode_flash", domain="attn_decode", priority=0,
    guard=_guard_decode_flash,
    cost=lambda s: (4.0 * s.m * s.n * s.k,
                    (s.m * s.k + 2 * s.n * s.k) * s.itemsize),
    describe="paged flash decode kernel (contiguous cache = identity "
             "block table)"))

register_route(Route(
    name="attn_decode_xla", domain="attn_decode", priority=1,
    guard=lambda s: "",
    cost=lambda s: (4.0 * s.m * s.n * s.k,
                    (s.m * s.k + 2 * s.n * s.k) * s.itemsize
                    + 2.0 * s.m * s.n * _F32),
    describe="XLA softmax decode (materialized [B,H,G,1,Smax] scores)"))


def decode_attention_route(cfg, *, group: int, head_dim: int, itemsize: int,
                           page: int, smax: int, ring: bool = False,
                           floating: bool = True) -> str:
    """Route selection for one-token decode attention — the gate that used
    to live inline in `decode_attention_apply`. Returns a route name from
    the ``attn_decode`` domain."""
    spec = OpSpec(domain="attn_decode", m=group, k=head_dim, n=smax,
                  itemsize=itemsize, out_itemsize=itemsize, page=page,
                  ring=ring, flash_active=flash_backend_active(cfg),
                  float_ok=floating)
    name, _ = select(spec, routes_from_cfg(cfg))
    return name


# ---------------------------------------------------------------------------
# head_sample domain (fused sampling head, DESIGN.md §15)
# ---------------------------------------------------------------------------

# VPU ops per logit in the sampling epilogue: penalty selects + 3 hash
# mixes (~4 ops each) + the log/log/scale of the gumbel transform
_SAMPLE_EPI_OPS = 16.0


def _guard_head_sample_fused(spec: OpSpec) -> str:
    if not spec.pallas:
        return ("Pallas route not selected (gemm_impl != 'pallas', or a "
                "global GSPMD graph — per-shard shard_map bodies "
                "re-enable it)")
    if not spec.float_ok:
        return "non-float hidden rows (the sampling epilogue is f32)"
    if spec.sample_tt:
        return ("top-k/top-p are global order statistics — the streaming "
                "epilogue cannot sort the row (XLA sampler materializes)")
    r = _tp_split_reason(spec)      # vocab-parallel: column split of N
    if r:
        return r
    if not skinny_ok(spec.m, spec.k, spec.itemsize):
        return (f"outside the skinny regime (M ≤ {SKINNY_M_MAX} and "
                f"resident [M,K] ≤ VMEM/4)")
    _, _, n_loc = _shard_dims(spec)
    if spec.k % 128 or n_loc % 128:
        return (f"K={spec.k} / local N={n_loc} not divisible by the "
                "128-lane tile (vocab padding could win the argmax)")
    return ""


def _hs_fused_cost(spec: OpSpec) -> Tuple[float, float]:
    mp, kp, np_ = _mm_dims(spec, skinny=True)
    flops = 2.0 * mp * kp * np_ + _SAMPLE_EPI_OPS * mp * np_
    # resident rows + streamed weight + streamed counts; the logits and
    # scores live only in VMEM — output traffic is the [M, 1] scalar pair
    nbytes = (mp * kp * spec.itemsize + kp * np_ * spec.itemsize
              + mp * np_ * _F32 + 2.0 * mp * _F32)
    return flops, nbytes


def _hs_xla_cost(spec: OpSpec) -> Tuple[float, float]:
    m, k, n = _shard_dims(spec)
    flops = 2.0 * m * k * n + _SAMPLE_EPI_OPS * m * n
    # the GEMV writes [M, N] logits to HBM, then the sampler re-reads
    # them for the penalty pass and the score/argmax pass
    nbytes = (m * k * spec.itemsize + k * n * spec.itemsize
              + m * n * _F32 + 2.0 * 2.0 * m * n * _F32
              + m * n * _F32)                       # counts read
    if spec.sample_tt:
        # sort + softmax/cumsum of the sorted row, another ~2 round-trips
        nbytes += 4.0 * m * n * _F32
    return flops, nbytes


register_route(Route(
    name="head_sample_fused", domain="head_sample", priority=0,
    guard=_guard_head_sample_fused,
    cost=_hs_fused_cost,
    describe="skinny head GEMV + fused penalty/temperature/Gumbel "
             "epilogue; logits never materialized, scalar (score, id) "
             "out (vocab-parallel combine under TP)"))

register_route(Route(
    name="head_sample_xla", domain="head_sample", priority=9,
    guard=lambda s: "",
    cost=_hs_xla_cost,
    describe="materialized [B,V] logits + XLA reference sampler "
             "(top-k/top-p capable)"))


def head_sample(h: jax.Array, w_head, counts: jax.Array, temp, rep, pres,
                freq, seed, step, *, top_k=None, top_p=None,
                use_tt: bool = False, base=0, cfg=None,
                pallas: Optional[bool] = None, route: Optional[str] = None,
                return_score: bool = False):
    """Front door for the sampling head: one token per row from hidden
    rows ``h [B, K]`` against the head weight ``w_head [K, N]``, with the
    TensorRT-LLM-contract penalties read from ``counts [B, N]`` and
    counter-hash Gumbel noise keyed by per-row ``(seed, step)``.

    ``use_tt`` is a STATIC flag — pass True only when some live row
    actually uses top-k/top-p; it forces the XLA sampler route (the
    masks are global order statistics) and traces the masking code.
    ``base`` offsets noise to global vocab ids for vocab-parallel TP
    shards; ``return_score=True`` additionally returns the winning score
    so the caller can run the scalar (max, argmax) shard combine.
    """
    b, k_dim = h.shape
    k_w, n = w_head.shape
    assert k_dim == k_w, (h.shape, w_head.shape)
    if pallas is None:
        pallas = pallas_route_active(cfg)
    spec = OpSpec(
        domain="head_sample", m=b, k=k_dim, n=n,
        itemsize=4, out_itemsize=4, gemv=True, pallas=bool(pallas),
        sample_tt=bool(use_tt),
        float_ok=jnp.issubdtype(h.dtype, jnp.floating))
    if route is not None:
        dec = _decide(_REGISTRY["head_sample"][route], spec, HW_V5E)
        if not dec.applicable:
            raise ValueError(f"route {route!r} rejected this op: "
                             f"{dec.reason}")
        name = route
    else:
        name, _ = select(spec, routes_from_cfg(cfg))

    if name == "head_sample_fused":
        from repro.kernels.sample.ops import head_sample_fused
        score, tok = head_sample_fused(
            h, w_head, counts, temp, rep, pres, freq, seed, step,
            base=base)
    else:
        from repro.kernels.sample.ref import sample_argmax
        logits = matmul(h.astype(jnp.float32),
                        w_head.astype(jnp.float32), cfg=cfg,
                        pallas=bool(pallas), gemv=True)
        score, tok = sample_argmax(
            logits, counts, temp, rep, pres, freq, seed, step,
            base=base, top_k=top_k, top_p=top_p, use_tt=use_tt)
    return (score, tok) if return_score else tok
