"""Fused GEMM epilogue: bias + activation + INT8 requantization (DESIGN.md §7).

The paper keeps the whole MAC→accumulate→writeback path on-chip; S2TA
(arXiv:2107.07983) extends that by fusing the requant logic into the PE
datapath. The TPU analogue: once the output-stationary accumulator tile has
seen its last K step, the epilogue runs *in VMEM on the VPU* before the one
store to HBM. Without fusion every consumer re-reads the [M, N] accumulator
from HBM to add a bias, apply an activation, or requantize — for the
memory-bound decode GEMMs that extra round-trip is pure roofline loss
(2·M·N·itemsize bytes per epilogue op).

One `Epilogue` spec + one `apply_epilogue` function are shared by the Pallas
kernels (applied to the accumulator tile in the final-K store) and the jnp
oracles (applied to the full accumulator), so fused/unfused parity is
structural, not coincidental.

Operation order (fixed; matches the INT8 serving datapath in core/quant.py):

    acc                     int32 (int8 operands) or f32
    1. scale   y = acc * scale        f32, per-out-channel [N] or scalar —
                                      dequant (x_s·w_s) and requant (1/y_s)
                                      multipliers, folded into one operand
                                      by the caller
    2. bias    y = y + bias           f32 [N], in post-scale (output) units
    3. act     y = act(y)             relu | gelu (tanh approx) | silu
    4. store   round+clip to ±127 when the output dtype is int8,
               plain dtype cast otherwise
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["Epilogue", "apply_epilogue", "apply_act", "default_out_dtype",
           "as_row", "ACTIVATIONS"]

ACTIVATIONS = ("none", "relu", "gelu", "silu")

_ACT_FNS = {
    "relu": lambda y: jnp.maximum(y, 0),
    "gelu": lambda y: jax.nn.gelu(y, approximate=True),
    "silu": jax.nn.silu,
}

_INT8_MAX = 127.0


def apply_act(y: jax.Array, act: str) -> jax.Array:
    """Apply one of ACTIVATIONS by name — the single dispatch shared by the
    kernel epilogue and every XLA fallback path, so fused and unfused
    routes cannot drift."""
    if act == "none":
        return y
    if act not in _ACT_FNS:
        raise ValueError(f"act={act!r} not in {ACTIVATIONS}")
    return _ACT_FNS[act](y)


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Static description of the fused epilogue (hashable — jit-static).

    The spec carries only *flags*; the runtime tensors (bias [N] f32,
    scale [N] or scalar f32) travel as ordinary kernel operands so one
    compiled kernel serves any bias/scale values.

    act:       one of ACTIVATIONS, applied after scale+bias.
    has_bias:  a bias operand is present.
    has_scale: a scale operand is present (per-channel dequant and/or
               scalar requant multiplier, pre-folded by the caller).
    """
    act: str = "none"
    has_bias: bool = False
    has_scale: bool = False

    def __post_init__(self):
        if self.act not in ACTIVATIONS:
            raise ValueError(
                f"act={self.act!r} not in {ACTIVATIONS}")

    @property
    def is_identity(self) -> bool:
        return (self.act == "none" and not self.has_bias
                and not self.has_scale)

    def tag(self) -> str:
        """Stable string key (autotune cache, benchmark labels)."""
        parts = [self.act]
        if self.has_bias:
            parts.append("bias")
        if self.has_scale:
            parts.append("scale")
        return "+".join(parts)


def apply_epilogue(acc: jax.Array, spec: Epilogue, out_dtype,
                   bias: Optional[jax.Array] = None,
                   scale: Optional[jax.Array] = None) -> jax.Array:
    """Accumulator tile/tensor → output tile/tensor of ``out_dtype``.

    acc:   [..., N] int32 or f32 accumulator values.
    bias:  broadcastable-to-acc f32 (row vector [1, N] inside kernels).
    scale: broadcastable-to-acc f32, or None.

    Math runs in f32 as soon as any float op is involved; a pure ReLU on an
    int32 accumulator stays exact in int32 (max(acc, 0)).
    """
    out_dtype = jnp.dtype(out_dtype)
    assert spec.has_bias == (bias is not None), (spec, bias is None)
    assert spec.has_scale == (scale is not None), (spec, scale is None)
    y = acc
    if spec.has_scale:
        y = y.astype(jnp.float32) * scale.astype(jnp.float32)
    if spec.has_bias:
        y = y.astype(jnp.float32) + bias.astype(jnp.float32)
    if spec.act == "relu":
        y = _ACT_FNS["relu"](y)                     # dtype-preserving, exact
    elif spec.act != "none":
        y = _ACT_FNS[spec.act](y.astype(jnp.float32))
    if out_dtype == jnp.int8:
        y = jnp.clip(jnp.round(y.astype(jnp.float32)),
                     -_INT8_MAX, _INT8_MAX)
    return y.astype(out_dtype)


def default_out_dtype(operand_dtype, spec: Epilogue = Epilogue()) -> jnp.dtype:
    """Output-dtype policy shared by kernels, refs, and ops wrappers:
    int8 operands emit the raw INT32 accumulator unless a dequant scale is
    fused (then f32); float operands keep their dtype."""
    if jnp.dtype(operand_dtype) == jnp.int8:
        return jnp.dtype(jnp.float32 if spec.has_scale else jnp.int32)
    return jnp.dtype(operand_dtype)


def as_row(a, n: int) -> jax.Array:
    """Normalize a scalar / [N] / [1, N] epilogue operand to the [1, N] f32
    row vector the kernels consume (shared by both ops wrappers)."""
    a = jnp.asarray(a, jnp.float32)
    if a.ndim == 0:
        a = a.reshape(1, 1)
    if a.ndim == 1:
        a = a.reshape(1, -1)
    return jnp.broadcast_to(a, (1, n))
