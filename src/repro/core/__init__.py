"""Core: the paper's contribution — DBB format, STA geometry, sparse training,
INT8 quantization, the analytical area/power model, and the DbbLinear router."""
from repro.core.dbb import (DbbWeight, dbb_mask, dbb_project, pack_dbb,
                            unpack_dbb, dbb_footprint_bytes, validate_dbb)
from repro.core.sparsity import ste_dbb, apply_dbb_to_tree, dbb_schedule_nnz
from repro.core.quant import (QuantizedWeight, quantize_weight,
                              dequantize_weight, fake_quant, int8_matmul)
from repro.core.dbb_linear import (dbb_linear_apply, pack_tree,
                                   maybe_decompress_tree, tree_footprint_bytes)
