"""Density-Bound Block (DBB) structured-sparse weight format (paper §IV-A).

A weight matrix ``W[K, N]`` (contraction dim first, as used by ``x @ W``) is
split into ``B×1`` blocks along K. DBB bounds the non-zeros per block:
``NNZ <= k``. Unlike block sparsity (all-or-nothing blocks), only the *count*
is constrained — the positions are free, which is why accuracy holds
(paper Table I) while hardware utilization is guaranteed a-priori.

Storage format (paper: "simple bitmask compression"; DESIGN.md §2):
  values  [K//B * k, N]  the (up to) k surviving values per block, slot-major
                         (row kb*k + s holds slot s of block kb), index-
                         sorted, zero-padded when a block has fewer than k
                         non-zeros
  bitmask [K//B, N]      uint32, bit ``pos`` set ⇔ dense row kb*B + pos kept
                         — what the Pallas kernels and `decompress_ref`
                         consume (rank(pos) = popcount of the lower bits
                         recovers the slot)
  indices [K//B * k, N]  block-local positions (0..B-1) of each value, int32
                         — diagnostics/validation only; the serving format
                         drops them (4 B/value vs the 1 mask byte per block)

For B=8, k=4, INT8: (4 value bytes + 1 mask byte) / 8 bytes = 62.5% of dense
⇒ the paper's 37.5% weight-memory reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DbbWeight", "dbb_mask", "dbb_project", "pack_dbb", "unpack_dbb",
    "dbb_footprint_bytes", "dense_footprint_bytes", "validate_dbb",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DbbWeight:
    """Packed DBB weight. A pytree; `block`/`nnz`/`k_dim` are static."""
    values: jax.Array    # [K//B * k, N]
    indices: jax.Array   # [K//B * k, N] int32, block-local in [0, B)
    bitmask: jax.Array   # [K//B, N] uint32
    scale: Optional[jax.Array]  # [N] per-out-channel quant scale, or None
    block: int = dataclasses.field(metadata=dict(static=True), default=8)
    nnz: int = dataclasses.field(metadata=dict(static=True), default=4)
    k_dim: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def n_dim(self) -> int:
        return self.values.shape[-1]

    @property
    def num_blocks(self) -> int:
        return self.k_dim // self.block


def _check_dims(k_dim: int, block: int, nnz: int) -> None:
    if k_dim % block != 0:
        raise ValueError(f"K={k_dim} not divisible by DBB block={block}")
    if not (1 <= nnz <= block):
        raise ValueError(f"nnz={nnz} must be in [1, block={block}]")


def _bitonic_kth_largest(mags: jax.Array, k: int) -> jax.Array:
    """k-th largest along axis 1 (size B, power of two) via a Batcher
    bitonic network of elementwise min/max pairs.

    Why not lax.top_k: it lowers to a variadic sort that the SPMD
    partitioner refuses to keep sharded on the non-sorted dims, so the DBB
    projection all-gathered the weights' model axis every step
    (§Perf iteration 11). Compare-exchanges are plain elementwise ops —
    fully partitionable.
    """
    b = mags.shape[1]
    lanes = [mags[:, i] for i in range(b)]

    def networks(n):
        # Batcher odd-even mergesort compare-exchange schedule
        out = []
        p = 1
        while p < n:
            kk = p
            while kk >= 1:
                for j in range(kk % p, n - kk, 2 * kk):
                    for i in range(0, min(kk, n - j - kk)):
                        if (i + j) // (2 * p) == (i + j + kk) // (2 * p):
                            out.append((i + j, i + j + kk))
                kk //= 2
            p *= 2
        return out

    for a, c in networks(b):      # ascending: lane b-k holds k-th largest
        lo = jnp.minimum(lanes[a], lanes[c])
        hi = jnp.maximum(lanes[a], lanes[c])
        lanes[a], lanes[c] = lo, hi
    return lanes[b - k]


def dbb_mask(w: jax.Array, block: int, nnz: int) -> jax.Array:
    """Boolean keep-mask: top-|w| `nnz` entries of every B-block along axis 0.

    Ties are broken toward lower indices (deterministic), matching
    amplitude-based pruning in the paper §V-A.
    """
    k_dim, n = w.shape
    _check_dims(k_dim, block, nnz)
    if nnz == block:
        return jnp.ones_like(w, dtype=bool)
    blocks = jnp.abs(w.reshape(k_dim // block, block, n))    # [Kb, B, N]
    if block & (block - 1) == 0:
        thr = _bitonic_kth_largest(blocks, nnz)[:, None, :]  # [Kb, 1, N]
        gt = blocks > thr
        # fill remaining slots from the == thr ties, lowest index first
        need = nnz - gt.sum(axis=1, keepdims=True)
        eq = blocks == thr
        rank = jnp.cumsum(eq, axis=1)
        keep = gt | (eq & (rank <= need))
        return keep.reshape(k_dim, n)
    # non-power-of-two block: top_k fallback
    bt = blocks.transpose(0, 2, 1)                           # [Kb, N, B]
    _, idx = jax.lax.top_k(bt, nnz)
    keep = jnp.put_along_axis(jnp.zeros(bt.shape, bool), idx, True,
                              axis=-1, inplace=False)
    return keep.transpose(0, 2, 1).reshape(k_dim, n)


def dbb_project(w: jax.Array, block: int, nnz: int) -> jax.Array:
    """Project a dense matrix onto the DBB constraint set (zero the rest)."""
    return jnp.where(dbb_mask(w, block, nnz), w, jnp.zeros_like(w))


def pack_dbb(
    w: jax.Array, block: int = 8, nnz: int = 4,
    scale: Optional[jax.Array] = None,
) -> DbbWeight:
    """Compress ``W[K, N]`` to the DBB format (projects first if needed).

    Returns a `DbbWeight` with ``values [K/B·k, N]`` (slot-major),
    ``bitmask [K/B, N]`` and diagnostic ``indices [K/B·k, N]`` — the layout
    contract in DESIGN.md §2, shared with `kernels.dbb_gemm`. K must divide
    by ``block``; N is unconstrained here (kernels pad it).
    """
    k_dim, n = w.shape
    _check_dims(k_dim, block, nnz)
    kb = k_dim // block
    blocks = w.reshape(kb, block, n).transpose(0, 2, 1)       # [Kb, N, B]
    mag = jnp.abs(blocks)
    _, idx = jax.lax.top_k(mag, nnz)                          # [Kb, N, k]
    idx = jnp.sort(idx, axis=-1)                              # index-sorted
    vals = jnp.take_along_axis(blocks, idx, axis=-1)          # [Kb, N, k]
    # zero-pad slots whose source was already zero keeps blocks canonical
    vals = jnp.where(jnp.take_along_axis(mag, idx, axis=-1) > 0, vals,
                     jnp.zeros_like(vals))
    bitmask = jnp.where(
        jnp.abs(vals) > 0,
        (jnp.uint32(1) << idx.astype(jnp.uint32)),
        jnp.uint32(0),
    ).sum(axis=-1, dtype=jnp.uint32)                          # [Kb, N]
    values = vals.transpose(0, 2, 1).reshape(kb * nnz, n)
    indices = idx.astype(jnp.int32).transpose(0, 2, 1).reshape(kb * nnz, n)
    return DbbWeight(values=values, indices=indices, bitmask=bitmask,
                     scale=scale, block=block, nnz=nnz, k_dim=k_dim)


def unpack_dbb(p: DbbWeight) -> jax.Array:
    """Decompress a `DbbWeight` to dense ``[K, N]`` and apply the
    per-channel scale if present — the host-side analogue of the kernels'
    in-VMEM decompression (DESIGN.md §2)."""
    kb, n, k = p.num_blocks, p.n_dim, p.nnz
    vals = p.values.reshape(kb, k, n).transpose(0, 2, 1)      # [Kb, N, k]
    idx = p.indices.reshape(kb, k, n).transpose(0, 2, 1)      # [Kb, N, k]
    onehot = jax.nn.one_hot(idx, p.block, dtype=vals.dtype, axis=-1)
    dense = jnp.einsum("bnk,bnkB->bnB", vals, onehot)         # [Kb, N, B]
    out = dense.transpose(0, 2, 1).reshape(p.k_dim, n)
    if p.scale is not None:
        out = out * p.scale[None, :]
    return out


def dense_footprint_bytes(k_dim: int, n: int, itemsize: int = 1) -> int:
    return k_dim * n * itemsize


def dbb_footprint_bytes(k_dim: int, n: int, block: int, nnz: int,
                        itemsize: int = 1) -> int:
    """Compressed bytes: values + per-block bitmask (paper §IV-A)."""
    kb = k_dim // block
    mask_bytes = (block + 7) // 8
    return kb * n * (nnz * itemsize + mask_bytes)


def validate_dbb(p: DbbWeight) -> Tuple[bool, str]:
    """Host-side invariant check (used by tests & checkpoint loading)."""
    vals = np.asarray(p.values).reshape(p.num_blocks, p.nnz, p.n_dim)
    idx = np.asarray(p.indices).reshape(p.num_blocks, p.nnz, p.n_dim)
    if idx.min() < 0 or idx.max() >= p.block:
        return False, f"index out of range [0,{p.block})"
    # indices strictly increasing wherever two non-zero values share a block
    nz = np.abs(vals) > 0
    for b in range(min(p.num_blocks, 64)):   # bounded spot-check
        for col in range(min(p.n_dim, 64)):
            live = idx[b, nz[b, :, col], col]
            if live.size and np.any(np.diff(live) < 0):
                return False, f"indices not sorted in block {b} col {col}"
    per_block_nnz = nz.sum(axis=1)
    if per_block_nnz.max(initial=0) > p.nnz:
        return False, "NNZ bound violated"
    return True, "ok"
