"""Density-Bound Block (DBB) structured-sparse weight format (paper §IV-A).

A weight matrix ``W[K, N]`` (contraction dim first, as used by ``x @ W``) is
split into ``B×1`` blocks along K. DBB bounds the non-zeros per block:
``NNZ <= k``. Unlike block sparsity (all-or-nothing blocks), only the *count*
is constrained — the positions are free, which is why accuracy holds
(paper Table I) while hardware utilization is guaranteed a-priori.

Storage format (paper: "simple bitmask compression"; DESIGN.md §2):
  values  [K//B * k, N]  the (up to) k surviving values per block, slot-major
                         (row kb*k + s holds slot s of block kb), index-
                         sorted, zero-padded when a block has fewer than k
                         non-zeros
  bitmask [K//B, N]      uint32, bit ``pos`` set ⇔ dense row kb*B + pos kept
                         — what the Pallas kernels and `decompress_ref`
                         consume (rank(pos) = popcount of the lower bits
                         recovers the slot)
  indices [K//B * k, N]  block-local positions (0..B-1) of each value, int32
                         — diagnostics/validation only; the serving format
                         drops them (4 B/value vs the 1 mask byte per block)

For B=8, k=4, INT8: (4 value bytes + 1 mask byte) / 8 bytes = 62.5% of dense
⇒ the paper's 37.5% weight-memory reduction.

Sub-8-bit values plane (DESIGN.md §16): ``pack_dbb(..., bits=4, group=G)``
stores the surviving values as nibble-packed INT4 — two slots per int8
byte (packed row i holds compressed row 2i in the low nibble, 2i+1 in the
high nibble) — quantized symmetrically to [-7, 7] per group of G dense K
rows, with the per-group scales in ``scale [K//G, N]`` f32. The group must
be a multiple of the DBB block so a compressed row's scale group is
column-independent (every dense position of block kb lands in group
kb·B // G). For B=8, k=4, INT4: (2 value bytes + 1 mask byte) / 8 = 37.5%
of dense INT8 bytes — the decode weight stream roughly halves again.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DbbWeight", "dbb_mask", "dbb_project", "pack_dbb", "unpack_dbb",
    "pack_nibbles", "unpack_nibbles", "INT4_MAX",
    "dbb_footprint_bytes", "dense_footprint_bytes", "validate_dbb",
]

# symmetric INT4 grid [-7, 7] (the -8 code is unused, like INT8's -128)
INT4_MAX = 7


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DbbWeight:
    """Packed DBB weight. A pytree; `block`/`nnz`/`k_dim`/`bits`/`group`
    are static. ``bits=8`` stores one value per ``values`` element;
    ``bits=4`` nibble-packs two INT4 slots per int8 byte and ``scale``
    holds the groupwise ``[K//G, N]`` dequant plane (DESIGN.md §16)."""
    values: jax.Array    # [K//B * k, N]  (bits=4: [K//B * k // 2, N] int8)
    indices: jax.Array   # [K//B * k, N] int32, block-local in [0, B)
    bitmask: jax.Array   # [K//B, N] uint32
    scale: Optional[jax.Array]  # [N] per-channel (bits=8) / [K//G, N] (bits=4)
    block: int = dataclasses.field(metadata=dict(static=True), default=8)
    nnz: int = dataclasses.field(metadata=dict(static=True), default=4)
    k_dim: int = dataclasses.field(metadata=dict(static=True), default=0)
    bits: int = dataclasses.field(metadata=dict(static=True), default=8)
    group: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def n_dim(self) -> int:
        return self.values.shape[-1]

    @property
    def num_blocks(self) -> int:
        return self.k_dim // self.block


def _check_dims(k_dim: int, block: int, nnz: int) -> None:
    if k_dim % block != 0:
        raise ValueError(f"K={k_dim} not divisible by DBB block={block}")
    if not (1 <= nnz <= block):
        raise ValueError(f"nnz={nnz} must be in [1, block={block}]")


def _bitonic_kth_largest(mags: jax.Array, k: int) -> jax.Array:
    """k-th largest along axis 1 (size B, power of two) via a Batcher
    bitonic network of elementwise min/max pairs.

    Why not lax.top_k: it lowers to a variadic sort that the SPMD
    partitioner refuses to keep sharded on the non-sorted dims, so the DBB
    projection all-gathered the weights' model axis every step
    (§Perf iteration 11). Compare-exchanges are plain elementwise ops —
    fully partitionable.
    """
    b = mags.shape[1]
    lanes = [mags[:, i] for i in range(b)]

    def networks(n):
        # Batcher odd-even mergesort compare-exchange schedule
        out = []
        p = 1
        while p < n:
            kk = p
            while kk >= 1:
                for j in range(kk % p, n - kk, 2 * kk):
                    for i in range(0, min(kk, n - j - kk)):
                        if (i + j) // (2 * p) == (i + j + kk) // (2 * p):
                            out.append((i + j, i + j + kk))
                kk //= 2
            p *= 2
        return out

    for a, c in networks(b):      # ascending: lane b-k holds k-th largest
        lo = jnp.minimum(lanes[a], lanes[c])
        hi = jnp.maximum(lanes[a], lanes[c])
        lanes[a], lanes[c] = lo, hi
    return lanes[b - k]


def dbb_mask(w: jax.Array, block: int, nnz: int) -> jax.Array:
    """Boolean keep-mask: top-|w| `nnz` entries of every B-block along axis 0.

    Ties are broken toward lower indices (deterministic), matching
    amplitude-based pruning in the paper §V-A.
    """
    k_dim, n = w.shape
    _check_dims(k_dim, block, nnz)
    if nnz == block:
        return jnp.ones_like(w, dtype=bool)
    blocks = jnp.abs(w.reshape(k_dim // block, block, n))    # [Kb, B, N]
    if block & (block - 1) == 0:
        thr = _bitonic_kth_largest(blocks, nnz)[:, None, :]  # [Kb, 1, N]
        gt = blocks > thr
        # fill remaining slots from the == thr ties, lowest index first
        need = nnz - gt.sum(axis=1, keepdims=True)
        eq = blocks == thr
        rank = jnp.cumsum(eq, axis=1)
        keep = gt | (eq & (rank <= need))
        return keep.reshape(k_dim, n)
    # non-power-of-two block: top_k fallback
    bt = blocks.transpose(0, 2, 1)                           # [Kb, N, B]
    _, idx = jax.lax.top_k(bt, nnz)
    keep = jnp.put_along_axis(jnp.zeros(bt.shape, bool), idx, True,
                              axis=-1, inplace=False)
    return keep.transpose(0, 2, 1).reshape(k_dim, n)


def dbb_project(w: jax.Array, block: int, nnz: int) -> jax.Array:
    """Project a dense matrix onto the DBB constraint set (zero the rest)."""
    return jnp.where(dbb_mask(w, block, nnz), w, jnp.zeros_like(w))


def pack_nibbles(q: jax.Array) -> jax.Array:
    """Nibble-pack an int8 array of INT4-range rows: ``[R, N] → [R//2, N]``,
    packed row i = row 2i in the low nibble, row 2i+1 in the high nibble.
    R must be even; values must lie in [-8, 7]."""
    r, _ = q.shape
    if r % 2 != 0:
        raise ValueError(f"nibble packing needs an even row count, got {r}")
    u = jax.lax.bitcast_convert_type(q.astype(jnp.int8), jnp.uint8)
    lo = u[0::2] & 0xF
    hi = u[1::2] & 0xF
    return jax.lax.bitcast_convert_type(lo | (hi << 4), jnp.int8)


def unpack_nibbles(packed: jax.Array) -> jax.Array:
    """Inverse of `pack_nibbles`: ``[R//2, N] int8 → [R, N] int8`` with each
    nibble sign-extended. Pure shift arithmetic (``(p << 4) >> 4`` for the
    low nibble, ``p >> 4`` for the high one) so the same expansion runs
    unchanged inside the Pallas kernel bodies."""
    r2, n = packed.shape
    lo = jnp.right_shift(jnp.left_shift(packed, 4), 4)
    hi = jnp.right_shift(packed, 4)
    return jnp.stack([lo, hi], axis=1).reshape(r2 * 2, n)


def _check_w4_dims(k_dim: int, block: int, nnz: int, group: int) -> None:
    if group <= 0 or group % block != 0:
        raise ValueError(f"group={group} must be a positive multiple of "
                         f"block={block} (scale groups cover whole blocks)")
    if k_dim % group != 0:
        raise ValueError(f"K={k_dim} not divisible by group={group}")
    if (k_dim // block * nnz) % 2 != 0:
        raise ValueError(
            f"K//B·k = {k_dim // block * nnz} compressed rows must be even "
            f"to nibble-pack (K={k_dim}, block={block}, nnz={nnz})")


def pack_dbb(
    w: jax.Array, block: int = 8, nnz: int = 4,
    scale: Optional[jax.Array] = None,
    bits: int = 8, group: int = 128,
) -> DbbWeight:
    """Compress ``W[K, N]`` to the DBB format (projects first if needed).

    Returns a `DbbWeight` with ``values [K/B·k, N]`` (slot-major),
    ``bitmask [K/B, N]`` and diagnostic ``indices [K/B·k, N]`` — the layout
    contract in DESIGN.md §2, shared with `kernels.dbb_gemm`. K must divide
    by ``block``; N is unconstrained here (kernels pad it).

    ``bits=4`` additionally quantizes the surviving values to the
    symmetric INT4 grid per ``group`` dense K rows (group % block == 0,
    K % group == 0), nibble-packs the values plane to ``[K/B·k/2, N]`` and
    stores the per-group scales in ``scale [K//G, N]`` (DESIGN.md §16);
    a caller-supplied ``scale`` is not accepted in that mode.
    """
    if bits not in (4, 8):
        raise ValueError(f"bits={bits} not supported (4 or 8)")
    if bits == 4:
        if scale is not None:
            raise ValueError("bits=4 derives groupwise scales itself; "
                             "per-channel scale is the bits=8 format")
        return _pack_dbb_w4(w, block, nnz, group)
    k_dim, n = w.shape
    _check_dims(k_dim, block, nnz)
    kb = k_dim // block
    blocks = w.reshape(kb, block, n).transpose(0, 2, 1)       # [Kb, N, B]
    mag = jnp.abs(blocks)
    _, idx = jax.lax.top_k(mag, nnz)                          # [Kb, N, k]
    idx = jnp.sort(idx, axis=-1)                              # index-sorted
    vals = jnp.take_along_axis(blocks, idx, axis=-1)          # [Kb, N, k]
    # zero-pad slots whose source was already zero keeps blocks canonical
    vals = jnp.where(jnp.take_along_axis(mag, idx, axis=-1) > 0, vals,
                     jnp.zeros_like(vals))
    bitmask = jnp.where(
        jnp.abs(vals) > 0,
        (jnp.uint32(1) << idx.astype(jnp.uint32)),
        jnp.uint32(0),
    ).sum(axis=-1, dtype=jnp.uint32)                          # [Kb, N]
    # canonical slot order = bitmask-rank order: live values compact into
    # the leading slots (dead zero slots trail), which is what the
    # kernels' popcount-rank decompression assumes. Continuous weights
    # never produce dead slots mid-block, but quantized (bits=4) input
    # routinely rounds selected values to exactly zero.
    live = jnp.abs(vals) > 0
    order = jnp.argsort(jnp.where(live, idx, idx + block), axis=-1)
    idx = jnp.take_along_axis(idx, order, axis=-1)
    vals = jnp.take_along_axis(vals, order, axis=-1)
    values = vals.transpose(0, 2, 1).reshape(kb * nnz, n)
    indices = idx.astype(jnp.int32).transpose(0, 2, 1).reshape(kb * nnz, n)
    return DbbWeight(values=values, indices=indices, bitmask=bitmask,
                     scale=scale, block=block, nnz=nnz, k_dim=k_dim)


def _pack_dbb_w4(w: jax.Array, block: int, nnz: int,
                 group: int) -> DbbWeight:
    """bits=4 pack: groupwise symmetric quantize to [-7, 7], DBB-select on
    the *quantized* grid (so the bitmask matches the stored INT4 values
    exactly), then nibble-pack the values plane."""
    k_dim, n = w.shape
    _check_dims(k_dim, block, nnz)
    _check_w4_dims(k_dim, block, nnz, group)
    g = w.astype(jnp.float32).reshape(k_dim // group, group, n)
    scale = (jnp.max(jnp.abs(g), axis=1) / INT4_MAX).astype(jnp.float32)
    scale = jnp.where(scale > 0, scale, jnp.ones_like(scale))  # [K//G, N]
    q = jnp.clip(jnp.round(g / scale[:, None, :]), -INT4_MAX, INT4_MAX)
    q = q.reshape(k_dim, n).astype(jnp.int8)
    p8 = pack_dbb(q, block=block, nnz=nnz)    # top-k on the INT4 grid
    return DbbWeight(values=pack_nibbles(p8.values), indices=p8.indices,
                     bitmask=p8.bitmask, scale=scale, block=block,
                     nnz=nnz, k_dim=k_dim, bits=4, group=group)


def _decompress_bitmask(values: jax.Array, bitmask: jax.Array, *,
                        block: int) -> jax.Array:
    """Bitmask-rank decompression ``[Kb·k, N] + [Kb, N] → [K, N]`` — the
    indices-free analogue of `unpack_dbb`'s one-hot path, for leaves whose
    diagnostic ``indices`` plane has been stripped (the serving format).
    Same rank = popcount-of-lower-bits recovery the kernels use."""
    kbn, n = values.shape
    kb = bitmask.shape[0]
    k = kbn // kb
    vals = values.reshape(kb, k, n)
    pos = jnp.arange(block, dtype=jnp.uint32)
    bits = ((bitmask[:, None, :] >> pos[None, :, None]) & 1)  # [Kb, B, N]
    rank = (jnp.cumsum(bits, axis=1) - bits).astype(jnp.int32)
    rank = jnp.clip(rank, 0, k - 1)
    gathered = jnp.take_along_axis(vals, rank, axis=1)        # [Kb, B, N]
    dense = jnp.where(bits.astype(bool), gathered,
                      jnp.zeros_like(gathered))
    return dense.reshape(kb * block, n)


def unpack_dbb(p: DbbWeight) -> jax.Array:
    """Decompress a `DbbWeight` to dense ``[K, N]`` and apply the scale
    plane if present — the host-side analogue of the kernels' in-VMEM
    decompression (DESIGN.md §2). ``bits=4`` leaves sign-extend the
    nibble plane first and dequantize with the groupwise ``[K//G, N]``
    scales; leaves whose diagnostic ``indices`` were stripped (serving)
    fall back to bitmask-rank decompression."""
    kb, n, k = p.num_blocks, p.n_dim, p.nnz
    values = unpack_nibbles(p.values) if p.bits == 4 else p.values
    if p.indices is None:
        out = _decompress_bitmask(values, p.bitmask, block=p.block)
    else:
        vals = values.reshape(kb, k, n).transpose(0, 2, 1)    # [Kb, N, k]
        idx = p.indices.reshape(kb, k, n).transpose(0, 2, 1)  # [Kb, N, k]
        onehot = jax.nn.one_hot(idx, p.block, dtype=vals.dtype, axis=-1)
        dense = jnp.einsum("bnk,bnkB->bnB", vals, onehot)     # [Kb, N, B]
        out = dense.transpose(0, 2, 1).reshape(p.k_dim, n)
    if p.bits == 4:
        grouped = out.astype(jnp.float32).reshape(
            p.k_dim // p.group, p.group, n)
        return (grouped * p.scale[:, None, :]).reshape(p.k_dim, n)
    if p.scale is not None:
        out = out * p.scale[None, :]
    return out


def dense_footprint_bytes(k_dim: int, n: int, itemsize: int = 1) -> int:
    return k_dim * n * itemsize


def dbb_footprint_bytes(k_dim: int, n: int, block: int, nnz: int,
                        itemsize: int = 1, bits: int = 8,
                        group: int = 0) -> int:
    """Compressed bytes: values + per-block bitmask (paper §IV-A).
    ``bits=4`` halves the values plane (nibble packing) and adds the
    groupwise f32 scale plane ``[K//G, N]`` (DESIGN.md §16)."""
    kb = k_dim // block
    mask_bytes = (block + 7) // 8
    if bits == 4:
        val_bytes = (kb * nnz + 1) // 2 * n       # two slots per byte
        scale_bytes = (k_dim // group) * n * 4 if group > 0 else 0
        return val_bytes + kb * n * mask_bytes + scale_bytes
    return kb * n * (nnz * itemsize + mask_bytes)


def validate_dbb(p: DbbWeight) -> Tuple[bool, str]:
    """Host-side invariant check (used by tests & checkpoint loading)."""
    if p.indices is None:
        return False, "indices plane stripped (serving format); " \
                      "validate against the host-side copy"
    values = unpack_nibbles(p.values) if p.bits == 4 else p.values
    vals = np.asarray(values).reshape(p.num_blocks, p.nnz, p.n_dim)
    idx = np.asarray(p.indices).reshape(p.num_blocks, p.nnz, p.n_dim)
    if idx.min() < 0 or idx.max() >= p.block:
        return False, f"index out of range [0,{p.block})"
    # indices strictly increasing wherever two non-zero values share a block
    nz = np.abs(vals) > 0
    for b in range(min(p.num_blocks, 64)):   # bounded spot-check
        for col in range(min(p.n_dim, 64)):
            live = idx[b, nz[b, :, col], col]
            if live.size and np.any(np.diff(live) < 0):
                return False, f"indices not sorted in block {b} col {col}"
    per_block_nnz = nz.sum(axis=1)
    if per_block_nnz.max(initial=0) > p.nnz:
        return False, "NNZ bound violated"
    return True, "ok"
