"""DbbLinear: one linear layer, three execution paths.

Training      : dense master weights; the train loop applies the DBB
                straight-through projection to the whole param tree
                (core/sparsity.py), so model code stays plain ``x @ w``.
Serving (TPU) : weights stored packed (`DbbWeight`); matmul routes through
                the DBB Pallas kernels via `kernels.dispatch` —
                decompression happens in VMEM.
Serving (XLA) : distributed graphs (and the CPU dry-run) use the pure-XLA
                path: packed weights live in HBM, `decompress_xla` expands
                them inside the jitted step, and GSPMD shards the dense
                matmul. Weight HBM *residency* is the compressed 62.5%.

`maybe_decompress_tree` converts a packed param tree to dense inside a jit;
`pack_tree` converts trained dense params to packed serving params.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import DbbConfig
from repro.core.dbb import DbbWeight, pack_dbb
from repro.core.sparsity import dbb_eligible, _path_str

__all__ = ["dbb_linear_apply", "decompress_xla", "pack_tree",
           "maybe_decompress_tree", "tree_footprint_bytes",
           "DECOMPRESS_STATS"]

# Trace-time instrumentation: every decompress_xla call (i.e. every place a
# dense copy of a packed weight is materialized inside a jitted graph)
# increments this counter at trace time. The decode benchmark and the
# fast-path tests assert the counter stays flat while tracing the packed
# streaming decode step — the structural proof that no stacked layer weight
# ever expands to dense (DESIGN.md §9).
DECOMPRESS_STATS = {"calls": 0}


def decompress_xla(p: DbbWeight, dtype=None) -> jax.Array:
    """Pure-XLA decompression (GSPMD-shardable). Handles stacked leaves
    ([L, Kc, N] scan stacks and [E, Kc, N] expert stacks) by vmapping.
    ``bits=4`` leaves dequantize through the groupwise scale plane and
    come back f32 (DESIGN.md §16)."""
    from repro.kernels import decompress_ref, decompress_w4_ref
    DECOMPRESS_STATS["calls"] += 1
    if p.bits == 4:
        def one4(values, bitmask, gscale):
            return decompress_w4_ref(values, bitmask.astype(jnp.int32),
                                     gscale, block=p.block, nnz=p.nnz,
                                     group=p.group)
        fn = one4
        for _ in range(p.values.ndim - 2):
            fn = jax.vmap(fn)
        w = fn(p.values, p.bitmask, p.scale)
        return w.astype(dtype) if dtype is not None else w
    def one(values, bitmask):
        return decompress_ref(values, bitmask.astype(jnp.int32),
                              block=p.block, nnz=p.nnz)
    values, bitmask = p.values, p.bitmask
    fn = one
    for _ in range(values.ndim - 2):
        fn = jax.vmap(fn)
    w = fn(values, bitmask)
    if p.scale is not None:
        w = w * p.scale[..., None, :]
    return w.astype(dtype) if dtype is not None else w


def dbb_linear_apply(x: jax.Array, w, bias=None, *, act: str = "none",
                     impl: str = "xla", out_dtype=None,
                     cfg=None) -> jax.Array:
    """``act(x @ w + bias)`` where w is dense or a DbbWeight, routed by the
    kernel dispatch registry (DESIGN.md §11).

    impl="pallas" activates the fused-kernel route family: the registry
    picks skinny/M-tiled STA for dense weights and skinny/M-tiled DBB for
    packed ones (bias/act and the DbbWeight per-channel scale fuse into
    the kernel epilogue — one HBM store of the finished output, DESIGN.md
    §7). impl="xla" keeps separate post-matmul ops, which GSPMD can shard.
    ``cfg`` (optional) supplies `kernel_routes` overrides.
    """
    from repro.kernels import dispatch
    return dispatch.matmul(x, w, bias, act=act, out_dtype=out_dtype,
                           cfg=cfg, pallas=(impl == "pallas"))


def pack_tree(params: Any, cfg: DbbConfig, quantize: bool = False) -> Any:
    """Pack every DBB-eligible dense leaf into DbbWeight (serving format).

    Stacked leaves [..., K, N] pack along their K axis; `quantize=True`
    stores INT8 values with per-out-channel scales — the paper's exact
    deployment format (INT8 operands + bitmask + 4 value bytes per 8)."""
    if not cfg.enabled:
        return params

    def visit(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if not dbb_eligible(_path_str(path), cfg):
            return leaf
        kd = leaf.shape[-2]
        if kd % cfg.block != 0:
            return leaf
        # sub-8-bit plane (DESIGN.md §16): only where the w4 format's
        # divisibility holds — other leaves stay INT8-packed
        w4 = (cfg.weight_bits == 4
              and cfg.quant_group > 0
              and cfg.quant_group % cfg.block == 0
              and kd % cfg.quant_group == 0
              and (kd // cfg.block * cfg.nnz) % 2 == 0)

        def pack_one(w):
            if w4:
                return pack_dbb(w.astype(jnp.float32), cfg.block, cfg.nnz,
                                bits=4, group=cfg.quant_group)
            if quantize:
                from repro.core.quant import quantize_weight
                qw = quantize_weight(w.astype(jnp.float32))
                p = pack_dbb(qw.q, cfg.block, cfg.nnz)
                return DbbWeight(values=p.values.astype(jnp.int8),
                                 indices=p.indices, bitmask=p.bitmask,
                                 scale=qw.scale, block=cfg.block,
                                 nnz=cfg.nnz, k_dim=kd)
            return pack_dbb(w, cfg.block, cfg.nnz)

        fn = pack_one
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        p = fn(leaf)
        # serving format drops the diagnostic int32 indices (4 B/value —
        # 4x the int8 payload); kernels and decompress consume the bitmask
        return DbbWeight(values=p.values, indices=None,
                         bitmask=p.bitmask, scale=p.scale,
                         block=cfg.block, nnz=cfg.nnz, k_dim=kd,
                         bits=4 if w4 else 8,
                         group=cfg.quant_group if w4 else 0)

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, DbbWeight))


def maybe_decompress_tree(params: Any, dtype=None) -> Any:
    """Expand every DbbWeight leaf to dense (call inside the jitted step so
    HBM residency stays compressed)."""
    def visit(leaf):
        if isinstance(leaf, DbbWeight):
            return decompress_xla(leaf, dtype=dtype)
        return leaf
    return jax.tree_util.tree_map(
        visit, params, is_leaf=lambda x: isinstance(x, DbbWeight))


def tree_footprint_bytes(params: Any) -> int:
    """HBM residency of a (possibly packed) param tree.

    DbbWeight leaves count values + 1 mask byte per block (the paper's
    storage format), not the diagnostic int32 arrays.
    """
    total = 0

    def visit(leaf):
        nonlocal total
        if isinstance(leaf, DbbWeight):
            # bitmask.size counts (block, col) pairs directly — values.size
            # over nnz would undercount on w4 leaves (nibble-packed rows)
            nb = leaf.bitmask.size
            total += leaf.values.size * leaf.values.dtype.itemsize
            total += nb * ((leaf.block + 7) // 8)
            if leaf.scale is not None:
                total += leaf.scale.size * leaf.scale.dtype.itemsize
        elif hasattr(leaf, "size"):
            total += leaf.size * leaf.dtype.itemsize
        return leaf

    jax.tree_util.tree_map(visit, params,
                           is_leaf=lambda x: isinstance(x, DbbWeight))
    return total
