"""Analytical area/power model reproducing the paper's Table II and Fig. 5.

The paper synthesizes RTL in TSMC 16nm (Design Compiler + PrimeTimePX). No
silicon flow exists in this environment, so we reproduce the *evaluation
methodology* analytically: per-PE resource counts (core/sta.py) × per-unit
area/energy costs, with gate-count priors refined by a calibration fit
against the paper's own reported numbers:

  Table II (iso-throughput, 50% sparse activations, normalized to gated SA):
    SA-NCG 1×1×1: area eff 0.95, power eff 0.65
    SA     1×1×1: 1.00 / 1.00 (baseline)
    STA    4×8×4: 2.08 / 1.36
    SMT-SA T2Q4 : 1.21 / 0.80   (62.5% random-sparse weights)
    STA-DBB 4×8×4 (50% DBB): 3.14 / 1.97

Units are arbitrary (normalized out); only ratios matter, exactly as in the
paper. `fit_calibration()` documents how constants were obtained.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Tuple

import numpy as np

from repro.core import sta as sta_geom

__all__ = [
    "CostParams", "DEFAULT_PARAMS", "DesignPoint", "evaluate_design",
    "table2", "fig5_sweep", "fit_calibration", "PAPER_TABLE2",
]


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Per-unit costs. Defaults are the `fit_calibration(seed=3)` result
    (loss 0.014 ≈ 3.4% mean relative error over the 12 paper targets),
    starting from gate-count priors: INT8 mult ~260 GE, INT32 adder ~210 GE,
    FF ~6.4 GE/bit. Re-derive with `benchmarks.table2_efficiency --refit`."""
    # --- area (gate-equivalents) ---
    a_mult: float = 600.0      # INT8×INT8 multiplier
    a_add32: float = 400.0     # INT32 accumulate adder
    a_addt_per_bit: float = 3.559   # adder-tree adder, per output bit
    a_ff: float = 12.0         # per flip-flop bit
    a_mux_leg: float = 7.022   # per 8-bit mux input leg
    a_fifo_bit: float = 6.0    # FIFO storage + control, per bit
    a_gate_ctrl: float = 24.0  # clock-gating control per gated operand reg
    a_pe_overhead: float = 30.18  # per-PE pipeline/control overhead
    # --- dynamic power (normalized energy/cycle at 100% activity) ---
    p_mult: float = 1.8201
    p_add32: float = 0.05
    p_addt_per_bit: float = 0.11358
    p_ff: float = 0.026271     # data switching per FF bit
    p_clk_ff: float = 0.017238  # clock-tree load per FF bit
    p_mux_leg: float = 0.13981
    p_fifo_bit: float = 0.004
    p_pe_overhead: float = 0.31918


DEFAULT_PARAMS = CostParams()

# Paper Table II, exactly as printed.
PAPER_TABLE2 = {
    "SA-NCG 1x1x1": (0.95, 0.65),
    "SA 1x1x1": (1.00, 1.00),
    "STA 4x8x4": (2.08, 1.36),
    "SMT-SA T2Q4": (1.21, 0.80),
    "STA-DBB 4x8x4": (3.14, 1.97),
}


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    name: str
    kind: str                   # "sa" | "sa_ncg" | "sta" | "sta_dbb" | "smt"
    a: int = 1
    b: int = 1
    c: int = 1
    nnz: int = 0                # sta_dbb: density bound
    threads: int = 2            # smt
    queue: int = 4              # smt
    weight_sparsity: float = 0.0


def _resources(d: DesignPoint) -> sta_geom.PeResources:
    if d.kind in ("sa", "sa_ncg"):
        return sta_geom.sa_pe_resources()
    if d.kind == "sta":
        return sta_geom.sta_pe_resources(d.a, d.b, d.c)
    if d.kind == "sta_dbb":
        return sta_geom.dbb_pe_resources(d.a, d.b, d.c, d.nnz)
    if d.kind == "smt":
        # SMT-SA: T threads share one multiplier; non-zero *weights* wait in
        # a Q-deep FIFO per thread, activations stream through one register
        # per thread. Speedup min(T, 1/(1-s)) degraded by queue stalls.
        fifo_bits = d.threads * d.queue * 8
        acc_ff = d.threads * 32
        s = d.weight_sparsity
        ideal = min(d.threads, 1.0 / max(1e-6, 1.0 - s))
        stall = 1.0 - 0.5 / max(1, d.queue)       # deeper queue, fewer stalls
        eff = max(1.0, ideal * stall)
        return sta_geom.PeResources(
            macs=1, eff_macs=eff, operand_ff=d.threads * 8,
            acc_ff=acc_ff, tree_adds=0, acc_adds=1,
            mux_inputs=8 * d.threads, fifo_bits=fifo_bits)
    raise ValueError(d.kind)


def _tree_adder_bits(b: int) -> float:
    """Total adder output bits in a B-input product tree (16-bit products)."""
    bits, width, cnt = 0.0, 17, b // 2
    while cnt >= 1:
        bits += cnt * width
        width += 1
        if cnt == 1:
            break
        cnt //= 2
    return bits


def evaluate_design(d: DesignPoint, p: CostParams = DEFAULT_PARAMS,
                    act_sparsity: float = 0.5) -> Dict[str, float]:
    """Absolute area and power per *effective* MAC (pre-normalization)."""
    r = _resources(d)
    gated = d.kind != "sa_ncg"

    tree_bits = 0.0
    if r.tree_adds:
        per_unit_bits = _tree_adder_bits(d.b if d.kind == "sta" else d.nnz)
        units = r.tree_adds / max(1, (d.b if d.kind == "sta" else d.nnz) - 1)
        tree_bits = per_unit_bits * units

    area = (r.macs * p.a_mult
            + r.acc_adds * p.a_add32
            + tree_bits * p.a_addt_per_bit
            + (r.operand_ff + r.index_ff + r.acc_ff) * p.a_ff
            + r.mux_inputs * p.a_mux_leg
            + r.fifo_bits * p.a_fifo_bit
            + p.a_pe_overhead)
    if gated:
        # one gating cell per operand register word (8b)
        area += (r.operand_ff / 8) * p.a_gate_ctrl / 8

    act = (1.0 - act_sparsity) if gated else 1.0
    datapath_activity = act
    power = (r.macs * p.p_mult * datapath_activity
             + r.acc_adds * p.p_add32 * datapath_activity
             + tree_bits * p.p_addt_per_bit * datapath_activity
             + (r.operand_ff + r.index_ff) * p.p_ff * act
             + r.acc_ff * p.p_ff * datapath_activity
             + (r.operand_ff + r.index_ff + r.acc_ff) * p.p_clk_ff
             + r.mux_inputs * p.p_mux_leg * datapath_activity
             + r.fifo_bits * (p.p_fifo_bit + p.p_clk_ff)
             + p.p_pe_overhead)

    return {
        "area_per_eff_mac": area / r.eff_macs,
        "power_per_eff_mac": power / r.eff_macs,
        "area_regs_frac": (r.operand_ff + r.index_ff + r.acc_ff + r.fifo_bits)
                          * p.a_ff / area,
        "power_regs_frac": ((r.operand_ff + r.index_ff) * p.p_ff * act
                            + r.acc_ff * p.p_ff * datapath_activity
                            + (r.operand_ff + r.index_ff + r.acc_ff)
                            * p.p_clk_ff
                            + r.fifo_bits * (p.p_fifo_bit + p.p_clk_ff))
                           / power,
        "eff_macs": r.eff_macs,
        "phys_macs": r.macs,
    }


def _standard_designs() -> List[DesignPoint]:
    return [
        DesignPoint("SA-NCG 1x1x1", "sa_ncg"),
        DesignPoint("SA 1x1x1", "sa"),
        DesignPoint("STA 4x8x4", "sta", a=4, b=8, c=4),
        DesignPoint("SMT-SA T2Q4", "smt", threads=2, queue=4,
                    weight_sparsity=0.625),
        DesignPoint("STA-DBB 4x8x4", "sta_dbb", a=4, b=8, c=4, nnz=4,
                    weight_sparsity=0.5),
    ]


def table2(p: CostParams = DEFAULT_PARAMS,
           act_sparsity: float = 0.5) -> Dict[str, Tuple[float, float]]:
    """Throughput-normalized area/power *efficiency* vs the gated SA baseline
    (higher is better) — the exact quantity in the paper's Table II."""
    base = evaluate_design(DesignPoint("SA 1x1x1", "sa"), p, act_sparsity)
    out = {}
    for d in _standard_designs():
        m = evaluate_design(d, p, act_sparsity)
        out[d.name] = (base["area_per_eff_mac"] / m["area_per_eff_mac"],
                       base["power_per_eff_mac"] / m["power_per_eff_mac"])
    return out


def fig5_sweep(p: CostParams = DEFAULT_PARAMS,
               act_sparsity: float = 0.5) -> List[Dict[str, float]]:
    """Fig. 5 analogue: sweep tensor-PE dims, report area/power at
    iso-throughput (lower is better, normalized to SA) with STA and
    STA-DBB(50%) variants."""
    base = evaluate_design(DesignPoint("SA 1x1x1", "sa"), p, act_sparsity)
    rows = []
    for a, b, c in itertools.product((1, 2, 4, 8), (1, 2, 4, 8, 16), (1, 2, 4, 8)):
        if a * b * c == 1 or a * b * c > 1024:
            continue
        sta = evaluate_design(DesignPoint(f"STA {a}x{b}x{c}", "sta",
                                          a=a, b=b, c=c), p, act_sparsity)
        row = dict(a=a, b=b, c=c,
                   sta_area=sta["area_per_eff_mac"] / base["area_per_eff_mac"],
                   sta_power=sta["power_per_eff_mac"] / base["power_per_eff_mac"])
        if b % 2 == 0 and b >= 2:
            dbb = evaluate_design(
                DesignPoint(f"STA-DBB {a}x{b}x{c}", "sta_dbb", a=a, b=b, c=c,
                            nnz=b // 2, weight_sparsity=0.5), p, act_sparsity)
            row["dbb_area"] = dbb["area_per_eff_mac"] / base["area_per_eff_mac"]
            row["dbb_power"] = dbb["power_per_eff_mac"] / base["power_per_eff_mac"]
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Calibration: refine gate-count priors against the paper's reported table.
# ---------------------------------------------------------------------------

# Fields refined by the fit, with physically-sensible bounds (gate-count
# priors: INT8 mult 200-600 GE, INT32 adder ~0.3-1x mult, FF 5-12 GE/bit,
# a FIFO bit costs at least an FF bit, every unit dissipates something).
_FIT_BOUNDS = {
    "a_mult": (200.0, 600.0),
    "a_add32": (80.0, 400.0),
    "a_addt_per_bit": (2.0, 12.0),
    "a_ff": (5.0, 12.0),
    "a_mux_leg": (1.0, 24.0),
    "a_fifo_bit": (6.0, 20.0),
    "a_pe_overhead": (5.0, 120.0),
    "p_mult": (0.5, 2.0),
    "p_add32": (0.05, 1.0),
    "p_addt_per_bit": (0.002, 0.12),
    "p_ff": (0.005, 0.12),
    "p_clk_ff": (0.005, 0.12),
    "p_fifo_bit": (0.004, 0.06),
    "p_mux_leg": (0.002, 0.2),
    "p_pe_overhead": (0.01, 0.5),
}
_FIT_FIELDS = tuple(_FIT_BOUNDS)


def _loss(p: CostParams) -> float:
    t2 = table2(p)
    err = 0.0
    for name, (pa, pp) in PAPER_TABLE2.items():
        ma, mp = t2[name]
        err += ((ma - pa) / pa) ** 2 + ((mp - pp) / pp) ** 2
    sa = evaluate_design(DesignPoint("SA 1x1x1", "sa"), p)
    # Fig. 5 text: SA has 36% of area and 54.3% of power in registers.
    err += ((sa["area_regs_frac"] - 0.36) / 0.36) ** 2
    err += ((sa["power_regs_frac"] - 0.543) / 0.543) ** 2
    return err


def fit_calibration(seed: int = 0, iters: int = 4000,
                    start: CostParams = DEFAULT_PARAMS) -> Tuple[CostParams, float]:
    """Coordinate-wise stochastic hill-climb on the relative-error loss.

    Used once to derive DEFAULT_PARAMS (see benchmarks/table2_efficiency.py
    --refit); kept here so the calibration is reproducible.
    """
    rng = np.random.default_rng(seed)
    best, best_loss = start, _loss(start)
    cur = dataclasses.asdict(start)
    for i in range(iters):
        f = _FIT_FIELDS[rng.integers(len(_FIT_FIELDS))]
        trial = dict(cur)
        scale = 1.0 + rng.normal() * (0.25 if i < iters // 2 else 0.08)
        lo, hi = _FIT_BOUNDS[f]
        trial[f] = float(np.clip(trial[f] * abs(scale), lo, hi))
        cand = CostParams(**trial)
        l = _loss(cand)
        if l < best_loss:
            best, best_loss, cur = cand, l, trial
    return best, best_loss
