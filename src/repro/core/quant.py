"""INT8 symmetric quantization (paper targets INT8 operands / INT32 acc).

Mobile CNN inference in the paper is INT8 end-to-end. Here:
  * weights: symmetric per-output-channel scales, int8 storage
  * activations: symmetric per-tensor scale (computed on the fly or calibrated)
  * matmul: int8×int8 → int32 accumulation via ``preferred_element_type``,
    exactly the SA/STA datapath (INT8 operands, INT32 accumulators)
  * QAT: fake-quant with straight-through gradients
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "QuantizedWeight", "quantize_weight", "dequantize_weight",
    "fake_quant", "act_scale", "int8_matmul", "quant_error",
]

_INT8_MAX = 127.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedWeight:
    q: jax.Array            # int8 [K, N]
    scale: jax.Array        # f32 [N] per-out-channel


def quantize_weight(w: jax.Array) -> QuantizedWeight:
    """Symmetric per-out-channel INT8 quantization of ``W[K, N]``."""
    amax = jnp.max(jnp.abs(w), axis=0)                      # [N]
    scale = jnp.where(amax > 0, amax / _INT8_MAX, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(w / scale[None, :]), -_INT8_MAX, _INT8_MAX)
    return QuantizedWeight(q=q.astype(jnp.int8), scale=scale)


def dequantize_weight(qw: QuantizedWeight, dtype=jnp.float32) -> jax.Array:
    return (qw.q.astype(jnp.float32) * qw.scale[None, :]).astype(dtype)


def act_scale(x: jax.Array) -> jax.Array:
    """Per-tensor symmetric activation scale."""
    amax = jnp.max(jnp.abs(x))
    return jnp.where(amax > 0, amax / _INT8_MAX, 1.0).astype(jnp.float32)


@jax.custom_vjp
def fake_quant(w: jax.Array) -> jax.Array:
    """Quantize-dequantize with straight-through gradient (QAT)."""
    qw = quantize_weight(w)
    return dequantize_weight(qw, w.dtype)


def _fq_fwd(w):
    return fake_quant(w), None


def _fq_bwd(_, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def int8_matmul(x: jax.Array, qw: QuantizedWeight,
                x_scale: Optional[jax.Array] = None,
                out_dtype=jnp.float32) -> jax.Array:
    """``x @ W`` on the INT8 datapath: int8 operands, INT32 accumulation.

    x: float [..., K] (quantized on the fly unless int8 already)
    Returns float [..., N] = (x_q @ w_q) * x_scale * w_scale.
    """
    if x.dtype == jnp.int8:
        xq, xs = x, (x_scale if x_scale is not None else jnp.float32(1.0))
    else:
        xs = act_scale(x) if x_scale is None else x_scale
        xq = jnp.clip(jnp.round(x / xs), -_INT8_MAX, _INT8_MAX).astype(jnp.int8)
    acc = jax.lax.dot_general(
        xq, qw.q,
        dimension_numbers=(((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * xs * qw.scale).astype(out_dtype)


def quant_error(w: jax.Array) -> jax.Array:
    """RMS relative quantization error (diagnostics)."""
    wq = dequantize_weight(quantize_weight(w))
    denom = jnp.sqrt(jnp.mean(w.astype(jnp.float32) ** 2)) + 1e-12
    return jnp.sqrt(jnp.mean((w - wq) ** 2)) / denom
