"""STA tensor-PE geometry → TPU tiling (paper §III-B, Fig. 2/3).

The paper's ``A×B×C @ M×N`` describes an M×N systolic grid of tensor PEs, each
an A×C array of B-input dot-product units, output-stationary. On TPU:

  * the MXU is a fixed 128×128 systolic array — the grid (M×N) and PE dims
    (A×C) collapse into the Pallas GEMM block shape (bm, bn);
  * B (dot-unit depth) maps to the K-tile (bk) streamed through VMEM;
  * "output-stationary" maps to an accumulator tile held in VMEM scratch
    across the K grid dimension (one final store replaces the shift-out).

This module is the single source of truth for block-shape selection and for
the per-PE resource ratios consumed by the analytical area model.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.config import StaConfig

__all__ = [
    "PeResources", "sta_pe_resources", "sa_pe_resources", "dbb_pe_resources",
    "choose_block_shape", "mxu_utilization",
    "VMEM_BYTES", "KERNEL_VMEM_BUDGET",
]

MXU_DIM = 128          # TPU MXU systolic dimension
LANE = 128             # VREG lane count (last-dim tiling quantum)
SUBLANE = 8            # sublane quantum for f32
VMEM_BYTES = 16 * 2**20  # ~16 MiB usable VMEM per core (v5e)

# Per-kernel working-set budget: every VMEM guard (choose_block_shape,
# flash_ok, paged_decode_ok, conv _vmem_fits, autotune candidate filters)
# admits a block-shape candidate only if its single-buffered footprint fits
# half of VMEM — the other half is the pipeline's double buffers. The
# analysis verifier (repro.analysis) cross-checks contracts against this
# constant, so headroom fractions must not be respelled as ad-hoc
# ``VMEM_BYTES // 2`` literals elsewhere.
KERNEL_VMEM_BUDGET = VMEM_BYTES // 2


@dataclasses.dataclass(frozen=True)
class PeResources:
    """Per-PE resource counts, normalized per effective MAC/cycle.

    Units: flip-flop bit counts and datapath unit counts; the area model
    multiplies these by calibrated per-unit costs.
    """
    macs: int                # physical multipliers
    eff_macs: int            # effective MACs/cycle (throughput)
    operand_ff: int          # operand pipeline register bits
    acc_ff: int              # accumulator register bits
    tree_adds: int           # adder-tree 2-input adders (narrow)
    acc_adds: int            # INT32 accumulate adders
    mux_inputs: int          # total mux input legs (DBB's activation select)
    fifo_bits: int = 0       # SMT-SA FIFO storage bits
    index_ff: int = 0        # DBB non-zero index register bits


def sa_pe_resources() -> PeResources:
    """Classic SA scalar PE: 2 INT8 operand regs, INT32 acc, 1 MAC."""
    return PeResources(macs=1, eff_macs=1, operand_ff=16, acc_ff=32,
                       tree_adds=0, acc_adds=1, mux_inputs=0)


def sta_pe_resources(a: int, b: int, c: int) -> PeResources:
    """Tensor-PE A×B×C: A·C dot-units of depth B.

    Operand regs: A row-vectors and C col-vectors of B INT8 each — each row
    register is reused by C dot units (and vice versa), which is exactly the
    paper's "intra-PE operand reuse".
    """
    macs = a * b * c
    operand_ff = (a + c) * b * 8
    acc_ff = a * c * 32
    tree_adds = a * c * (b - 1)
    acc_adds = a * c
    return PeResources(macs=macs, eff_macs=macs, operand_ff=operand_ff,
                       acc_ff=acc_ff, tree_adds=tree_adds, acc_adds=acc_adds,
                       mux_inputs=0)


def dbb_pe_resources(a: int, b: int, c: int, nnz: int) -> PeResources:
    """STA-DBB tensor-PE: each B-input dot unit keeps only `nnz` multipliers,
    each fed by a B:1 activation mux + log2(B)-bit index register
    (paper §IV-B: "trade two 8-bit multipliers for two 8-bit 4:1 MUXes").
    Weight operand registers shrink to the nnz values (+ indices); activation
    registers still hold all B inputs. Effective throughput stays A·B·C.
    """
    idx_bits = max(1, (b - 1).bit_length())
    macs = a * nnz * c
    operand_ff = a * b * 8 + c * nnz * 8       # acts full, weights compressed
    index_ff = c * nnz * idx_bits
    acc_ff = a * c * 32
    tree_adds = a * c * (nnz - 1)
    acc_adds = a * c
    mux_inputs = a * c * nnz * b               # nnz muxes of radix B per unit
    return PeResources(macs=macs, eff_macs=a * b * c, operand_ff=operand_ff,
                       acc_ff=acc_ff, tree_adds=tree_adds, acc_adds=acc_adds,
                       mux_inputs=mux_inputs, index_ff=index_ff)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def choose_block_shape(m: int, k: int, n: int, cfg: StaConfig,
                       itemsize: int = 2) -> Tuple[int, int, int]:
    """Pick (bm, bk, bn) honoring MXU alignment and the VMEM budget.

    VMEM working set = bm·bk + bk·bn operand tiles + bm·bn f32 accumulator;
    shrink K first (it streams), then M (batch rows), keeping N lane-aligned.
    """
    bm = min(cfg.block_m, _round_up(max(m, 1), SUBLANE))
    bk = min(cfg.block_k, _round_up(max(k, 1), LANE))
    bn = min(cfg.block_n, _round_up(max(n, 1), LANE))

    def footprint(bm, bk, bn):
        return (bm * bk + bk * bn) * itemsize + bm * bn * 4

    while footprint(bm, bk, bn) > KERNEL_VMEM_BUDGET and bk > LANE:
        bk //= 2
    while footprint(bm, bk, bn) > KERNEL_VMEM_BUDGET and bm > SUBLANE:
        bm //= 2
    while footprint(bm, bk, bn) > KERNEL_VMEM_BUDGET and bn > LANE:
        bn //= 2
    return bm, bk, bn


def mxu_utilization(m: int, k: int, n: int) -> float:
    """Fraction of MXU issue slots doing useful work for an M×K×N GEMM
    (padding waste from non-128-aligned dims — the TPU analogue of the
    paper's PE-array utilization argument)."""
    mm, kk, nn = (_round_up(m, MXU_DIM), _round_up(k, MXU_DIM),
                  _round_up(n, MXU_DIM))
    return (m * k * n) / float(mm * kk * nn)
