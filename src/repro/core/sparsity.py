"""DBB-sparse training: straight-through projection + density schedules.

The paper (§V-A) trains DBB models with "conventional INT8 quantization and
amplitude-based pruning". We implement that as projected training: the forward
pass sees the DBB-projected weight, the backward pass is straight-through
(gradients flow to the dense master weights), and the density bound is
annealed from fully dense down to the target NNZ over a ramp.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import DbbConfig
from repro.core.dbb import dbb_mask, dbb_project

__all__ = [
    "ste_dbb", "dbb_schedule_nnz", "apply_dbb_to_tree", "tree_sparsity_report",
]


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ste_dbb(w: jax.Array, block: int, nnz: int) -> jax.Array:
    # block/nnz stay static Python ints (top_k needs them concrete even
    # when the projection runs inside a jitted eval step)
    return dbb_project(w, block, nnz)


def _ste_fwd(w, block, nnz):
    return dbb_project(w, block, nnz), None


def _ste_bwd(block, nnz, _, g):
    # Straight-through: dense master weights receive the full gradient so
    # pruned entries can be resurrected while the bound anneals.
    return (g,)


ste_dbb.defvjp(_ste_fwd, _ste_bwd)


def dbb_schedule_nnz(cfg: DbbConfig, step: int, start: int, ramp: int) -> int:
    """Anneal the density bound: dense until `start`, then linearly shrink the
    per-block NNZ from `block` to `cfg.nnz` over `ramp` steps."""
    if not cfg.enabled:
        return cfg.block
    if ramp <= 0:
        return cfg.nnz if step >= start else cfg.block
    frac = min(max((step - start) / ramp, 0.0), 1.0)
    nnz = round(cfg.block - frac * (cfg.block - cfg.nnz))
    return int(max(cfg.nnz, min(cfg.block, nnz)))


# Param-name policy: which leaves of the param tree are DBB-able. Matches the
# naming used by repro.models (wi/wg/wo mlp, q/k/v/o proj, expert stacks).
_DBB_FAMILY_PATTERNS: Dict[str, Tuple[str, ...]] = {
    "mlp": (r"\bmlp\b.*\bw[igo]\b", r"channel_mix.*\bw[kvr]\b"),
    "attn_proj": (r"\battn\b.*\b[qkvo]_proj\b", r"time_mix.*\b[rkvgo]_proj\b",
                  r"\bmamba\b.*\b(in_proj|out_proj)\b"),
    "expert": (r"\bexperts?\b.*\bw[igo]\b",),
    "lm_head": (r"\blm_head\b",),
    "conv": (r"\bconv\d*\b.*\bw\b", r"\bfc\b.*\bw\b"),
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def dbb_eligible(path_s: str, cfg: DbbConfig) -> bool:
    # DBB is a weight-matrix format: bias vectors (leaf name "b") are never
    # packed — a stacked [L, out] bias would otherwise be "projected" along
    # the layer dimension
    if path_s.rsplit("/", 1)[-1] == "b":
        return False
    for fam in cfg.apply_to:
        for pat in _DBB_FAMILY_PATTERNS.get(fam, ()):
            if re.search(pat, path_s.replace("/", " ")):
                return True
    return False


def apply_dbb_to_tree(params: Any, cfg: DbbConfig, nnz: Optional[int] = None,
                      straight_through: bool = True) -> Any:
    """Return params with every eligible 2D+ leaf DBB-projected.

    Leaves with rank >= 2 are projected along their second-to-last axis
    (the contraction dim for ``x @ W``); stacked per-layer weights
    ``[L, K, N]`` and expert stacks ``[E, K, N]`` are handled by reshaping.
    """
    if not cfg.enabled:
        return params
    k = cfg.nnz if nnz is None else nnz
    if k >= cfg.block:
        return params
    proj = (lambda w: ste_dbb(w, cfg.block, k)) if straight_through else (
        lambda w: dbb_project(w, cfg.block, k))

    def visit(path, leaf):
        if not isinstance(leaf, jax.Array) and not hasattr(leaf, "shape"):
            return leaf
        if getattr(leaf, "ndim", 0) < 2:
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        path_s = _path_str(path)
        if not dbb_eligible(path_s, cfg):
            return leaf
        kd = leaf.shape[-2]
        if kd % cfg.block != 0:
            return leaf
        # nested vmap, NOT reshape(-1, K, N): flattening a [L, E@model, ...]
        # stack merges sharded and unsharded dims, which GSPMD can only
        # replicate — 86 GB/leaf temps on kimi (§Perf iteration 15)
        fn = proj
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        return fn(leaf)

    return jax.tree_util.tree_map_with_path(visit, params)


def tree_sparsity_report(params: Any, cfg: DbbConfig) -> Dict[str, float]:
    """Measured zero-fraction per eligible leaf (for logging / Table I)."""
    report = {}

    def visit(path, leaf):
        if getattr(leaf, "ndim", 0) >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            path_s = _path_str(path)
            if dbb_eligible(path_s, cfg):
                report[path_s] = float(jnp.mean(leaf == 0))
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return report
