"""Trip-count-aware HLO text analyzer.

``compiled.cost_analysis()`` visits every computation ONCE — a `lax.scan`
over 60 layers under-counts flops and collective bytes by 60×. This module
re-derives the three roofline inputs from `compiled.as_text()` (the
post-SPMD, post-fusion per-device module):

  * flops            — dot/convolution ops, × while-loop trip counts
                       (recursing into fusion bodies, where dots live);
  * hbm_bytes        — per top-level op: operand + output bytes (fusion =
                       one op, matching XLA's post-fusion accounting),
                       × trip counts;
  * collective_bytes — per collective kind, operand bytes × trip counts.

Trip counts come from the while condition's comparison constant (exact for
lax.scan/fori_loop lowerings).

The analyzer is validated against ``cost_analysis()`` on scan-free graphs
(tests/test_roofline.py) where both must agree.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloStats", "analyze_hlo_text"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")

# ops that move no real bytes
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "tuple-select",
}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # (kind, per-execution bytes, trip multiplier, op name) — the perf-loop
    # profile: which collectives carry the traffic
    top_ops: List[Tuple[str, float, float, str]] = dataclasses.field(
        default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def scaled(self, f: float) -> "HloStats":
        return HloStats(
            flops=self.flops * f, hbm_bytes=self.hbm_bytes * f,
            collective_bytes={k: v * f
                              for k, v in self.collective_bytes.items()},
            collective_counts={k: v * f
                               for k, v in self.collective_counts.items()},
            top_ops=[(k, b, t * f, n) for k, b, t, n in self.top_ops])

    def __iadd__(self, o: "HloStats") -> "HloStats":
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        for k, v in o.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        for k, v in o.collective_counts.items():
            self.collective_counts[k] = (self.collective_counts.get(k, 0.0)
                                         + v)
        self.top_ops.extend(o.top_ops)
        return self

    def top_collectives(self, k: int = 12) -> List[Tuple[str, float, str]]:
        """[(kind, total bytes, op name)] sorted by traffic."""
        rows = [(kind, b * t, name) for kind, b, t, name in self.top_ops]
        rows.sort(key=lambda r: -r[1])
        return rows[:k]


# ---------------------------------------------------------------------------
# shape parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(stype: str) -> float:
    """Bytes of one (possibly tuple) HLO type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(stype):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(stype: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(stype)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


# ---------------------------------------------------------------------------
# module parsing
# ---------------------------------------------------------------------------

# op line: `  %name = <type> kind(...` — the type may be a tuple with
# embedded `/*index=N*/` comments; the kind is the first `word(` occurrence
# (types never put a word directly before an open paren).
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_DNUMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


@dataclasses.dataclass
class _Op:
    name: str
    stype: str
    kind: str
    rest: str       # everything after the open paren (operands + attrs)


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op] = dataclasses.field(default_factory=list)
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


def _parse_computations(text: str) -> Tuple[Dict[str, _Computation], str]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry = ""
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line[0].isspace():
            # computation headers start at column 0: `%name (args) -> ty {`
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            name, stype, kind = m.groups()
            rest = line[m.end():]
            cur.ops.append(_Op(name, stype.strip(), kind, rest))
            cur.shapes[name] = stype
    return comps, entry


def _operand_names(rest: str) -> List[str]:
    # operands appear before the first "), " attr separator; just take all
    # %refs on the line — attr refs (calls/body/cond) are filtered by caller
    rest_ops = rest.split("),")[0] if ")," in rest else rest.split(")")[0]
    return _OPERAND_RE.findall(rest_ops)


def _dot_flops(op: _Op, comp: _Computation) -> float:
    _, out_dims = _shape_elems(op.stype)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    operands = _operand_names(op.rest)
    if not operands:
        return 0.0
    lhs_shape = comp.shapes.get(operands[0], "")
    _, lhs_dims = _shape_elems(lhs_shape)
    m = _DNUMS_RE.search(op.rest)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    elif lhs_dims:
        contract = lhs_dims[-1]
    return 2.0 * out_elems * contract


def _conv_flops(op: _Op, comp: _Computation) -> float:
    # flops ≈ 2 × out_elems × (kh·kw·Cin) — parse rhs (kernel) shape
    _, out_dims = _shape_elems(op.stype)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    operands = _operand_names(op.rest)
    if len(operands) < 2:
        return 0.0
    _, k_dims = _shape_elems(comp.shapes.get(operands[1], ""))
    if not k_dims:
        return 0.0
    kprod = 1
    for d in k_dims[:-1]:       # all dims except output-feature
        kprod *= d
    return 2.0 * out_elems * kprod


def _trip_count(op: _Op, comps: Dict[str, _Computation]) -> float:
    """XLA records exact scan/fori trip counts in the while op's
    backend_config (`"known_trip_count":{"n":N}`); fall back to the largest
    integer constant in the condition computation."""
    m = _TRIP_RE.search(op.rest)
    if m:
        return float(m.group(1))
    cond_m = _COND_RE.search(op.rest)
    if cond_m and cond_m.group(1) in comps:
        best = 1
        for cop in comps[cond_m.group(1)].ops:
            for c in _CONST_RE.findall(cop.stype + " " + cop.rest):
                best = max(best, int(c))
        return float(best)
    return 1.0


def _analyze_comp(comp: _Computation, comps: Dict[str, _Computation],
                  memo: Dict[str, HloStats], flops_only: bool = False
                  ) -> HloStats:
    key = comp.name + ("#f" if flops_only else "")
    if key in memo:
        return memo[key]
    st = HloStats()
    memo[key] = st          # break cycles defensively
    for op in comp.ops:
        kind = op.kind
        if kind == "dot":
            st.flops += _dot_flops(op, comp)
        elif kind == "convolution":
            st.flops += _conv_flops(op, comp)
        if kind == "while":
            body_m = _BODY_RE.search(op.rest)
            trips = _trip_count(op, comps)
            if body_m and body_m.group(1) in comps:
                inner = _analyze_comp(comps[body_m.group(1)], comps, memo,
                                      flops_only)
                st += inner.scaled(trips)
            continue
        if kind in ("call", "conditional"):
            for cname in _CALLS_RE.findall(op.rest) + \
                    _OPERAND_RE.findall(op.rest.split("branch_computations")[-1]
                                        if "branch_computations" in op.rest
                                        else ""):
                if cname in comps:
                    st += _analyze_comp(comps[cname], comps, memo, flops_only)
            continue
        if kind == "fusion":
            # recurse for flops only (dots hide in fusion bodies); bytes are
            # the fusion's own operands/outputs (post-fusion accounting)
            m = _CALLS_RE.search(op.rest)
            if m and m.group(1) in comps:
                st += _analyze_comp(comps[m.group(1)], comps, memo,
                                    flops_only=True)
        if flops_only:
            continue
        base = kind.replace("-start", "")
        if base in _COLLECTIVES and not kind.endswith("-done"):
            operands = _operand_names(op.rest)
            b = sum(_shape_bytes(comp.shapes.get(o, ""))
                    for o in operands)
            if b == 0.0:        # e.g. shapes not found: use output size
                b = _shape_bytes(op.stype)
            # XLA:CPU promotes bf16 reductions to f32 ("..._promoted"
            # to_apply) and reduces converts of bf16 data — TPU collectives
            # run at the logical bf16 width, so count those bytes halved.
            promoted = "promot" in op.rest
            if not promoted:
                for o in operands:
                    prod = comp.shapes.get(o, "")
                    if prod.strip().startswith("f32"):
                        src = next((pp for pp in comp.ops
                                    if pp.name == o), None)
                        if src is not None and src.kind == "convert":
                            ins = _operand_names(src.rest)
                            if ins and comp.shapes.get(
                                    ins[0], "").strip().startswith("bf16"):
                                promoted = True
                    break
            if promoted:
                b /= 2
            st.collective_bytes[base] = st.collective_bytes.get(base, 0) + b
            st.collective_counts[base] = st.collective_counts.get(base, 0) + 1
            st.top_ops.append((base, b, 1.0,
                               f"{op.name}:{op.stype[:80]}"
                               + (" [promoted]" if promoted else "")))
        if kind in _FREE_OPS or kind.endswith("-done"):
            continue
        out_b = _shape_bytes(op.stype)
        in_b = sum(_shape_bytes(comp.shapes.get(o, ""))
                   for o in _operand_names(op.rest))
        st.hbm_bytes += out_b + in_b
    memo[key] = st
    return st


def cpu_upcast_param_bytes(text: str) -> float:
    """Bytes of whole-parameter bf16→f32 upcast copies in the ENTRY scope.

    XLA:CPU legalizes bf16 dots by converting operands to f32; for weights
    consumed inside a scan the convert is loop-invariant and hoisted, so the
    compiled module carries an f32 copy of entire (bf16) parameter stacks.
    A TPU compile runs bf16 natively on the MXU and allocates none of this.
    The dry-run subtracts this quantity to report a TPU-faithful temp size
    (`memory.temp_adjusted`, see DESIGN.md §2 fidelity notes).
    """
    comps, entry = _parse_computations(text)
    if not entry:
        return 0.0
    ec = comps[entry]
    bf16_params = {op.name for op in ec.ops
                   if op.kind == "parameter" and
                   op.stype.strip().startswith("bf16")}
    total = 0.0
    for op in ec.ops:
        if op.kind not in ("fusion", "convert"):
            continue
        if not op.stype.strip().startswith("f32"):
            continue
        operands = _operand_names(op.rest)
        if len(operands) != 1 or operands[0] not in bf16_params:
            continue
        if op.kind == "fusion":
            m = _CALLS_RE.search(op.rest)
            if not (m and m.group(1) in comps):
                continue
            body = comps[m.group(1)].ops
            if not all(o.kind in ("parameter", "convert", "bitcast", "copy")
                       for o in body):
                continue
        total += _shape_bytes(op.stype)
    return total


def analyze_hlo_text(text: str) -> HloStats:
    """Analyze one per-device HLO module (from ``compiled.as_text()``)."""
    comps, entry = _parse_computations(text)
    if not entry:
        # fall back: computation with the most ops
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else ""
    if not entry:
        return HloStats()
    # called computations (while bodies, fusions) must not be double-counted:
    # start from ENTRY only.
    return _analyze_comp(comps[entry], comps, {})
