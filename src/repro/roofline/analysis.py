"""Three-term roofline model for TPU v5e (target hardware).

  compute    = flops / peak_flops          (197 TFLOP/s bf16 per chip)
  memory     = hbm_bytes / hbm_bw          (819 GB/s per chip)
  collective = Σ_kind bytes_kind / effective_bw(kind)

All inputs are *per-device* quantities from the per-device HLO module
(roofline/hlo.py), so no further division by chip count is needed. The
dominant term approximates the step time lower bound; the bottleneck is
whichever term is largest.

Collective effective bandwidths model the v5e 2D-torus ICI (~50 GB/s/link,
4 links/chip usable per direction pair):
  * all-reduce moves 2×(N-1)/N ≈ 2 bytes/elem over the slowest axis ring →
    counted bytes are operand bytes; effective bw ≈ link_bw × links/2;
  * all-gather / reduce-scatter move (N-1)/N ≈ 1× → link_bw × links;
  * all-to-all is bisection-limited → link_bw × links / 2;
  * collective-permute is point-to-point → link_bw.
These are first-order planning numbers (the paper's own Table II is a
calibrated model, in the same spirit).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.roofline.hlo import HloStats

__all__ = ["Hardware", "HW_V5E", "RooflineTerms", "roofline_terms",
           "model_flops_per_step", "collective_bw"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float            # per chip, bf16
    hbm_bw: float                # bytes/s per chip
    ici_link_bw: float           # bytes/s per link per direction
    ici_links: int               # usable links per chip


HW_V5E = Hardware(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                  ici_link_bw=50e9, ici_links=4)


def _collective_bw(kind: str, hw: Hardware) -> float:
    if kind == "all-reduce":
        return hw.ici_link_bw * hw.ici_links / 2
    if kind in ("all-gather", "reduce-scatter"):
        return hw.ici_link_bw * hw.ici_links
    if kind in ("all-to-all", "ragged-all-to-all"):
        return hw.ici_link_bw * hw.ici_links / 2
    return hw.ici_link_bw          # collective-permute & friends


# public alias: the kernel dispatcher's TP collective-bytes term
# (kernels/dispatch) charges boundary collectives against the same ICI
# model that roofline_terms applies to HLO collective ops
collective_bw = _collective_bw


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float              # headline: TPU-fused estimate (see below)
    collective_s: float
    collective_breakdown: Dict[str, float]
    flops: float
    hbm_bytes: float             # unfused per-op HLO bytes (upper bracket)
    io_bytes: float              # argument+output bytes (fused lower bound)
    collective_bytes: float
    model_flops: float = 0.0     # analytic 6·N·D (per device share)
    int8_compute_s: float = 0.0  # if the datapath ran INT8 (paper mode)
    memory_unfused_s: float = 0.0

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Lower bound: perfect overlap of the three engines."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / step-time lower bound — the fraction of the
        compute roofline this step achieves assuming perfect overlap."""
        if self.step_time_lb == 0:
            return 0.0
        useful_s = self.model_flops / HW_V5E.peak_flops
        return useful_s / self.step_time_lb

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_unfused_s": self.memory_unfused_s,
            "collective_s": self.collective_s,
            "collective_breakdown": self.collective_breakdown,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "io_bytes": self.io_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "step_time_lb": self.step_time_lb,
        }


def roofline_terms(stats: HloStats, hw: Hardware = HW_V5E,
                   model_flops_per_device: float = 0.0,
                   io_bytes_per_device: float = 0.0) -> RooflineTerms:
    """Three terms per device.

    Memory fidelity note (DESIGN.md §2): this container compiles with the
    XLA *CPU* backend, whose fusion is far weaker than TPU's — per-op HLO
    bytes over-count what a TPU would move by 5-20×. The headline memory
    term is therefore the artifact-derived *fused* estimate: every step must
    at minimum stream its arguments in and outputs out of HBM
    (params + optimizer state + caches + batch). The unfused per-op number
    is reported alongside as the upper bracket.
    """
    coll_s = {k: v / _collective_bw(k, hw)
              for k, v in stats.collective_bytes.items()}
    mem_fused = io_bytes_per_device / hw.hbm_bw
    mem_unfused = stats.hbm_bytes / hw.hbm_bw
    return RooflineTerms(
        compute_s=stats.flops / hw.peak_flops,
        memory_s=mem_fused if io_bytes_per_device else mem_unfused,
        memory_unfused_s=mem_unfused,
        collective_s=sum(coll_s.values()),
        collective_breakdown=coll_s,
        flops=stats.flops,
        hbm_bytes=stats.hbm_bytes,
        io_bytes=io_bytes_per_device,
        collective_bytes=stats.total_collective_bytes,
        model_flops=model_flops_per_device,
        int8_compute_s=stats.flops / (hw.peak_flops * 2),
    )


def model_flops_per_step(n_active_params: int, tokens_per_step: int,
                         train: bool) -> float:
    """6·N·D for training (fwd+bwd), 2·N·D for inference forward."""
    per_tok = (6 if train else 2) * n_active_params
    return float(per_tok) * tokens_per_step
