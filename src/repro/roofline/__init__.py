from repro.roofline.analysis import HW_V5E, RooflineTerms, roofline_terms
from repro.roofline.hlo import HloStats, analyze_hlo_text

__all__ = ["analyze_hlo_text", "HloStats", "roofline_terms",
           "RooflineTerms", "HW_V5E"]
