"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Initializes (or restores) weights, optionally DBB-packs them (compressed
HBM residency — the paper's deployment mode), and runs batched greedy
generation over synthetic prompts, reporting the weight-footprint saving.
``--requests N`` (N > batch) drives the continuous-batching scheduler
instead of one static batch: requests admit into free slots between
decode chunks (DESIGN.md §9). ``--attn-backend`` picks the attention
implementation (flash = fused Pallas kernels, DESIGN.md §10) and
``--kv-page-size`` / ``--kv-pool-pages`` serve through the paged KV cache
(admission by pages actually used instead of a max_len reserve per slot).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.dbb_linear import pack_tree, tree_footprint_bytes
from repro.models import registry
from repro.serve.engine import ServeEngine

__all__ = ["main"]


def _log_routes(cfg, batch: int, smax: int, packed: bool,
                total_tokens: int = 0, sampling_on: bool = False,
                use_tt: bool = False) -> None:
    """Print the dispatch registry's ranked route tables (DESIGN.md §11)
    for this serving run's hot shapes — decode-batch layer GEMM, prefill
    attention at the shape the engine actually dispatches, and decode
    attention at the *actual* cache length — so the serve log shows *why*
    each kernel runs. ``smax`` and the page derivation mirror
    `decode_attention_apply` exactly (gcd-adaptive page when kv_page_size
    is unset); a fabricated shape here could log a route the engine never
    takes. ``total_tokens > 0`` means packed admission: prefill is charged
    at the ragged batch's real token count (one cu_seqlens call), not the
    padded B×T_max rectangle the legacy scheduler would dispatch."""
    import math

    import jax.numpy as jnp

    from repro.kernels import dispatch
    from repro.kernels.attn import DEFAULT_PAGE

    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    print(f"\nkernel routes (gemm_impl={cfg.gemm_impl!r}, "
          f"attn_impl={cfg.attn_impl!r}, overrides="
          f"{dict(cfg.kernel_routes) or 'none'}):")
    w4 = packed and cfg.dbb.weight_bits == 4
    w4_kw = dict(bits=4, group=cfg.dbb.quant_group) if w4 else {}
    print(f"- decode layer GEMM [M={batch}, K={d}, N={ff}]"
          f"{' packed w4' if w4 else ' packed' if packed else ''}:")
    print(dispatch.format_table(dispatch.explain(
        "matmul", m=batch, k=d, n=ff, dtype=cfg.dtype, packed=packed,
        cfg=cfg, epilogue_ops=1, **w4_kw)))  # the MLP GEMMs fuse 1 act/scale
    if total_tokens > 0:
        print(f"- prefill attention [total_tokens={total_tokens}, "
              f"packed cu_seqlens]:")
        print(dispatch.format_table(dispatch.explain(
            "attention", m=total_tokens, k=hd, n=total_tokens,
            dtype=cfg.dtype, cfg=cfg, packed_seq=True)))
    else:
        print(f"- prefill attention [B={batch}, T_max={smax}, padded]:")
        print(dispatch.format_table(dispatch.explain(
            "attention", m=smax, k=hd, n=smax, dtype=cfg.dtype, cfg=cfg,
            batch=batch)))
    if sampling_on:
        print(f"- head sample [M={batch}, K={d}, N={cfg.vocab_size}]"
              f"{' (top-k/top-p active)' if use_tt else ''}:")
        print(dispatch.format_table(dispatch.explain(
            "head_sample", m=batch, k=d, n=cfg.vocab_size,
            dtype=cfg.dtype, cfg=cfg, sample_tt=use_tt)))
    g = cfg.num_heads // max(1, cfg.num_kv_heads)
    page = cfg.kv_page_size or math.gcd(smax, DEFAULT_PAGE)
    route = dispatch.decode_attention_route(
        cfg, group=g, head_dim=hd,
        itemsize=jnp.dtype(cfg.dtype).itemsize, page=page, smax=smax)
    print(f"- decode attention (G={g}, smax={smax}, page={page}): "
          f"{route}\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--packed", action="store_true",
                    help="serve DBB-packed weights")
    ap.add_argument("--weight-bits", type=int, default=0,
                    choices=[0, 4, 8],
                    help="packed value-plane width (with --packed): 4 = "
                         "nibble-packed INT4 + groupwise scales, the "
                         "decode bandwidth floor (DESIGN.md §16); 8 = "
                         "INT8/float plane; 0 = the arch config's "
                         "dbb.weight_bits")
    ap.add_argument("--quant-group", type=int, default=0,
                    help="w4 scale-group length G along K (0 = the arch "
                         "config's dbb.quant_group, default 128)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0,
                    help="total request count; > batch engages the "
                         "continuous-batching scheduler (default: one "
                         "static batch)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--attn-backend", default=None,
                    choices=["auto", "flash", "chunked", "naive"],
                    help="attention backend override (DESIGN.md §10); "
                         "default: the arch config's attn_impl")
    ap.add_argument("--kv-page-size", type=int, default=0,
                    help="KV page size in cache slots; > 0 serves through "
                         "the paged KV cache (block-table flash decode, "
                         "admission by pages used)")
    ap.add_argument("--kv-pool-pages", type=int, default=0,
                    help="physical page pool size (with --kv-page-size); "
                         "0 = contiguous-cache HBM parity")
    ap.add_argument("--prefill-mode", default="packed",
                    choices=["packed", "padded"],
                    help="prompt admission: 'packed' concatenates the "
                         "ragged batch into one cu_seqlens prefill call "
                         "(no pad rows in any GEMM, DESIGN.md §12); "
                         "'padded' is the legacy per-row rectangle")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: split prompts into chunks of "
                         "this many tokens so long prompts interleave "
                         "with decode steps (bounds TTFT jitter); 0 = "
                         "whole-prompt prefill (packed mode only)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every request "
                         "(0 = greedy, bit-identical to the legacy "
                         "argmax path; DESIGN.md §15)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation (0 = off; any truncation "
                         "pins the head to the XLA sampler route)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus truncation (1.0 = off)")
    ap.add_argument("--draft-k", type=int, default=0,
                    help="self-speculative decode: draft this many "
                         "tokens per step with the truncated-layer "
                         "model, verify in one batched step (0 = off; "
                         "incompatible with top-k/top-p)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.weight_bits or args.quant_group:
        import dataclasses as _dc
        dbb = cfg.dbb
        dbb = _dc.replace(
            dbb,
            weight_bits=args.weight_bits or dbb.weight_bits,
            quant_group=args.quant_group or dbb.quant_group)
        cfg = cfg.replace(dbb=dbb)
    if args.attn_backend:
        cfg = cfg.replace(attn_impl=args.attn_backend)
    if args.kv_page_size:
        cfg = cfg.replace(kv_page_size=args.kv_page_size)
    elif args.kv_pool_pages and cfg.kv_page_size <= 0:
        raise SystemExit("--kv-pool-pages only takes effect with paged "
                         "serving (--kv-page-size, or a config that sets "
                         "kv_page_size); without it the contiguous cache "
                         "ignores the pool budget")
    if cfg.family == "cnn" or cfg.embeds_input or cfg.prefix_embed_len:
        raise SystemExit(f"{args.arch}: token-decoder serving only "
                         "(modality frontends are stubs)")
    params = registry.init_params(jax.random.PRNGKey(args.seed), cfg)
    dense_bytes = tree_footprint_bytes(params)
    if args.packed and cfg.dbb.enabled:
        from repro.core.sparsity import apply_dbb_to_tree
        params = apply_dbb_to_tree(params, cfg.dbb, straight_through=False)
        params = pack_tree(params, cfg.dbb)
        packed_bytes = tree_footprint_bytes(params)
        print(f"weight footprint: dense {dense_bytes/1e6:.1f} MB -> packed "
              f"{packed_bytes/1e6:.1f} MB "
              f"({100*packed_bytes/dense_bytes:.1f}%)")

    rng = np.random.default_rng(args.seed)
    n_req = args.requests or args.batch
    prompts = [list(rng.integers(2, cfg.vocab_size,
                                 size=args.prompt_len))
               for _ in range(n_req)]
    # generate() caches prompt+budget slots; serve() buckets to powers of
    # two — log the generate()-shaped cache length (the common case);
    # "packed" only when the weights actually are (--packed AND dbb on).
    # Packed admission charges prefill at the first wave's real token
    # count (sum over admitted prompts), not the B×T_max rectangle.
    sampled = (args.temperature > 0.0 or args.top_k > 0
               or args.top_p < 1.0 or args.draft_k > 0)
    sampling = None
    if sampled:
        from repro.serve.sampling import SamplingParams
        sampling = [SamplingParams(temperature=args.temperature,
                                   top_k=args.top_k, top_p=args.top_p,
                                   seed=args.seed + i)
                    for i in range(n_req)]
    use_tt = args.top_k > 0 or args.top_p < 1.0
    wave = sum(len(p) for p in prompts[:args.batch])
    _log_routes(cfg, args.batch, args.prompt_len + args.max_new,
                packed=bool(args.packed and cfg.dbb.enabled),
                total_tokens=wave if args.prefill_mode == "packed" else 0,
                sampling_on=sampled, use_tt=use_tt)
    if sampled:
        print(f"sampling: temperature={args.temperature} "
              f"top_k={args.top_k} top_p={args.top_p} "
              f"seeds={args.seed}..{args.seed + n_req - 1} (per request); "
              f"speculative draft_k={args.draft_k}"
              + (" (draft = first num_layers//2 layers, rejection-"
                 "sampling verify)" if args.draft_k else " (off)"))
    eng = ServeEngine(cfg, params, max_batch=args.batch,
                      kv_pool_pages=args.kv_pool_pages,
                      prefill_mode=args.prefill_mode,
                      prefill_chunk=args.prefill_chunk,
                      draft_k=args.draft_k)
    if n_req > args.batch:
        outs = eng.serve(prompts, max_new_tokens=args.max_new,
                         sampling=sampling)
    else:
        outs = eng.generate(prompts, max_new_tokens=args.max_new,
                            sampling=sampling)
    for i, o in enumerate(outs):
        print(f"req{i}: {o}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
