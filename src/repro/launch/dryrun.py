import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- the two lines above MUST run before any other import (jax locks the
# --- device count at first init); everything else follows.
import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.config import SHAPES, shape_applicable  # noqa: E402
from repro.configs import ASSIGNED, get_config  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.dist.mesh_ctx import use_mesh  # noqa: E402
from repro.launch import specs as sp  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline.analysis import (model_flops_per_step,  # noqa: E402
                                     roofline_terms)
from repro.roofline.hlo import (analyze_hlo_text,  # noqa: E402
                                cpu_upcast_param_bytes)
from repro.serve.engine import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.loop import make_train_step  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _cell_id(arch: str, shape: str, mesh: str, packed: bool,
             int8: bool = False) -> str:
    sfx = ("__dbb_int8" if int8 else "__dbb") if packed else ""
    return f"{arch}__{shape}__{mesh}{sfx}"


def _mem_stats(compiled) -> Dict[str, Any]:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            out[f] = int(getattr(ma, f, 0) or 0)
        out["total_per_device"] = (out.get("argument_size_in_bytes", 0)
                                   + out.get("output_size_in_bytes", 0)
                                   + out.get("temp_size_in_bytes", 0)
                                   - out.get("alias_size_in_bytes", 0))
    except Exception as e:           # pragma: no cover
        out["error"] = repr(e)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             packed: bool = False, int8: bool = False,
             fsdp: Optional[int] = None,
             headpad: bool = True, verbose: bool = True) -> Dict[str, Any]:
    mesh_name = "multipod" if multi_pod else "pod"
    cfg = get_config(arch)
    orig_cfg = cfg          # MODEL_FLOPS counts the *published* arch only
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "packed": packed, "int8": int8,
        "cell": _cell_id(arch, shape_name, mesh_name, packed, int8),
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    fsdp_elems = fsdp if fsdp is not None else shd.FSDP_MIN_SHARD_ELEMS
    if headpad:
        cfg = sp.pad_attention_heads(cfg, mesh.shape["model"])
        rec["head_pad"] = cfg.num_heads != orig_cfg.num_heads
    t0 = time.time()
    data_shards = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            data_shards *= mesh.shape[a]
    with use_mesh(mesh):
        if shape.kind == "train":
            rc = sp.run_config_for(cfg, shape, data_shards=data_shards,
                                   model_shards=mesh.shape.get("model", 1))
            state_sds, state_spec = sp.train_state_specs(rc, mesh,
                                                         fsdp=fsdp_elems)
            state_sh = shd.named_sharding_tree(state_spec, mesh)
            batch_sds = sp.train_input_specs(rc.model, shape)
            bspecs = shd.batch_specs(rc.model, mesh, shape.global_batch,
                                     shape.seq_len)
            batch_sh = shd.named_sharding_tree(
                {k: bspecs.get(k, P()) for k in batch_sds}, mesh)
            step = make_train_step(rc)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
            tokens_per_step = shape.global_batch * shape.seq_len
            train = True
        else:
            packed_eff = packed and cfg.dbb.enabled
            params_sds, pspec = sp.serve_param_specs(cfg, mesh,
                                                     packed=packed_eff,
                                                     int8=int8,
                                                     fsdp=fsdp_elems)
            params_sh = shd.named_sharding_tree(pspec, mesh)
            cell = sp.input_specs(cfg, shape, mesh)
            cache_sh = shd.named_sharding_tree(cell["specs"]["cache"], mesh)
            tok_sh = shd.named_sharding_tree(cell["specs"]["tokens"], mesh)
            if shape.kind == "decode":
                step = make_decode_step(cfg)
                jitted = jax.jit(step, in_shardings=(params_sh, cache_sh,
                                                     tok_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_sds, cell["cache"],
                                       cell["tokens"])
                tokens_per_step = shape.global_batch
            else:
                step = make_prefill_step(cfg)
                jitted = jax.jit(step, in_shardings=(params_sh, cache_sh,
                                                     tok_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_sds, cell["cache"],
                                       cell["tokens"])
                tokens_per_step = shape.global_batch * shape.seq_len
            train = False

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = _mem_stats(compiled)
    cost = {k: float(v) for k, v in (compiled.cost_analysis() or {}).items()
            if isinstance(v, (int, float))}
    hlo_text = compiled.as_text()
    stats = analyze_hlo_text(hlo_text)
    # XLA:CPU legalization artifact: hoisted f32 copies of bf16 weights.
    # A TPU compile allocates none of these (bf16 is MXU-native).
    upcast = cpu_upcast_param_bytes(hlo_text)
    mem["cpu_upcast_bytes"] = upcast
    mem["temp_adjusted"] = mem.get("temp_size_in_bytes", 0) - upcast
    mem["total_adjusted"] = mem.get("total_per_device", 0) - upcast
    mf_total = model_flops_per_step(orig_cfg.active_param_count(),
                                    tokens_per_step, train)
    # HBM lower bound: read all args; write non-aliased outputs; aliased
    # (donated) outputs are rewritten fully by train/prefill (params / cache
    # fill) but only one token-slice per step by decode.
    args_b = mem.get("argument_size_in_bytes", 0)
    out_b = mem.get("output_size_in_bytes", 0)
    alias_b = mem.get("alias_size_in_bytes", 0)
    if shape.kind == "decode":
        alias_write = alias_b / max(shape.seq_len, 1)
    else:
        alias_write = alias_b
    io_bytes = args_b + max(out_b - alias_b, 0) + alias_write
    terms = roofline_terms(stats, model_flops_per_device=mf_total / n_dev,
                           io_bytes_per_device=io_bytes)

    rec.update({
        "status": "ok",
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost_analysis": {k: cost[k] for k in ("flops", "bytes accessed")
                          if k in cost},
        "hlo_stats": {
            "flops": stats.flops,
            "hbm_bytes": stats.hbm_bytes,
            "collective_bytes": stats.collective_bytes,
            "collective_counts": stats.collective_counts,
            "top_collectives": stats.top_collectives(12),
        },
        "roofline": terms.as_dict(),
        "tokens_per_step": tokens_per_step,
    })
    if verbose:
        print(f"== {rec['cell']} ==")
        print("memory_analysis:", json.dumps(mem))
        print("cost_analysis:", json.dumps(rec["cost_analysis"]))
        print("roofline:", json.dumps(terms.as_dict()))
    return rec


def _artifact_path(cell: str) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    return os.path.join(ART_DIR, f"{cell}.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="one shape (default: all four)")
    ap.add_argument("--mesh", default="both",
                    choices=("pod", "multipod", "both"))
    ap.add_argument("--packed", action="store_true",
                    help="serve cells with DBB-packed weights")
    ap.add_argument("--int8", action="store_true",
                    help="with --packed: INT8 values + per-channel scales")
    ap.add_argument("--fsdp", type=int, default=None,
                    help="FSDP min-shard-elems override")
    ap.add_argument("--no-headpad", dest="headpad", action="store_false",
                    help="disable TP attention-head padding (baseline mode)")
    ap.add_argument("--jobs", type=int, default=2,
                    help="parallel subprocesses in --all mode")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have artifacts")
    ap.add_argument("--inline", action="store_true",
                    help="run cells in-process (single cell debugging)")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = (["pod", "multipod"] if args.mesh == "both" else [args.mesh])

    cells = [(a, s, m) for a in archs for s in shapes for m in meshes]
    single = len(cells) == 1

    if single or args.inline:
        code = 0
        for a, s, m in cells:
            try:
                rec = run_cell(a, s, m == "multipod", packed=args.packed,
                               int8=args.int8, fsdp=args.fsdp,
                               headpad=args.headpad)
            except Exception:
                rec = {"arch": a, "shape": s, "mesh": m, "status": "error",
                       "cell": _cell_id(a, s, m, args.packed),
                       "error": traceback.format_exc()}
                print(rec["error"], file=sys.stderr)
                code = 1
            with open(_artifact_path(rec["cell"]), "w") as f:
                json.dump(rec, f, indent=1)
        return code

    # orchestrator mode: one subprocess per cell (isolation + parallelism)
    procs: Dict[str, subprocess.Popen] = {}
    pending = list(cells)
    failures = []
    done = 0

    def launch(a, s, m):
        cell = _cell_id(a, s, m, args.packed, args.int8)
        path = _artifact_path(cell)
        if not args.force and os.path.exists(path):
            return None
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s, "--mesh", m]
        if args.packed:
            cmd.append("--packed")
        if args.int8:
            cmd.append("--int8")
        if args.fsdp is not None:
            cmd += ["--fsdp", str(args.fsdp)]
        if not args.headpad:
            cmd.append("--no-headpad")
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        return subprocess.Popen(cmd, env=env, stdout=subprocess.DEVNULL,
                                stderr=subprocess.PIPE)

    t_start = time.time()
    while pending or procs:
        while pending and len(procs) < args.jobs:
            a, s, m = pending.pop(0)
            cell = _cell_id(a, s, m, args.packed, args.int8)
            p = launch(a, s, m)
            if p is None:
                done += 1
                print(f"[cached] {cell}")
            else:
                procs[cell] = p
        for cell, p in list(procs.items()):
            rc = p.poll()
            if rc is None:
                if time.time() - t_start > args.timeout * len(cells):
                    p.kill()
                continue
            _, err = p.communicate()
            del procs[cell]
            done += 1
            path = _artifact_path(cell)
            status = "?"
            if os.path.exists(path):
                with open(path) as f:
                    status = json.load(f).get("status", "?")
            if rc != 0 or status == "error":
                failures.append(cell)
                print(f"[FAIL {done}/{len(cells)}] {cell}\n"
                      f"{err.decode()[-2000:]}")
            else:
                print(f"[ok {done}/{len(cells)}] {cell} ({status})")
        time.sleep(0.5)

    print(f"\n{done} cells, {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
