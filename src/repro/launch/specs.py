"""ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

`input_specs` returns weak-type-correct, shardable SDS trees for each model
input (and the cache/state trees for serving cells) — no device allocation
ever happens in the dry-run path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import (ModelConfig, RunConfig, ShapeSpec, TrainConfig,
                          MeshConfig)
from repro.dist import sharding as shd
from repro.models import registry
from repro.train.loop import TrainState, init_train_state

__all__ = ["run_config_for", "train_input_specs", "serve_input_specs",
           "train_state_specs", "serve_param_specs", "input_specs",
           "sds_tree"]

SDS = jax.ShapeDtypeStruct

# the two assigned giants need factored optimizer state + bf16 params to fit
_BIG_MOE = ("arctic-480b", "kimi-k2-1t-a32b")


# activation-memory budget: tokens per data-shard per microbatch. 16k keeps
# a 60L×d7168 layer-boundary save set under ~2 GB/device (§Perf iteration 3)
MB_TOKENS_TARGET = 16_384


def pad_attention_heads(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Deployment transform (§Perf iteration 4): pad Q heads up to a
    multiple of the model-axis size, Megatron-padded-vocab style.

    When num_heads % tp != 0 GSPMD cannot keep heads local, falls back to
    contracting head_dim across shards, and every attention score picks up
    an all-reduce (measured: ~290 GB/step on qwen train_4k). Padded Q heads
    shard cleanly; KV projections replicate via the `hkv % tp != 0` rule in
    dist/sharding.py, so scores are shard-local. The extra heads are a
    strict superset of the published arch (zero-extended at init, trainable
    thereafter — exactly like Megatron's padded embedding rows).
    """
    if cfg.family in ("cnn", "rwkv6") or cfg.num_heads % tp == 0:
        return cfg
    mha = cfg.num_kv_heads == cfg.num_heads
    hq = -(-cfg.num_heads // tp) * tp
    while not mha and hq % cfg.num_kv_heads:
        hq += tp                    # GQA: padded heads must group evenly
    return cfg.replace(num_heads=hq,
                       num_kv_heads=hq if mha else cfg.num_kv_heads,
                       head_dim=cfg.resolved_head_dim)


def microbatches_for(shape: Optional[ShapeSpec],
                     data_shards: int = 16,
                     cfg: Optional[ModelConfig] = None,
                     tp: int = 16) -> int:
    if shape is None or shape.kind != "train":
        return 1
    if shape.global_batch % data_shards:
        return 1
    b_loc = shape.global_batch // data_shards
    target = MB_TOKENS_TARGET
    if cfg is not None and cfg.family == "moe_lm":
        # FSDP'd expert weights are re-gathered and their grads re-reduced
        # once per microbatch — for the MoE giants that wire traffic
        # dominates activation memory, so run the whole batch in one
        # microbatch (§Perf iteration 14)
        target = MB_TOKENS_TARGET * 8
    m = min(b_loc, max(1, (b_loc * shape.seq_len) // target))
    if cfg is not None and cfg.parallel != "dp":
        # saved-activation budget: the named mlp_wi/wg saves cost
        # tokens_mb × L × gates × (d_ff/tp) × 2B per device — cap at ~3 GB
        gates = 2 if cfg.mlp_gated else 1
        f_loc = max(cfg.d_ff // max(tp, 1), 1)
        saved = (b_loc * shape.seq_len * cfg.num_layers * gates
                 * f_loc * 2)
        m = max(m, min(b_loc, -(-saved // (3 << 30))))
    while b_loc % m:            # round UP to a divisor (memory cap is hard)
        m += 1
    return min(m, b_loc)


def run_config_for(cfg: ModelConfig, shape: Optional[ShapeSpec] = None,
                   data_shards: int = 16, model_shards: int = 16,
                   **train_kw) -> RunConfig:
    opt = "adafactor" if cfg.name in _BIG_MOE else "adamw"
    if cfg.name in _BIG_MOE:
        cfg = cfg.replace(param_dtype="bfloat16")
    # §Perf iteration 12: d<=2048 models are TP-boundary-bound at 16-way
    # model parallelism — flip the model axis to batch parallelism for
    # training (params replicated + ZeRO; ~4x less wire traffic). Giant
    # vocabs stay vocab-parallel (the CE/embedding win dominates there).
    eff_shards = data_shards
    if (shape is not None and shape.kind == "train"
            and cfg.d_model <= 2048 and cfg.vocab_size <= 100_000
            and cfg.family != "moe_lm"
            and shape.global_batch % (data_shards * model_shards) == 0):
        # only when the batch actually divides data×model — otherwise the
        # model axis would sit idle and replicate compute 16×
        cfg = cfg.replace(parallel="dp")
        eff_shards = data_shards * model_shards
    train_kw.setdefault("microbatches",
                        microbatches_for(shape, eff_shards, cfg=cfg,
                                         tp=model_shards))
    train = TrainConfig(optimizer=opt, **train_kw)
    return RunConfig(model=cfg, train=train)


def sds_tree(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: SDS(x.shape, x.dtype) if hasattr(x, "shape") else x, tree)


# ---------------------------------------------------------------------------
# batch inputs
# ---------------------------------------------------------------------------

def train_input_specs(cfg: ModelConfig, shape: ShapeSpec
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    """{tokens|embeds, labels, loss_mask, [prefix_embeds]} SDS for one step."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "cnn":
        return {"images": SDS((b, cfg.cnn_img, cfg.cnn_img, cfg.cnn_in_ch),
                              jnp.float32),
                "labels": SDS((b,), jnp.int32)}
    out: Dict[str, jax.ShapeDtypeStruct] = {
        "labels": SDS((b, s), jnp.int32),
        "loss_mask": SDS((b, s), jnp.float32),
    }
    if cfg.embeds_input:
        out["embeds"] = SDS((b, s, cfg.d_model), dt)
    elif cfg.prefix_embed_len:
        out["tokens"] = SDS((b, s - cfg.prefix_embed_len), jnp.int32)
        out["prefix_embeds"] = SDS((b, cfg.prefix_embed_len, cfg.d_model), dt)
    else:
        out["tokens"] = SDS((b, s), jnp.int32)
    return out


def serve_input_specs(cfg: ModelConfig, shape: ShapeSpec
                      ) -> Tuple[Any, Any]:
    """(tokens_or_batch, cache) SDS for decode/prefill cells."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        functools.partial(registry.init_cache, cfg, b, s))
    if shape.kind == "decode":
        return SDS((b,), jnp.int32), cache
    # prefill: full-context batch (no labels)
    batch = dict(train_input_specs(cfg, shape))
    batch.pop("labels", None)
    batch.pop("loss_mask", None)
    return batch, cache


# ---------------------------------------------------------------------------
# state + sharding assembly
# ---------------------------------------------------------------------------

def train_state_specs(run_cfg: RunConfig, mesh: Mesh,
                      fsdp: Optional[int] = shd.FSDP_MIN_SHARD_ELEMS
                      ) -> Tuple[Any, Any]:
    """(state_sds, state_spec_tree) for TrainState under `mesh`."""
    state_sds = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), run_cfg))
    pspecs = shd.param_specs(state_sds.params, mesh, run_cfg.model,
                             fsdp_min_shard_elems=fsdp)
    ospecs = shd.opt_state_specs_like(state_sds.opt_state, state_sds.params,
                                      pspecs, mesh)
    efspecs = (None if state_sds.ef is None else
               shd.opt_state_specs_like({"m": state_sds.ef},
                                        state_sds.params, pspecs, mesh)["m"])
    spec = TrainState(params=pspecs, opt_state=ospecs, ef=efspecs,
                      step=P())
    return state_sds, spec


def serve_param_specs(cfg: ModelConfig, mesh: Mesh, packed: bool = False,
                      int8: bool = False,
                      fsdp: Optional[int] = shd.FSDP_MIN_SHARD_ELEMS
                      ) -> Tuple[Any, Any]:
    """(params_sds, spec_tree) for serving weights (cfg.dtype at rest;
    optionally DBB-packed, optionally INT8 values + per-channel scales —
    the paper's deployment format)."""
    def build():
        p = registry.init_params(jax.random.PRNGKey(0), cfg)
        p = jax.tree_util.tree_map(
            lambda x: x.astype(cfg.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
        if packed:
            from repro.core.dbb_linear import pack_tree
            p = pack_tree(p, cfg.dbb, quantize=int8)
        return p

    params_sds = jax.eval_shape(build)
    specs = shd.param_specs(params_sds, mesh, cfg,
                            fsdp_min_shard_elems=fsdp)
    return params_sds, specs


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Dict:
    """Sharding-annotated SDS dict for the cell's step inputs (brief step 2):
    training → batch dict; serving → (tokens/batch, cache)."""
    if shape.kind == "train":
        sds = train_input_specs(cfg, shape)
        specs = shd.batch_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        return {"batch": sds, "specs": {k: specs.get(k, P()) for k in sds}}
    tok, cache = serve_input_specs(cfg, shape)
    cspecs = shd.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
    ba = shd._batch_axes(mesh, shape.global_batch)
    if shape.kind == "decode":
        tspec: Any = P(ba)
    else:
        full = shd.batch_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        tspec = {k: full.get(k, P()) for k in tok}
    return {"tokens": tok, "cache": cache,
            "specs": {"tokens": tspec, "cache": cspecs}}
