"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Single-host entry point that composes every substrate layer: config →
synthetic data pipeline → (optional) virtual mesh → DBB-annealed train loop
→ checkpointing → fault tolerance. The same loop body is what the dry-run
lowers for the production meshes.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional

import jax
import numpy as np

from repro.config import RunConfig, ShapeSpec, TrainConfig
from repro.configs import get_config
from repro.core.sparsity import dbb_schedule_nnz, tree_sparsity_report
from repro.data.pipeline import make_pipeline
from repro.dist import sharding as shd
from repro.dist.mesh_ctx import use_mesh
from repro.launch.mesh import make_smoke_mesh
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (PreemptionGuard, StragglerMonitor,
                                         retry_step)
from repro.train.loop import init_train_state, make_train_step

__all__ = ["train_loop", "main"]


def train_loop(run_cfg: RunConfig, shape: ShapeSpec, mesh=None,
               log=print, host_index: int = 0, host_count: int = 1):
    """Returns (final TrainState, list of metric dicts)."""
    cfg = run_cfg.model
    tcfg = run_cfg.train
    pipe = make_pipeline(cfg, shape, seed=tcfg.seed, host_index=host_index,
                         host_count=host_count)
    mgr = (ckpt.CheckpointManager(tcfg.checkpoint_dir, tcfg.checkpoint_every)
           if tcfg.checkpoint_dir else None)
    monitor = StragglerMonitor()
    history = []

    def build_state():
        return init_train_state(jax.random.PRNGKey(tcfg.seed), run_cfg)

    ctx = use_mesh(mesh) if mesh is not None else None
    if ctx is not None:
        ctx.__enter__()
    try:
        state = build_state()
        start_step = 0
        if mgr is not None and ckpt.latest_step(tcfg.checkpoint_dir) is not None:
            state, meta = ckpt.restore(tcfg.checkpoint_dir, state)
            start_step = meta["step"]
            log(f"resumed from step {start_step}")

        if mesh is not None:
            pspecs = shd.param_specs(state.params, mesh, cfg)
            sh = shd.named_sharding_tree(pspecs, mesh)
            state = state.__class__(
                params=jax.device_put(state.params, sh),
                opt_state=state.opt_state, ef=state.ef, step=state.step)

        jit_cache = {}

        def step_fn_for(nnz: Optional[int]):
            if nnz not in jit_cache:
                jit_cache[nnz] = jax.jit(make_train_step(run_cfg, nnz=nnz),
                                         donate_argnums=(0,))
            return jit_cache[nnz]

        with PreemptionGuard() as guard:
            for step in range(start_step, tcfg.steps):
                t0 = time.time()
                nnz = dbb_schedule_nnz(cfg.dbb, step, tcfg.dbb_prune_start,
                                       tcfg.dbb_prune_ramp)
                batch = {k: jax.numpy.asarray(v)
                         for k, v in pipe.batch_at(step).items()}
                fn = step_fn_for(nnz if cfg.dbb.enabled else None)
                state, metrics = retry_step(lambda: fn(state, batch))
                dt = time.time() - t0
                straggler = monitor.update(step, dt)
                if step % max(tcfg.log_every, 1) == 0 or straggler:
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update(step=step, dt=round(dt, 3), nnz=nnz,
                             straggler=straggler)
                    history.append(m)
                    log(json.dumps(m))
                if mgr is not None:
                    mgr.maybe_save(step, state, {"dt": dt})
                if guard.should_stop:
                    log("preemption signal: emergency checkpoint")
                    if mgr is not None:
                        mgr.maybe_save(step, state, {"preempted": True},
                                       force=True)
                    break
        if mgr is not None:
            mgr.maybe_save(tcfg.steps, state, force=True)
        if monitor.straggler_steps:
            log(f"stragglers flagged: {monitor.straggler_steps} "
                f"(mean step {monitor.mean_step_time:.3f}s)")
        return state, history
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", default="none")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--dense", action="store_true", help="disable DBB")
    ap.add_argument("--dbb-ramp", type=int, default=0)
    ap.add_argument("--mesh", default="none",
                    help="none | dxm (e.g. 2x4) virtual mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.dense:
        cfg = cfg.replace(dbb=cfg.dbb.__class__(enabled=False))
    run_cfg = RunConfig(model=cfg, train=TrainConfig(
        steps=args.steps, learning_rate=args.lr, optimizer=args.optimizer,
        microbatches=args.microbatches, grad_compress=args.grad_compress,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every, seed=args.seed,
        dbb_prune_ramp=args.dbb_ramp))
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    mesh = None
    if args.mesh != "none":
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_smoke_mesh(data=d, model=m)
    state, history = train_loop(run_cfg, shape, mesh=mesh)
    if cfg.dbb.enabled:
        rep = tree_sparsity_report(state.params, cfg.dbb)
        nz = {k: round(v, 3) for k, v in list(rep.items())[:5]}
        print("sparsity (first 5 leaves):", json.dumps(nz))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
