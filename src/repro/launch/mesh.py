"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_smoke_mesh", "POD_SHAPE",
           "MULTI_POD_SHAPE"]

POD_SHAPE = (16, 16)                       # 256 chips (one v5e pod)
MULTI_POD_SHAPE = (2, 16, 16)              # 2 pods = 512 chips


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if hasattr(jax.sharding, "AxisType"):   # jax >= 0.5 explicit-axes API
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_smoke_mesh(data: int = 2, model: int = 4,
                    pod: Optional[int] = None) -> Mesh:
    """Small virtual mesh for CPU tests (requires >= data*model*(pod or 1)
    visible devices, e.g. via xla_force_host_platform_device_count)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))
