"""On-device sampling subsystem (DESIGN.md §15): per-request params as
device-resident [B]-vectors, the penalty→temperature→gumbel sampling
head, and the self-speculative accept/reject rule."""
from repro.serve.sampling.ops import (accept_speculative, record_emitted,
                                      record_tokens, sample_from_hidden,
                                      speculative_accept_state)
from repro.serve.sampling.params import (SamplingParams, any_uses_tt,
                                         fresh_state, pack_params,
                                         sampling_state, state_from_params,
                                         state_install)

__all__ = [
    "SamplingParams", "sampling_state", "state_from_params",
    "state_install", "pack_params", "fresh_state", "any_uses_tt",
    "sample_from_hidden", "record_tokens", "record_emitted",
    "accept_speculative", "speculative_accept_state",
]
