"""Device-side sampling operations for the serving engine (DESIGN.md §15).

Three groups, all pure functions over the `sampling_state` dict so they
compose inside the engine's jitted chunk scans:

  * `sample_from_hidden` — the sampling twin of `engine.greedy_from_hidden`:
    last-position hidden state → sampled token through the dispatch
    registry (`head_sample`), with the vocab-parallel TP combine when a
    mesh is live. Default params reduce to greedy bit-exactly.
  * `record_tokens` / `record_emitted` — the on-device history update
    (counts scatter-add + RNG-ordinal advance). Unconditional: dead rows
    accumulate garbage into their own lanes, re-zeroed at admission.
  * `accept_speculative` — the standard rejection-sampling acceptance
    rule for self-speculative decode. Draft token ``d_i`` (drawn from the
    truncated-model distribution ``q_i``) is accepted iff
    ``u_i < p_i[d_i] / q_i[d_i]`` with ``p_i`` the full-model
    distribution; the first rejected position resamples from the
    residual ``norm(max(p_i - q_i, 0))``, and a fully-accepted draft
    earns a bonus token from ``p_k`` — expressed as the SAME residual
    formula with ``q_k := 0`` (``max(p - 0, 0) = p``), so one gather and
    one gumbel-argmax cover both cases. The emitted prefix is provably
    distributed as k+1 i.i.d. draws from ``p`` (Leviathan et al. 2023);
    at temperature 0 every quantity is deterministic and the emitted
    stream is bit-identical to plain greedy decode of the full model.

Penalty counts are snapshotted at the start of a speculative step and
shared by all k+1 positions (draft and verify see the same history) —
exact when the penalties sit at their identity defaults, the standard
approximation otherwise (a non-spec loop would fold each emitted token
into the next position's counts).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.mesh_ctx import shard_tp
from repro.kernels import dispatch
from repro.kernels.sample import (NEG_INF, SALT_ACCEPT, SALT_RESAMPLE,
                                  gumbel_noise, probs_from_logits,
                                  uniform_noise)

__all__ = ["sample_from_hidden", "record_tokens", "record_emitted",
           "accept_speculative", "speculative_accept_state"]

# Floor for the draft probability in the acceptance ratio: q[d] is
# mathematically > 0 (d was sampled from q) but an extreme softmax can
# underflow in f32; the floor keeps the ratio finite without changing
# any non-degenerate comparison. Well above f32 denormals.
_Q_TINY = np.float32(1e-30)


def sample_from_hidden(hidden: jax.Array, w_head: jax.Array,
                       state: Dict[str, jax.Array], *, impl: str = "xla",
                       cfg=None, use_tt: bool = False,
                       step_offset=0) -> jax.Array:
    """hidden [B, T, d] → sampled next token [B] (last position).

    The sampling twin of `greedy_from_hidden`: the head GEMV and the
    penalty→temperature→gumbel epilogue go through the dispatch registry
    (fused Pallas route when its guard admits, XLA reference otherwise).
    Inside the TP serving wrap the vocab-column-sharded head runs the
    same epilogue per shard on local columns and combines [B]-sized
    (score, index) scalars — never [B, V] logits (DESIGN.md §14/§15).

    ``step_offset`` shifts the RNG ordinal (the speculative draft loop
    draws its i-th token at ``state["step"] + i``).
    """
    h = hidden[:, -1].astype(jnp.float32)
    s = state
    step = s["step"] + step_offset
    if shard_tp() > 1:
        from repro.dist.collectives import shard_sample
        return shard_sample(h, w_head, s["counts"], s["temp"], s["rep"],
                            s["pres"], s["freq"], s["seed"], step,
                            top_k=s["top_k"], top_p=s["top_p"],
                            use_tt=use_tt, impl=impl, cfg=cfg)
    return dispatch.head_sample(h, w_head.astype(jnp.float32), s["counts"],
                                s["temp"], s["rep"], s["pres"], s["freq"],
                                s["seed"], step, top_k=s["top_k"],
                                top_p=s["top_p"], use_tt=use_tt, cfg=cfg,
                                pallas=(impl == "pallas"))


def record_tokens(state: Dict[str, jax.Array], tok: jax.Array
                  ) -> Dict[str, jax.Array]:
    """Fold one emitted token per row into the history: counts[b, tok] += 1
    and the RNG ordinal advances by one. Unconditional (see module doc)."""
    b = tok.shape[0]
    counts = state["counts"].at[jnp.arange(b), tok].add(1)
    return dict(state, counts=counts, step=state["step"] + 1)


def record_emitted(state: Dict[str, jax.Array], emit: jax.Array,
                   n_emit: jax.Array) -> Dict[str, jax.Array]:
    """Speculative variant: per row, the first ``n_emit[b]`` entries of
    ``emit[b]`` [B, k+1] are real; the rest contribute zero. The ordinal
    advances by ``n_emit`` so the next step's draws continue the exact
    same counter stream a token-at-a-time loop would use."""
    b, ke = emit.shape
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], (b, ke))
    live = (jnp.arange(ke)[None, :] < n_emit[:, None]).astype(jnp.int32)
    counts = state["counts"].at[rows, emit].add(live)
    return dict(state, counts=counts, step=state["step"] + n_emit)


def accept_speculative(draft_tok: jax.Array, p_probs: jax.Array,
                       q_probs: jax.Array, seed: jax.Array,
                       step: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Rejection-sampling acceptance for one speculative step.

    draft_tok [B, k] i32 — tokens drawn from the draft distributions;
    p_probs [B, k+1, V] — full-model (verify) distributions at each of
    the k draft positions plus the bonus position; q_probs [B, k, V] —
    draft distributions; seed/step [B] — each row's RNG key and the
    emitted-token ordinal at the start of this speculative step.

    Returns ``(emit [B, k+1] i32, n_emit [B] i32 in 1..k+1)``: per row
    the accepted draft prefix followed by the resampled (or bonus)
    token; entries past ``n_emit`` are garbage the caller must mask.

    Acceptance uniforms draw from the SALT_ACCEPT stream keyed at the
    position's would-be ordinal ``step + i``; the residual resample
    draws SALT_RESAMPLE gumbel at ``step + n_acc`` — both independent of
    the SALT_TOKEN stream the draft consumed, and both functions of
    (seed, ordinal) only, so acceptance is reproducible across batch
    slots, chunk sizes, and TP layouts.
    """
    b, k = draft_tok.shape
    v = p_probs.shape[-1]
    pos = step[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    u = uniform_noise(seed[:, None], pos, jnp.zeros_like(pos), SALT_ACCEPT)
    p_d = jnp.take_along_axis(p_probs[:, :k], draft_tok[..., None],
                              axis=-1)[..., 0]              # [B, k]
    q_d = jnp.take_along_axis(q_probs, draft_tok[..., None],
                              axis=-1)[..., 0]
    acc = u < p_d / jnp.maximum(q_d, _Q_TINY)
    # leading run of accepts: position i survives iff 0..i all accepted
    run = jnp.cumprod(acc.astype(jnp.int32), axis=-1)
    n_acc = jnp.sum(run, axis=-1).astype(jnp.int32)          # [B] 0..k
    # residual at the first non-accepted position; q extended with a
    # zero row makes the all-accepted bonus draw the same gather
    q_ext = jnp.concatenate(
        [q_probs, jnp.zeros((b, 1, v), q_probs.dtype)], axis=1)
    resid = jnp.maximum(p_probs - q_ext, 0.0)                # [B, k+1, V]
    r = jnp.take_along_axis(resid, n_acc[:, None, None], axis=1)[:, 0]
    # gumbel-argmax over log r samples r/sum(r) without normalizing; a
    # temperature-0 row's r is one-hot, so NEG_INF on the zero lanes
    # dominates the (bounded) gumbel and the draw is the deterministic
    # argmax — bit-identical to greedy.
    logr = jnp.where(r > 0, jnp.log(jnp.maximum(r, _Q_TINY)),
                     jnp.float32(NEG_INF))
    col = jnp.arange(v, dtype=jnp.int32)[None, :]
    g = gumbel_noise(seed[:, None], (step + n_acc)[:, None], col,
                     SALT_RESAMPLE)
    res_tok = jnp.argmax(logr + g, axis=-1).astype(jnp.int32)
    emit = jnp.concatenate(
        [draft_tok, jnp.zeros((b, 1), jnp.int32)], axis=1)
    emit = emit.at[jnp.arange(b), n_acc].set(res_tok)
    return emit, n_acc + 1


def speculative_accept_state(draft_tok: jax.Array, draft_logits: jax.Array,
                             verify_logits: jax.Array,
                             state: Dict[str, jax.Array]
                             ) -> Tuple[jax.Array, jax.Array]:
    """Convenience wrapper: build p/q from raw logits under the state's
    penalty/temperature knobs (counts snapshotted across all positions —
    module doc) and run the acceptance rule.

    draft_logits [B, k, V]; verify_logits [B, k+1, V].
    """
    s = state
    b = draft_tok.shape[0]

    def bc(x):
        return x.reshape(b, 1, 1)

    counts = s["counts"][:, None]                            # [B, 1, V]
    p = probs_from_logits(verify_logits, counts, bc(s["temp"]),
                          bc(s["rep"]), bc(s["pres"]), bc(s["freq"]))
    q = probs_from_logits(draft_logits, counts, bc(s["temp"]),
                          bc(s["rep"]), bc(s["pres"]), bc(s["freq"]))
    return accept_speculative(draft_tok, p, q, s["seed"], s["step"])
