"""Per-request sampling parameters + the device-resident sampling state
(DESIGN.md §15).

`SamplingParams` is the host-side request knob set (what `launch.serve`
parses and the engine's admission queue carries). The device twin is a
plain dict of ``[B]``-vectors — `sampling_state` — that rides through
`_serve_loop`'s jitted chunk functions next to the KV cache:

  * ``temp/top_p/rep/pres/freq`` f32 and ``top_k/seed/step`` i32 vectors,
    one lane per batch slot;
  * ``counts [B, V]`` i32 — the on-device output-token history the
    penalty contract reads. It is updated inside the decode chunk (a
    scatter-add per emitted token), so penalties never add a host sync
    to the one-sync-per-chunk loop;
  * ``step`` is each row's emitted-token ordinal — the RNG counter. The
    prefill-sampled token draws at step 0; every later draw at the count
    of tokens emitted before it. Keying noise by ordinal (not by decode
    iteration) is what makes streams reproducible across chunk sizes and
    what lets speculative decode (which emits a variable number of
    tokens per step) advance the counter by ``n_emit``.

State updates are unconditional on purpose: a finished row keeps
accumulating garbage into its own lanes, but admission reinstalls the
slot (`state_install`) which zeroes them — same lifecycle as the KV
cache rows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "sampling_state", "state_from_params",
           "state_install", "pack_params", "fresh_state", "any_uses_tt"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """One request's sampling knobs (TensorRT-LLM-compatible defaults:
    every field at its default is an exact identity, so the default
    request is bit-identical to greedy decoding)."""
    temperature: float = 0.0
    top_k: int = 0                    # <= 0: off
    top_p: float = 1.0                # >= 1: off
    repetition_penalty: float = 1.0
    presence_penalty: float = 0.0
    frequency_penalty: float = 0.0
    seed: int = 0

    @property
    def uses_tt(self) -> bool:
        """Whether this request needs top-k/top-p masking — a *static*
        routing fact: any such request pins the head to the XLA sampler
        route (the masks are global order statistics)."""
        return self.top_k > 0 or self.top_p < 1.0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def sampling_state(max_batch: int, vocab: int) -> Dict[str, jax.Array]:
    """Fresh all-defaults device state for ``max_batch`` slots."""
    b = max_batch
    return {
        "temp": jnp.zeros((b,), jnp.float32),
        "top_k": jnp.zeros((b,), jnp.int32),
        "top_p": jnp.ones((b,), jnp.float32),
        "rep": jnp.ones((b,), jnp.float32),
        "pres": jnp.zeros((b,), jnp.float32),
        "freq": jnp.zeros((b,), jnp.float32),
        "seed": jnp.zeros((b,), jnp.int32),
        "step": jnp.zeros((b,), jnp.int32),
        "counts": jnp.zeros((b, vocab), jnp.int32),
    }


def pack_params(p: SamplingParams) -> Tuple[jax.Array, jax.Array]:
    """Host → device marshalling for one request: a [5] f32 + [2] i32
    pair, so the jitted installer never retraces on knob values."""
    f = jnp.asarray([p.temperature, p.top_p, p.repetition_penalty,
                     p.presence_penalty, p.frequency_penalty], jnp.float32)
    # seeds are arbitrary 32-bit patterns; wrap into int32 range
    i = jnp.asarray([p.top_k, (p.seed & 0xFFFFFFFF) - (1 << 32)
                     if (p.seed & 0xFFFFFFFF) >= (1 << 31)
                     else (p.seed & 0xFFFFFFFF)], jnp.int32)
    return f, i


def state_install(state: Dict[str, jax.Array], slot, fvals: jax.Array,
                  ivals: jax.Array) -> Dict[str, jax.Array]:
    """Install one request into a batch slot: set its knob lanes, zero
    its history row, reset its RNG counter. jit-safe (traced ``slot``)."""
    return {
        "temp": state["temp"].at[slot].set(fvals[0]),
        "top_p": state["top_p"].at[slot].set(fvals[1]),
        "rep": state["rep"].at[slot].set(fvals[2]),
        "pres": state["pres"].at[slot].set(fvals[3]),
        "freq": state["freq"].at[slot].set(fvals[4]),
        "top_k": state["top_k"].at[slot].set(ivals[0]),
        "seed": state["seed"].at[slot].set(ivals[1]),
        "step": state["step"].at[slot].set(0),
        "counts": state["counts"].at[slot].set(0),
    }


def fresh_state(fvals: jax.Array, ivals: jax.Array, vocab: int
                ) -> Dict[str, jax.Array]:
    """Zero-history state for a batch of brand-new requests, straight from
    the packed knob arrays (``fvals`` [G, 5] f32, ``ivals`` [G, 2] i32 —
    rows of `pack_params`). This is what the sampled *prefill* steps use:
    a fresh request has an empty output history, so counts are zeros (all
    penalties reduce to identities) and the RNG ordinal is 0."""
    g = fvals.shape[0]
    return {
        "temp": fvals[:, 0], "top_p": fvals[:, 1], "rep": fvals[:, 2],
        "pres": fvals[:, 3], "freq": fvals[:, 4],
        "top_k": ivals[:, 0], "seed": ivals[:, 1],
        "step": jnp.zeros((g,), jnp.int32),
        "counts": jnp.zeros((g, vocab), jnp.int32),
    }


def state_from_params(params: Sequence[SamplingParams], max_batch: int,
                      vocab: int) -> Dict[str, jax.Array]:
    """Whole-batch state for the static `generate` path (row i gets
    ``params[i]``; spare slots keep defaults)."""
    state = sampling_state(max_batch, vocab)
    for i, p in enumerate(params):
        f, iv = pack_params(p)
        state = state_install(state, i, f, iv)
    return state


def any_uses_tt(params: Sequence[SamplingParams]) -> bool:
    return any(p.uses_tt for p in params)
