"""Paged KV cache: fixed-size pages, free-list allocation, per-row block
tables (DESIGN.md §10).

The contiguous decode cache reserves ``max_len`` slots for every batch
slot, so continuous-batching occupancy is capped by the *longest possible*
request: HBM holds ``B · smax`` KV slots of which a short request uses a
sliver. The paged cache splits KV storage into a pool of fixed-size pages
(``[L, P, page, Hkv, D]``) shared by all slots; a request is admitted with
exactly ``ceil((prompt + budget) / page)`` pages and a block table row
mapping its logical pages to wherever the allocator placed them. At a
fixed HBM budget, max concurrent rows grows from ``budget / smax_bytes``
to ``budget / used_bytes`` per request — the occupancy win measured by
``benchmarks/attn_paged.py``.

Physical **page 0 is a reserved dummy**: unallocated block-table entries
point at it, so the traced admission scatter (fixed ``n_log`` width) and
the clamped overshoot writes of retired-but-still-stepping slots (see
`ServeEngine.serve`) land harmlessly there instead of corrupting a live
row. The dummy is never read as valid context — every read is masked by
the owning row's ``length``/``start``, and live rows never map to it.

`PageAllocator` is deliberately host-side Python (admission happens
between decode chunks on the host anyway); only the pool, tables, and
lengths live on device.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.config import ModelConfig

__all__ = ["PageAllocator", "init_paged_cache", "pages_needed", "DUMMY_PAGE"]

DUMMY_PAGE = 0


def pages_needed(prompt_len: int, budget: int, page: int) -> int:
    """Pages a request touches: prompt slots (pads included — prefill
    writes them, masked) plus one slot per generated token (the first
    token comes from prefill; decode writes at slots
    ``prompt .. prompt + budget - 1``)."""
    return -(-(prompt_len + max(budget, 1)) // page)


class PageAllocator:
    """Free-list allocator over the physical page pool. Page 0 (the dummy)
    is never handed out. Pages are recycled LIFO so a recently-retired
    request's pages (still warm in cache hierarchies that have one) go to
    the next admission."""

    def __init__(self, total_pages: int):
        assert total_pages >= 2, "pool needs the dummy page plus one"
        self.total_pages = total_pages
        self._free: List[int] = list(range(total_pages - 1, DUMMY_PAGE, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.total_pages - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n physical page ids, or None if the pool can't cover them (the
        caller defers admission until retirements free pages)."""
        if n > len(self._free):
            return None
        got = self._free[-n:]
        del self._free[-n:]
        return got

    def free(self, pages: List[int]) -> None:
        for p in pages:
            assert p != DUMMY_PAGE, "dummy page is never allocated"
        self._free.extend(pages)


def init_paged_cache(cfg: ModelConfig, n_slots: int, pool_pages: int,
                     page: int, n_log: int) -> Dict:
    """Device-side paged decode cache.

    k_pages/v_pages: [L, P, page, Hkv, D] physical pools (page 0 = dummy).
    block_table:     [n_slots, n_log] int32, logical → physical page
                     (unadmitted/retired rows point wholly at the dummy).
    length/start:    per-slot absolute context length and first real slot,
                     same contract as the contiguous cache (DESIGN.md §5).
    """
    from repro.models.common import dtype_of
    dtype = dtype_of(cfg)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.num_layers
    return {
        "k_pages": jnp.zeros((L, pool_pages, page, hkv, hd), dtype),
        "v_pages": jnp.zeros((L, pool_pages, page, hkv, hd), dtype),
        "block_table": jnp.zeros((n_slots, n_log), jnp.int32),
        "length": jnp.zeros((n_slots,), jnp.int32),
        "start": jnp.zeros((n_slots,), jnp.int32),
    }
