"""Serving engine: batched prefill + decode over any assigned architecture.

Weights may be DBB-packed (`core.dbb_linear.pack_tree`): the stacked layer
weights keep their compressed 62.5% HBM residency and expand transiently
per layer inside the jitted scan body — the XLA analogue of the STA-DBB
on-chip decompress (DESIGN.md §2). Non-layer leaves (embedding table, LM
head) are small and read on *every* decode step, so `ServeEngine` expands
them once up front instead of re-decompressing per token
(`_decompress_non_layer` stays in the step functions for callers that pass
raw packed trees — it no-ops on pre-expanded params). On a single device
(`ModelConfig.gemm_impl = "pallas"`) the hot GEMMs route through the Pallas
kernels with the fused bias/activation/requant epilogue instead
(DESIGN.md §7) — the MLP up-projections fuse their activation and the LM
head goes through `sta_gemm`.

`make_decode_step` / `make_prefill_step` produce the exact functions the
multi-pod dry-run lowers for the ``decode_*`` / ``prefill_*`` / ``long_*``
input-shape cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.dbb_linear import maybe_decompress_tree
from repro.dist.collectives import cross_entropy  # noqa: F401 (API surface)
from repro.models import registry

__all__ = ["make_decode_step", "make_prefill_step", "ServeEngine",
           "greedy_from_hidden"]


def greedy_from_hidden(hidden: jax.Array, w_head: jax.Array,
                       impl: str = "xla") -> jax.Array:
    """hidden [B, 1, d] → greedy next token [B]. The [B, V] logits are tiny
    (one position); vocab stays sharded under GSPMD. impl="pallas" routes
    the head GEMM through the fused STA kernel (single device only)."""
    h = hidden[:, -1].astype(jnp.float32)
    if impl == "pallas":
        from repro.kernels.sta_gemm.ops import sta_gemm
        logits = sta_gemm(h, w_head.astype(jnp.float32))
    else:
        logits = h @ w_head.astype(jnp.float32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _gemm_impl(cfg: ModelConfig) -> str:
    """Resolve the engine's GEMM route (single predicate shared with the
    model layer: Pallas only without a live mesh)."""
    from repro.models.common import use_fused_gemm
    return "pallas" if use_fused_gemm(cfg) else "xla"


def _decompress_non_layer(params, cfg: ModelConfig):
    """Expand packed leaves OUTSIDE the layer stack only. The stacked layer
    weights stay packed and are decompressed per-layer *inside* the scan
    body (transformer.py) — HBM never holds a whole-model dense copy
    (§Perf iteration 17)."""
    dt = jnp.dtype(cfg.dtype)
    if not isinstance(params, dict) or "layers" not in params:
        return maybe_decompress_tree(params, dtype=dt)
    out = {k: (v if k == "layers" else maybe_decompress_tree(v, dtype=dt))
           for k, v in params.items()}
    return out


def make_decode_step(cfg: ModelConfig):
    """decode_step(params, cache, tokens [B]) -> (next_tokens [B], cache)."""

    def step(params, cache, tokens):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.decode_step(p, cfg, tokens, cache)
        nxt = greedy_from_hidden(hidden, registry.lm_head_weight(p, cfg),
                                 impl=_gemm_impl(cfg))
        return nxt, new_cache

    return step


def make_prefill_step(cfg: ModelConfig):
    """prefill(params, cache, batch) -> (first generated token [B], cache).

    batch may carry ``start`` [B] — per-request left-pad counts for ragged
    batches; attention archs thread it through positions/masking and stash
    it in the cache for the decode steps (DESIGN.md §5)."""

    def step(params, cache, batch):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.prefill(
            p, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            prefix_embeds=batch.get("prefix_embeds"),
            cache=cache,
            start=batch.get("start"))
        nxt = greedy_from_hidden(hidden[:, -1:],
                                 registry.lm_head_weight(p, cfg),
                                 impl=_gemm_impl(cfg))
        return nxt, new_cache

    return step


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched greedy-decoding engine (examples + tests).

    Single-host: pads request batches to `max_batch`, runs one prefill then
    a decode loop; per-request early stop on `eos_id`.

    Ragged batches: prompts are left-padded to the longest request and the
    per-row pad counts travel as ``start`` offsets — attention archs mask
    pad keys and shift RoPE positions so a short prompt in a mixed batch
    decodes token-identically to running it solo (DESIGN.md §5). SSM
    archs' recurrent state still consumes the pads (see `prefill`).

    Packed (DBB) weights outside the layer stack — embedding table, LM
    head — are decompressed ONCE at engine construction, not inside every
    jitted decode step; the stacked layer weights stay compressed in HBM
    and expand per-layer inside the scan body (§Perf iteration 17).
    """
    cfg: ModelConfig
    params: Any
    max_batch: int = 8
    eos_id: int = 1

    def __post_init__(self):
        # hoisted non-layer decompression: pay the embed/LM-head DBB
        # expansion once here instead of on every decode step (the inner
        # _decompress_non_layer then no-ops — no packed non-layer leaves);
        # drop our reference to the packed originals so they don't reside
        # next to their dense copies for the engine's lifetime
        self._serve_params = jax.jit(
            lambda p: _decompress_non_layer(p, self.cfg))(self.params)
        self.params = self._serve_params
        self._prefill = jax.jit(make_prefill_step(self.cfg))
        self._decode = jax.jit(make_decode_step(self.cfg), donate_argnums=1)

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 16
                 ) -> List[List[int]]:
        assert len(prompts) <= self.max_batch
        b = len(prompts)
        max_len = max(len(p) for p in prompts)
        total = max_len + max_new_tokens
        toks = np.zeros((self.max_batch, max_len), np.int32)
        start = np.zeros((self.max_batch,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, max_len - len(p):] = p          # left-pad
            start[i] = max_len - len(p)
        cache = registry.init_cache(self.cfg, self.max_batch, total)
        batch = {"tokens": jnp.asarray(toks)}
        if start.any():
            # only genuinely ragged batches pay the per-row position/mask
            # machinery — an all-zero start would force every batched
            # prefill onto the naive [B,S] attention path for nothing
            batch["start"] = jnp.asarray(start)
            if self.cfg.family in ("rwkv6", "zamba2"):
                import warnings
                warnings.warn(
                    f"{self.cfg.family}: ragged batch pads feed the "
                    "recurrent state — short prompts may decode "
                    "differently than solo (needs right-padding + state "
                    "masking; see transformer.prefill)", stacklevel=2)
        nxt, cache = self._prefill(self._serve_params, cache, batch)
        outs: List[List[int]] = [[] for _ in range(b)]
        done = np.zeros(self.max_batch, bool)
        cur = nxt
        for _ in range(max_new_tokens):
            host = np.asarray(cur)
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(host[i]))
                    done[i] |= host[i] == self.eos_id
            if done[:b].all():
                break
            cur, cache = self._decode(self._serve_params, cache, cur)
        return outs
