"""Serving engine: batched prefill + decode over any assigned architecture.

Weights may be DBB-packed (`core.dbb_linear.pack_tree`). Under the Pallas
route (`ModelConfig.gemm_impl = "pallas"`, single device) the stacked layer
weights stay compressed **end-to-end**: the scan body hands the DbbWeight
leaves straight to the DBB kernels, which stream values+bitmask through
their K loop and decompress tiles in VMEM — no per-layer transient dense
copy, HBM residency is the compressed 62.5% for the whole decode step
(DESIGN.md §9). Decode-shaped GEMMs (M ≤ 32) dispatch to the skinny
weight-streaming kernels automatically. On the XLA route (distributed
graphs, CPU dry-run) packed layers expand transiently per layer inside the
scan body as before. Non-layer leaves (embedding table, LM head) are small
and read on *every* decode step, so `ServeEngine` expands them once up
front (`_decompress_non_layer` stays in the step functions for callers
that pass raw packed trees — it no-ops on pre-expanded params).

`ServeEngine.generate` runs static batches with **chunked token fetch**:
generated tokens and the per-row done mask live on device and cross to the
host once per `fetch_chunk` decode steps (a single scalar sync per chunk),
not once per token. `ServeEngine.serve` is the **continuous-batching**
scheduler on top of the same decode step: requests are admitted into free
slots between decode chunks (per-slot prefill scattered into the shared
cache), finished rows retire immediately, and every request decodes
token-identically to running solo (per-row lengths/start offsets,
DESIGN.md §5/§9). With ``cfg.kv_page_size > 0`` serve() switches to the
**paged KV cache** (DESIGN.md §10): KV lives in a fixed-size page pool,
requests admit with the pages they actually use (first-fit over the
queue) instead of reserving ``smax`` slots each, and decode runs the
block-table flash kernel — bit-identical tokens to the contiguous cache
at far higher occupancy per HBM byte.

`make_decode_step` / `make_prefill_step` produce the exact functions the
multi-pod dry-run lowers for the ``decode_*`` / ``prefill_*`` / ``long_*``
input-shape cells.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core.dbb_linear import maybe_decompress_tree
from repro.dist.collectives import cross_entropy  # noqa: F401 (API surface)
from repro.dist.compat import shard_map
from repro.dist.mesh_ctx import current_mesh, shard_tp, shard_tp_ctx
from repro.kernels import dispatch
from repro.models import registry

__all__ = ["make_decode_step", "make_prefill_step",
           "make_packed_prefill_step", "make_chunk_prefill_step",
           "make_sample_decode_step", "make_spec_decode_step",
           "make_sample_prefill_step", "make_sample_packed_prefill_step",
           "make_sample_chunk_prefill_step",
           "ServeEngine", "greedy_from_hidden", "tp_serve_reason"]

# Families whose decode cache is the attention [L, B, S, H, D] K/V layout
# with per-row lengths — the continuous-batching scheduler scatters per-slot
# prefills into it. SSM/hybrid states are admitted wave-wise instead.
_CONT_BATCH_FAMILIES = ("dense_lm", "moe_lm", "vlm_lm", "audio_lm")


def greedy_from_hidden(hidden: jax.Array, w_head: jax.Array,
                       impl: str = "xla",
                       cfg: Optional[ModelConfig] = None) -> jax.Array:
    """hidden [B, 1, d] → greedy next token [B]. The [B, V] logits are tiny
    (one position); vocab stays sharded under GSPMD. impl="pallas" hands
    the head GEMV to the dispatch registry with the ``gemv`` hint
    (DESIGN.md §11): the skinny weight-streaming STA kernel when the batch
    fits the decode regime (B ≤ 32, §9), the XLA matmul otherwise — a
    [B, d]·[d, V] GEMV gains nothing from the M-tiled kernel's padding,
    which is exactly what the hint tells the `sta` route guard.

    Inside a TP shard_map body (the serving wrapper, DESIGN.md §14) the
    head arrives vocab-column-sharded [d, V/tp]: the local GEMV runs on
    the shard's vocab slice and a max/argmax all-gather of [B]-sized
    scalars — not [B, V] logits — picks the global winner."""
    h = hidden[:, -1].astype(jnp.float32)
    if shard_tp() > 1:
        from repro.dist.collectives import shard_greedy
        return shard_greedy(h, w_head, impl=impl, cfg=cfg)
    logits = dispatch.matmul(h, w_head.astype(jnp.float32), cfg=cfg,
                             pallas=(impl == "pallas"), gemv=True)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _gemm_impl(cfg: ModelConfig) -> str:
    """Resolve the engine's GEMM route (single predicate shared with the
    model layer: Pallas without a live mesh, or per-shard inside the TP
    shard_map wrapper)."""
    return "pallas" if dispatch.pallas_route_active(cfg) else "xla"


def tp_serve_reason(cfg: ModelConfig, mesh=None, params: Any = None) -> str:
    """Why the TP shard_map serving wrap is NOT active (empty = it is).

    The wrap (DESIGN.md §14) runs every step function's body per-shard —
    column-parallel QKV/up-projections, row-parallel o_proj/wo with one
    boundary all-reduce each, KV heads sharded over the cache — so it only
    engages when every axis it splits actually divides. With `params` the
    inferred specs are verified too (`tp_spec_violations`): a weight the
    divisibility fallback replicated would be reduce-summed tp× inside the
    body, so any gap keeps the wrap off. The returned string names the
    real rejection; dispatch.explain prints it alongside the mesh shape."""
    mesh = current_mesh() if mesh is None else mesh
    if mesh is None or "model" not in mesh.axis_names \
            or mesh.shape["model"] <= 1:
        return "no live mesh with a model axis > 1"
    tp = mesh.shape["model"]
    if cfg.gemm_impl != "pallas":
        return (f"gemm_impl={cfg.gemm_impl!r} — the wrap exists to put the "
                "Pallas kernels on per-shard shapes; XLA serving stays on "
                "the GSPMD graph")
    if cfg.parallel == "dp":
        return 'parallel="dp": the model axis carries ZeRO, not TP'
    if cfg.family not in _CONT_BATCH_FAMILIES or cfg.family == "moe_lm":
        return (f"family {cfg.family!r}: MoE expert dispatch / SSM state "
                "keep their own sharding (no generic KV-head split)")
    if cfg.num_heads % tp or cfg.num_kv_heads % tp:
        return (f"heads do not divide the model axis: num_heads="
                f"{cfg.num_heads}, num_kv_heads={cfg.num_kv_heads}, "
                f"tp={tp}")
    if cfg.d_ff % tp:
        return f"d_ff={cfg.d_ff} % tp={tp} != 0 (column-parallel MLP split)"
    if cfg.vocab_size % tp:
        return (f"vocab_size={cfg.vocab_size} % tp={tp} != 0 "
                "(vocab-parallel embed/head split)")
    if params is not None:
        from repro.dist.sharding import param_specs, tp_spec_violations
        gaps = tp_spec_violations(
            params, param_specs(params, mesh, cfg,
                                fsdp_min_shard_elems=None))
        if gaps:
            return ("weight leaves fall back to replication under the TP "
                    "specs (packed K-planes must split on DBB block "
                    "boundaries): " + ", ".join(gaps[:4])
                    + ("..." if len(gaps) > 4 else ""))
    return ""


def _decompress_non_layer(params, cfg: ModelConfig):
    """Expand packed leaves OUTSIDE the layer stack only. The stacked layer
    weights stay packed and either stream compressed through the DBB
    kernels (Pallas route, DESIGN.md §9) or expand per-layer *inside* the
    scan body (XLA route) — HBM never holds a whole-model dense copy
    (§Perf iteration 17)."""
    dt = jnp.dtype(cfg.dtype)
    if not isinstance(params, dict) or "layers" not in params:
        return maybe_decompress_tree(params, dtype=dt)
    out = {k: (v if k == "layers" else maybe_decompress_tree(v, dtype=dt))
           for k, v in params.items()}
    return out


def make_decode_step(cfg: ModelConfig):
    """decode_step(params, cache, tokens [B]) -> (next_tokens [B], cache)."""

    def step(params, cache, tokens):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.decode_step(p, cfg, tokens, cache)
        nxt = greedy_from_hidden(hidden, registry.lm_head_weight(p, cfg),
                                 impl=_gemm_impl(cfg), cfg=cfg)
        return nxt, new_cache

    return step


def make_prefill_step(cfg: ModelConfig):
    """prefill(params, cache, batch) -> (first generated token [B], cache).

    batch may carry ``start`` [B] — per-request left-pad counts for ragged
    batches; attention archs thread it through positions/masking and stash
    it in the cache for the decode steps (DESIGN.md §5)."""

    def step(params, cache, batch):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.prefill(
            p, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            prefix_embeds=batch.get("prefix_embeds"),
            cache=cache,
            start=batch.get("start"))
        nxt = greedy_from_hidden(hidden[:, -1:],
                                 registry.lm_head_weight(p, cfg),
                                 impl=_gemm_impl(cfg), cfg=cfg)
        return nxt, new_cache

    return step


def make_packed_prefill_step(cfg: ModelConfig):
    """packed_prefill(params, cache, tokens [1, Tp], seg_ids [Tp],
    positions [1, Tp], rows [Tp], cols [Tp], gather_idx [Gp])
    -> (next tokens [Gp], cache).

    One call prefills EVERY request packed into the token axis (DESIGN.md
    §12): K/V scatter to (rows, cols) — padding carries an out-of-range
    row and is dropped — and ``gather_idx`` names each request's last
    packed position, whose hidden state feeds the greedy head."""

    def step(params, cache, tokens, seg_ids, positions, rows, cols,
             gather_idx):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.prefill_packed(
            p, cfg, tokens, seg_ids, positions, rows, cols, cache)
        last = jnp.take(hidden[0], gather_idx, axis=0)[:, None]  # [Gp, 1, d]
        nxt = greedy_from_hidden(last, registry.lm_head_weight(p, cfg),
                                 impl=_gemm_impl(cfg), cfg=cfg)
        return nxt, new_cache

    return step


def make_chunk_prefill_step(cfg: ModelConfig):
    """chunk_prefill(params, cache, tokens [1, Cp], positions [1, Cp],
    rows [Cp], cols [Cp], kv_sel, last_idx) -> (next token [1], cache).

    One continuation chunk of a long prompt for ONE request (DESIGN.md
    §12): scatter the chunk's K/V, attend the row's cache (selected by
    ``kv_sel`` — slot index or block-table row), and return the greedy
    token from the chunk's last real position (only consumed when this
    chunk completes the prompt)."""

    def step(params, cache, tokens, positions, rows, cols, kv_sel,
             last_idx):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.prefill_continue(
            p, cfg, tokens, positions, rows, cols, kv_sel, cache)
        last = jnp.take(hidden, last_idx, axis=1)[:, None]       # [1, 1, d]
        nxt = greedy_from_hidden(last, registry.lm_head_weight(p, cfg),
                                 impl=_gemm_impl(cfg), cfg=cfg)
        return nxt, new_cache

    return step


def make_sample_decode_step(cfg: ModelConfig, use_tt: bool = False):
    """Sampled decode (DESIGN.md §15): ``step(params, cache, tokens [B],
    sstate) -> ((next_tokens [B], sstate), cache)``.

    The sampling twin of `make_decode_step`: the head runs the fused
    penalty→temperature→gumbel epilogue through the dispatch registry and
    the emitted token folds into the on-device history (counts scatter +
    RNG ordinal) — no host sync added to the chunk loop. ``use_tt`` is
    static: False traces no top-k/top-p code at all (and keeps the fused
    route eligible); True pins the head to the XLA sampler."""
    from repro.serve import sampling

    def step(params, cache, tokens, sstate):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.decode_step(p, cfg, tokens, cache)
        nxt = sampling.sample_from_hidden(
            hidden, registry.lm_head_weight(p, cfg), sstate,
            impl=_gemm_impl(cfg), cfg=cfg, use_tt=use_tt)
        return (nxt, sampling.record_tokens(sstate, nxt)), new_cache

    return step


def make_spec_decode_step(cfg: ModelConfig, draft_k: int,
                          draft_layers: int):
    """Self-speculative decode (DESIGN.md §15): ``step(params, cache,
    tokens [B], sstate) -> ((emit [B, k+1], n_emit [B], sstate), cache)``.

    One speculative step per call: the TRUNCATED model (first
    ``draft_layers`` of the stacked weights, same embed/head) drafts
    ``draft_k`` tokens autoregressively against a throwaway copy of the
    cache's first layers; the FULL model verifies all k+1 positions in
    one skinny-M batched forward (`registry.verify_step` — K/V written at
    the absolute slots, ``length`` untouched); the standard
    rejection-sampling rule accepts a prefix and resamples the first
    rejected position from the residual distribution. Acceptance-aware
    slot accounting: ``length`` advances by exactly ``n_emit``, so the
    rejected tokens' K/V writes sit above the attention mask and are
    overwritten by the next step — the paged cache's rejected writes land
    in still-granted pages of the same row, never another request's.

    Top-k/top-p are not supported here (the engine gates speculation off
    for such batches): the acceptance rule needs matched p/q
    distributions, and truncating both would still leave the draft
    sampling its tokens from a differently-truncated support."""
    from repro.serve import sampling
    nd = draft_layers
    assert 0 < nd < cfg.num_layers, (nd, cfg.num_layers)
    dcfg = cfg.replace(num_layers=nd)
    k = draft_k
    _KV_KEYS = ("k", "v", "k_pages", "v_pages")

    def head_logits(h2d, p):
        """[M, d] hidden rows → [M, V] FULL-vocab f32 logits (the accept
        rule needs whole distributions; under TP the per-shard GEMV
        all-gathers its vocab columns — [M, V] with M ≤ B·(k+1) skinny
        rows, not a decode-batch [B, V] per layer)."""
        w = registry.lm_head_weight(p, cfg).astype(jnp.float32)
        lg = dispatch.matmul(h2d.astype(jnp.float32), w, cfg=cfg,
                             pallas=(_gemm_impl(cfg) == "pallas"),
                             gemv=True)
        if shard_tp() > 1:
            lg = jax.lax.all_gather(lg, "model", axis=-1, tiled=True)
        return lg

    def step(params, cache, tokens, sstate):
        from repro.kernels.sample import sample_logits
        p = _decompress_non_layer(params, cfg)
        b = tokens.shape[0]
        s = sstate
        # -- draft: k autoregressive steps of the truncated model over a
        # throwaway first-nd-layers view of the cache (functional copies
        # — the real cache is untouched until verify writes it)
        dparams = dict(p, layers=jax.tree_util.tree_map(
            lambda a: a[:nd], p["layers"]))
        dcache = {key: (v[:nd] if key in _KV_KEYS else v)
                  for key, v in cache.items()}
        cur = tokens
        d_toks, d_lgs = [], []
        for i in range(k):
            hidden, dcache = registry.decode_step(dparams, dcfg, cur,
                                                  dcache)
            lg = head_logits(hidden[:, -1], p)
            # counts snapshotted across the step (sampling/ops.py doc);
            # ordinal step+i matches the non-spec stream's draw counter
            tok = sample_logits(lg, s["counts"], s["temp"], s["top_k"],
                                s["top_p"], s["rep"], s["pres"], s["freq"],
                                s["seed"], s["step"] + i)
            d_toks.append(tok)
            d_lgs.append(lg)
            cur = tok
        draft_tok = jnp.stack(d_toks, axis=1)            # [B, k]
        draft_lg = jnp.stack(d_lgs, axis=1)              # [B, k, V]
        # -- verify: one skinny-M forward of the FULL model over
        # [cur, d_0..d_{k-1}]; writes K/V at slots length..length+k in
        # every layer, leaves cache["length"] untouched
        vt = jnp.concatenate([tokens[:, None], draft_tok], axis=1)
        hidden, vcache = registry.verify_step(p, cfg, vt, cache)
        vlg = head_logits(hidden.reshape(b * (k + 1), -1), p)
        vlg = vlg.reshape(b, k + 1, -1)                  # [B, k+1, V]
        emit, n_emit = sampling.speculative_accept_state(
            draft_tok, draft_lg, vlg, s)
        # acceptance-aware slot accounting: exactly the accepted prefix +
        # cur become resident (the new cur = emit[n_emit-1] is NOT yet
        # written — same invariant as plain decode); rejected tokens'
        # writes sit at kpos >= length and are re-written next step
        new_cache = dict(vcache, length=cache["length"] + n_emit)
        return (emit, n_emit,
                sampling.record_emitted(s, emit, n_emit)), new_cache

    return step


def make_sample_prefill_step(cfg: ModelConfig, use_tt: bool = False):
    """Sampled prefill: ``step(params, cache, batch, fvals [G, 5],
    ivals [G, 2]) -> ((first token [G], sstate [G-row]), cache)``.

    The knob arrays are `pack_params` rows; a fresh request has zero
    output history, so the step builds a zero-counts state, samples the
    first token at RNG ordinal 0, and returns the state with that token
    already recorded (counts + ordinal advanced to 1)."""
    from repro.serve import sampling

    def step(params, cache, batch, fvals, ivals):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.prefill(
            p, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            prefix_embeds=batch.get("prefix_embeds"),
            cache=cache,
            start=batch.get("start"))
        w = registry.lm_head_weight(p, cfg)
        vocab = w.shape[-1] * max(1, shard_tp())
        state = sampling.fresh_state(fvals, ivals, vocab)
        nxt = sampling.sample_from_hidden(hidden[:, -1:], w, state,
                                          impl=_gemm_impl(cfg), cfg=cfg,
                                          use_tt=use_tt)
        return (nxt, sampling.record_tokens(state, nxt)), new_cache

    return step


def make_sample_packed_prefill_step(cfg: ModelConfig,
                                    use_tt: bool = False):
    """Sampled twin of `make_packed_prefill_step` (+ ``fvals [Gp, 5]`` /
    ``ivals [Gp, 2]`` in packed item order) → ``((tokens [Gp], sstate),
    cache)``. Spare gather rows carry zero knobs — temperature 0 over a
    zero history is a plain argmax, and their tokens are never consumed."""
    from repro.serve import sampling

    def step(params, cache, tokens, seg_ids, positions, rows, cols,
             gather_idx, fvals, ivals):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.prefill_packed(
            p, cfg, tokens, seg_ids, positions, rows, cols, cache)
        last = jnp.take(hidden[0], gather_idx, axis=0)[:, None]
        w = registry.lm_head_weight(p, cfg)
        vocab = w.shape[-1] * max(1, shard_tp())
        state = sampling.fresh_state(fvals, ivals, vocab)
        nxt = sampling.sample_from_hidden(last, w, state,
                                          impl=_gemm_impl(cfg), cfg=cfg,
                                          use_tt=use_tt)
        return (nxt, sampling.record_tokens(state, nxt)), new_cache

    return step


def make_sample_chunk_prefill_step(cfg: ModelConfig,
                                   use_tt: bool = False):
    """Sampled twin of `make_chunk_prefill_step` (+ ``fvals [1, 5]`` /
    ``ivals [1, 2]``) → ``((token [1], sstate), cache)``. The token is
    only consumed when the chunk completes the prompt — it is that
    request's FIRST emitted token, drawn at RNG ordinal 0."""
    from repro.serve import sampling

    def step(params, cache, tokens, positions, rows, cols, kv_sel,
             last_idx, fvals, ivals):
        p = _decompress_non_layer(params, cfg)
        hidden, new_cache = registry.prefill_continue(
            p, cfg, tokens, positions, rows, cols, kv_sel, cache)
        last = jnp.take(hidden, last_idx, axis=1)[:, None]
        w = registry.lm_head_weight(p, cfg)
        vocab = w.shape[-1] * max(1, shard_tp())
        state = sampling.fresh_state(fvals, ivals, vocab)
        nxt = sampling.sample_from_hidden(last, w, state,
                                          impl=_gemm_impl(cfg), cfg=cfg,
                                          use_tt=use_tt)
        return (nxt, sampling.record_tokens(state, nxt)), new_cache

    return step


def _consume_slot(host_emit: np.ndarray, host_nem: np.ndarray, slot: int,
                  row: List[int], left: int, eos_id: int
                  ) -> Tuple[int, bool]:
    """Drain one slot's emitted tokens from a fetched chunk into ``row``.

    ``host_emit`` [steps, B, ke] / ``host_nem`` [steps, B]: per decode
    step, the first ``host_nem[s, slot]`` entries of
    ``host_emit[s, slot]`` are real (speculative steps emit a variable
    1..k+1; plain steps always 1). Consumption stops at EOS or when the
    request's remaining ``left`` budget hits zero — surplus tokens from
    overshoot steps are discarded, exactly like the greedy loops.
    Returns (remaining budget, finished)."""
    for s in range(host_emit.shape[0]):
        for j in range(int(host_nem[s, slot])):
            t = int(host_emit[s, slot, j])
            row.append(t)
            left -= 1
            if t == eos_id or left <= 0:
                return left, True
    return left, False


def _bump_spec_stats(stats: Dict[str, int], host_n: np.ndarray,
                     active: Dict[int, int]) -> None:
    """Accumulate speculative accounting over a chunk's live slots:
    tokens emitted vs speculative steps run (acceptance rate falls out as
    ``(spec_emitted / spec_steps - 1) / draft_k``). Overshoot steps of
    rows retiring mid-chunk are included — a slight undercount of the
    true acceptance, fine for the serve-stats gauge."""
    stats["spec_steps"] = (stats.get("spec_steps", 0)
                           + host_n.shape[0] * len(active))
    stats["spec_emitted"] = (stats.get("spec_emitted", 0)
                             + sum(int(host_n[:, s].sum())
                                   for s in active))


def _bucket_len(n: int, minimum: int = 8) -> int:
    """Pad a prompt length up to a power-of-two bucket (≥ minimum) so the
    per-slot admission prefill compiles once per bucket, not once per
    prompt length. Left-pad + ``start`` offsets make the padding exact
    (DESIGN.md §5)."""
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class ServeEngine:
    """Batched greedy-decoding engine (examples + tests + benchmarks).

    Single-host. Two entry points:

    * `generate(prompts)` — one static batch (≤ `max_batch`): one prefill,
      then a decode loop. Generated tokens and the done mask stay ON
      DEVICE; the host syncs one scalar per `fetch_chunk` decode steps and
      pulls the token buffer when the batch finishes — no per-token
      device→host round-trip.
    * `serve(prompts)` — continuous batching over any number of requests:
      new requests are admitted into free slots between decode chunks (a
      single-row prefill scattered into the shared cache at the slot
      index), finished rows retire immediately and free their slot. A
      request admitted mid-stream decodes token-identically to running
      solo: per-row cache lengths, left-pad ``start`` offsets and RoPE
      positions isolate every row (DESIGN.md §5/§9).

    Ragged batches: prompts are left-padded and the per-row pad counts
    travel as ``start`` offsets — attention archs mask pad keys and shift
    RoPE positions so a short prompt in a mixed batch decodes
    token-identically to running it solo. SSM archs' recurrent state still
    consumes the pads (see `prefill`); they also fall back to wave-wise
    static batching under `serve`.

    Packed (DBB) weights outside the layer stack — embedding table, LM
    head — are decompressed ONCE at engine construction; the stacked layer
    weights stay compressed in HBM and, on the Pallas route, stream
    compressed through the DBB kernels for the whole decode step
    (DESIGN.md §9).
    """
    cfg: ModelConfig
    params: Any
    max_batch: int = 8
    eos_id: int = 1
    fetch_chunk: int = 8
    # paged KV (DESIGN.md §10): physical page pool size for serve() when
    # ``cfg.kv_page_size > 0``. 0 = parity with the contiguous cache's HBM
    # footprint (max_batch · n_log pages, + the reserved dummy); set it
    # explicitly to serve against a fixed HBM budget — admission then packs
    # as many requests as their *used* pages allow.
    kv_pool_pages: int = 0
    # None: serve() pages iff cfg.kv_page_size > 0. False pins the
    # contiguous scheduler while keeping kv_page_size as the flash decode
    # kernel's KV tile — the identity-block-table control the paged-vs-
    # contiguous bit-equivalence suite compares against.
    paged: Optional[bool] = None
    # prefill layout for serve() (DESIGN.md §12): "packed" concatenates
    # admitted prompts into one [total_tokens] axis (no pad token ever
    # enters a GEMM); "padded" is the legacy per-slot left-padded bucket
    # prefill the parity suite compares against.
    prefill_mode: str = "packed"
    # split prompts into fixed-size chunks so the scheduler interleaves
    # prefill with decode chunks (bounds decode-row TTFT jitter under
    # heavy admission). 0 = whole-prompt prefill. Packed mode only.
    prefill_chunk: int = 0
    # self-speculative decode (DESIGN.md §15): draft_k > 0 drafts that
    # many tokens per step with the truncated model and verifies them in
    # one batched forward. Only engages on sampled calls (generate/serve
    # with ``sampling=``); per-call ``draft_k=`` overrides. draft_layers
    # picks the truncation depth (0 = num_layers // 2).
    draft_k: int = 0
    draft_layers: int = 0

    def __post_init__(self):
        # the diagnostic int32 indices plane is host-side validation
        # material (validate_dbb) — 4 B/value of dead HBM on a serving
        # engine. Strip it from every device-resident packed leaf up
        # front; kernels and decompress consume the bitmask only.
        from repro.core.dbb import DbbWeight as _Dbb
        self.params = jax.tree_util.tree_map(
            lambda l: (dataclasses.replace(l, indices=None)
                       if isinstance(l, _Dbb) and l.indices is not None
                       else l),
            self.params, is_leaf=lambda l: isinstance(l, _Dbb))
        # hoisted non-layer decompression: pay the embed/LM-head DBB
        # expansion once here instead of on every decode step (the inner
        # _decompress_non_layer then no-ops — no packed non-layer leaves);
        # drop our reference to the packed originals so they don't reside
        # next to their dense copies for the engine's lifetime
        self.params = jax.jit(
            lambda p: _decompress_non_layer(p, self.cfg))(self.params)
        # TP serving wrap (DESIGN.md §14): with a live TP mesh and the
        # Pallas route requested, every step function's body runs per-shard
        # under one shard_map — params/KV sharded by the Megatron specs,
        # boundary collectives inside the body. tp_reason records why the
        # wrap is off (empty = on) for explain/diagnostics.
        mesh = current_mesh()
        self.tp_reason = tp_serve_reason(self.cfg, mesh, self.params)
        self._tp = 0 if self.tp_reason else mesh.shape["model"]
        self._mesh = None if self.tp_reason else mesh
        if self._tp:
            from repro.dist.sharding import (named_sharding_tree,
                                             param_specs)
            self._pspecs = param_specs(self.params, mesh, self.cfg,
                                       fsdp_min_shard_elems=None)
            self.params = jax.device_put(
                self.params, named_sharding_tree(self._pspecs, mesh))
        self._prefill = jax.jit(self._tp_step(make_prefill_step))
        self._decode_raw = self._tp_step(make_decode_step)
        self._decode = jax.jit(self._decode_raw, donate_argnums=1)
        self._chunk_fns: Dict[int, Any] = {}
        self._admit = jax.jit(self._admit_fn, donate_argnums=0)
        self._admit_paged = jax.jit(self._admit_paged_fn, donate_argnums=0)
        self._packed_prefill = jax.jit(self._tp_step(make_packed_prefill_step),
                                       donate_argnums=1)
        self._prefill_continue = jax.jit(self._tp_step(make_chunk_prefill_step),
                                         donate_argnums=1)
        self._install = jax.jit(self._install_fn, donate_argnums=0)
        self._install_paged = jax.jit(self._install_paged_fn,
                                      donate_argnums=0)
        # sampled/speculative variants, built lazily per static knob set
        # (use_tt, draft_k) — a greedy engine never traces sampling code
        self._sample_raws: Dict[Any, Any] = {}
        self._sample_chunks: Dict[Any, Any] = {}
        self._sample_prefills: Dict[Any, Any] = {}
        self._sstate_admit = jax.jit(self._sstate_admit_fn,
                                     donate_argnums=0)
        # filled by the paged serve() scheduler (occupancy benchmarking)
        self.serve_stats: Dict[str, int] = {}

    def _tp_step(self, maker):
        """Build one step function from its maker; when the TP wrap is
        active, shard_map it over the serving mesh (DESIGN.md §14).

        The body runs the step built with a *localized* cfg (heads ÷ tp,
        head_dim pinned so the ratio survives) inside `shard_tp_ctx`, which
        is what re-enables the Pallas route guards on per-shard shapes.
        Params shard by the Megatron TP specs; the KV cache shards its
        KV-heads dim (contiguous and paged layouts both carry it at dim 3,
        so paged block tables are per-shard: replicated tables indexing
        shard-local pools of local heads); token/bookkeeping args
        replicate. Cache specs are derived per call from the actual tree —
        generate/serve/paged caches differ in structure."""
        if not self._tp:
            return maker(self.cfg)
        tp, mesh, pspecs = self._tp, self._mesh, self._pspecs
        lcfg = self.cfg.replace(
            num_heads=self.cfg.num_heads // tp,
            num_kv_heads=self.cfg.num_kv_heads // tp,
            head_dim=self.cfg.resolved_head_dim)
        inner = maker(lcfg)

        def stepped(params, cache, *rest):
            from repro.dist.sharding import serve_cache_specs
            cspecs = serve_cache_specs(cache, mesh)

            def body(p, c, *r):
                with shard_tp_ctx(tp):
                    return inner(p, c, *r)

            return shard_map(
                body, mesh=mesh,
                in_specs=(pspecs, cspecs) + (P(),) * len(rest),
                out_specs=(P(), cspecs),
                check_vma=False)(params, cache, *rest)

        return stepped

    # -- decode chunks: N steps per host round-trip -----------------------

    def _chunk_fn(self, steps: int):
        """Jitted scan of `steps` decode steps. Carries (cur, cache, done)
        on device and emits the [steps, B] token block — ONE host fetch
        and ONE all-done scalar sync per chunk instead of per token.

        Callers always pass the engine's fixed `fetch_chunk` and discard
        surplus tokens host-side: each distinct `steps` compiles its own
        whole-model scan, and a variable tail size would turn the end of
        every request into a mid-serving XLA compile. (Overshoot decode
        steps write per-row clamped cache slots whose tokens are never
        consumed — see generate/serve.)"""
        fn = self._chunk_fns.get(steps)
        if fn is None:
            raw, eos = self._decode_raw, self.eos_id

            def chunk(params, cache, cur, done):
                def live(carry):
                    cur, cache, done = carry
                    nxt, cache = raw(params, cache, cur)
                    done = done | (nxt == eos)
                    return (nxt, cache, done), nxt

                def skip(carry):
                    # early exit: once every row is done mid-chunk the
                    # remaining scan iterations skip the whole-model step
                    # (the repeated cur is never consumed — done rows'
                    # token loops already broke at their EOS)
                    return carry, carry[0]

                def body(carry, _):
                    return jax.lax.cond(jnp.all(carry[2]), skip, live,
                                        carry)

                (cur, cache, done), toks = jax.lax.scan(
                    body, (cur, cache, done), None, length=steps)
                return cur, cache, done, toks

            fn = jax.jit(chunk, donate_argnums=1)
            self._chunk_fns[steps] = fn
        return fn

    # -- sampled / speculative variants (DESIGN.md §15) -------------------

    def _resolved_draft_layers(self) -> int:
        return self.draft_layers or max(1, self.cfg.num_layers // 2)

    def _sample_raw(self, use_tt: bool, draft_k: int):
        """`_tp_step`-wrapped sampled (or speculative) decode step, cached
        per static knob set."""
        key = (use_tt, draft_k)
        fn = self._sample_raws.get(key)
        if fn is None:
            if draft_k > 0:
                nd = self._resolved_draft_layers()
                fn = self._tp_step(
                    lambda c: make_spec_decode_step(c, draft_k, nd))
            else:
                fn = self._tp_step(
                    lambda c: make_sample_decode_step(c, use_tt))
            self._sample_raws[key] = fn
        return fn

    def _sample_prefill_fn(self, mode: str, use_tt: bool):
        """Jitted sampled prefill for ``mode`` in padded/packed/chunk."""
        key = (mode, use_tt)
        fn = self._sample_prefills.get(key)
        if fn is None:
            maker = {"padded": make_sample_prefill_step,
                     "packed": make_sample_packed_prefill_step,
                     "chunk": make_sample_chunk_prefill_step}[mode]
            stepped = self._tp_step(lambda c: maker(c, use_tt))
            # padded admission reuses a pristine cache template (never
            # donated); packed/chunk scatter into the live shared cache
            fn = (jax.jit(stepped) if mode == "padded"
                  else jax.jit(stepped, donate_argnums=1))
            self._sample_prefills[key] = fn
        return fn

    def _sample_chunk_fn(self, steps: int, use_tt: bool, draft_k: int):
        """Sampled twin of `_chunk_fn`: carries (cur, cache, done, sstate)
        and emits ``(emit [steps, B, ke], n_emit [steps, B])`` with
        ``ke = draft_k + 1`` (1 for plain sampling) — the host drains a
        variable number of real tokens per step (`_consume_slot`). Same
        all-done early exit as the greedy chunk."""
        key = (steps, use_tt, draft_k)
        fn = self._sample_chunks.get(key)
        if fn is None:
            raw = self._sample_raw(use_tt, draft_k)
            eos, ke = self.eos_id, draft_k + 1
            spec = draft_k > 0

            def chunk(params, cache, cur, done, sstate):
                def live(carry):
                    cur, cache, done, sstate = carry
                    if spec:
                        (emit, nem, sstate), cache = raw(
                            params, cache, cur, sstate)
                        mask = jnp.arange(ke)[None, :] < nem[:, None]
                        done = done | jnp.any((emit == eos) & mask,
                                              axis=1)
                        cur = jnp.take_along_axis(
                            emit, (nem - 1)[:, None], axis=1)[:, 0]
                    else:
                        (cur, sstate), cache = raw(params, cache, cur,
                                                   sstate)
                        emit = cur[:, None]
                        nem = jnp.ones(cur.shape, jnp.int32)
                        done = done | (cur == eos)
                    return (cur, cache, done, sstate), (emit, nem)

                def skip(carry):
                    cur = carry[0]
                    return carry, (
                        jnp.broadcast_to(cur[:, None],
                                         (cur.shape[0], ke)),
                        jnp.ones(cur.shape, jnp.int32))

                def body(carry, _):
                    return jax.lax.cond(jnp.all(carry[2]), skip, live,
                                        carry)

                (cur, cache, done, sstate), (emit, nem) = jax.lax.scan(
                    body, (cur, cache, done, sstate), None, length=steps)
                return cur, cache, done, sstate, emit, nem

            fn = jax.jit(chunk, donate_argnums=(1, 4))
            self._sample_chunks[key] = fn
        return fn

    @staticmethod
    def _sstate_admit_fn(sstate, slot, fvals, ivals, tok):
        """Install one admitted request's sampling lanes at ``slot`` and
        fold its prefill-sampled first token into the fresh history
        (counts[slot, tok] = 1, RNG ordinal = 1 — matching what
        `record_tokens` did inside the prefill step's own G-row state)."""
        from repro.serve.sampling import state_install
        s = state_install(sstate, slot, fvals, ivals)
        return dict(s, counts=s["counts"].at[slot, tok].add(1),
                    step=s["step"].at[slot].set(1))

    # -- static batch -----------------------------------------------------

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 16,
                 sampling: Optional[Sequence[Any]] = None,
                 draft_k: Optional[int] = None) -> List[List[int]]:
        assert len(prompts) <= self.max_batch
        if sampling is not None:
            return self._generate_sampled(prompts, max_new_tokens,
                                          sampling, draft_k)
        b = len(prompts)
        max_len = max(len(p) for p in prompts)
        total = max_len + max_new_tokens
        toks = np.zeros((self.max_batch, max_len), np.int32)
        start = np.zeros((self.max_batch,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, max_len - len(p):] = p          # left-pad
            start[i] = max_len - len(p)
        cache = registry.init_cache(self.cfg, self.max_batch, total)
        batch = {"tokens": jnp.asarray(toks)}
        if start.any():
            # only genuinely ragged batches pay the per-row position/mask
            # machinery — an all-zero start would force every batched
            # prefill onto the naive [B,S] attention path for nothing
            batch["start"] = jnp.asarray(start)
            if self._tp:
                # the TP wrap derives shard_map out_specs from the INPUT
                # cache tree; ragged prefill adds the "start" leaf to the
                # returned cache, so seed it up front to keep the pytree
                # structures aligned
                cache["start"] = jnp.zeros((self.max_batch,), jnp.int32)
            if self.cfg.family in ("rwkv6", "zamba2"):
                import warnings
                warnings.warn(
                    f"{self.cfg.family}: ragged batch pads feed the "
                    "recurrent state — short prompts may decode "
                    "differently than solo (needs right-padding + state "
                    "masking; see transformer.prefill)", stacklevel=2)
        cur, cache = self._prefill(self.params, cache, batch)
        # device-side recording: pad rows start done, real rows check eos
        done = jnp.asarray(np.arange(self.max_batch) >= b) | (
            cur == self.eos_id)
        chunks = [cur[None]]                        # [1, B] on device
        remaining = max_new_tokens - 1
        while remaining > 0 and not bool(jnp.all(done)):
            # fixed-size chunks (one compiled scan); the tail overshoot's
            # tokens are trimmed below and its clamped cache writes only
            # ever feed further discarded tokens
            cur, cache, done, toks_d = self._chunk_fn(self.fetch_chunk)(
                self.params, cache, cur, done)
            chunks.append(toks_d)
            remaining -= self.fetch_chunk
        host = np.concatenate([np.asarray(c) for c in chunks], axis=0)
        outs: List[List[int]] = []
        for i in range(b):
            row: List[int] = []
            for t in host[:max_new_tokens, i]:
                row.append(int(t))
                if t == self.eos_id:
                    break
            outs.append(row)
        return outs

    def _spec_mode(self, sampling: Sequence[Any],
                   draft_k: Optional[int]) -> Tuple[bool, int]:
        """Resolve a sampled call's static knobs: (use_tt, draft_k), with
        speculation gated OFF (warning, not error) when this config or
        batch cannot honor it."""
        import warnings

        from repro.serve.sampling import any_uses_tt
        use_tt = any_uses_tt(sampling)
        dk = self.draft_k if draft_k is None else draft_k
        if dk > 0:
            reason = ""
            if self.cfg.family not in _CONT_BATCH_FAMILIES:
                reason = (f"family {self.cfg.family!r} has no "
                          "slot-addressed K/V cache for batched verify")
            elif use_tt:
                reason = ("top-k/top-p requests in the batch — the "
                          "acceptance rule needs untruncated p/q")
            elif self.cfg.num_layers < 2:
                reason = "needs num_layers >= 2 to truncate a draft"
            if reason:
                warnings.warn(f"speculative decode disabled ({reason}) — "
                              "serving with plain sampling", stacklevel=3)
                dk = 0
        return use_tt, dk

    def _generate_sampled(self, prompts: List[List[int]],
                          max_new_tokens: int, sampling: Sequence[Any],
                          draft_k: Optional[int]) -> List[List[int]]:
        """Sampled/speculative twin of the static `generate` path. Same
        one-sync-per-chunk loop; chunks emit (emit, n_emit) blocks and the
        host drains a variable token count per step."""
        from repro.serve.sampling import pack_params
        b = len(prompts)
        assert len(sampling) == b, (len(sampling), b)
        use_tt, dk = self._spec_mode(sampling, draft_k)
        ke = dk + 1
        max_len = max(len(p) for p in prompts)
        # speculative verify writes a (k+1)-slab at the write cursor:
        # give the cache that margin past the budget so no in-budget
        # step's slab ever clamps into resident slots
        total = max_len + max_new_tokens + (ke if dk else 0)
        toks = np.zeros((self.max_batch, max_len), np.int32)
        start = np.zeros((self.max_batch,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, max_len - len(p):] = p          # left-pad
            start[i] = max_len - len(p)
        fv = np.zeros((self.max_batch, 5), np.float32)
        fv[:, 1] = 1.0                               # top_p identity
        fv[:, 2] = 1.0                               # repetition identity
        iv = np.zeros((self.max_batch, 2), np.int32)
        for i, sp in enumerate(sampling):
            f, ivv = pack_params(sp)
            fv[i], iv[i] = np.asarray(f), np.asarray(ivv)
        cache = registry.init_cache(self.cfg, self.max_batch, total)
        batch = {"tokens": jnp.asarray(toks)}
        if start.any():
            batch["start"] = jnp.asarray(start)
            if self._tp:
                # keep shard_map in/out cache pytrees aligned (see
                # `generate`)
                cache["start"] = jnp.zeros((self.max_batch,), jnp.int32)
        (cur, sstate), cache = self._sample_prefill_fn("padded", use_tt)(
            self.params, cache, batch, jnp.asarray(fv), jnp.asarray(iv))
        done = jnp.asarray(np.arange(self.max_batch) >= b) | (
            cur == self.eos_id)
        first = np.zeros((1, self.max_batch, ke), np.int64)
        first[0, :, 0] = np.asarray(cur)
        he_list = [first]
        hn_list = [np.ones((1, self.max_batch), np.int64)]
        # per-row emitted counts steer the loop: speculative chunks emit
        # 1..k+1 per step, so "steps run" no longer measures progress
        got = np.ones((self.max_batch,), np.int64)
        while True:
            dh = np.asarray(done)
            if np.all(dh | (got >= max_new_tokens)):
                break
            cur, cache, done, sstate, e_d, n_d = self._sample_chunk_fn(
                self.fetch_chunk, use_tt, dk)(
                    self.params, cache, cur, done, sstate)
            he_list.append(np.asarray(e_d))
            hn = np.asarray(n_d)
            hn_list.append(hn)
            got += hn.sum(axis=0)
        host_e = np.concatenate(he_list, axis=0)
        host_n = np.concatenate(hn_list, axis=0)
        outs: List[List[int]] = []
        for i in range(b):
            row: List[int] = []
            _consume_slot(host_e, host_n, i, row, max_new_tokens,
                          self.eos_id)
            outs.append(row)
        return outs

    # -- continuous batching ----------------------------------------------

    @staticmethod
    def _admit_fn(cache, cache_one, cur, done, slot, tok):
        """Scatter a finished single-row prefill into the shared decode
        state at `slot` (traced index — one compilation serves every
        slot). Row-indexed leaves (length/start) write at [slot], stacked
        K/V leaves at [:, slot]."""
        new = {}
        for key, leaf in cache.items():
            if leaf.ndim == 1:                       # length / start
                new[key] = leaf.at[slot].set(cache_one[key][0])
            else:                                    # [L, B, S, H, D] K/V
                new[key] = leaf.at[:, slot].set(cache_one[key][:, 0])
        return new, cur.at[slot].set(tok), done.at[slot].set(False)

    @staticmethod
    def _admit_paged_fn(cache, cache_one, cur, done, table_row, slot, tok):
        """Paged admission (DESIGN.md §10): scatter the single-row
        contiguous prefill cache into the physical page pool at the pages
        named by ``table_row`` [n_log] and install the table row at
        ``slot``. Unallocated tail entries of the row point at the
        reserved dummy page — their scatter writes (and any later
        overshoot writes of this slot) land there harmlessly. Traced row /
        slot / token: one compilation serves every admission."""
        n_log = cache["block_table"].shape[1]
        page = cache["k_pages"].shape[2]
        k1 = cache_one["k"]                          # [L, 1, smax, H, D]
        L, _, smax, h, d = k1.shape
        kpg = k1.reshape(L, n_log, page, h, d)
        vpg = cache_one["v"].reshape(L, n_log, page, h, d)
        new = {
            "k_pages": cache["k_pages"].at[:, table_row].set(kpg),
            "v_pages": cache["v_pages"].at[:, table_row].set(vpg),
            "block_table": cache["block_table"].at[slot].set(table_row),
            "length": cache["length"].at[slot].set(cache_one["length"][0]),
            "start": cache["start"].at[slot].set(cache_one["start"][0]),
        }
        return new, cur.at[slot].set(tok), done.at[slot].set(False)

    @staticmethod
    def _install_fn(cache, cur, done, slot, tok, length):
        """Activate a slot whose prompt finished PACKED prefill: the K/V
        already sits in the shared cache (scattered token-by-token by the
        packed/chunk prefill calls), so activation only installs the
        bookkeeping — length, a zero start (packed rows have no left-pad),
        the first generated token, and the live done bit."""
        new = dict(cache,
                   length=cache["length"].at[slot].set(length),
                   start=cache["start"].at[slot].set(0))
        return new, cur.at[slot].set(tok), done.at[slot].set(False)

    @staticmethod
    def _install_paged_fn(cache, cur, done, table_row, slot, tok, length):
        """Paged activation: same as `_install_fn` plus the block-table
        row. Until this runs the slot's table points at the dummy page, so
        the half-prefilled pages (written physically, table-bypassing)
        were invisible to every decode step."""
        new = dict(cache,
                   block_table=cache["block_table"].at[slot].set(table_row),
                   length=cache["length"].at[slot].set(length),
                   start=cache["start"].at[slot].set(0))
        return new, cur.at[slot].set(tok), done.at[slot].set(False)

    def serve(self, prompts: List[List[int]],
              max_new_tokens: Union[int, Sequence[int]] = 16,
              fetch_chunk: Optional[int] = None,
              prompt_bucket: int = 8,
              prefill_mode: Optional[str] = None,
              prefill_chunk: Optional[int] = None,
              sampling: Optional[Sequence[Any]] = None,
              draft_k: Optional[int] = None) -> List[List[int]]:
        """Continuous-batching greedy decode over any number of requests.

        max_new_tokens: one budget for all requests, or one per request.
        Requests are admitted into free slots between decode chunks and
        retire the moment they hit EOS or their budget — the batch stays
        full whenever there is queued work, instead of draining to the
        slowest request like a static wave.

        prefill_mode / prefill_chunk override the engine defaults per
        call: "packed" (default) prefills admitted prompts padding-free
        through the cu_seqlens path, optionally split into
        ``prefill_chunk``-token chunks interleaved with decode chunks;
        "padded" is the legacy left-padded per-slot prefill (DESIGN.md
        §12)."""
        n_req = len(prompts)
        if isinstance(max_new_tokens, int):
            budgets = [max_new_tokens] * n_req
        else:
            budgets = list(max_new_tokens)
            assert len(budgets) == n_req, (len(budgets), n_req)
        if n_req == 0:
            return []
        if sampling is not None:
            assert len(sampling) == n_req, (len(sampling), n_req)
        if self.cfg.family not in _CONT_BATCH_FAMILIES:
            # SSM/hybrid states have no slot-scatterable K/V cache yet —
            # serve them as static waves (correct, just not continuous)
            import warnings
            warnings.warn(
                f"{self.cfg.family}: continuous batching needs the "
                "attention K/V cache layout — falling back to static "
                "waves", stacklevel=2)
            outs = []
            for i in range(0, n_req, self.max_batch):
                wave_p = prompts[i:i + self.max_batch]
                wave_b = budgets[i:i + self.max_batch]
                wave_s = (None if sampling is None
                          else sampling[i:i + self.max_batch])
                res = self.generate(wave_p, max_new_tokens=max(wave_b),
                                    sampling=wave_s, draft_k=draft_k)
                outs.extend(r[:bud] for r, bud in zip(res, wave_b))
            return outs

        use_tt, dk = (False, 0) if sampling is None else \
            self._spec_mode(sampling, draft_k)
        # speculative margin: verify writes a (k+1)-slab at the write
        # cursor, so every reservation (and smax) carries that headroom
        dmargin = dk + 1 if dk else 0
        chunk = fetch_chunk or self.fetch_chunk
        blens = [_bucket_len(len(p), prompt_bucket) for p in prompts]
        # bucket the cache length too: serve() calls with nearby budgets
        # must reuse one compiled chunk scan / admit scatter / prefill
        smax = _bucket_len(max(blens) + max(budgets) + dmargin,
                           prompt_bucket)
        if self.cfg.kv_page_size > 0:
            # page-align smax for BOTH schedulers: the contiguous flash
            # decode gate needs smax % page == 0, and a contiguous engine
            # on an unaligned smax would silently take the XLA softmax
            # path while the paged engine runs the kernel — breaking the
            # paged-vs-contiguous bit-identity contract (DESIGN.md §10)
            page = self.cfg.kv_page_size
            smax = -(-smax // page) * page
        use_paged = (self.cfg.kv_page_size > 0 if self.paged is None
                     else self.paged)
        if use_paged:
            reason = _paged_unsupported_reason(self.cfg, self._tp)
            if reason:
                # the paged branch decodes through the flash kernel
                # unconditionally — honor a config it cannot serve by
                # falling back to the contiguous scheduler instead of
                # silently overriding the user's backend choice
                import warnings
                warnings.warn(f"paged KV serving unavailable ({reason}) — "
                              "falling back to the contiguous scheduler",
                              stacklevel=2)
                use_paged = False
        backend = (_PagedKvBackend(self, smax) if use_paged
                   else _ContiguousKvBackend(self, smax))
        mode = prefill_mode if prefill_mode is not None else self.prefill_mode
        assert mode in ("packed", "padded"), mode
        if mode == "packed":
            pchunk = (prefill_chunk if prefill_chunk is not None
                      else self.prefill_chunk)
            return self._serve_loop_packed(prompts, budgets, blens, smax,
                                           chunk, backend, pchunk,
                                           sampling, use_tt, dk)
        return self._serve_loop(prompts, budgets, blens, smax, chunk,
                                backend, sampling, use_tt, dk)

    def _serve_loop(self, prompts: List[List[int]], budgets: List[int],
                    blens: List[int], smax: int, chunk: int, backend,
                    sampling: Optional[Sequence[Any]] = None,
                    use_tt: bool = False, dk: int = 0) -> List[List[int]]:
        """The one continuous-batching scheduler both KV layouts share.
        The backend only decides how cache space is reserved and where
        admissions scatter (contiguous slots vs allocated pages) — token
        accounting, chunk decode, and retirement live here once, so the
        two layouts cannot drift apart (their token streams are asserted
        bit-identical, DESIGN.md §10). With ``sampling`` the decode chunks
        carry the device-resident sampling state (and, with ``dk > 0``,
        run speculative steps emitting 1..k+1 tokens each)."""
        sampled = sampling is not None
        dmargin = dk + 1 if dk else 0
        cache = backend.init_cache()
        cur = jnp.zeros((self.max_batch,), jnp.int32)
        done = jnp.ones((self.max_batch,), bool)
        sstate = None
        if sampled:
            from repro.serve.sampling import pack_params, sampling_state
            sstate = sampling_state(self.max_batch, self.cfg.vocab_size)
        outs: List[List[int]] = [[] for _ in prompts]
        queue = deque(range(len(prompts)))
        free = list(range(self.max_batch))
        active: Dict[int, int] = {}                  # slot -> request idx
        left: Dict[int, int] = {}                    # request idx -> budget

        # one reusable zero cache for every admission prefill (the jitted
        # prefill never donates it, so the template stays pristine)
        c1_template = registry.init_cache(self.cfg, 1, smax)

        def admit(slot: int, ridx: int):
            nonlocal cache, cur, done, sstate
            grant = backend.reserve(ridx, blens[ridx],
                                    budgets[ridx] + dmargin)
            if grant is None:
                return "defer"                       # wait for retirements
            p, bl = prompts[ridx], blens[ridx]
            toks = np.zeros((1, bl), np.int32)
            toks[0, bl - len(p):] = p                # left-pad to bucket
            batch1 = {"tokens": jnp.asarray(toks),
                      "start": jnp.asarray([bl - len(p)], np.int32)}
            if sampled:
                fv, iv = pack_params(sampling[ridx])
                (nxt1, _), c1 = self._sample_prefill_fn("padded", use_tt)(
                    self.params, c1_template, batch1,
                    fv[None], iv[None])
            else:
                nxt1, c1 = self._prefill(self.params, c1_template, batch1)
            tok = int(jax.device_get(nxt1)[0])       # first generated token
            outs[ridx].append(tok)
            if tok == self.eos_id or budgets[ridx] <= 1:
                backend.release(grant)
                return False                         # finished at prefill
            cache, cur, done = backend.admit(cache, c1, cur, done, slot,
                                             nxt1[0], grant)
            if sampled:
                sstate = self._sstate_admit(sstate, jnp.int32(slot), fv,
                                            iv, nxt1[0])
            active[slot] = ridx
            left[ridx] = budgets[ridx] - 1
            return True

        while queue or active:
            # first-fit admission between decode chunks: a request whose
            # reservation doesn't fit yet is skipped (kept in arrival
            # order), not head-of-line blocking — short requests backfill
            # slots behind a deferred long one. The contiguous backend
            # always grants, which degenerates to plain FIFO fill.
            skipped: List[int] = []
            while queue and free:
                ridx = queue.popleft()
                if budgets[ridx] <= 0:
                    continue
                slot = free.pop()
                r = admit(slot, ridx)
                if r == "defer":
                    free.append(slot)
                    skipped.append(ridx)
                    backend.stats["deferred_admissions"] += 1
                    continue
                if not r:
                    free.append(slot)
            queue.extendleft(reversed(skipped))
            if not active:
                if queue:        # deferred with nothing left to retire
                    backend.starved(queue[0], blens, budgets)
                continue
            backend.stats["peak_active"] = max(
                backend.stats["peak_active"], len(active))
            # fixed-size chunks (one compiled scan); rows that hit EOS or
            # their budget mid-chunk have their surplus tokens discarded
            # below and retire at the chunk boundary
            if sampled:
                cur, cache, done, sstate, e_d, n_d = self._sample_chunk_fn(
                    chunk, use_tt, dk)(self.params, cache, cur, done,
                                       sstate)
                host_e = np.asarray(e_d)             # one fetch per chunk
                host_n = np.asarray(n_d)
            else:
                cur, cache, done, toks_d = self._chunk_fn(chunk)(
                    self.params, cache, cur, done)
                host_e = np.asarray(toks_d)[:, :, None]
                host_n = np.ones(host_e.shape[:2], np.int64)
            if dk:
                _bump_spec_stats(backend.stats, host_n, active)
            retired = []
            for slot, ridx in active.items():
                left[ridx], fin = _consume_slot(host_e, host_n, slot,
                                                outs[ridx], left[ridx],
                                                self.eos_id)
                if fin:
                    retired.append(slot)
            for slot in retired:
                del active[slot]
                free.append(slot)
                done = done.at[slot].set(True)
                cache = backend.retire(cache, slot)
        self.serve_stats = backend.stats
        return outs

    def _serve_loop_packed(self, prompts: List[List[int]],
                           budgets: List[int], blens: List[int], smax: int,
                           chunk: int, backend, prefill_chunk: int,
                           sampling: Optional[Sequence[Any]] = None,
                           use_tt: bool = False, dk: int = 0
                           ) -> List[List[int]]:
        """Padding-free continuous batching (DESIGN.md §12). Differences
        from `_serve_loop`:

        * Admission splits into slot ASSIGNMENT (reserve cache space, no
          compute) and PREFILL. Assigned-but-unfinished requests sit in
          ``pending``; their rows stay done=True, so decode never sees a
          half-prefilled prompt.
        * All first chunks pack into ONE cu_seqlens call per scheduler
          iteration — total_tokens of work, zero pad rows — and requests
          admit with start=0 (no left-pad: packed rows are solo-exact by
          construction, not by masking).
        * With ``prefill_chunk > 0`` at most that many prompt tokens
          prefill between consecutive decode chunks (continuations run
          FIFO, one chunk per row per iteration), which bounds the TTFT
          jitter a long admission inflicts on in-flight decode rows.

        Half-prefilled/free rows still decode-step (the chunk scan is
        whole-batch); their garbage K/V writes are neutralized by
        construction: contiguous rows park their write cursor at ``smax``
        (clamped writes land in slot smax-1, which chunk prefill never
        addresses and a live row always real-overwrites before attending);
        paged rows write through a block table still pointing at the
        reserved dummy page."""
        import time
        t0 = time.perf_counter()
        sampled = sampling is not None
        dmargin = dk + 1 if dk else 0
        cache = backend.init_cache()
        paged = "k_pages" in cache
        if not paged:
            cache = dict(cache, length=jnp.full((self.max_batch,), smax,
                                                jnp.int32))
        cur = jnp.zeros((self.max_batch,), jnp.int32)
        done = jnp.ones((self.max_batch,), bool)
        sstate = None
        if sampled:
            from repro.serve.sampling import pack_params, sampling_state
            sstate = sampling_state(self.max_batch, self.cfg.vocab_size)
        outs: List[List[int]] = [[] for _ in prompts]
        queue = deque(range(len(prompts)))
        free = list(range(self.max_batch))
        active: Dict[int, int] = {}                  # slot -> request idx
        left: Dict[int, int] = {}                    # request idx -> budget
        # slot -> [ridx, prefilled_offset, grant] (insertion order = FIFO)
        pending: Dict[int, list] = {}
        stats = backend.stats
        stats.update(prefill_calls=0, packed_prefill_tokens=0,
                     prompt_tokens=0, max_prefill_call_tokens=0,
                     prefill_iters=0)
        ttft: Dict[int, float] = {}

        def bump(tokens_padded: int, tokens_real: int):
            stats["prefill_calls"] += 1
            stats["packed_prefill_tokens"] += tokens_padded
            stats["prompt_tokens"] += tokens_real
            stats["max_prefill_call_tokens"] = max(
                stats["max_prefill_call_tokens"], tokens_padded)

        def complete(slot: int, st: list, tok: int):
            nonlocal cache, cur, done, sstate
            ridx, grant = st[0], st[2]
            outs[ridx].append(tok)
            ttft[ridx] = time.perf_counter() - t0
            del pending[slot]
            if tok == self.eos_id or budgets[ridx] <= 1:
                backend.release(grant)
                free.append(slot)
                return
            cache, cur, done = backend.install(
                cache, cur, done, slot, jnp.int32(tok),
                len(prompts[ridx]), grant)
            if sampled:
                fv, iv = pack_params(sampling[ridx])
                sstate = self._sstate_admit(sstate, jnp.int32(slot), fv,
                                            iv, jnp.int32(tok))
            active[slot] = ridx
            left[ridx] = budgets[ridx] - 1

        def run_continue(slot: int, st: list) -> int:
            nonlocal cache
            ridx, off = st[0], st[1]
            p = prompts[ridx]
            c = (min(len(p) - off, prefill_chunk) if prefill_chunk > 0
                 else len(p) - off)
            cp = _bucket_len(c, 8)
            toks = np.zeros((1, cp), np.int32)
            toks[0, :c] = p[off:off + c]
            pos = off + np.arange(cp, dtype=np.int32)
            rows = np.full((cp,), backend.pad_row(), np.int32)
            cols = np.zeros((cp,), np.int32)
            rows[:c], cols[:c] = backend.token_addr(
                slot, st[2], np.arange(off, off + c, dtype=np.int64))
            cargs = (self.params, cache, jnp.asarray(toks),
                     jnp.asarray(pos)[None], jnp.asarray(rows),
                     jnp.asarray(cols), backend.kv_sel(slot, st[2]),
                     jnp.int32(c - 1))
            if sampled:
                fv, iv = pack_params(sampling[ridx])
                (nxt, _), cache = self._sample_prefill_fn("chunk", use_tt)(
                    *cargs, fv[None], iv[None])
            else:
                nxt, cache = self._prefill_continue(*cargs)
            st[1] = off + c
            bump(cp, c)
            if st[1] == len(p):
                complete(slot, st, int(jax.device_get(nxt)[0]))
            return c

        while queue or pending or active:
            # 1) slot assignment: reservation only, arrival order; a
            # deferred reservation (paged pool exhausted) is skipped, not
            # head-of-line blocking
            skipped: List[int] = []
            while queue and free:
                ridx = queue.popleft()
                if budgets[ridx] <= 0:
                    continue
                grant = backend.reserve(ridx, len(prompts[ridx]),
                                        budgets[ridx] + dmargin)
                if grant is None:
                    skipped.append(ridx)
                    stats["deferred_admissions"] += 1
                    continue
                pending[free.pop()] = [ridx, 0, grant]
            queue.extendleft(reversed(skipped))
            if not pending and not active:
                if queue:        # deferred with nothing left to retire
                    backend.starved(queue[0], blens, budgets)
                continue

            # 2) prefill: ≤ prefill_chunk prompt tokens this iteration
            # (always ≥ one chunk of progress when anything is pending) —
            # continuations first, then the packed first-chunk call
            budget = prefill_chunk if prefill_chunk > 0 else float("inf")
            spent = 0
            if pending:
                stats["prefill_iters"] += 1
            for slot, st in list(pending.items()):
                if st[1] == 0:
                    continue
                if spent >= budget:
                    break
                spent += run_continue(slot, st)
            items = []
            for slot, st in list(pending.items()):
                if st[1] != 0:
                    continue
                length = len(prompts[st[0]])
                c = (min(length, prefill_chunk) if prefill_chunk > 0
                     else length)
                if (spent > 0 or items) and spent + c > budget:
                    break
                items.append((slot, st, c))
                spent += c
            if items:
                total = sum(c for _, _, c in items)
                tp = _bucket_len(total, 8)
                toks = np.zeros((tp,), np.int32)
                # pad positions carry segment id n_items: larger than every
                # real id (keeps seg non-decreasing), matched by nothing
                seg = np.full((tp,), len(items), np.int32)
                pos = np.zeros((tp,), np.int32)
                rows = np.full((tp,), backend.pad_row(), np.int32)
                cols = np.zeros((tp,), np.int32)
                gidx = np.zeros((_bucket_len(len(items), 1),), np.int32)
                off = 0
                for i, (slot, st, c) in enumerate(items):
                    toks[off:off + c] = prompts[st[0]][:c]
                    seg[off:off + c] = i
                    pos[off:off + c] = np.arange(c)
                    rows[off:off + c], cols[off:off + c] = \
                        backend.token_addr(slot, st[2],
                                           np.arange(c, dtype=np.int64))
                    gidx[i] = off + c - 1
                    off += c
                pargs = (self.params, cache, jnp.asarray(toks)[None],
                         jnp.asarray(seg), jnp.asarray(pos)[None],
                         jnp.asarray(rows), jnp.asarray(cols),
                         jnp.asarray(gidx))
                if sampled:
                    fvp = np.zeros((gidx.shape[0], 5), np.float32)
                    fvp[:, 1] = 1.0                  # spare rows: identity
                    fvp[:, 2] = 1.0
                    ivp = np.zeros((gidx.shape[0], 2), np.int32)
                    for i, (slot, st, c) in enumerate(items):
                        f, ivv = pack_params(sampling[st[0]])
                        fvp[i], ivp[i] = np.asarray(f), np.asarray(ivv)
                    (nxt, _), cache = self._sample_prefill_fn(
                        "packed", use_tt)(*pargs, jnp.asarray(fvp),
                                          jnp.asarray(ivp))
                else:
                    nxt, cache = self._packed_prefill(*pargs)
                bump(tp, total)
                host_tok = None
                for i, (slot, st, c) in enumerate(items):
                    st[1] = c
                    if c == len(prompts[st[0]]):
                        if host_tok is None:     # one sync per packed call
                            host_tok = np.asarray(jax.device_get(nxt))
                        complete(slot, st, int(host_tok[i]))

            # 3) decode chunk + retirement (same accounting as _serve_loop)
            if not active:
                continue
            stats["peak_active"] = max(stats["peak_active"], len(active))
            if sampled:
                cur, cache, done, sstate, e_d, n_d = self._sample_chunk_fn(
                    chunk, use_tt, dk)(self.params, cache, cur, done,
                                       sstate)
                host_e = np.asarray(e_d)             # one fetch per chunk
                host_n = np.asarray(n_d)
            else:
                cur, cache, done, toks_d = self._chunk_fn(chunk)(
                    self.params, cache, cur, done)
                host_e = np.asarray(toks_d)[:, :, None]
                host_n = np.ones(host_e.shape[:2], np.int64)
            if dk:
                _bump_spec_stats(stats, host_n, active)
            retired = []
            for slot, ridx in active.items():
                left[ridx], fin = _consume_slot(host_e, host_n, slot,
                                                outs[ridx], left[ridx],
                                                self.eos_id)
                if fin:
                    retired.append(slot)
            for slot in retired:
                del active[slot]
                free.append(slot)
                done = done.at[slot].set(True)
                cache = backend.retire(cache, slot)
                if not paged:
                    # park the freed stripe's write cursor back at smax
                    # (see the loop docstring)
                    cache = dict(cache, length=cache["length"].at[slot]
                                 .set(smax))
        stats["ttft_s"] = [ttft.get(i, float("nan"))
                           for i in range(len(prompts))]
        self.serve_stats = stats
        return outs


# ---------------------------------------------------------------------------
# serve() KV backends: how cache space is reserved and admissions scatter
# ---------------------------------------------------------------------------

def _paged_unsupported_reason(cfg: ModelConfig, tp: int = 0) -> str:
    """Why the paged scheduler cannot serve this config (empty = it can).
    Its decode branch runs the flash kernel unconditionally, so it is
    only offered when the flash backend is what the contiguous engine
    would run too (same `_flash_backend` predicate — anything else, e.g.
    a pinned XLA oracle or the default xla GEMM route, would void the
    paged-vs-contiguous bit-identity contract) and when the GQA group
    passes the kernel's resident-query gate. Under the TP serving wrap
    (tp > 1) the predicate is evaluated as the shard bodies will see it —
    the live mesh alone no longer vetoes the kernel."""
    from repro.kernels.common import SKINNY_M_MAX, skinny_ok
    from repro.models.attention import _flash_backend
    if tp > 1:
        with shard_tp_ctx(tp):
            flash = _flash_backend(cfg)
    else:
        flash = _flash_backend(cfg)
    if not flash:
        return (f"flash attention backend inactive (attn_impl="
                f"{cfg.attn_impl!r}, gemm_impl={cfg.gemm_impl!r}; needs "
                "attn_impl='flash', or 'auto' with the Pallas route — "
                "single device, or per-shard under the TP serving wrap)")
    g = cfg.num_heads // max(1, cfg.num_kv_heads)
    if not skinny_ok(g, cfg.resolved_head_dim,
                     jnp.dtype(cfg.dtype).itemsize):
        return (f"GQA group size {g} exceeds the decode kernel's "
                f"resident-query gate (SKINNY_M_MAX={SKINNY_M_MAX})")
    return ""


class _ContiguousKvBackend:
    """Classic layout: every slot owns a reserved [smax] stripe of the
    shared cache. Reservations always succeed (slot availability is the
    only resource, and `_serve_loop` hands us a free slot)."""

    def __init__(self, eng: "ServeEngine", smax: int):
        self.eng = eng
        self.smax = smax
        self.stats: Dict[str, int] = {"peak_active": 0,
                                      "deferred_admissions": 0}

    def init_cache(self):
        cache = registry.init_cache(self.eng.cfg, self.eng.max_batch,
                                    self.smax)
        cache["start"] = jnp.zeros((self.eng.max_batch,), jnp.int32)
        return cache

    def reserve(self, ridx: int, blen: int, budget: int):
        return ()                                    # always grants

    def release(self, grant) -> None:
        pass

    def admit(self, cache, c1, cur, done, slot: int, tok, grant):
        return self.eng._admit(cache, c1, cur, done, jnp.int32(slot), tok)

    def retire(self, cache, slot: int):
        return cache                                 # slot stripe just idles

    def starved(self, ridx: int, blens, budgets) -> None:
        raise AssertionError("contiguous reservations cannot defer")

    # -- packed-prefill addressing (DESIGN.md §12) ------------------------

    def pad_row(self) -> int:
        """Out-of-range scatter row for packed padding tokens (dropped)."""
        return self.eng.max_batch

    def token_addr(self, slot: int, grant, pos: np.ndarray):
        """(rows, cols) scatter address for this request's token at each
        absolute position: its slot stripe, slot index = position."""
        return (np.full(pos.shape, slot, np.int32), pos.astype(np.int32))

    def kv_sel(self, slot: int, grant):
        return jnp.int32(slot)

    def install(self, cache, cur, done, slot: int, tok, length: int, grant):
        return self.eng._install(cache, cur, done, jnp.int32(slot), tok,
                                 jnp.int32(length))


class _PagedKvBackend:
    """Paged layout (DESIGN.md §10): requests reserve
    ``ceil((prompt + budget) / page)`` pages from a shared pool instead of
    an smax stripe, so a fixed HBM budget packs requests by what they
    actually use. Deferred reservations wait for retirements to free
    pages; retirement also points the slot's block table at the reserved
    dummy page so the retired-but-still-stepping row's overshoot writes
    land harmlessly instead of corrupting recycled pages."""

    def __init__(self, eng: "ServeEngine", smax: int):
        from repro.kernels.attn import paged_decode_ok
        from repro.serve.kv_cache import PageAllocator
        cfg = eng.cfg
        self.eng = eng
        self.smax = smax
        self.page = cfg.kv_page_size
        assert self.page > 0, "paged serving needs cfg.kv_page_size > 0"
        if self.page < 8:
            # the contiguous flash-decode gate (attention.py) rejects
            # sub-sublane pages; accepting them here would put the two
            # schedulers on different numeric paths
            raise ValueError(
                f"kv_page_size={self.page} below the minimum page of 8 "
                "slots (sublane quantum)")
        if not paged_decode_ok(self.page, cfg.resolved_head_dim,
                               jnp.dtype(cfg.dtype).itemsize):
            raise ValueError(
                f"kv_page_size={self.page} makes a KV page tile that "
                "cannot fit the decode kernel's VMEM budget — lower it")
        self.n_log = smax // self.page
        self.pool_pages = (eng.kv_pool_pages
                           or (eng.max_batch * self.n_log + 1))
        self.alloc = PageAllocator(self.pool_pages)
        self.slot_pages: Dict[int, List[int]] = {}   # slot -> phys pages
        self.stats: Dict[str, int] = {
            "peak_active": 0, "deferred_admissions": 0,
            "pool_pages": self.pool_pages, "page": self.page,
            "n_log": self.n_log}

    def init_cache(self):
        from repro.serve.kv_cache import init_paged_cache
        return init_paged_cache(self.eng.cfg, self.eng.max_batch,
                                self.pool_pages, self.page, self.n_log)

    def reserve(self, ridx: int, blen: int, budget: int):
        from repro.serve.kv_cache import pages_needed
        need = pages_needed(blen, budget, self.page)
        if need > self.pool_pages - 1:
            raise RuntimeError(
                f"request {ridx} needs {need} pages; pool has "
                f"{self.pool_pages - 1} usable — raise kv_pool_pages")
        return self.alloc.alloc(need)                # None = defer

    def release(self, grant: List[int]) -> None:
        self.alloc.free(grant)

    def admit(self, cache, c1, cur, done, slot: int, tok,
              grant: List[int]):
        row = np.zeros((self.n_log,), np.int32)      # tail -> dummy page
        row[:len(grant)] = grant
        self.slot_pages[slot] = grant
        return self.eng._admit_paged(cache, c1, cur, done,
                                     jnp.asarray(row), jnp.int32(slot), tok)

    def retire(self, cache, slot: int):
        self.alloc.free(self.slot_pages.pop(slot))
        # stale decode writes of this still-stepping slot must not touch
        # the recycled pages: point its table at the dummy
        cache["block_table"] = cache["block_table"].at[slot].set(0)
        return cache

    def starved(self, ridx: int, blens, budgets) -> None:
        from repro.serve.kv_cache import pages_needed
        raise RuntimeError(
            f"request {ridx} cannot be admitted: needs "
            f"{pages_needed(blens[ridx], budgets[ridx], self.page)} "
            f"pages, pool has {self.alloc.free_pages} free")

    # -- packed-prefill addressing (DESIGN.md §12) ------------------------

    def pad_row(self) -> int:
        """Out-of-range scatter row for packed padding tokens: one past
        the pool (the dummy page 0 is a real pool page — pads must not
        collide with it)."""
        return self.pool_pages

    def token_addr(self, slot: int, grant, pos: np.ndarray):
        """Physical (page, offset) per absolute position through the
        granted page list — packed prefill writes the pool directly; the
        block table only learns about these pages at install time."""
        g = np.asarray(grant, np.int64)
        return (g[pos // self.page].astype(np.int32),
                (pos % self.page).astype(np.int32))

    def kv_sel(self, slot: int, grant):
        row = np.zeros((self.n_log,), np.int32)      # tail -> dummy page
        row[:len(grant)] = grant
        return jnp.asarray(row)

    def install(self, cache, cur, done, slot: int, tok, length: int, grant):
        row = np.zeros((self.n_log,), np.int32)      # tail -> dummy page
        row[:len(grant)] = grant
        self.slot_pages[slot] = grant
        return self.eng._install_paged(cache, cur, done, jnp.asarray(row),
                                       jnp.int32(slot), tok,
                                       jnp.int32(length))
