from repro.serve.engine import (ServeEngine, make_decode_step,
                                make_prefill_step)
from repro.serve.kv_cache import (PageAllocator, init_paged_cache,
                                  pages_needed)

__all__ = ["ServeEngine", "make_decode_step", "make_prefill_step",
           "PageAllocator", "init_paged_cache", "pages_needed"]
