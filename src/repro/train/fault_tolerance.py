"""Fault-tolerance machinery for long multi-pod runs.

* PreemptionGuard — SIGTERM/SIGINT → flag checked once per step → emergency
  checkpoint before exit (maps to GCP/Borg preemption notice).
* StragglerMonitor — per-step wall-time EWMA + deviation; flags steps beyond
  ``threshold×`` the running mean (on real fleets this feeds the scheduler's
  hot-spare swap; here it logs and counts).
* retry_step — bounded retries with backoff for transient XLA/runtime errors.
* elastic re-mesh is a property of the checkpoint format (full arrays) —
  `train driver restores onto whatever mesh it was launched with`.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, List, Optional

__all__ = ["PreemptionGuard", "StragglerMonitor", "retry_step"]


class PreemptionGuard:
    """Installs signal handlers; `should_stop` flips on SIGTERM/SIGINT."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._signals = signals
        self._prev = {}
        self.should_stop = False

    def _handler(self, signum, frame):
        self.should_stop = True

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time monitor. On a fleet, `straggler_steps` triggers
    hot-spare replacement; here it is surfaced in train logs/metrics."""
    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 3

    _mean: float = 0.0
    _count: int = 0
    straggler_steps: int = dataclasses.field(default=0)
    last_flagged: Optional[int] = None
    history: List[float] = dataclasses.field(default_factory=list)

    def update(self, step: int, dt: float) -> bool:
        """Record one step time; returns True if flagged as straggler."""
        self.history.append(dt)
        self._count += 1
        if self._count <= self.warmup:
            self._mean = dt if self._count == 1 else (
                self._mean + (dt - self._mean) / self._count)
            return False
        flagged = dt > self.threshold * self._mean
        if flagged:
            self.straggler_steps += 1
            self.last_flagged = step
        else:   # stragglers don't poison the running mean
            self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
        return flagged

    @property
    def mean_step_time(self) -> float:
        return self._mean


def retry_step(fn: Callable[[], Any], retries: int = 2,
               backoff_s: float = 0.5,
               retriable=(RuntimeError,)) -> Any:
    """Run `fn`, retrying transient runtime failures (device OOM-transients,
    collective timeouts on real fleets)."""
    for attempt in range(retries + 1):
        try:
            return fn()
        except retriable:
            if attempt == retries:
                raise
            time.sleep(backoff_s * (2 ** attempt))
