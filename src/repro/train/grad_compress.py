"""Gradient compression with error feedback.

On real hardware the compressed representation rides the data-parallel
reduce-scatter (half/quarter wire bytes); under GSPMD the all-reduce is
implicit in the autodiff graph, so we model the *numerics* exactly — the
quantize→dequantize roundtrip each worker's gradient contribution undergoes —
with an error-feedback accumulator (Seide et al. / EF-SGD) so the bias is
compensated across steps. The roofline collective-bytes model in
`repro.roofline` scales DP gradient traffic by `wire_bytes_per_elem / 4`
when compression is on.

Modes: "none" | "bf16" | "int8_ef" (per-tensor symmetric INT8 + EF).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_ef_state", "compress_grads", "wire_bytes_per_elem"]


def wire_bytes_per_elem(mode: str) -> float:
    return {"none": 4.0, "bf16": 2.0, "int8_ef": 1.0}[mode]


def init_ef_state(params: Any, mode: str) -> Optional[Any]:
    if mode != "int8_ef":
        return None
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q_int8(g: jax.Array) -> jax.Array:
    """Symmetric per-tensor INT8 quantize→dequantize roundtrip."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127)
    return q * scale


def compress_grads(grads: Any, ef: Optional[Any], mode: str
                   ) -> Tuple[Any, Optional[Any]]:
    """Returns (decompressed grads as seen post-all-reduce, new EF state)."""
    if mode == "none":
        return grads, ef
    if mode == "bf16":
        return jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads), ef
    if mode == "int8_ef":
        def one(g, e):
            target = g.astype(jnp.float32) + e
            sent = _q_int8(target)
            return sent, target - sent
        out = jax.tree_util.tree_map(one, grads, ef)
        flat, tdef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        sent = jax.tree_util.tree_unflatten(tdef, [f[0] for f in flat])
        new_ef = jax.tree_util.tree_unflatten(tdef, [f[1] for f in flat])
        return sent, new_ef
    raise ValueError(mode)
