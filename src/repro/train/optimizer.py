"""Optimizers from scratch (no optax in this environment): AdamW, Adafactor
(factored second moment — required to fit arctic-480b / kimi-k2 optimizer
state on 512 chips, DESIGN.md §6), SGD-momentum; warmup+cosine LR schedule;
global-norm clipping; optional DBB-mask-frozen updates."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig

__all__ = ["make_optimizer", "lr_schedule", "global_norm", "clip_by_global_norm"]


def lr_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    base, warm, total = cfg.learning_rate, cfg.warmup_steps, max(cfg.steps, 1)

    def fn(step):
        step = step.astype(jnp.float32)
        warm_lr = base * (step + 1) / max(warm, 1)
        t = jnp.clip((step - warm) / max(total - warm, 1), 0.0, 1.0)
        cos_lr = base * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warm, warm_lr, cos_lr)

    return fn


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros((), jnp.float32)


def clip_by_global_norm(tree: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _adamw(cfg: TrainConfig, b1=0.9, b2=0.95, eps=1e-8):
    sched = lr_schedule(cfg)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr = sched(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            u = mh / (jnp.sqrt(vh) + eps)
            if p.ndim >= 2:          # decoupled decay, matrices only
                u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     params)
        flat, tdef = jax.tree_util.tree_flatten(out, is_leaf=lambda x:
                                                isinstance(x, tuple))
        ups = jax.tree_util.tree_unflatten(tdef, [f[0] for f in flat])
        m = jax.tree_util.tree_unflatten(tdef, [f[1] for f in flat])
        v = jax.tree_util.tree_unflatten(tdef, [f[2] for f in flat])
        return ups, {"m": m, "v": v}

    return init, update


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored for >=2D leaves
# ---------------------------------------------------------------------------

def _adafactor(cfg: TrainConfig, eps1=1e-30, eps2=1e-3, clip_thr=1.0,
               beta2_cap=0.999):
    sched = lr_schedule(cfg)

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"s": jax.tree_util.tree_map(st, params,
                                            is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-0.8)
        beta2 = jnp.minimum(beta2, beta2_cap)
        lr = sched(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if p.ndim >= 2:
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True),
                                    eps1)[..., None]          # [..., 1, 1]
                u = (g * jax.lax.rsqrt(vr[..., None] / denom)
                     * jax.lax.rsqrt(vc[..., None, :]))
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v)
                ns = {"v": v}
            # update clipping by RMS
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_thr)
            # relative step size
            p32 = p.astype(jnp.float32)
            scale = jnp.maximum(eps2, jnp.sqrt(jnp.mean(p32 * p32)))
            upd_ = -lr * scale * u
            if p.ndim >= 2 and cfg.weight_decay:
                upd_ = upd_ - lr * cfg.weight_decay * p32
            return upd_.astype(p.dtype), ns

        is_state = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        out = jax.tree_util.tree_map(
            upd, grads, state["s"], params,
            is_leaf=lambda x: is_state(x))
        flat, tdef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        ups = jax.tree_util.tree_unflatten(tdef, [f[0] for f in flat])
        ns = jax.tree_util.tree_unflatten(tdef, [f[1] for f in flat])
        return ups, {"s": ns}

    return init, update


# ---------------------------------------------------------------------------
# SGD-momentum
# ---------------------------------------------------------------------------

def _sgd(cfg: TrainConfig, momentum=0.9):
    sched = lr_schedule(cfg)

    def init(params):
        return {"mom": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = sched(step)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            u = -lr * (m + cfg.weight_decay * p.astype(jnp.float32)
                       if p.ndim >= 2 else m)
            return u.astype(p.dtype), m

        out = jax.tree_util.tree_map(upd, grads, state["mom"], params)
        flat, tdef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        ups = jax.tree_util.tree_unflatten(tdef, [f[0] for f in flat])
        m = jax.tree_util.tree_unflatten(tdef, [f[1] for f in flat])
        return ups, {"mom": m}

    return init, update


def make_optimizer(cfg: TrainConfig):
    """Returns (init_fn, update_fn): update(grads, state, params, step) ->
    (updates, new_state). Updates are *deltas* (add to params)."""
    if cfg.optimizer == "adamw":
        return _adamw(cfg)
    if cfg.optimizer == "adafactor":
        return _adafactor(cfg)
    if cfg.optimizer == "sgd":
        return _sgd(cfg)
    raise ValueError(cfg.optimizer)
