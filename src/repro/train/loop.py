"""Train step builder: DBB straight-through projection → forward →
vocab-parallel CE → grads (microbatched via lax.scan) → clip → optional
compression → optimizer update.

The DBB density bound `nnz` is a static argument (top_k needs a static k);
the driver re-builds the step when the anneal schedule moves it — at most
`block - nnz` retraces over a run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.core.sparsity import apply_dbb_to_tree
from repro.dist.collectives import cross_entropy
from repro.dist.mesh_ctx import current_mesh, data_axes_of, shard_hint
from repro.models import registry
from repro.train import optimizer as opt_mod
from repro.train.grad_compress import compress_grads, init_ef_state

__all__ = ["TrainState", "init_train_state", "make_train_step",
           "make_eval_step", "make_loss_fn"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    ef: Any                      # error-feedback state or None
    step: jax.Array              # scalar int32


def init_train_state(key, run_cfg: RunConfig) -> TrainState:
    params = registry.init_params(key, run_cfg.model)
    init_fn, _ = opt_mod.make_optimizer(run_cfg.train)
    return TrainState(
        params=params,
        opt_state=init_fn(params),
        ef=init_ef_state(params, run_cfg.train.grad_compress),
        step=jnp.zeros((), jnp.int32),
    )


def _classification_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             labels[:, None], axis=-1)[:, 0]
    return (lse - ll).mean()


def make_loss_fn(cfg: ModelConfig, nnz: Optional[int] = None,
                 project_dbb: bool = True
                 ) -> Callable[[Any, Dict], Tuple[jax.Array, Dict]]:
    """loss_fn(params, batch) -> (loss, metrics). Applies the DBB STE
    (unless the caller projects once outside, §Perf iteration 9)."""

    # training always differentiates the forward; the fused Pallas GEMMs
    # (gemm_impl="pallas") have no VJP and would also drop the named remat
    # saves — force the XLA route for the loss graph (DESIGN.md §7)
    if cfg.gemm_impl != "xla":
        cfg = cfg.replace(gemm_impl="xla")

    def loss_fn(params, batch):
        p_eff = (apply_dbb_to_tree(params, cfg.dbb, nnz=nnz)
                 if project_dbb else params)
        if cfg.family == "cnn":
            logits, _ = registry.forward(p_eff, cfg, batch)
            loss = _classification_ce(logits, batch["labels"])
            acc = (logits.argmax(-1) == batch["labels"]).mean()
            return loss, {"loss": loss, "acc": acc}
        hidden, aux = registry.forward(p_eff, cfg, batch)
        w_head = registry.lm_head_weight(p_eff, cfg)
        loss = cross_entropy(hidden, w_head, batch["labels"],
                             mask=batch.get("loss_mask"),
                             vocab_parallel=cfg.parallel != "dp")
        total = loss + cfg.moe.aux_loss_weight * aux
        return total, {"loss": loss, "aux": aux}

    return loss_fn


def _microbatch(batch: Dict, m: int) -> Dict:
    def re(x):
        b = x.shape[0]
        return x.reshape(m, b // m, *x.shape[1:])
    return {k: re(v) for k, v in batch.items()}


def make_train_step(run_cfg: RunConfig, nnz: Optional[int] = None
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    cfg = run_cfg.model
    tcfg = run_cfg.train
    # DBB projection is hoisted out of the (micro-batched) grad graph:
    # differentiating the loss at the *projected* params and applying the
    # update to the dense masters IS the straight-through estimator, and
    # projects once per step instead of once per microbatch inside the
    # backward graph (§Perf iteration 9: −27 GB temp on qwen train_4k).
    loss_fn = make_loss_fn(cfg, nnz=nnz, project_dbb=False)
    _, update_fn = opt_mod.make_optimizer(tcfg)
    sched = opt_mod.lr_schedule(tcfg)

    def grads_of(params, batch):
        m = tcfg.microbatches
        if m <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics

        mb = _microbatch(batch, m)

        def body(carry, mbatch):
            g_acc, met_acc = carry
            mbatch = {k: shard_hint(v, ("pod", "data"),
                                    *(None,) * (v.ndim - 1))
                      for k, v in mbatch.items()}
            (_, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mbatch)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            met_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), met_acc, met)
            return (g_acc, met_acc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        met0 = {"loss": jnp.zeros((), jnp.float32),
                "aux": jnp.zeros((), jnp.float32)} if cfg.family != "cnn" \
            else {"loss": jnp.zeros((), jnp.float32),
                  "acc": jnp.zeros((), jnp.float32)}
        (grads, mets), _ = jax.lax.scan(body, (g0, met0), mb)
        inv = 1.0 / m
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        mets = jax.tree_util.tree_map(lambda x: x * inv, mets)
        return grads, mets

    def train_step(state: TrainState, batch: Dict
                   ) -> Tuple[TrainState, Dict]:
        p_eff = apply_dbb_to_tree(state.params, cfg.dbb, nnz=nnz,
                                  straight_through=False)
        mesh = current_mesh()
        specs = None
        if mesh is not None:
            from repro.dist.sharding import param_specs
            specs = param_specs(state.params, mesh, cfg)

        def constrain(tree):
            return jax.tree_util.tree_map(
                lambda t, s: jax.lax.with_sharding_constraint(
                    t, jax.NamedSharding(mesh, s))
                if hasattr(t, "shape") else t,
                tree, specs)

        if specs is not None and p_eff is not state.params:
            # keep the projection sharded like the masters — without the
            # constraint GSPMD gathers the model axis to run top_k
            # (§Perf iteration 10a)
            p_eff = constrain(p_eff)
        grads, metrics = grads_of(p_eff, batch)
        if specs is not None:
            # grads resident like the params: lets XLA lower the data-axis
            # gradient reduction of FSDP-sharded leaves as reduce-scatter
            # instead of all-reduce + slice (§Perf iteration 13 — the
            # expert-grad reductions were 4.2 GB/layer at full d on kimi)
            grads = constrain(grads)
        grads, gnorm = opt_mod.clip_by_global_norm(grads, tcfg.grad_clip)
        grads, new_ef = compress_grads(grads, state.ef, tcfg.grad_compress)
        updates, new_opt = update_fn(grads, state.opt_state, state.params,
                                     state.step)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32)
                          + u.astype(jnp.float32)).astype(p.dtype),
            state.params, updates)
        metrics = dict(metrics, grad_norm=gnorm, lr=sched(state.step))
        return TrainState(params=new_params, opt_state=new_opt, ef=new_ef,
                          step=state.step + 1), metrics

    return train_step


def make_eval_step(run_cfg: RunConfig, nnz: Optional[int] = None):
    loss_fn = make_loss_fn(run_cfg.model, nnz=nnz)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
