"""Sharded-training checkpointing: atomic, mesh-shape-agnostic, resumable.

Format: one directory per step, one ``.npy`` per pytree leaf (leaf order =
``jax.tree_util.tree_flatten`` order, which is deterministic for a fixed
config) + ``meta.json``. Writes go to a temp directory that is ``os.replace``d
into place — a crash mid-save never corrupts the latest checkpoint.

Checkpoints store *full* (unsharded) arrays: restore can re-shard onto any
mesh (elastic scaling), at the cost of host-side gathers on save. On a real
multi-host deployment only process 0 writes (`should_write`); per-shard
streaming writes are the documented follow-up in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "available_steps",
           "CheckpointManager"]

_META = "meta.json"


def should_write() -> bool:
    return jax.process_index() == 0


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def save(root: str, step: int, state: Any,
         extra_meta: Optional[dict] = None, keep_last: int = 3) -> str:
    """Atomically persist `state` (any pytree of arrays) at `step`."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f".tmp_step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    shapes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        shapes.append([list(arr.shape), str(arr.dtype)])
    meta = {"step": step, "num_leaves": len(leaves), "shapes": shapes,
            "treedef": str(treedef)}
    if extra_meta:
        meta["extra"] = extra_meta
    with open(os.path.join(tmp, _META), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    final = _step_dir(root, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _prune(root, keep_last)
    return final


def _prune(root: str, keep_last: int) -> None:
    steps = available_steps(root)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


def available_steps(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and os.path.exists(
                os.path.join(root, name, _META)):
            out.append(int(name[len("step_"):]))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    steps = available_steps(root)
    return steps[-1] if steps else None


def restore(root: str, template: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[Any, dict]:
    """Load a checkpoint into the structure of `template`.

    `shardings`: optional pytree of Sharding matching template — leaves are
    device_put with them (elastic re-mesh: any mesh works, the stored arrays
    are unsharded)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, _META)) as f:
        meta = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if meta["num_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {meta['num_leaves']} leaves, template has "
            f"{len(leaves)} — config mismatch")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (tleaf, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        want = tuple(getattr(tleaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: stored {arr.shape} != {want}")
        dtype = getattr(tleaf, "dtype", arr.dtype)
        arr = arr.astype(dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), meta


@dataclasses.dataclass
class CheckpointManager:
    """save_every-driven manager with emergency-save support."""
    root: str
    save_every: int = 100
    keep_last: int = 3

    def maybe_save(self, step: int, state: Any,
                   extra_meta: Optional[dict] = None,
                   force: bool = False) -> Optional[str]:
        if not should_write():
            return None
        if force or (self.save_every > 0 and step > 0
                     and step % self.save_every == 0):
            return save(self.root, step, state, extra_meta, self.keep_last)
        return None
