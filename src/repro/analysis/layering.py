"""Repo-wide import-layering pass (DESIGN.md §13).

Promotes the old single-test grep (`test_dispatch.py`) into a linter
rule over all of ``src/repro``:

  * **kernels stay at the bottom**: ``repro.kernels.*`` must not import
    the upper layers (``models`` / ``serve`` / ``train`` / ``launch`` /
    ``data``). One documented exception: ``kernels/dispatch.py`` front
    doors delegate the attention *implementations* back to
    ``models.attention`` (the registry owns the decision, the model
    layer owns the math).
  * **kernel internals go through the front doors**: outside
    ``kernels/`` (and this analysis package), the kernel subsystem
    packages (``sta_gemm`` / ``dbb_gemm`` / ``skinny`` / ``conv_gemm`` /
    ``attn`` / ``epilogue``) are private — model/serve layers import
    ``repro.kernels`` root, ``dispatch``, ``common`` or ``autotune``.
    Documented exceptions: the attention/conv model layers and the
    serving engine reach named ``attn`` / ``conv_gemm.ref`` helpers
    (wrappers and reference oracles, not kernels).

Only genuine ``import`` / ``from`` statements count — mentions in
docstrings or comments don't trip the pass.
"""
from __future__ import annotations

import os
import re
from typing import Dict, List, Sequence, Tuple

from repro.analysis.contracts import Violation

__all__ = ["check", "LayerRule", "DEFAULT_RULES"]

_IMPORT_RE = re.compile(
    r"^\s*(?:from\s+(?P<from>[\w.]+)\s+import|import\s+(?P<mod>[\w.]+))")


class LayerRule:
    """One layering rule: files under ``scope`` must not import modules
    matching ``banned`` (regex on the dotted module path), except the
    (file-suffix → allowed-module-prefixes) pairs in ``allow``."""

    def __init__(self, name: str, scope: str, banned: str,
                 allow: Dict[str, Sequence[str]] = (), describe: str = ""):
        self.name = name
        self.scope = scope
        self.banned = re.compile(banned)
        self.allow = dict(allow or {})
        self.describe = describe

    def allowed(self, rel: str, module: str) -> bool:
        for pat, prefixes in self.allow.items():
            # trailing-separator patterns match whole directories,
            # otherwise match the file path suffix
            hit = (rel.startswith(pat) if pat.endswith(os.sep)
                   else rel.endswith(pat))
            if hit and any(module == p or module.startswith(p + ".")
                           for p in prefixes):
                return True
        return False


DEFAULT_RULES = (
    LayerRule(
        name="kernels-no-upper-layers",
        scope=os.path.join("repro", "kernels"),
        banned=r"^repro\.(models|serve|train|launch|data)(\.|$)",
        allow={
            # dispatch front doors delegate attention impls to the model
            # layer — the one sanctioned upward edge
            os.path.join("kernels", "dispatch.py"): ("repro.models",),
        },
        describe="kernels/ never imports models/ serve/ train/ launch/ "
                 "data/"),
    LayerRule(
        name="kernel-internals-private",
        scope="repro",
        banned=r"^repro\.kernels\.(sta_gemm|dbb_gemm|skinny|conv_gemm"
               r"|attn|epilogue)(\.|$)",
        allow={
            # kernels may use their own internals, and the analysis
            # package reads the contract/ops modules by design
            os.path.join("repro", "kernels") + os.sep: ("repro.kernels",),
            os.path.join("repro", "analysis") + os.sep: ("repro.kernels",),
            # sanctioned named helpers (wrappers / reference oracles)
            os.path.join("models", "attention.py"): ("repro.kernels.attn",),
            os.path.join("models", "transformer.py"):
                ("repro.kernels.attn.ref",),
            os.path.join("models", "cnn.py"): ("repro.kernels.conv_gemm.ref",),
            os.path.join("serve", "engine.py"): ("repro.kernels.attn",),
            os.path.join("launch", "serve.py"): ("repro.kernels.attn",),
        },
        describe="kernel subsystem packages are private — go through "
                 "repro.kernels / dispatch / common / autotune"),
)


def _scan_imports(path: str) -> List[Tuple[int, str]]:
    """(lineno, dotted module) for every import statement in the file."""
    out = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            m = _IMPORT_RE.match(line)
            if m:
                out.append((lineno, m.group("from") or m.group("mod")))
    return out


def check(src_root: str, rules: Sequence[LayerRule] = DEFAULT_RULES
          ) -> Tuple[int, List[Violation]]:
    """Scan ``src_root`` (the directory containing ``repro/``)."""
    out: List[Violation] = []
    checked = 0
    for dirpath, _, files in os.walk(os.path.join(src_root, "repro")):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, src_root)
            checked += 1
            imports = None
            for rule in rules:
                if rule.scope and not rel.startswith(rule.scope + os.sep):
                    continue
                if imports is None:
                    imports = _scan_imports(path)
                for lineno, module in imports:
                    if not rule.banned.match(module):
                        continue
                    if rule.allowed(rel, module):
                        continue
                    out.append(Violation(
                        pass_name="layering", code=rule.name,
                        subject=f"{rel}:{lineno}",
                        message=f"imports {module} ({rule.describe})"))
    return checked, out
