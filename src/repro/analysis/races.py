"""Pass 2 — grid revisit / race analysis (DESIGN.md §13).

A grid dim that an output block's index map *ignores* revisits that
block once per step of the dim. Revisiting is how output-stationary
accumulation works (the K grid dim of every GEMM kernel here), but it is
only safe under the full discipline:

  * the contract must *declare* the dim as an accumulation dim
    (``acc_dims``) — an undeclared revisit is an unintended overwrite;
  * the kernel must guard accumulator init on the first visit and the
    final store on the last visit (``pl.when`` — ``guarded_init`` /
    ``guarded_store``);
  * the dim's ``dimension_semantics`` must be ``"arbitrary"`` —
    declaring it ``"parallel"`` tells Mosaic the visits are reorderable
    or concurrent, a read-modify-write race on the block.

The inverse is also checked: a declared acc dim that no output is
actually revisited over is dead declaration drift. Blocks declared
``resident`` must really be grid-constant.
"""
from __future__ import annotations

from typing import List, Sequence, Set, Tuple

from repro.analysis.contracts import (BlockDecl, KernelContract, Violation)

__all__ = ["ignored_dims", "check_contracts"]


def _eval_map(blk: BlockDecl, ids: Sequence[int]) -> Tuple[int, ...]:
    idx = blk.index_map(*ids)
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(i) for i in idx)


def ignored_dims(blk: BlockDecl, grid: Sequence[int]) -> Set[int]:
    """Grid dims (with extent > 1) whose value never changes the block
    index. Probed from two base points (all-low / all-high) so a map
    that varies only jointly with other dims is still seen as varying."""
    out: Set[int] = set()
    lo = [0] * len(grid)
    hi = [g - 1 for g in grid]
    for d, extent in enumerate(grid):
        if extent <= 1:
            continue
        varies = False
        for base in (lo, hi):
            ids = list(base)
            ids[d] = 0
            first = _eval_map(blk, ids)
            for v in range(1, extent):
                ids[d] = v
                if _eval_map(blk, ids) != first:
                    varies = True
                    break
            if varies:
                break
        if not varies:
            out.add(d)
    return out


def check_contracts(contracts: Sequence[KernelContract]
                    ) -> Tuple[int, List[Violation]]:
    out: List[Violation] = []
    for c in contracts:
        revisit_union: Set[int] = set()
        for blk in c.outputs:
            rd = ignored_dims(blk, c.grid)
            revisit_union |= rd
            undeclared = rd - set(c.acc_dims)
            if undeclared:
                out.append(Violation(
                    pass_name="races", code="undeclared-accumulation",
                    subject=f"{c.name}:{blk.name}",
                    message=f"output revisited over grid dims "
                            f"{sorted(undeclared)} not declared in "
                            f"acc_dims {list(c.acc_dims)}"))
            if rd and not (c.guarded_init and c.guarded_store):
                out.append(Violation(
                    pass_name="races", code="unguarded-accumulation",
                    subject=f"{c.name}:{blk.name}",
                    message="revisited output without pl.when-guarded "
                            "init + final store "
                            f"(init={c.guarded_init}, "
                            f"store={c.guarded_store})"))
            for d in sorted(rd):
                if (d < len(c.dimension_semantics)
                        and c.dimension_semantics[d] != "arbitrary"):
                    out.append(Violation(
                        pass_name="races", code="race",
                        subject=f"{c.name}:{blk.name}",
                        message=f"grid dim {d} revisits this output but "
                                f"is declared "
                                f"{c.dimension_semantics[d]!r} — "
                                f"read-modify-write order is not "
                                f"guaranteed (must be 'arbitrary')"))
        dead = set(c.acc_dims) - revisit_union
        # acc dims with grid extent 1 revisit trivially; only flag dims
        # the kernel actually iterates
        dead = {d for d in dead if d < len(c.grid) and c.grid[d] > 1}
        if dead:
            out.append(Violation(
                pass_name="races", code="dead-acc-declaration",
                subject=c.name,
                message=f"declared acc_dims {sorted(dead)} revisit no "
                        f"output block"))
        for blk in c.inputs + c.outputs:
            if blk.resident:
                live = {d for d, g in enumerate(c.grid) if g > 1}
                if live - ignored_dims(blk, c.grid):
                    out.append(Violation(
                        pass_name="races", code="not-resident",
                        subject=f"{c.name}:{blk.name}",
                        message="block declared resident but its index "
                                "map varies with the grid"))
        if len(c.dimension_semantics) != len(c.grid):
            out.append(Violation(
                pass_name="races", code="semantics-arity",
                subject=c.name,
                message=f"dimension_semantics rank "
                        f"{len(c.dimension_semantics)} != grid rank "
                        f"{len(c.grid)}"))
    return len(contracts), out
