"""Pass 1 — VMEM budget analysis (DESIGN.md §13).

Two obligations, checked in both directions against the dispatch guards:

  * every contract instance the guard *admitted* must actually fit: its
    worst-case residency (operand + output blocks + scratch + declared
    body intermediates) within ``vmem_budget``, and its grid-constant
    resident blocks within ``resident_budget`` when one is declared;
  * every instance the guard rejected *for VMEM reasons*
    (``vmem_reject``) must actually not fit — a rejected instance whose
    residency satisfies every declared budget is dead headroom: the
    guard drifted conservative and turns away work the kernel could run.

Plus a source-level check that the headroom fractions stay *named*:
``VMEM_BYTES // n`` literals may appear only where the named constants
(`KERNEL_VMEM_BUDGET`, `SKINNY_RESIDENT_BUDGET`) are defined, so guards
can't quietly fork their own fraction again.
"""
from __future__ import annotations

import os
import re
from typing import List, Sequence, Tuple

from repro.analysis.contracts import KernelContract, Violation

__all__ = ["check_contracts", "check_headroom_constants"]

# files allowed to spell a raw VMEM fraction: the definition sites
_FRACTION_DEF_SITES = (
    os.path.join("core", "sta.py"),         # KERNEL_VMEM_BUDGET
    os.path.join("kernels", "common.py"),   # SKINNY_RESIDENT_BUDGET
)
_FRACTION_RE = re.compile(r"VMEM_BYTES\s*//\s*\d")


def check_contracts(contracts: Sequence[KernelContract]
                    ) -> Tuple[int, List[Violation]]:
    out: List[Violation] = []
    for c in contracts:
        res = c.residency_bytes()
        rb = c.resident_bytes()
        over = []
        if c.vmem_budget and res > c.vmem_budget:
            over.append(f"residency {res} > budget {c.vmem_budget}")
        if c.resident_budget and rb > c.resident_budget:
            over.append(f"resident blocks {rb} > resident budget "
                        f"{c.resident_budget}")
        if c.admitted and over:
            out.append(Violation(
                pass_name="vmem", code="vmem-overflow", subject=c.name,
                message="guard admits an instance that does not fit: "
                        + "; ".join(over)))
        if (not c.admitted) and c.vmem_reject and not over:
            out.append(Violation(
                pass_name="vmem", code="dead-headroom", subject=c.name,
                message=f"guard rejects for VMEM but residency {res} "
                        f"(resident {rb}) satisfies every declared "
                        f"budget — conservative drift"))
        if not c.vmem_budget:
            out.append(Violation(
                pass_name="vmem", code="no-budget", subject=c.name,
                message="contract declares no vmem_budget"))
    return len(contracts), out


def check_headroom_constants(src_root: str) -> Tuple[int, List[Violation]]:
    """Raw ``VMEM_BYTES // n`` fractions outside the definition sites."""
    out: List[Violation] = []
    checked = 0
    for dirpath, _, files in os.walk(src_root):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, src_root)
            checked += 1
            if any(rel.endswith(site) for site in _FRACTION_DEF_SITES):
                continue
            with open(path, encoding="utf-8") as f:
                for lineno, line in enumerate(f, 1):
                    if _FRACTION_RE.search(line):
                        out.append(Violation(
                            pass_name="vmem",
                            code="raw-headroom-fraction",
                            subject=f"{rel}:{lineno}",
                            message="raw VMEM_BYTES fraction — use "
                                    "KERNEL_VMEM_BUDGET / "
                                    "SKINNY_RESIDENT_BUDGET from "
                                    "kernels.common"))
    return checked, out
