"""Pass 6 — per-shard VMEM / route survival under tensor parallelism
(DESIGN.md §14).

The TP serving wrap runs every Pallas kernel on *local* shapes: column
splits hand the kernel N/tp, row splits K/tp. The dispatch guards are the
only thing standing between the wrap and a per-shard VMEM overflow, so
their sharded-spec answers must be consistent with what the kernel will
actually be invoked on. Two obligations, swept over the matmul sweep ×
tp ∈ {2, 4, 8} × both shard layouts:

  * ``tp-vmem-overflow`` — a guard admits a TP-sharded spec but rejects
    the equivalent *local* spec (same dims `_shard_dims` reports, tp=1).
    The shard body will invoke the kernel on exactly those local dims, so
    the admission is a per-shard budget violation waiting to lower.
  * ``tp-route-loss`` — a guard rejects a TP-sharded spec whose local
    shape it admits, for a reason that is not a divisibility split.
    Shrinking an axis by tp never grows residency, so a non-split
    rejection means the guard consulted global dims somewhere — dead
    per-shard headroom (the bug class satellites 1's misleading guard
    strings used to hide).

Only the matmul domain is swept: attention shards KV *heads*, which the
(t, s, d)-shaped attention specs don't carry, and conv never rides the
TP wrap (cnn family is excluded from it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.analysis.contracts import Violation

__all__ = ["check_registry", "TP_SWEEP"]

TP_SWEEP = (2, 4, 8)

# rejection reasons that legitimately differ between sharded and local
# specs: the declared axis simply doesn't divide tp (no local instance
# exists at all, so there is nothing to lose)
_SPLIT_MARKERS = ("unsupported axis split", "splits inside a block")


def check_registry(routes_by_domain: Dict[str, Dict],
                   specs_by_domain: Dict[str, Sequence],
                   tps: Sequence[int] = TP_SWEEP,
                   ) -> Tuple[int, List[Violation]]:
    out: List[Violation] = []
    flagged = set()
    checked = 0
    table = routes_by_domain.get("matmul", {})
    specs = [s for s in specs_by_domain.get("matmul", ())
             if getattr(s, "pallas", False)]
    if not table or not specs:
        return 0, out
    from repro.kernels.dispatch import _shard_dims

    for spec in specs:
        for tp in tps:
            # column-parallel (N split, no boundary collective declared)
            # and row-parallel (K split behind an all-reduce) layouts
            for coll in ("", "all-reduce"):
                sharded = dataclasses.replace(spec, tp=tp, collective=coll)
                m, k, n = _shard_dims(sharded)
                local = dataclasses.replace(spec, m=m, k=k, n=n)
                checked += 1
                for name, route in table.items():
                    g_sh = route.guard(sharded)
                    g_loc = route.guard(local)
                    layout = "row" if coll else "column"
                    if g_sh == "" and g_loc != "":
                        key = (name, "tp-vmem-overflow")
                        if key in flagged:
                            continue
                        flagged.add(key)
                        out.append(Violation(
                            pass_name="tp-vmem", code="tp-vmem-overflow",
                            subject=f"matmul:{name}",
                            message=f"guard admits the tp={tp} "
                                    f"{layout}-sharded instance of m="
                                    f"{spec.m} k={spec.k} n={spec.n} but "
                                    f"rejects its local shape m={m} k={k} "
                                    f"n={n}: {g_loc}"))
                    elif (g_sh != "" and g_loc == ""
                          and not any(t in g_sh for t in _SPLIT_MARKERS)):
                        key = (name, "tp-route-loss")
                        if key in flagged:
                            continue
                        flagged.add(key)
                        out.append(Violation(
                            pass_name="tp-vmem", code="tp-route-loss",
                            subject=f"matmul:{name}",
                            message=f"guard rejects the tp={tp} "
                                    f"{layout}-sharded instance of m="
                                    f"{spec.m} k={spec.k} n={spec.n} "
                                    f"(\"{g_sh}\") although its local "
                                    f"shape m={m} k={k} n={n} is admitted "
                                    f"— guard consults global dims"))
    return checked, out
