"""``python -m repro.analysis.lint`` — run the static verifier.

Default: all contracts from the kernel packages' ``contract`` modules,
the repo materialization checks, the real dispatch registry, and the
repo-wide source passes (headroom constants, import layering). Exit 0
when clean, 1 when any pass reports a violation.

``--contracts MODULE`` swaps the inputs for a module (dotted path or
``.py`` file) exporting any of ``CONTRACTS`` (list of KernelContract),
``MATERIALIZATION_CHECKS``, ``ROUTES`` + ``SPECS`` (dicts keyed by
domain); passes without input are skipped, as are the repo-wide source
scans. This is how the known-bad fixture kernels under
``tests/fixtures/`` prove each pass catches its bug class.

``--json PATH`` writes the machine-readable report (CI artifact).
"""
from __future__ import annotations

import argparse
import importlib
import importlib.util
import json
import os
import sys
from typing import Any, Dict, List, Optional

from repro.analysis import (bounds, dispatch_check, layering, races,
                            tp_vmem, vmem)
from repro.analysis import materialize
from repro.analysis.contracts import Violation, all_contracts

__all__ = ["run", "main"]


def _load_module(spec: str):
    if spec.endswith(".py"):
        name = os.path.splitext(os.path.basename(spec))[0]
        modspec = importlib.util.spec_from_file_location(name, spec)
        mod = importlib.util.module_from_spec(modspec)
        modspec.loader.exec_module(mod)
        return mod
    return importlib.import_module(spec)


def _src_root() -> str:
    # .../src/repro/analysis/lint.py → .../src
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def run(contracts_module: Optional[str] = None) -> Dict[str, Any]:
    """Execute every pass; returns the JSON-able report."""
    repo_mode = contracts_module is None
    if repo_mode:
        contracts = all_contracts()
        checks = materialize.repo_checks()
        from repro.kernels import dispatch
        routes = {d: dispatch.routes_for(d) for d in dispatch.DOMAINS}
        specs = dispatch_check.default_specs()
    else:
        mod = _load_module(contracts_module)
        contracts = list(getattr(mod, "CONTRACTS", ()))
        checks = list(getattr(mod, "MATERIALIZATION_CHECKS", ()))
        routes = dict(getattr(mod, "ROUTES", {}))
        specs = dict(getattr(mod, "SPECS", {}))

    passes: Dict[str, Dict[str, Any]] = {}

    def record(name: str, checked: int, violations: List[Violation],
               skipped: bool = False) -> None:
        passes[name] = {
            "checked": checked, "skipped": skipped,
            "violations": [v.as_dict() for v in violations]}

    if contracts:
        n, v = vmem.check_contracts(contracts)
        if repo_mode:
            n2, v2 = vmem.check_headroom_constants(_src_root())
            n, v = n + n2, v + v2
        record("vmem", n, v)
        record("races", *races.check_contracts(contracts))
        record("bounds", *bounds.check_contracts(contracts))
    else:
        record("vmem", 0, [], skipped=True)
        record("races", 0, [], skipped=True)
        record("bounds", 0, [], skipped=True)

    if checks:
        record("materialize", *materialize.run_checks(checks))
    else:
        record("materialize", 0, [], skipped=True)

    if routes and specs:
        record("dispatch", *dispatch_check.check_registry(routes, specs))
        record("tp-vmem", *tp_vmem.check_registry(routes, specs))
    else:
        record("dispatch", 0, [], skipped=True)
        record("tp-vmem", 0, [], skipped=True)

    if repo_mode:
        record("layering", *layering.check(_src_root()))
    else:
        record("layering", 0, [], skipped=True)

    total = sum(len(p["violations"]) for p in passes.values())
    return {"ok": total == 0, "violation_count": total,
            "contracts": [c.name for c in contracts], "passes": passes}


def _render(report: Dict[str, Any]) -> str:
    lines = []
    for name, p in report["passes"].items():
        if p["skipped"]:
            lines.append(f"  {name:<12} skipped (no input)")
            continue
        n_v = len(p["violations"])
        status = "OK" if n_v == 0 else f"{n_v} violation(s)"
        lines.append(f"  {name:<12} checked {p['checked']:<4} {status}")
        for v in p["violations"]:
            lines.append(f"    [{v['code']}] {v['subject']}")
            lines.append(f"        {v['message']}")
    verdict = ("clean" if report["ok"]
               else f"{report['violation_count']} violation(s)")
    lines.append(f"repro.analysis.lint: {verdict}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static kernel-contract verifier (DESIGN.md §13)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the JSON report here")
    ap.add_argument("--contracts", metavar="MODULE",
                    help="dotted module or .py file supplying CONTRACTS/"
                         "MATERIALIZATION_CHECKS/ROUTES+SPECS instead of "
                         "the repo's own")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the human-readable report")
    args = ap.parse_args(argv)

    report = run(contracts_module=args.contracts)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2)
    if not args.quiet:
        print(_render(report))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
