"""Materialization lint: the shared jaxpr-walk API (DESIGN.md §13 pass 4).

The paper's memory claims are *absence* claims — the [B,H,T,S] attention
score tensor, the decompressed dense DBB weight, and the [M,K] im2col
patch matrix must never exist as whole arrays. These are provable at
trace time: walk every intermediate aval of the traced computation
(recursing into pallas/scan/cond sub-jaxprs, whose avals are the
block-sized VMEM refs) and bound the largest one. This module is the one
implementation of that walk — tests and benchmarks import it instead of
carrying private copies.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Sequence, Tuple

__all__ = ["iter_avals", "trace_avals", "max_intermediate_elems",
           "max_intermediate_bytes", "assert_no_intermediate_larger_than",
           "MaterializationCheck", "run_checks"]


def iter_avals(jaxpr) -> Iterator:
    """Yield the output aval of every equation in ``jaxpr``, recursing
    into sub-jaxprs held in equation params (pallas kernel bodies,
    scan/while/cond/jit bodies, custom_vjp branches)."""
    from jax.core import ClosedJaxpr, Jaxpr

    def subs(val):
        if isinstance(val, (Jaxpr, ClosedJaxpr)):
            yield val if isinstance(val, Jaxpr) else val.jaxpr
        elif isinstance(val, (tuple, list)):
            for v in val:
                yield from subs(v)
        elif isinstance(val, dict):
            for v in val.values():
                yield from subs(v)

    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for val in eqn.params.values():
            for sub in subs(val):
                yield from iter_avals(sub)


def trace_avals(fn: Callable, *args, **kwargs) -> List:
    """Shaped intermediate avals of ``fn(*args)`` — trace-time only, the
    function is never executed."""
    import jax
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args)
    return [a for a in iter_avals(jaxpr.jaxpr) if hasattr(a, "shape")]


def _elems(aval) -> int:
    out = 1
    for s in aval.shape:
        out *= int(s)
    return out


def max_intermediate_elems(fn: Callable, *args) -> int:
    """Largest intermediate (elements) anywhere in the traced jaxpr."""
    return max((_elems(a) for a in trace_avals(fn, *args)), default=0)


def max_intermediate_bytes(fn: Callable, *args) -> int:
    """Largest intermediate (bytes) anywhere in the traced jaxpr."""
    return max((_elems(a) * getattr(a.dtype, "itemsize", 4)
                for a in trace_avals(fn, *args)), default=0)


def assert_no_intermediate_larger_than(fn: Callable, *args,
                                       max_elems: int,
                                       what: str = "") -> int:
    """Assert no traced intermediate of ``fn(*args)`` reaches
    ``max_elems`` elements; returns the observed peak (so callers can
    additionally assert a positive control *does* cross the limit)."""
    peak = max_intermediate_elems(fn, *args)
    label = what or getattr(fn, "__name__", "fn")
    assert peak < max_elems, (
        f"{label}: materialized a {peak}-element intermediate "
        f"(limit {max_elems})")
    return peak


@dataclasses.dataclass(frozen=True)
class MaterializationCheck:
    """One no-materialization claim: ``build()`` returns ``(fn, args,
    limit_elems)``; the pass traces ``fn(*args)`` and flags any
    intermediate of ``limit_elems`` elements or more. ``build`` is lazy
    so the repo checks import models/serve only when the pass runs."""
    name: str
    describe: str
    build: Callable[[], Tuple[Callable, tuple, int]]


def run_checks(checks: Sequence[MaterializationCheck]):
    """Run materialization checks; returns (n_checked, violations)."""
    from repro.analysis.contracts import Violation
    out: List[Violation] = []
    for chk in checks:
        try:
            fn, args, limit = chk.build()
            peak = max_intermediate_elems(fn, *args)
        except Exception as e:  # a check that cannot trace is a finding
            out.append(Violation(
                pass_name="materialize", code="trace-failed",
                subject=chk.name, message=f"{type(e).__name__}: {e}"))
            continue
        if peak >= limit:
            out.append(Violation(
                pass_name="materialize", code="materialized",
                subject=chk.name,
                message=f"{chk.describe}: traced a {peak}-element "
                        f"intermediate (limit {limit})"))
    return len(checks), out


def repo_checks() -> List[MaterializationCheck]:
    """The repo's three structural absence claims (DESIGN.md §8/§9/§10)."""

    def _attn_no_score():
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import attention as attn_mod
        cfg = get_config("olmo-1b", smoke=True).replace(
            remat="none", attn_impl="flash")
        b, t, hq, hkv, d = 2, 256, 4, 2, 32
        q = jnp.zeros((b, t, hq, d))
        k = jnp.zeros((b, t, hkv, d))
        v = jnp.zeros((b, t, hkv, d))
        pos = jnp.arange(t)[None, :]
        fn = jax.jit(lambda *a: attn_mod._attention_core(*a, cfg))
        return fn, (q, k, v, pos), b * hq * t * t

    def _dbb_no_dense():
        import jax.numpy as jnp
        from repro.core.dbb import dbb_mask, pack_dbb
        from repro.kernels import dispatch
        m, k, n = 8, 512, 512
        w = jnp.ones((k, n), jnp.float32)
        w = w * dbb_mask(w, block=8, nnz=4)
        pw = pack_dbb(w, block=8, nnz=4)
        x = jnp.zeros((m, k), jnp.float32)
        fn = lambda x: dispatch.matmul(x, pw, pallas=True)  # noqa: E731
        return fn, (x,), k * n

    def _conv_no_im2col():
        import jax.numpy as jnp
        from repro.kernels import dispatch
        b, h, w_dim, c, kh, kw = 4, 16, 16, 16, 3, 3
        n = 32
        x = jnp.zeros((b, h, w_dim, c), jnp.float32)
        w = jnp.zeros((kh * kw * c, n), jnp.float32)
        fn = (lambda x, w: dispatch.conv(x, w, kh=kh, kw=kw, stride=1,
                                         route="conv_sta"))
        # implied GEMM's M·K im2col patch matrix (SAME: ho=h, wo=w)
        return fn, (x, w), b * h * w_dim * kh * kw * c

    return [
        MaterializationCheck(
            name="attn-no-score-tensor",
            describe="flash route must not materialize the [B,Hq,T,S] "
                     "score tensor",
            build=_attn_no_score),
        MaterializationCheck(
            name="dbb-no-dense-weight",
            describe="packed DBB matmul must not expand the dense [K,N] "
                     "weight",
            build=_dbb_no_dense),
        MaterializationCheck(
            name="conv-no-im2col",
            describe="implicit-GEMM conv must not materialize the [M,K] "
                     "im2col patch matrix",
            build=_conv_no_im2col),
    ]
