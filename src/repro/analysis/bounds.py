"""Pass 3 — index-map bounds analysis (DESIGN.md §13).

Evaluate every BlockSpec index map over the whole grid (exhaustively up
to a cap, corner/edge-sampled beyond it) at the contract's *padded*
array shapes, and flag:

  * ``oob`` — a block index addressing elements outside the array
    (Pallas block semantics: block ``i`` covers
    ``[i·bs, (i+1)·bs)`` per dim);
  * ``index-map-arity`` / ``index-map-rank`` — maps whose signature
    doesn't match the grid or whose result doesn't match the block rank;
  * ``overlapping-write`` — two grid points writing the same output
    block while differing in a non-accumulation dim (accumulation
    revisits are sequential by pass 2's discipline; anything else is a
    write conflict).
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterator, List, Sequence, Tuple

from repro.analysis.contracts import (BlockDecl, KernelContract, Violation)

__all__ = ["grid_points", "check_contracts", "GRID_ENUM_CAP"]

# full enumeration up to this many grid points; beyond it sample the
# corner/mid lattice (3^rank points) — affine maps fail at corners first
GRID_ENUM_CAP = 65536


def grid_points(grid: Sequence[int], cap: int = GRID_ENUM_CAP
                ) -> Iterator[Tuple[int, ...]]:
    total = 1
    for g in grid:
        total *= max(int(g), 1)
    if total <= cap:
        yield from itertools.product(*(range(g) for g in grid))
        return
    axes = []
    for g in grid:
        vals = sorted({0, g // 2, g - 1})
        axes.append(vals)
    yield from itertools.product(*axes)


def _eval(blk: BlockDecl, ids: Tuple[int, ...]):
    idx = blk.index_map(*ids)
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(i) for i in idx)


def _check_block(c: KernelContract, blk: BlockDecl, is_output: bool,
                 out: List[Violation]) -> None:
    subject = f"{c.name}:{blk.name}"
    # writes per output block: grid-dim value sets seen at each block idx
    seen: Dict[Tuple[int, ...], List[set]] = {}
    for ids in grid_points(c.grid):
        try:
            idx = _eval(blk, ids)
        except TypeError as e:
            out.append(Violation(
                pass_name="bounds", code="index-map-arity",
                subject=subject,
                message=f"index map rejected grid ids {ids}: {e}"))
            return
        if len(idx) != len(blk.block_shape):
            out.append(Violation(
                pass_name="bounds", code="index-map-rank",
                subject=subject,
                message=f"index map returned rank {len(idx)} for a "
                        f"rank-{len(blk.block_shape)} block"))
            return
        for d, (i, bs, asz) in enumerate(
                zip(idx, blk.block_shape, blk.array_shape)):
            if i < 0 or (i + 1) * bs > asz:
                out.append(Violation(
                    pass_name="bounds", code="oob", subject=subject,
                    message=f"grid ids {ids} → block {idx}: dim {d} "
                            f"covers [{i * bs}, {(i + 1) * bs}) outside "
                            f"array extent {asz}"))
                return          # one witness per block is enough
        if is_output:
            slot = seen.setdefault(
                idx, [set() for _ in range(len(c.grid))])
            for d, v in enumerate(ids):
                slot[d].add(v)
    if is_output:
        acc = set(c.acc_dims)
        for idx, dimvals in seen.items():
            conflict = [d for d, vals in enumerate(dimvals)
                        if len(vals) > 1 and d not in acc]
            if conflict:
                out.append(Violation(
                    pass_name="bounds", code="overlapping-write",
                    subject=subject,
                    message=f"output block {idx} written from multiple "
                            f"values of non-accumulation grid dims "
                            f"{conflict}"))
                return


def check_contracts(contracts: Sequence[KernelContract]
                    ) -> Tuple[int, List[Violation]]:
    out: List[Violation] = []
    for c in contracts:
        for blk in c.inputs:
            _check_block(c, blk, is_output=False, out=out)
        for blk in c.outputs:
            _check_block(c, blk, is_output=True, out=out)
    return len(contracts), out
