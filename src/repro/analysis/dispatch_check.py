"""Pass 5 — dispatch registry consistency (DESIGN.md §13).

Sweeps each domain's route table over a grid of `OpSpec`s spanning the
shapes the ``configs/`` model zoo actually produces (decode GEMV through
prefill GEMM, packed and dense, flash on and off) and flags:

  * ``unreachable`` — a route whose guard rejects every spec in the
    sweep: its guard (or the sweep) has drifted and the kernel is dead
    code in practice;
  * ``shadowed`` — a route that is applicable somewhere but *chosen*
    nowhere: its cost/priority combination can never win, so either the
    cost model or the priority is wrong;
  * ``non-monotone-cost`` — a route whose modeled cost decreases when a
    problem dimension (M, N, or K) grows, all else fixed. The roofline
    terms are all sums of monotone products, so a decrease means a
    typo'd term (the bug class that silently flips a route choice).

The sweep replays `dispatch.select`'s auto path (guards, costs, defer,
cost-tie priority break) over the *given* route table — hermetic, so it
analyzes fixture registries the same way as the real one, and no
``REPRO_FORCE_ROUTE`` override can distort reachability.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.contracts import Violation

__all__ = ["default_specs", "check_registry"]

# canonical M ladder: decode token, GQA group, skinny cap, prefill tiles
_MS = (1, 8, 32, 256, 1024)


def default_specs() -> Dict[str, List]:
    """Per-domain OpSpec sweep derived from the configs/ model zoo dims
    (d_model / d_ff / vocab of the smoke zoo) plus the canonical M
    ladder."""
    from repro.configs import get_config
    from repro.kernels.dispatch import OpSpec

    cfg = get_config("olmo-1b", smoke=True)
    dims = sorted({cfg.d_model, cfg.d_ff, cfg.vocab_size, 256, 4096})

    mm: List[OpSpec] = []
    for m in _MS:
        for k in dims:
            for n in dims:
                for packed in (False, True):
                    mm.append(OpSpec(
                        domain="matmul", m=m, k=k, n=n, itemsize=4,
                        packed=packed, pallas=True))
                    if packed and k % 128 == 0:
                        # nibble-plane variant (DESIGN.md §16): reachable
                        # only where the scale group divides K
                        mm.append(OpSpec(
                            domain="matmul", m=m, k=k, n=n, itemsize=4,
                            packed=True, pallas=True, bits=4, group=128))
    # reachability extremes: XLA-only call sites and the decode GEMV
    mm.append(OpSpec(domain="matmul", m=8, k=256, n=256, pallas=False))
    mm.append(OpSpec(domain="matmul", m=8, k=256, n=32000, pallas=True,
                     gemv=True))
    mm.append(OpSpec(domain="matmul", m=8, k=250, n=256, pallas=True,
                     packed=True))          # K % block != 0

    conv: List[OpSpec] = []
    for (b, h, w, c) in ((2, 8, 8, 8), (2, 16, 16, 16), (4, 32, 32, 32)):
        for packed in (False, True):
            for pallas in (True, False):
                conv.append(_conv_spec(b, h, w, c, 3, 3, 1, 32,
                                       packed=packed, pallas=pallas))

    attn: List[OpSpec] = []
    for t in (256, 2048):
        for flash in (True, False):
            attn.append(OpSpec(
                domain="attention", m=t, k=64, n=t, itemsize=4, batch=2,
                chunk=256, flash_active=flash, float_ok=True))
    for flash in (True, False):
        attn.append(OpSpec(
            domain="attention", m=1024, k=64, n=1024, itemsize=4,
            batch=1, chunk=256, flash_active=flash, float_ok=True,
            packed_seq=True))

    dec: List[OpSpec] = []
    for flash in (True, False):
        for ring in (False, True):
            dec.append(OpSpec(
                domain="attn_decode", m=4, k=64, n=512, itemsize=4,
                page=64, ring=ring, flash_active=flash, float_ok=True))

    return {"matmul": mm, "conv": conv, "attention": attn,
            "attn_decode": dec}


def _conv_spec(b, h, w, c, kh, kw, stride, n, *, packed, pallas):
    from repro.kernels.conv_gemm.ops import out_spatial
    from repro.kernels.dispatch import OpSpec
    ho, _, _ = out_spatial(h, kh, stride, "SAME")
    wo, _, _ = out_spatial(w, kw, stride, "SAME")
    return OpSpec(domain="conv", m=b * ho * wo, k=kh * kw * c, n=n,
                  itemsize=4, packed=packed, pallas=pallas,
                  conv_geom=(b, h, w, c, kh, kw, stride, "SAME"))


def _grow(spec, dim: str):
    """The same spec with one problem dimension doubled (conv specs grow
    the generating geometry so conv_geom stays consistent)."""
    if spec.domain == "conv" and spec.conv_geom:
        b, h, w, c, kh, kw, stride = spec.conv_geom[:7]
        if dim == "m":
            return _conv_spec(b, 2 * h, w, c, kh, kw, stride, spec.n,
                              packed=spec.packed, pallas=spec.pallas)
        if dim == "k":
            return _conv_spec(b, h, w, 2 * c, kh, kw, stride, spec.n,
                              packed=spec.packed, pallas=spec.pallas)
        return dataclasses.replace(spec, n=2 * spec.n)
    if spec.domain == "attention" and dim in ("m", "n"):
        # T and S grow together for self-attention specs (T != S flips
        # the chunked guard rather than testing cost shape)
        return dataclasses.replace(spec, m=2 * spec.m, n=2 * spec.n)
    return dataclasses.replace(spec, **{dim: 2 * getattr(spec, dim)})


def _auto_select(table: Dict, spec) -> Optional[str]:
    """`dispatch.select`'s auto path over an explicit route table."""
    from repro.kernels.dispatch import COST_TIE_RTOL, _decide
    from repro.roofline.analysis import HW_V5E
    decisions = [_decide(r, spec, HW_V5E) for r in table.values()]
    cands = [d for d in decisions if d.applicable and not d.deferred]
    if not cands:
        cands = [d for d in decisions if d.applicable]
    if not cands:
        return None
    best = min(d.cost_s for d in cands)
    tied = [d for d in cands if d.cost_s <= best * (1.0 + COST_TIE_RTOL)]
    return min(tied, key=lambda d: (d.priority, d.cost_s, d.name)).name


def check_registry(routes_by_domain: Dict[str, Dict],
                   specs_by_domain: Dict[str, Sequence],
                   ) -> Tuple[int, List[Violation]]:
    """Run the three registry checks. ``routes_by_domain`` maps domain →
    {name: Route}; ``specs_by_domain`` maps domain → OpSpec sweep."""
    out: List[Violation] = []
    checked = 0
    for domain, table in routes_by_domain.items():
        specs = list(specs_by_domain.get(domain, ()))
        if not specs:
            continue
        applicable = {name: 0 for name in table}
        chosen = {name: 0 for name in table}
        for spec in specs:
            checked += 1
            for name, route in table.items():
                if route.guard(spec) == "":
                    applicable[name] += 1
            name = _auto_select(table, spec)
            if name in chosen:
                chosen[name] += 1
        for name, route in table.items():
            if applicable[name] == 0:
                out.append(Violation(
                    pass_name="dispatch", code="unreachable",
                    subject=f"{domain}:{name}",
                    message=f"guard rejects all {len(specs)} specs "
                            f"in the sweep"))
            elif chosen[name] == 0:
                out.append(Violation(
                    pass_name="dispatch", code="shadowed",
                    subject=f"{domain}:{name}",
                    message=f"applicable on {applicable[name]} "
                            f"specs but never selected (cost/"
                            f"priority can never win)"))
        out.extend(_check_monotone(domain, table, specs))
    return checked, out


def _check_monotone(domain: str, table: Dict, specs: Sequence
                    ) -> List[Violation]:
    from repro.roofline.analysis import HW_V5E
    out: List[Violation] = []
    flagged = set()
    for spec in specs:
        for dim in ("m", "k", "n"):
            try:
                grown = _grow(spec, dim)
            except Exception:
                continue
            for name, route in table.items():
                if name in flagged:
                    continue
                c0 = _cost_s(route, spec, HW_V5E)
                c1 = _cost_s(route, grown, HW_V5E)
                if c1 < c0 * (1.0 - 1e-9):
                    flagged.add(name)
                    out.append(Violation(
                        pass_name="dispatch", code="non-monotone-cost",
                        subject=f"{domain}:{name}",
                        message=f"cost decreases when {dim.upper()} "
                                f"doubles ({c0:.3e}s → {c1:.3e}s at "
                                f"m={spec.m} k={spec.k} n={spec.n})"))
    return out


def _cost_s(route, spec, hw) -> float:
    flops, nbytes = route.cost(spec)
    return max(flops / hw.peak_flops, nbytes / hw.hbm_bw)
