"""Static kernel-contract verifier (DESIGN.md §13).

Five passes over declarative `KernelContract`s — no kernel execution:

  vmem           worst-case VMEM residency vs the named budgets, cross-
                 checked against the dispatch guards (drift both ways)
  races          grid-revisit analysis: revisited output blocks need
                 declared accumulation + guarded init/final-store
  bounds         BlockSpec index maps evaluated over the whole grid:
                 out-of-bounds blocks and overlapping writes
  materialize    shared jaxpr walk (`assert_no_intermediate_larger_than`)
                 proving the no-score / no-dense-DBB / no-im2col claims
  dispatch       registry consistency: unreachable or shadowed routes,
                 cost monotonicity in M/N/K

Plus the repo-wide import-layering pass (`layering`). CLI:
``python -m repro.analysis.lint`` (JSON report via ``--json``).
"""
from repro.analysis.contracts import (BlockDecl, KernelContract, ScratchDecl,
                                      Violation, all_contracts)
from repro.analysis.materialize import (MaterializationCheck,
                                        assert_no_intermediate_larger_than,
                                        iter_avals, max_intermediate_elems)

__all__ = [
    "BlockDecl", "ScratchDecl", "KernelContract", "Violation",
    "all_contracts", "iter_avals", "max_intermediate_elems",
    "assert_no_intermediate_larger_than", "MaterializationCheck",
]
