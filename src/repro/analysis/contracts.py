"""Declarative kernel contracts — the verifier's input (DESIGN.md §13).

Each kernel package exports a ``contract`` module with one function
``contracts() -> List[KernelContract]`` describing representative
instances of every Pallas kernel it owns: the grid, every BlockSpec
(block shape + index map + the padded array it tiles), scratch buffers,
which grid dims the output accumulates over, and the VMEM budgets the
dispatch guards enforce. Contracts mirror the ``pallas_call`` sites in
``kernel.py`` 1:1 — they are the checkable statement of what the kernel
*claims*, and the passes in this package hold both the claims and the
dispatch guards to it.

Conventions:

  * index maps take the grid ids as plain ints (one per grid dim, in
    grid order) and return a tuple of *block* indices, exactly like the
    Pallas ``BlockSpec`` index_map;
  * ``admitted`` records the verdict of the real dispatch guard
    (`skinny_ok` / `flash_ok` / `paged_decode_ok` / `_vmem_fits` /
    `choose_block_shape`) on this instance, and ``vmem_reject`` whether
    a rejection was specifically a VMEM rejection — the vmem pass
    cross-checks both directions (guard admits what doesn't fit; guard
    rejects what does = dead headroom);
  * contracts include boundary instances the guards *reject*, so guard
    drift is observable, not just in-budget happy paths.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["BlockDecl", "ScratchDecl", "KernelContract", "Violation",
           "all_contracts", "CONTRACT_MODULES"]

IndexMap = Callable[..., Tuple[int, ...]]

# every kernel package that exports a contract module
CONTRACT_MODULES = (
    "repro.kernels.sta_gemm.contract",
    "repro.kernels.dbb_gemm.contract",
    "repro.kernels.skinny.contract",
    "repro.kernels.conv_gemm.contract",
    "repro.kernels.attn.contract",
    "repro.kernels.sample.contract",
)


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


@dataclasses.dataclass(frozen=True)
class BlockDecl:
    """One BlockSpec of a ``pallas_call``: a block of ``block_shape``
    carved out of a (padded) ``array_shape`` operand by ``index_map``."""
    name: str
    block_shape: Tuple[int, ...]
    index_map: IndexMap
    array_shape: Tuple[int, ...]
    itemsize: int = 4
    resident: bool = False       # declared grid-constant (skinny A block)

    @property
    def block_bytes(self) -> int:
        return _prod(self.block_shape) * self.itemsize


@dataclasses.dataclass(frozen=True)
class ScratchDecl:
    """One VMEM scratch buffer (accumulator / running-softmax state)."""
    name: str
    shape: Tuple[int, ...]
    itemsize: int = 4

    @property
    def nbytes(self) -> int:
        return _prod(self.shape) * self.itemsize


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """Everything the static passes need about one kernel instance."""
    name: str                    # unique, e.g. "sta_gemm[m256 k512 n1024]"
    route: str                   # dispatch route family this belongs to
    domain: str                  # dispatch domain
    grid: Tuple[int, ...]
    dimension_semantics: Tuple[str, ...]
    inputs: Tuple[BlockDecl, ...]
    outputs: Tuple[BlockDecl, ...]
    scratch: Tuple[ScratchDecl, ...] = ()
    acc_dims: Tuple[int, ...] = ()       # grid dims the output sums over
    guarded_init: bool = False           # pl.when(first)-guarded acc init
    guarded_store: bool = False          # pl.when(last)-guarded final store
    vmem_budget: int = 0                 # whole-working-set budget (bytes)
    resident_budget: int = 0             # budget for resident blocks only
    extra_vmem_bytes: int = 0            # body intermediates (score tile)
    admitted: bool = True                # the real dispatch guard's verdict
    vmem_reject: bool = False            # ...and whether a "no" was VMEM
    notes: str = ""

    def residency_bytes(self) -> int:
        """Worst-case single-buffered VMEM working set: every operand and
        output block live at once, plus scratch and declared body
        intermediates (double-buffering is what the budget's /2 headroom
        pays for — see KERNEL_VMEM_BUDGET)."""
        blocks = sum(b.block_bytes for b in self.inputs + self.outputs)
        return (blocks + sum(s.nbytes for s in self.scratch)
                + self.extra_vmem_bytes)

    def resident_bytes(self) -> int:
        """Bytes of blocks declared grid-constant (resident)."""
        return sum(b.block_bytes for b in self.inputs + self.outputs
                   if b.resident)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: which pass, which rule, on what, and why."""
    pass_name: str
    code: str                    # stable rule id, e.g. "vmem-overflow"
    subject: str                 # contract / route / file the rule hit
    message: str

    def as_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


def all_contracts(modules: Optional[Tuple[str, ...]] = None
                  ) -> List[KernelContract]:
    """Collect every kernel package's declared contracts (unique names)."""
    out: List[KernelContract] = []
    seen: Dict[str, str] = {}
    for modname in (modules or CONTRACT_MODULES):
        mod: Any = importlib.import_module(modname)
        for c in mod.contracts():
            if c.name in seen:
                raise ValueError(
                    f"duplicate contract name {c.name!r} "
                    f"({modname} and {seen[c.name]})")
            seen[c.name] = modname
            out.append(c)
    return out
