"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay.

Faithful pieces: token-shift with data-dependent lerp (LoRA), r/k/v/g
projections, decay ``w_t = exp(-exp(ww_t))`` produced by a LoRA head, the
bonus ``u`` term, multi-head WKV state ``S ∈ R^{D×D}`` per head, group-norm
on the WKV output, squared-ReLU channel mix. Documented simplifications:
single shared ddlerp LoRA (instead of five), no tiny init-state learning.

WKV numerics: the chunked form keeps every exponent ≤ 0 (pairwise decays
``exp(cs_t - cs_s)`` with s ≤ t and cumulative-sum cs monotone decreasing),
trading the unsafe r′/k′ matmul factorization for a small pairwise einsum on
a short chunk — exact and overflow-free for any learned decay. A per-token
`lax.scan` recurrence (`wkv_recurrent`) is the oracle and the decode path.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import linear_init, normal_init, norm_apply, norm_init

__all__ = ["rwkv6_layer_init", "rwkv6_layer_apply", "rwkv6_decode_step",
           "wkv_recurrent", "wkv_chunked", "init_rwkv_state"]

_LORA_R = 64


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.ssm.head_dim
    return cfg.d_model // hd, hd


def rwkv6_layer_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d)
    return {
        "time_mix": {
            "mu": 0.5 * jnp.ones((5, d), dtype),      # r,k,v,w,g lerp bases
            "lora_a": normal_init(ks[0], (d, _LORA_R), s, dtype),
            "lora_b": normal_init(ks[1], (_LORA_R, 5 * d), 0.01, dtype),
            "r_proj": linear_init(ks[2], d, d, dtype),
            "k_proj": linear_init(ks[3], d, d, dtype),
            "v_proj": linear_init(ks[4], d, d, dtype),
            "g_proj": linear_init(ks[5], d, d, dtype),
            "o_proj": linear_init(ks[6], d, d, dtype,
                                  scale=s / math.sqrt(2 * cfg.num_layers)),
            "w0": normal_init(ks[7], (d,), 1.0, jnp.float32) - 4.0,
            "w_lora_a": normal_init(ks[8], (d, _LORA_R), s, dtype),
            "w_lora_b": normal_init(ks[9], (_LORA_R, d), 0.01, dtype),
            "u": normal_init(ks[10], (d,), 0.5, jnp.float32),
            "ln_out": norm_init("layernorm", d, dtype),
        },
        "channel_mix": {
            "mu": 0.5 * jnp.ones((2, d), dtype),
            "wk": linear_init(ks[11], d, f, dtype),
            "wv": linear_init(jax.random.fold_in(key, 101), f, d, dtype,
                              scale=1.0 / math.sqrt(f * 2 * cfg.num_layers)),
            "wr": linear_init(jax.random.fold_in(key, 102), d, d, dtype),
        },
        "ln1": norm_init("layernorm", d, dtype),
        "ln2": norm_init("layernorm", d, dtype),
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array] = None) -> jax.Array:
    """shift(x)[t] = x[t-1]; position 0 takes `last` (or zeros)."""
    sx = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return sx.at[:, :1].set(first.astype(x.dtype))


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------

def wkv_recurrent(r, k, v, logw, u, state):
    """Exact per-token recurrence (oracle / decode).

    r,k,v: [B,T,H,D]; logw: [B,T,H,D] (log decay, ≤0); u: [H,D];
    state: [B,H,D,D] (key × value). Returns (out [B,T,H,D], final state).
    """
    def step(s, xs):
        rt, kt, vt, lwt = xs                         # [B,H,D]
        kv = kt[..., :, None] * vt[..., None, :]     # [B,H,Dk,Dv]
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = jnp.exp(lwt)[..., None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (r, k, v, logw))
    state, out = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(out, 0, 1), state


def wkv_chunked(r, k, v, logw, u, state, chunk: int = 32):
    """Chunked WKV with all exponents ≤ 0 (see module docstring)."""
    b, t, h, d = r.shape
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    f32 = jnp.float32

    def to_chunks(a):
        return jnp.moveaxis(a.astype(f32).reshape(b, n, chunk, h, d), 1, 0)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))   # [n,B,C,H,D]

    def step(s, xs):
        rj, kj, vj, lwj = xs                            # [B,C,H,D]
        cs = jnp.cumsum(lwj, axis=1)                    # inclusive cumsum
        cs_prev = cs - lwj                              # exclusive: Σ_{u<t}
        # inter-chunk: y_t += (r_t ⊙ exp(cs_prev_t)) @ S   (exp ≤ 0 ✓)
        r_in = rj * jnp.exp(cs_prev)
        y = jnp.einsum("bchk,bhkv->bchv", r_in, s)
        # intra-chunk, strictly causal pairs s<t:
        #   y_t += Σ_{s<t} (r_t ⊙ exp(cs_prev_t − cs_s) ⊙ k_s) · v_s
        # exponent cs_prev_t − cs_s ≤ 0 for s ≤ t−1 since cs decreases. ✓
        expo = cs_prev[:, :, None] - cs[:, None, :]     # [B,C,C,H,D] (t,s)
        tri = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
        expo = jnp.where(tri[None, :, :, None, None], expo, -jnp.inf)
        att = jnp.einsum("bthd,btshd,bshd->bths", rj, jnp.exp(expo), kj)
        # diagonal bonus term (s == t): r_t ⊙ u ⊙ k_t
        diag = jnp.einsum("bthd,bthd->bth", rj * u[None, None], kj)
        att = att + diag[..., None] * jnp.eye(chunk)[None, :, None, :]
        y = y + jnp.einsum("bths,bshd->bthd", att, vj)
        # state update: S ← diag(exp(cs_C)) S + Σ_s (exp(cs_C − cs_s) k_s)ᵀ v_s
        decay_all = jnp.exp(cs[:, -1:])                 # [B,1,H,D]
        k_out = kj * jnp.exp(cs[:, -1:] - cs)           # exp ≤ 0 ✓
        s = decay_all[:, 0, :, :, None] * s + jnp.einsum(
            "bchk,bchv->bhkv", k_out, vj)
        return s, y

    state, ys = jax.lax.scan(step, state.astype(f32), (rc, kc, vc, lwc))
    return jnp.moveaxis(ys, 0, 1).reshape(b, t, h, d), state


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _ddlerp(tm: Dict, x, sx):
    """Data-dependent lerp between x and shifted x for (r,k,v,w,g)."""
    base = sx + (x - sx) * 0.5
    adj = jnp.tanh(base @ tm["lora_a"].astype(x.dtype)) @ \
        tm["lora_b"].astype(x.dtype)
    adj = adj.reshape(*x.shape[:-1], 5, x.shape[-1])
    mix = jnp.clip(tm["mu"].astype(jnp.float32) + adj.astype(jnp.float32),
                   0.0, 1.0)
    xm = (sx[..., None, :].astype(jnp.float32)
          + (x - sx)[..., None, :].astype(jnp.float32) * mix)
    return [xm[..., i, :].astype(x.dtype) for i in range(5)]


def _decay(tm: Dict, xw: jax.Array) -> jax.Array:
    """log decay ≤ 0: −exp(w0 + lora(xw)), clamped for sanity."""
    ww = tm["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ tm["w_lora_a"].astype(xw.dtype))
        @ tm["w_lora_b"].astype(xw.dtype)).astype(jnp.float32)
    return -jnp.exp(jnp.clip(ww, -8.0, 4.0))


def _time_mix(tm: Dict, cfg: ModelConfig, x, sx, state, *, chunk: int):
    b, t, d = x.shape
    h, hd = _heads(cfg)
    xr, xk, xv, xw, xg = _ddlerp(tm, x, sx)
    r = (xr @ tm["r_proj"]["w"].astype(x.dtype)).reshape(b, t, h, hd)
    k = (xk @ tm["k_proj"]["w"].astype(x.dtype)).reshape(b, t, h, hd)
    v = (xv @ tm["v_proj"]["w"].astype(x.dtype)).reshape(b, t, h, hd)
    g = jax.nn.silu(xg @ tm["g_proj"]["w"].astype(x.dtype))
    logw = _decay(tm, xw).reshape(b, t, h, hd)
    u = tm["u"].astype(jnp.float32).reshape(h, hd)
    if t == 1 or chunk == 1:
        out, state = wkv_recurrent(r, k, v, logw, u, state)
    else:
        out, state = wkv_chunked(r, k, v, logw, u, state, chunk=chunk)
    out = out.reshape(b, t, d)
    out = norm_apply("layernorm", tm["ln_out"], out.astype(x.dtype))
    return (out * g) @ tm["o_proj"]["w"].astype(x.dtype), state


def _channel_mix(cm: Dict, x, sx):
    mu = cm["mu"].astype(jnp.float32)
    xk = (sx + (x - sx) * mu[0]).astype(x.dtype)
    xr = (sx + (x - sx) * mu[1]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ cm["wk"]["w"].astype(x.dtype)))
    rr = jax.nn.sigmoid(xr @ cm["wr"]["w"].astype(x.dtype))
    return rr * (kk @ cm["wv"]["w"].astype(x.dtype))


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    h, hd = _heads(cfg)
    L = cfg.num_layers
    return {
        "wkv": jnp.zeros((L, batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((L, batch, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((L, batch, cfg.d_model), dtype),
    }


def rwkv6_layer_apply(p: Dict, cfg: ModelConfig, x: jax.Array,
                      state: Optional[Dict] = None,
                      chunk: Optional[int] = None):
    """Full-sequence layer. Returns (y, state dict for continuation)."""
    b, t, d = x.shape
    h, hd = _heads(cfg)
    if state is None:
        wkv_state = jnp.zeros((b, h, hd, hd), jnp.float32)
        last_tm = last_cm = None
    else:
        wkv_state = state["wkv"]
        last_tm, last_cm = state["shift_tm"], state["shift_cm"]
    # cap the chunk: the safe pairwise intra tensor is [B,C,C,H,D]
    ck = min(chunk or cfg.ssm.chunk, 32)
    if t % ck != 0:
        ck = 1          # odd smoke shapes: exact recurrent path
    xn = norm_apply("layernorm", p["ln1"], x)
    att, wkv_state = _time_mix(p["time_mix"], cfg, xn,
                               _token_shift(xn, last_tm), wkv_state, chunk=ck)
    shift_tm = xn[:, -1]
    x = x + att
    xn = norm_apply("layernorm", p["ln2"], x)
    x = x + _channel_mix(p["channel_mix"], xn, _token_shift(xn, last_cm))
    state = {"wkv": wkv_state, "shift_tm": shift_tm, "shift_cm": xn[:, -1]}
    return x, state


def rwkv6_decode_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict):
    """Single-token decode: x [B, 1, d]; per-layer state dict with keys
    wkv [B,H,D,D], shift_tm [B,d], shift_cm [B,d]."""
    return rwkv6_layer_apply(p, cfg, x, state=state)
