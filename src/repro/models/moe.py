"""Mixture-of-Experts FFN: top-k routing, capacity-bounded dispatch.

Two execution paths share one dispatch/combine core:

  * `local` — every expert lives on every shard (smoke tests, single device).
  * `ep`    — experts sharded over the mesh "model" axis via shard_map: each
    model shard dispatches *all* of its data-shard's tokens to its local
    experts only and contributes a partial output, combined with one psum.
    Communication per layer = one [T_local, d] all-reduce (same order as a
    tensor-parallel MLP), with no all-to-all and a-priori-bounded load —
    the same load-balancing argument the paper makes for DBB blocks.

Arctic's dense-residual FFN and Kimi's shared expert are both expressed as
`dense_residual_ff` (an always-active parallel MLP).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.dist.compat import shard_map
from repro.dist.mesh_ctx import current_mesh, data_axes_of
from repro.models.common import linear_init, normal_init
from repro.models.mlp import _ACTS, mlp_apply, mlp_init, seq_parallel_ok

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 6)
    scale_in = 1.0 / (d ** 0.5)
    scale_out = 1.0 / (f ** 0.5 * (2 * cfg.num_layers) ** 0.5)
    p = {
        "router": {"w": normal_init(ks[0], (d, e), scale_in, jnp.float32)},
        "experts": {
            "wi": normal_init(ks[1], (e, d, f), scale_in, dtype),
            "wo": normal_init(ks[2], (e, f, d), scale_out, dtype),
        },
    }
    if cfg.mlp_gated:
        p["experts"]["wg"] = normal_init(ks[3], (e, d, f), scale_in, dtype)
    if cfg.moe.dense_residual_ff:
        p["dense_mlp"] = mlp_init(ks[4], d, cfg.moe.dense_residual_ff, cfg,
                                  dtype)
    return p


_FUSED_EXPERT_MAX = 16


def _expert_ffn(ew: Dict, xs: jax.Array, cfg: ModelConfig) -> jax.Array:
    """xs: [E, C, d] -> [E, C, d] through per-expert gated MLP.

    On the single-device Pallas route each expert's GEMMs go through the
    dispatch registry (DESIGN.md §11) with the activation fused into the
    up-projection's final-K store — a static per-expert loop, bounded to
    small expert counts so the unrolled kernel count stays sane. The
    expert-parallel shard_map path (mesh live) keeps the batched einsums
    that GSPMD shards."""
    from repro.kernels import dispatch
    e = xs.shape[0]
    if dispatch.pallas_route_active(cfg) and e <= _FUSED_EXPERT_MAX:
        outs = []
        for i in range(e):
            h = dispatch.matmul(
                xs[i], ew["wi"][i].astype(xs.dtype),
                act="none" if cfg.mlp_gated else cfg.act,
                out_dtype=xs.dtype, cfg=cfg, pallas=True)
            if cfg.mlp_gated:
                h = dispatch.matmul(xs[i], ew["wg"][i].astype(xs.dtype),
                                    act=cfg.act, out_dtype=xs.dtype,
                                    cfg=cfg, pallas=True) * h
            outs.append(dispatch.matmul(h, ew["wo"][i].astype(xs.dtype),
                                        out_dtype=xs.dtype, cfg=cfg,
                                        pallas=True))
        return jnp.stack(outs, axis=0)
    act = _ACTS[cfg.act]
    h = jnp.einsum("ecd,edf->ecf", xs, ew["wi"].astype(xs.dtype))
    if cfg.mlp_gated:
        h = act(jnp.einsum("ecd,edf->ecf", xs, ew["wg"].astype(xs.dtype))) * h
    else:
        h = act(h)
    return jnp.einsum("ecf,efd->ecd", h, ew["wo"].astype(xs.dtype))


def _dispatch_compute_combine(
    x: jax.Array,              # [T, d] tokens on this shard
    ew: Dict,                  # expert weights, local slice [E_loc, ...]
    top_idx: jax.Array,        # [T, k] global expert ids
    top_p: jax.Array,          # [T, k] combine probabilities
    e0: int | jax.Array,       # first global expert id owned here
    e_loc: int,                # number of local experts
    capacity: int,
    cfg: ModelConfig,
) -> jax.Array:
    """Capacity-bounded sort-based dispatch for the local expert slice."""
    t, d = x.shape
    k = top_idx.shape[1]
    e_flat = top_idx.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(t), k)
    p_flat = top_p.reshape(-1).astype(jnp.float32)

    local = e_flat - e0                                   # local expert id
    in_range = (local >= 0) & (local < e_loc)
    # sort by (local expert, arrival) — out-of-range keys sink to the end
    sort_key = jnp.where(in_range, local, e_loc)
    order = jnp.argsort(sort_key, stable=True)
    se, st, sp = sort_key[order], t_flat[order], p_flat[order]
    # rank of each entry within its expert group
    start = jnp.searchsorted(se, jnp.arange(e_loc))       # [E_loc]
    rank = jnp.arange(t * k) - start[jnp.clip(se, 0, e_loc - 1)]
    valid = (se < e_loc) & (rank < capacity)
    slot = jnp.where(valid, se * capacity + rank, e_loc * capacity)

    xs = jnp.zeros((e_loc * capacity + 1, d), x.dtype).at[slot].set(x[st])
    ys = _expert_ffn(ew, xs[:-1].reshape(e_loc, capacity, d), cfg)
    ys = ys.reshape(e_loc * capacity, d)
    # combine in the activation dtype: f32 combine weights keep a full
    # [T·k, d] f32 tensor live (15 GB/layer on kimi, §Perf iteration 15)
    contrib = jnp.where(valid[:, None],
                        ys[jnp.clip(slot, 0, e_loc * capacity - 1)],
                        jnp.zeros((), x.dtype)) * sp[:, None].astype(x.dtype)
    return jnp.zeros((t, d), x.dtype).at[st].add(contrib.astype(x.dtype))


def _route(x: jax.Array, router_w: jax.Array, cfg: ModelConfig,
           mean_axes: Tuple[str, ...] = (),
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (top_idx [T,k], top_p [T,k], aux_loss scalar).

    `mean_axes`: mapped axes whose token shards must be averaged *before*
    the f·P product so the Switch aux loss is the global quantity (per-shard
    products don't commute with the mean)."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(gates, cfg.moe.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    e = gates.shape[-1]
    pe = gates.mean(axis=0)
    fe = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0 / top_idx.size)
    if mean_axes:
        pe = jax.lax.pmean(pe, mean_axes)
        fe = jax.lax.pmean(fe, mean_axes)
    aux = e * jnp.sum(fe * pe)
    return top_idx, top_p, aux


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.moe.top_k * cfg.moe.capacity_factor
            / max(1, cfg.moe.num_experts))
    return max(8, -(-c // 8) * 8)       # round up to sublane multiple


def moe_apply(p: Dict, cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss). Picks local vs EP path."""
    b, s, d = x.shape
    mesh = current_mesh()
    e = cfg.moe.num_experts
    impl = cfg.moe.impl
    if impl == "auto":
        ep_ok = (mesh is not None and "model" in mesh.axis_names
                 and mesh.shape["model"] > 1 and e % mesh.shape["model"] == 0)
        impl = "ep" if ep_ok else "local"

    router_w = p["router"]["w"]
    if impl == "local":
        xt = x.reshape(b * s, d)
        top_idx, top_p, aux = _route(xt, router_w, cfg)
        y = _dispatch_compute_combine(
            xt, p["experts"], top_idx, top_p, 0, e,
            _capacity(b * s, cfg), cfg)
        y = y.reshape(b, s, d)
    else:
        tp = mesh.shape["model"]
        e_loc = e // tp
        daxes = data_axes_of(mesh)
        denom = 1                      # tokens per (pod × data) shard
        for a in daxes:
            denom *= mesh.shape[a]
        t_local = (b * s) // denom
        cap = _capacity(t_local, cfg)

        sp = seq_parallel_ok(cfg, s, tp)
        # token-chunked dispatch (§Perf iteration 16): the [T·k, d] gather
        # is real HBM on any backend — scanning 16k-token chunks caps it at
        # [chunk·k, d] with per-chunk capacity (equal chunks ⇒ the batched
        # aux statistics are exact)
        chunk_tokens = 16_384

        def shard_fn(xl, rw, ew):
            if sp:      # SP: gather sequence shards at block entry
                xl = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
            bl, sl = xl.shape[0], xl.shape[1]
            t_all = bl * sl
            xt = xl.reshape(t_all, d)
            midx = jax.lax.axis_index("model")
            nc = max(1, t_all // chunk_tokens)
            while t_all % nc:
                nc -= 1
            t_c = t_all // nc
            cap_c = _capacity(t_c, cfg)

            @jax.checkpoint
            def one(carry, xc):
                aux_acc = carry
                top_idx, top_p, aux = _route(xc, rw, cfg, mean_axes=daxes)
                yc = _dispatch_compute_combine(
                    xc, ew, top_idx, top_p, midx * e_loc, e_loc, cap_c, cfg)
                return aux_acc + aux, yc

            aux0 = jnp.zeros((), jnp.float32)
            if nc == 1:
                aux, y = one(aux0, xt)
            else:
                aux, y = jax.lax.scan(one, aux0, xt.reshape(nc, t_c, d))
                aux = aux / nc
                y = y.reshape(t_all, d)
            y = y.reshape(bl, sl, d)
            if sp:      # reduce-scatter back to the seq-sharded residual
                y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                         tiled=True)
            else:
                y = jax.lax.psum(y, "model")
            return y, aux

        ba = daxes if daxes else None
        batch_spec = P(ba, "model", None) if sp else P(ba)
        y, aux = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(batch_spec, P(), P("model")),
            out_specs=(batch_spec, P()),
            check_vma=False,
        )(x, router_w, p["experts"])
        # aux is already pmean'd over model; the per-data-shard mean folds
        # into the global loss mean through the data-parallel grad psum.

    if "dense_mlp" in p:
        y = y + mlp_apply(p["dense_mlp"],
                          cfg.replace(d_ff=cfg.moe.dense_residual_ff), x)
    return y, aux
