"""Mamba2 (SSD) block (arXiv:2405.21060), used by the Zamba2 hybrid.

Scalar-per-head decay makes the chunked "state-space dual" form numerically
safe without factorization tricks: every pairwise decay is
``exp(cs_t - cs_s) ≤ 1`` for ``s ≤ t``. Chunked scan carries the inter-chunk
state ``S [B, H, P, N]``; a per-token recurrence serves as oracle + decode.

Simplifications vs the reference (documented): single B/C group
(`ngroups=1`), no learned initial state.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import linear_init, normal_init, norm_apply, norm_init

__all__ = ["mamba2_init", "mamba2_apply", "mamba2_decode_step",
           "ssd_recurrent", "ssd_chunked", "init_mamba_state"]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm.expand * cfg.d_model
    p = cfg.ssm.head_dim
    h = d_in // p
    n = cfg.ssm.state_size
    return d_in, h, p, n


def mamba2_init(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    d_in, h, p, n = _dims(cfg)
    cw = cfg.ssm.conv_width
    ks = jax.random.split(key, 4)
    # in_proj -> [z, x, B, C, dt]
    d_proj = 2 * d_in + 2 * n + h
    return {
        "in_proj": linear_init(ks[0], d, d_proj, dtype),
        "out_proj": linear_init(ks[1], d_in, d, dtype,
                                scale=1.0 / math.sqrt(d_in * 2 * cfg.num_layers)),
        "conv_w": normal_init(ks[2], (cw, d_in + 2 * n), 0.5, dtype),
        "conv_b": jnp.zeros((d_in + 2 * n,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[3], (h,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": norm_init("rmsnorm", d_in, dtype),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_recurrent(x, b_mat, c_mat, la, state):
    """Exact recurrence (oracle / decode).

    x:  [B,T,H,P] (already dt-scaled)      la: [B,T,H] log decay (≤ 0)
    b_mat, c_mat: [B,T,N]                  state: [B,H,P,N]
    Returns (y [B,T,H,P], final state)."""
    def step(s, xs):
        xt, bt, ct, lat = xs
        s = jnp.exp(lat)[..., None, None] * s + \
            xt[..., :, None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (x, b_mat, c_mat, la))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), state


def ssd_chunked(x, b_mat, c_mat, la, state, chunk: int = 128):
    """Chunked SSD; all pairwise exponents ≤ 0."""
    bb, t, h, p = x.shape
    n = b_mat.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    f32 = jnp.float32

    def to_chunks(a, last):
        return jnp.moveaxis(
            a.astype(f32).reshape(bb, nc, chunk, *a.shape[2:]), 1, 0)

    xc = to_chunks(x, 2)
    bc = to_chunks(b_mat, 1)
    cc = to_chunks(c_mat, 1)
    lac = to_chunks(la, 1)
    tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])

    @jax.checkpoint   # per-chunk remat: the [B,C,C,H] pairwise tensors
    def step(s, xs):  # would otherwise be saved for every chunk
        xj, bj, cj, laj = xs               # [B,C,H,P] [B,C,N] [B,C,N] [B,C,H]
        cs = jnp.cumsum(laj, axis=1)       # inclusive [B,C,H]
        # inter-chunk: y_t += exp(cs_t) * C_t · S
        y = jnp.exp(cs)[..., None] * jnp.einsum("bhpn,btn->bthp", s, cj)
        # intra-chunk (s ≤ t): att[t,s,h] = exp(cs_t − cs_s) (C_t·B_s)
        expo = cs[:, :, None, :] - cs[:, None, :, :]          # [B,C,C,H]
        expo = jnp.where(tri[None, :, :, None], expo, -jnp.inf)
        cb = jnp.einsum("btn,bsn->bts", cj, bj)               # [B,C,C]
        att = jnp.exp(expo) * cb[..., None]
        y = y + jnp.einsum("btsh,bshp->bthp", att, xj)
        # state: S ← exp(cs_L) S + Σ_s exp(cs_L − cs_s) x_s ⊗ B_s
        k_out = jnp.exp(cs[:, -1:, :] - cs)                   # [B,C,H] ≤ 1
        s = jnp.exp(cs[:, -1])[..., None, None] * s + jnp.einsum(
            "bsh,bshp,bsn->bhpn", k_out, xj, bj)
        return s, y

    state, ys = jax.lax.scan(step, state.astype(f32), (xc, bc, cc, lac))
    return jnp.moveaxis(ys, 0, 1).reshape(bb, t, h, p), state


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in, h, p, n = _dims(cfg)
    z, xs, b_mat, c_mat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    return z, xs, b_mat, c_mat, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 ctx: Optional[jax.Array] = None):
    """Depthwise causal conv over time. x: [B,T,C]; w: [W,C].
    ctx: [B,W-1,C] trailing context from the previous segment (decode)."""
    width = w.shape[0]
    pad = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype) \
        if ctx is None else ctx.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(width))
    return out + b[None, None, :], xp[:, -(width - 1):]


def mamba2_apply(p: Dict, cfg: ModelConfig, x: jax.Array,
                 state: Optional[jax.Array] = None,
                 conv_ctx: Optional[jax.Array] = None,
                 chunk: Optional[int] = None):
    """Full-sequence Mamba2 block. Returns (y, (ssd_state, conv_ctx))."""
    bsz, t, _ = x.shape
    d_in, h, pp, n = _dims(cfg)
    proj = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xs, b_mat, c_mat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, b_mat, c_mat], axis=-1)
    conv_out, new_conv_ctx = _causal_conv(
        conv_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype),
        conv_ctx)
    conv_out = jax.nn.silu(conv_out)
    xs, b_mat, c_mat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])        # [B,T,H]
    a = -jnp.exp(p["a_log"])[None, None, :]                    # [1,1,H] < 0
    la = dt * a                                                # log decay ≤ 0
    xh = xs.reshape(bsz, t, h, pp).astype(jnp.float32) * dt[..., None]
    if state is None:
        state = jnp.zeros((bsz, h, pp, n), jnp.float32)
    ck = chunk or cfg.ssm.chunk
    if t == 1:
        y, state = ssd_recurrent(xh, b_mat, c_mat, la, state)
    elif t % ck == 0:
        y, state = ssd_chunked(xh, b_mat, c_mat, la, state, chunk=ck)
    else:
        y, state = ssd_recurrent(xh, b_mat, c_mat, la, state)
    y = y + p["d_skip"][None, None, :, None] * \
        xs.reshape(bsz, t, h, pp).astype(jnp.float32)
    y = y.reshape(bsz, t, d_in).astype(x.dtype)
    y = norm_apply("rmsnorm", p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"]["w"].astype(x.dtype), (state, new_conv_ctx)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_in, h, pp, n = _dims(cfg)
    cw = cfg.ssm.conv_width
    return (jnp.zeros((batch, h, pp, n), jnp.float32),
            jnp.zeros((batch, cw - 1, d_in + 2 * n), dtype))


def mamba2_decode_step(p: Dict, cfg: ModelConfig, x: jax.Array, state):
    """x: [B,1,d]; state = (ssd_state, conv_ctx)."""
    ssd_state, conv_ctx = state
    y, new_state = mamba2_apply(p, cfg, x, state=ssd_state,
                                conv_ctx=conv_ctx)
    return y, new_state
