"""Model registry: uniform entry points keyed by config family."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import cnn as cnn_mod
from repro.models import transformer as tf

__all__ = ["init_params", "forward", "decode_step", "verify_step",
           "prefill", "prefill_packed", "prefill_continue", "init_cache",
           "lm_head_weight"]

_LM_FAMILIES = ("dense_lm", "moe_lm", "rwkv6", "zamba2", "vlm_lm", "audio_lm")


def init_params(key, cfg: ModelConfig) -> Dict:
    if cfg.family == "cnn":
        return cnn_mod.cnn_init(key, cfg)
    if cfg.family in _LM_FAMILIES:
        return tf.init_params(key, cfg)
    raise ValueError(f"unknown family {cfg.family}")


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, jax.Array]:
    """batch keys: tokens | embeds | prefix_embeds | images (cnn).
    Returns (hidden/logits, aux)."""
    if cfg.family == "cnn":
        return (cnn_mod.cnn_apply(params, cfg, batch["images"]),
                jnp.zeros((), jnp.float32))
    return tf.forward(params, cfg,
                      tokens=batch.get("tokens"),
                      embeds=batch.get("embeds"),
                      prefix_embeds=batch.get("prefix_embeds"))


decode_step = tf.decode_step
verify_step = tf.verify_step
prefill = tf.prefill
prefill_packed = tf.prefill_packed
prefill_continue = tf.prefill_continue
init_cache = tf.init_cache
lm_head_weight = tf.lm_head_weight
