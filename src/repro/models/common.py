"""Shared model building blocks (pure-functional, pytree params)."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

__all__ = [
    "Initializer", "normal_init", "zeros_init", "norm_apply", "norm_init",
    "rope_freqs", "apply_rope", "embed_init", "embed_apply", "linear_init",
    "linear_apply", "use_fused_gemm", "dtype_of",
]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def param_dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def normal_init(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(_key, shape, _scale, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def linear_init(key, d_in: int, d_out: int, dtype,
                scale: Optional[float] = None, bias: bool = False) -> Dict:
    """Truncated-normal-ish fan-in init, [K, N] layout (contraction first)."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    p = {"w": normal_init(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def use_fused_gemm(cfg: ModelConfig) -> bool:
    """Whether the fused Pallas GEMM path is active: requires
    ``cfg.gemm_impl == "pallas"``, and either no live device mesh or a
    per-shard shard_map body (the TP serving wrapper, DESIGN.md §14, runs
    the kernels on local shards). A *global* GSPMD graph under a live mesh
    still stays on XLA matmuls — the kernels are not partitioner-aware.
    (Delegates to the dispatch layer's route-family predicate.)"""
    from repro.kernels.dispatch import pallas_route_active
    return pallas_route_active(cfg)


def linear_apply(p: Dict, x: jax.Array, *, act: str = "none",
                 fused: bool = False, cfg: Optional[ModelConfig] = None
                 ) -> jax.Array:
    """``act(x @ w + b)`` for a `linear_init` param dict.

    fused=True hands the GEMM to the dispatch registry's Pallas route
    family (DESIGN.md §11) — bias+activation applied in the kernel's
    final-K store (§7), the pre-activation [M, N] tensor never
    round-trips through HBM. fused=False is the plain XLA path
    (shardable, differentiable — use for training / GSPMD).
    """
    from repro.kernels import dispatch
    return dispatch.matmul(x, p["w"].astype(x.dtype), p.get("b"), act=act,
                           out_dtype=x.dtype if fused else None,
                           cfg=cfg, pallas=fused)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(kind: str, d: int, dtype) -> Dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparam_ln":   # OLMo: LayerNorm without affine params
        return {}
    raise ValueError(kind)


def norm_apply(kind: str, p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    angles = angles[..., None, :]                      # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype) -> Dict:
    return {"table": normal_init(key, (vocab, d), 1.0, dtype)}


def embed_apply(p: Dict, tokens: jax.Array, dtype,
                vocab_parallel: bool = True) -> jax.Array:
    """Vocab-parallel gather when a model axis is active (the table is the
    single largest weight in half the assigned archs — never all-gather it);
    plain gather otherwise (single device / "dp" layouts)."""
    from repro.dist.collectives import (shard_embed_lookup,
                                        vocab_parallel_embed)
    from repro.dist.mesh_ctx import current_mesh, shard_tp

    table = p["table"]
    if shard_tp() > 1 and vocab_parallel:
        # inside a TP shard_map body (serving wrapper, DESIGN.md §14): the
        # table arrives row-sharded — shard-local masked gather + psum,
        # no nested shard_map
        return shard_embed_lookup(table, tokens, dtype)
    mesh = current_mesh()
    if (vocab_parallel and shard_tp() == 0
            and mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1 and tokens.ndim == 2
            and table.shape[0] % mesh.shape["model"] == 0):
        return vocab_parallel_embed(table, tokens, dtype, mesh)
    return table.astype(dtype)[tokens]
