"""GQA/MQA attention: fused Pallas flash path (DESIGN.md §10), chunked
(memory-efficient) XLA path for long prefill, naive oracle, cached decode.

Backend dispatch (``ModelConfig.attn_impl``): the **flash** kernel blocks
over KV with an online softmax — the ``[B, H, T, S]`` score tensor never
materializes — and handles causal + sliding-window + ragged left-pad
masking from the same qpos/kpos convention as `_mask_bias`, so ragged
serving batches stay token-identical. "auto" takes it whenever the Pallas
route is active (single device, float operands, VMEM guard passes); the
**chunked** path unrolls q-chunks in Python and scans only the kv-chunks
each q-chunk attends to; **naive** is the quadratic oracle. Decode routes
through the paged flash kernel (a contiguous cache is an identity block
table); `paged_decode_attention_apply` is the true paged-pool variant the
continuous-batching engine scans over. KV heads are never materialized at
Hq width (GQA grouping stays factored) on any path.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core.dbb import DbbWeight
from repro.dist.compat import shard_map
from repro.dist.mesh_ctx import current_mesh, shard_tp
from repro.kernels.attn import (DEFAULT_PAGE, identity_block_table,
                                paged_decode_attention)
from repro.models.common import apply_rope, linear_init

__all__ = ["attention_init", "attention_apply", "packed_attention_apply",
           "chunk_attention_apply", "decode_attention_apply",
           "paged_decode_attention_apply", "verify_attention_apply",
           "paged_verify_attention_apply", "init_kv_cache"]

_NEG_INF = -1e30


def _lin(pp: Dict, x: jax.Array, cfg: Optional[ModelConfig] = None
         ) -> jax.Array:
    """Projection against a dense or DBB-packed weight, routed by the
    kernel dispatch registry. Packed weights (decode fast path, DESIGN.md
    §9) stream compressed through the DBB kernels with the bias fused into
    the epilogue — the dense [K, N] form never materializes, in HBM or
    VMEM. Dense weights keep the plain XLA matmul (shardable,
    differentiable) via ``dense_fused=False``, which the route guards
    honor (DESIGN.md §11)."""
    from repro.kernels import dispatch
    w = pp["w"]
    return dispatch.matmul(x, w, pp.get("b"),
                           out_dtype=x.dtype if isinstance(w, DbbWeight)
                           else None,
                           cfg=cfg, pallas=isinstance(w, DbbWeight),
                           dense_fused=False)


def _o_proj(pp: Dict, o2d: jax.Array, cfg: Optional[ModelConfig] = None
            ) -> jax.Array:
    """Row-parallel output projection epilogue. Inside a TP shard_map body
    (serving wrapper, DESIGN.md §14) the o_proj weight arrives row-sharded
    over the local heads' K slice, so the GEMM output is a partial sum —
    one chunked boundary all-reduce completes the attention block (chunked
    so XLA's async collective scheduler overlaps the first chunk's wire
    time with the later chunks' epilogue stores). Outside a shard body
    this is exactly `_lin`."""
    y = _lin(pp, o2d, cfg)
    if shard_tp() > 1:
        from repro.dist.collectives import overlapped_psum
        y = overlapped_psum(y, "model")
    return y


def attention_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, hq, hkv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                      cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    return {
        "q_proj": linear_init(ks[0], d, hq * hd, dtype, bias=cfg.qkv_bias),
        "k_proj": linear_init(ks[1], d, hkv * hd, dtype, bias=cfg.qkv_bias),
        "v_proj": linear_init(ks[2], d, hkv * hd, dtype, bias=cfg.qkv_bias),
        "o_proj": linear_init(ks[3], hq * hd, d, dtype,
                              scale=1.0 / math.sqrt(hq * hd * 2 * cfg.num_layers)),
    }


def _project_qkv(p: Dict, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    q = _lin(p["q_proj"], x, cfg).reshape(b, s, hq, hd)
    k = _lin(p["k_proj"], x, cfg).reshape(b, s, hkv, hd)
    v = _lin(p["v_proj"], x, cfg).reshape(b, s, hkv, hd)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scores(q, k, cfg: ModelConfig):
    """q: [B,T,Hkv,G,D], k: [B,S,Hkv,D] -> scores [B,Hkv,G,T,S] (f32).

    Operands stay in their storage dtype (bf16) — the MXU accumulates in
    f32 via preferred_element_type. Casting q/k to f32 up front would make
    XLA materialize (and on scan paths hoist) f32 copies of the whole KV
    cache: 2× the HBM traffic for zero precision gain on the MXU
    (EXPERIMENTS.md §Perf iteration 1)."""
    hd = q.shape[-1]
    s = jnp.einsum("bthgd,bshd->bhgts", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        s = c * jnp.tanh(s / c)
    return s


def _mask_bias(qpos, kpos, window: int) -> jax.Array:
    """Additive bias [T, S] (1-D positions) or [B, T, S] (per-row ragged
    positions): causal (+ optional sliding window). Keys at negative
    positions are left-padding (ragged serving batches, DESIGN.md §5) and
    are masked out — for ordinary arange positions the term is a no-op."""
    q = qpos[..., :, None]
    kk = kpos[..., None, :]
    m = (kk <= q) & (kk >= 0)
    if window > 0:
        m &= kk > (q - window)
    return jnp.where(m, 0.0, _NEG_INF)


def _naive_attention(q, k, v, qpos, kpos, cfg: ModelConfig):
    """q:[B,T,Hq,D] k,v:[B,S,Hkv,D]; quadratic reference path.
    qpos/kpos: [T]/[S] shared positions, or [B,T]/[B,S] per-row (ragged)."""
    b, t, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, t, hkv, g, hd)
    bias = _mask_bias(qpos, kpos, cfg.sliding_window)
    if bias.ndim == 3:                     # [B,T,S] -> [B,1,1,T,S]
        bias = bias[:, None, None]
    s = _scores(qg, k, cfg) + bias
    p = jax.nn.softmax(s, axis=-1)
    # PV in storage dtype with f32 accumulation (flash-attention practice)
    o = jnp.einsum("bhgts,bshd->bthgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, t, hq, hd).astype(q.dtype)


def _chunked_causal_attention(q, k, v, cfg: ModelConfig, chunk: int):
    """No-waste blocked causal attention with running-softmax combine."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    window = cfg.sliding_window
    qg = q.reshape(b, n, chunk, hkv, g, hd)
    kc = k.reshape(b, n, chunk, hkv, hd)
    vc = v.reshape(b, n, chunk, hkv, hd)
    # chunk-major for scan: [n, B, C, H, D]
    kc = jnp.moveaxis(kc, 1, 0)
    vc = jnp.moveaxis(vc, 1, 0)

    outs = []
    for i in range(n):                      # static unroll over q chunks
        j0 = 0
        if window > 0:
            j0 = max(0, (i * chunk - window) // chunk)
        qi = qg[:, i]                       # [B, C, Hkv, G, D] storage dtype
        qpos = i * chunk + jnp.arange(chunk)

        def step(carry, xs):
            m_run, l_run, acc = carry
            kj, vj, jidx = xs               # [B,C,H,D], [B,C,H,D], scalar
            sc = _scores(qi, kj, cfg)       # [B,H,G,T,S]
            kpos = jidx * chunk + jnp.arange(chunk)
            sc = sc + _mask_bias(qpos, kpos, window)
            m_new = jnp.maximum(m_run, sc.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            pj = jnp.exp(sc - m_new[..., None])
            l_new = l_run * alpha + pj.sum(axis=-1)
            oj = jnp.einsum("bhgts,bshd->bhgtd", pj.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + oj
            return (m_new, l_new, acc), None

        shape_ml = (b, hkv, g, chunk)
        carry0 = (jnp.full(shape_ml, _NEG_INF, jnp.float32),
                  jnp.zeros(shape_ml, jnp.float32),
                  jnp.zeros((*shape_ml, hd), jnp.float32))
        xs = (kc[j0:i + 1], vc[j0:i + 1], jnp.arange(j0, i + 1))
        # flash-attention backward: recompute scores per kv-chunk instead
        # of saving [B,H,C,C] probability tensors for every chunk pair
        (m_f, l_f, acc), _ = jax.lax.scan(jax.checkpoint(step), carry0, xs)
        o = acc / jnp.maximum(l_f[..., None], 1e-30)   # [B,H,G,T,D]
        outs.append(jnp.moveaxis(o, 3, 1).reshape(b, chunk, hq, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def _flash_backend(cfg: ModelConfig) -> bool:
    """Whether the fused flash kernel is the selected backend (delegates
    to the dispatch layer's route-family predicate, DESIGN.md §11)."""
    from repro.kernels.dispatch import flash_backend_active
    return flash_backend_active(cfg)


def _start_from_positions(positions: jax.Array, b: int) -> jax.Array:
    """Per-row first-real-key slot from the logical position ladder.
    Every caller builds positions as ``arange(s) - start`` (shared or
    per-row, DESIGN.md §5), so the leading entry recovers ``start``; for
    plain arange ladders this is zero and the pad mask is a no-op."""
    return jnp.broadcast_to(-positions[..., 0], (b,)).astype(jnp.int32)


def _attention_core(q, k, v, positions, cfg: ModelConfig,
                    ragged: bool = False) -> jax.Array:
    """Dispatch flash vs chunked vs naive on projected q/k/v. Returns
    o [B,S,Hq,D].

    The flash kernel serves every shape — ragged per-row positions ride in
    as ``start`` offsets (same masks as `_mask_bias`, never a [B,H,T,T]
    bias tensor). Without it, ragged=True (left-padded serving batch)
    forces the naive oracle with full batched masking and the chunked path
    assumes one shared arange position ladder."""
    from repro.kernels import dispatch
    return dispatch.attention(q, k, v, positions, cfg, ragged=ragged)


def attention_apply(p: Dict, cfg: ModelConfig, x: jax.Array,
                    positions: Optional[jax.Array] = None,
                    window_override: Optional[int] = None,
                    ragged: bool = False,
                    qkv: Optional[Tuple] = None) -> jax.Array:
    """Full-sequence (train / prefill) attention.

    ragged: positions are per-row (left-padded serving batch) — bypasses
    the chunked/TP fast paths, whose masks assume one shared ladder.
    qkv: optionally reuse already-projected (q, k, v) for these positions
    (prefill projects for the cache fill anyway); the TP branch ignores
    it — its projections are shard-local by construction."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if window_override is not None:
        cfg = cfg.replace(sliding_window=window_override)
    mesh = current_mesh()
    # inside a TP shard_map body (serving wrapper, DESIGN.md §14) the cfg
    # is already localized and collectives ride on the enclosing mesh —
    # never nest the GSPMD-era _attention_tp shard_map
    tp = mesh.shape["model"] if (mesh is not None
                                 and "model" in mesh.axis_names
                                 and cfg.parallel != "dp"
                                 and shard_tp() == 0) else 1
    if tp > 1 and cfg.num_heads % tp == 0 and s > 1 and not ragged:
        return _attention_tp(p, cfg, x, positions, mesh, tp)
    q, k, v = qkv if qkv is not None else _project_qkv(p, cfg, x, positions)
    o = _attention_core(q, k, v, positions, cfg, ragged=ragged)
    b_, s_, hq, hd = o.shape
    return _o_proj(p["o_proj"], o.reshape(b_, s_, hq * hd), cfg)


def _attention_tp(p: Dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, mesh, tp: int) -> jax.Array:
    """Explicit tensor-parallel attention (§Perf iterations 4+5).

    Q heads shard over "model" (hq % tp == 0, padded upstream when needed);
    K/V are computed per-shard from (small) replicated-or-gathered weights,
    and each local Q head gathers its own KV head — all score/softmax/PV
    work is shard-local, and the single boundary collective is the o_proj
    row-parallel psum in the storage dtype (bf16)."""
    from repro.models.mlp import (batch_axes_for,   # avoid import cycle
                                  seq_parallel_ok)

    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    hq_l = hq // tp
    g = hq // hkv
    ba = batch_axes_for(mesh, b)
    pos1d = positions[0] if positions.ndim > 1 else positions
    # sequence parallelism (§Perf iteration 7): residual stays seq-sharded;
    # block entry all-gathers, block exit reduce-scatters — same bytes as
    # the TP all-reduce at 2× the effective ring bandwidth, and norms /
    # residual adds run on 1/tp of the tokens.
    sp = seq_parallel_ok(cfg, s, tp)
    xspec = P(ba, "model", None) if sp else P(ba, None, None)

    # K/V projections stay column-sharded for COMPUTE (fractional heads are
    # fine for the GEMM); the small K/V activations are all-gathered so the
    # head-structured attention is shard-local. Computing K/V replicated
    # instead costs the full projection per device (+264 TFLOP/step on
    # qwen train_4k — §Perf iteration 6 refuted that variant).
    kvd = hkv * hd
    kv_shardable = kvd % tp == 0
    kv_w = P(None, "model") if kv_shardable else P(None, None)
    kv_b = P("model") if kv_shardable else P(None)
    wspecs = {
        "q_proj": {"w": P(None, "model")},
        "k_proj": {"w": kv_w},
        "v_proj": {"w": kv_w},
        "o_proj": {"w": P("model", None)},
    }
    if "b" in p["q_proj"]:
        wspecs["q_proj"]["b"] = P("model")
        wspecs["k_proj"]["b"] = kv_b
        wspecs["v_proj"]["b"] = kv_b

    def lin(pp, xx):
        y = xx @ pp["w"].astype(xx.dtype)
        if "b" in pp:
            y = y + pp["b"].astype(xx.dtype)
        return y

    def fn(xl, pl):
        bl = xl.shape[0]
        midx = jax.lax.axis_index("model")
        if sp:      # gather sequence shards at block entry (SP)
            xl = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        q = lin(pl["q_proj"], xl).reshape(bl, s, hq_l, hd)
        k = lin(pl["k_proj"], xl)                     # [b,s,kvd/tp]
        v = lin(pl["v_proj"], xl)
        if kv_shardable:
            k = jax.lax.all_gather(k, "model", axis=2, tiled=True)
            v = jax.lax.all_gather(v, "model", axis=2, tiled=True)
        k = k.reshape(bl, s, hkv, hd)
        v = v.reshape(bl, s, hkv, hd)
        if cfg.rope:
            q = apply_rope(q, pos1d[None, :], cfg.rope_theta)
            k = apply_rope(k, pos1d[None, :], cfg.rope_theta)
        # each local q head pairs with its kv head (present locally)
        kv_idx = (midx * hq_l + jnp.arange(hq_l)) // g
        k_sel = jnp.take(k, kv_idx, axis=2)           # [b,s,hq_l,hd]
        v_sel = jnp.take(v, kv_idx, axis=2)
        o = _attention_core(q, k_sel, v_sel, positions, cfg)
        y = o.reshape(bl, s, hq_l * hd) @ pl["o_proj"]["w"].astype(o.dtype)
        if sp:      # reduce-scatter back to the seq-sharded residual
            return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(y, "model")               # bf16 boundary reduce

    return shard_map(
        fn, mesh=mesh,
        in_specs=(xspec, wspecs),
        out_specs=xspec,
        check_vma=False)(x, {k: p[k] for k in wspecs})


def packed_attention_apply(p: Dict, cfg: ModelConfig, x: jax.Array,
                           seg_ids: jax.Array, positions: jax.Array,
                           qkv: Optional[Tuple] = None) -> jax.Array:
    """Packed (cu_seqlens) prefill attention (DESIGN.md §12): x [1, T, d]
    is a ragged batch's tokens concatenated along one axis, ``seg_ids [T]``
    names the owning request per packed position (non-decreasing; padding
    carries a larger sentinel), ``positions [1, T]`` the per-token logical
    position within its request (RoPE). Block-diagonal-causal by
    construction — no cross-request attention, no pad row in any GEMM with
    real extent. qkv optionally reuses the prefill body's projections."""
    q, k, v = qkv if qkv is not None else _project_qkv(p, cfg, x, positions)
    from repro.kernels import dispatch
    o = dispatch.packed_attention(q, k, v, seg_ids, cfg)
    b, t, hq, hd = o.shape
    return _o_proj(p["o_proj"], o.reshape(b, t, hq * hd), cfg)


def chunk_attention_apply(p: Dict, cfg: ModelConfig, q: jax.Array,
                          cache_k: jax.Array, cache_v: jax.Array,
                          offset: jax.Array) -> jax.Array:
    """Continuation attention for one chunk-prefilling row (DESIGN.md §12):
    q [1, C, Hq, D] are the chunk's projected queries at absolute cache
    positions ``offset .. offset+C-1``; cache_k/v [1, S, Hkv, D] is the
    row's full cache (earlier chunks + this chunk already scattered in).
    The causal mask bounds reads to slots <= qpos, all of which are real —
    packed-admitted rows have no left-pad. Returns the o_proj output
    [1, C, d]."""
    from repro.kernels import dispatch
    c, s = q.shape[1], cache_k.shape[1]
    hq, hd = q.shape[2], q.shape[3]
    route = dispatch.chunk_attention_route(
        cfg, t=c, s=s, d=hd, itemsize=q.dtype.itemsize,
        floating=jnp.issubdtype(q.dtype, jnp.floating))
    if route == "attn_flash":
        from repro.kernels.attn import flash_attention
        o = flash_attention(q, cache_k, cache_v,
                            q_offset=jnp.broadcast_to(offset, (1,)),
                            window=cfg.sliding_window,
                            softcap=cfg.attn_logit_softcap)
    else:
        qpos = offset + jnp.arange(c)
        kpos = jnp.arange(s)
        o = _naive_attention(q, cache_k, cache_v, qpos, kpos, cfg)
    return _o_proj(p["o_proj"], o.reshape(1, c, hq * hd), cfg)


# ---------------------------------------------------------------------------
# decode path (KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, hkv, hd), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def decode_attention_apply(p: Dict, cfg: ModelConfig, x: jax.Array,
                           cache_k: jax.Array, cache_v: jax.Array,
                           lengths: jax.Array,
                           window_override: Optional[int] = None,
                           ring: bool = False,
                           start: Optional[jax.Array] = None,
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: x [B, 1, d]; cache_k/v [B, Smax, Hkv, D];
    lengths [B] current *absolute* context lengths (cache slot of the new
    token). Returns (y, new_k, new_v).

    start [B] (optional): index of the first real (non-pad) cache slot per
    row — left-padded ragged batches (DESIGN.md §5). RoPE positions shift
    to ``lengths - start`` (the logical context length) and slots below
    ``start`` are masked out, so a short prompt in a mixed batch decodes
    exactly as it would solo.

    ring=True treats the cache as a sliding-window ring buffer of size Smax:
    the new KV lands at ``lengths % Smax`` and every slot written so far is
    valid (window = Smax by construction). K entries are RoPE-rotated at
    their absolute positions, so relative offsets stay correct after wrap.
    """
    b = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = hq // hkv
    smax = cache_k.shape[1]
    rope_pos = lengths if start is None else lengths - start
    q, k, v = _project_qkv(p, cfg, x, rope_pos[:, None])
    ins = (lengths % smax) if ring else lengths

    def upd(cache, new, i):
        return jax.lax.dynamic_update_slice(cache, new, (i, 0, 0))
    new_k = jax.vmap(upd)(cache_k, k, ins)
    new_v = jax.vmap(upd)(cache_v, v, ins)

    # flash decode (DESIGN.md §10): the updated contiguous cache is a paged
    # pool under an identity block table — same kernel, same page-visit
    # order as the true paged pool, which is what makes paged serving
    # bit-identical to contiguous. The gate (flash backend + skinny-regime
    # G + page/VMEM guards) lives in the dispatch registry's attn_decode
    # domain (DESIGN.md §11); with kv_page_size unset the page adapts to
    # the cache length (largest power-of-two divisor up to DEFAULT_PAGE)
    # so arbitrary generate()/serve() cache sizes still take the kernel.
    from repro.kernels import dispatch
    page = cfg.kv_page_size or math.gcd(smax, DEFAULT_PAGE)
    decode_route = dispatch.decode_attention_route(
        cfg, group=g, head_dim=hd, itemsize=new_k.dtype.itemsize,
        page=page, smax=smax, ring=ring,
        floating=jnp.issubdtype(x.dtype, jnp.floating))
    if decode_route == "attn_decode_flash":
        window = (cfg.sliding_window if window_override is None
                  else window_override)
        n_log = smax // page
        kp = new_k.reshape(b * n_log, page, hkv, hd)
        vp = new_v.reshape(b * n_log, page, hkv, hd)
        o = paged_decode_attention(
            q.reshape(b, hkv, g, hd), kp, vp, identity_block_table(b, n_log),
            lengths, start, window=window, softcap=cfg.attn_logit_softcap)
        o = o.reshape(b, 1, hq * hd).astype(x.dtype)
        return _o_proj(p["o_proj"], o, cfg), new_k, new_v

    qg = q.reshape(b, 1, hkv, g, hd)
    sc = _scores(qg, new_k, cfg)                     # [B,H,G,1,Smax]
    kpos = jnp.arange(smax)[None, :]                 # [1, Smax]
    if ring:
        valid = kpos < jnp.minimum(lengths[:, None] + 1, smax)
    else:
        valid = kpos <= lengths[:, None]
        if start is not None:
            valid &= kpos >= start[:, None]      # pad slots never attended
        window = (cfg.sliding_window if window_override is None
                  else window_override)
        if window > 0:
            valid &= kpos > (lengths[:, None] - window)
    sc = sc + jnp.where(valid, 0.0, _NEG_INF)[:, None, None, None, :]
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgts,bshd->bthgd", pr.astype(new_v.dtype), new_v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, hq * hd).astype(x.dtype)
    y = _o_proj(p["o_proj"], o, cfg)
    return y, new_k, new_v


def verify_attention_apply(p: Dict, cfg: ModelConfig, x: jax.Array,
                           cache_k: jax.Array, cache_v: jax.Array,
                           lengths: jax.Array,
                           start: Optional[jax.Array] = None,
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Speculative VERIFY attention (DESIGN.md §15): x [B, T, d] carries
    the current token plus the T-1 draft tokens; their K/V land at
    absolute cache slots ``lengths .. lengths+T-1`` and every position
    attends the row's cache causally (self included) — one skinny-M
    batched step scores all T candidates through the unchanged cache
    instead of T sequential decode steps.

    Rejected drafts are rolled back by LENGTH ACCOUNTING alone: the
    engine advances ``length`` by the accepted count, future steps mask
    ``kpos > length`` and the next write overwrites the stale slots, so
    the pool itself is never touched twice. Same ragged contract as
    `decode_attention_apply`: RoPE at logical positions
    ``lengths - start + t``, pad slots below ``start`` never attended.
    """
    b, t, _ = x.shape
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    smax = cache_k.shape[1]
    st = jnp.zeros_like(lengths) if start is None else start
    qpos = (lengths - st)[:, None] + jnp.arange(t)[None, :]   # [B,T] logical
    q, k, v = _project_qkv(p, cfg, x, qpos)

    def upd(cache, new, i):
        return jax.lax.dynamic_update_slice(cache, new, (i, 0, 0))
    new_k = jax.vmap(upd)(cache_k, k.astype(cache_k.dtype), lengths)
    new_v = jax.vmap(upd)(cache_v, v.astype(cache_v.dtype), lengths)

    # logical key positions: slot s holds logical position s - start, so
    # pad slots sit below zero (masked) and the block's fresh keys line
    # up exactly under qpos — causal `kpos <= qpos` bounds each candidate
    # to its own prefix, matching a token-at-a-time decode bit-for-bit.
    kpos = jnp.arange(smax)[None, :] - st[:, None]            # [B, Smax]
    o = _naive_attention(q, new_k, new_v, qpos, kpos, cfg)
    return _o_proj(p["o_proj"], o.reshape(b, t, hq * hd), cfg), new_k, new_v


def paged_verify_attention_apply(p: Dict, cfg: ModelConfig, x: jax.Array,
                                 k_pages: jax.Array, v_pages: jax.Array,
                                 block_table: jax.Array, lengths: jax.Array,
                                 start: Optional[jax.Array] = None,
                                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """`verify_attention_apply` against the paged KV pool (DESIGN.md
    §10/§15): the T candidate K/V scatter through the block table to
    their owning physical pages, then the row's logical cache is
    gathered back for the same naive masked attention — identical key
    order and identical f32 arithmetic as the contiguous twin, so paged
    and contiguous speculative serving stay bit-identical. Rows whose
    table points at the reserved dummy page (retired slots still
    stepping) write there harmlessly; logical page indices clamp so
    overshoot never runs off the table."""
    from repro.kernels.attn.ref import gather_pages
    b, t, _ = x.shape
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    page = k_pages.shape[1]
    n_log = block_table.shape[1]
    st = jnp.zeros_like(lengths) if start is None else start
    qpos = (lengths - st)[:, None] + jnp.arange(t)[None, :]   # [B,T] logical
    q, k, v = _project_qkv(p, cfg, x, qpos)

    slots = lengths[:, None] + jnp.arange(t)[None, :]         # [B,T] absolute
    logp = jnp.clip(slots // page, 0, n_log - 1)
    phys = jnp.take_along_axis(block_table, logp, axis=1)     # [B,T]
    off = slots % page
    new_kp = k_pages.at[phys, off].set(k.astype(k_pages.dtype))
    new_vp = v_pages.at[phys, off].set(v.astype(v_pages.dtype))

    krow = gather_pages(new_kp, block_table)                  # [B, S, Hkv, D]
    vrow = gather_pages(new_vp, block_table)
    kpos = jnp.arange(n_log * page)[None, :] - st[:, None]
    o = _naive_attention(q, krow, vrow, qpos, kpos, cfg)
    return (_o_proj(p["o_proj"], o.reshape(b, t, hq * hd), cfg),
            new_kp, new_vp)


def paged_decode_attention_apply(p: Dict, cfg: ModelConfig, x: jax.Array,
                                 k_pages: jax.Array, v_pages: jax.Array,
                                 block_table: jax.Array, lengths: jax.Array,
                                 window_override: Optional[int] = None,
                                 start: Optional[jax.Array] = None,
                                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a paged KV pool (DESIGN.md §10): x [B, 1, d];
    k_pages/v_pages [P, page, Hkv, D]; block_table [B, n_log] maps each
    row's logical pages to physical pool pages. Returns
    (y, new_k_pages, new_v_pages).

    Same per-row contract as `decode_attention_apply`: ``lengths`` is the
    absolute cache slot of the new token, ``start`` the first real slot of
    a left-padded row. The new K/V scatter resolves the owning physical
    page through the table; rows whose table points at the reserved dummy
    page (retired slots still stepping inside a decode chunk) write there
    harmlessly, and the logical page index clamps so overshoot never runs
    off the table (mirroring the contiguous cache's clamped
    dynamic_update_slice)."""
    b = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = hq // hkv
    page = k_pages.shape[1]
    n_log = block_table.shape[1]
    rope_pos = lengths if start is None else lengths - start
    q, k, v = _project_qkv(p, cfg, x, rope_pos[:, None])

    logp = jnp.clip(lengths // page, 0, n_log - 1)
    phys = jnp.take_along_axis(block_table, logp[:, None], axis=1)[:, 0]
    off = lengths % page
    new_kp = k_pages.at[phys, off].set(k[:, 0].astype(k_pages.dtype))
    new_vp = v_pages.at[phys, off].set(v[:, 0].astype(v_pages.dtype))

    window = (cfg.sliding_window if window_override is None
              else window_override)
    o = paged_decode_attention(
        q.reshape(b, hkv, g, hd), new_kp, new_vp, block_table, lengths,
        start, window=window, softcap=cfg.attn_logit_softcap)
    o = o.reshape(b, 1, hq * hd).astype(x.dtype)
    return _o_proj(p["o_proj"], o, cfg), new_kp, new_vp
