"""Decoder-LM host: embeddings → scan-over-layers → final norm (→ LM head).

One host covers every assigned family:
  dense_lm / audio_lm / vlm_lm : attention + MLP blocks
  moe_lm                       : attention + MoE blocks (aux loss threaded)
  rwkv6                        : time-mix + channel-mix (attention-free)
  zamba2                       : Mamba2 backbone + one *shared* attention
                                 block applied every `shared_period` layers

Layers are scanned with stacked params (compile time O(1 layer)); the
zamba2 hybrid scans each Mamba group and interleaves the shared block in a
static Python loop. `remat` wraps the layer body per config.

`forward` returns hidden states (not logits): the LM head is applied by the
loss/serve layer so the vocab-parallel cross-entropy never materializes
unsharded logits.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.mesh_ctx import shard_hint
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rw
from repro.models.common import (dtype_of, embed_apply, embed_init,
                                 linear_init, norm_apply, norm_init)
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init

__all__ = ["init_params", "forward", "decode_step", "verify_step",
           "prefill", "prefill_packed", "prefill_continue", "init_cache",
           "lm_head_weight"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    if cfg.family == "rwkv6":
        return rw.rwkv6_layer_init(ks[0], cfg, dtype)
    if cfg.family == "zamba2":
        return {"mamba": m2.mamba2_init(ks[0], cfg, dtype),
                "ln": norm_init(cfg.norm, cfg.d_model, dtype)}
    p = {
        "attn": attn.attention_init(ks[0], cfg, dtype),
        "ln_attn": norm_init(cfg.norm, cfg.d_model, dtype),
        "ln_mlp": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.family == "moe_lm":
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg, dtype)
    return p


def _shared_block_init(key, cfg: ModelConfig, dtype) -> Dict:
    """Zamba2's shared attention+MLP block (one set of weights)."""
    ks = jax.random.split(key, 2)
    return {
        "attn": attn.attention_init(ks[0], cfg, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg, dtype),
        "ln_attn": norm_init(cfg.norm, cfg.d_model, dtype),
        "ln_mlp": norm_init(cfg.norm, cfg.d_model, dtype),
    }


def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(ks[2], cfg.d_model, cfg.vocab_size,
                                        dtype)
    if cfg.family == "zamba2":
        params["shared_block"] = _shared_block_init(ks[3], cfg, dtype)
    return params


def lm_head_weight(params: Dict, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

# Families whose layer blocks (attention + MLP) consume DbbWeight leaves
# directly through the DBB kernels — the packed-weight streaming fast path
# (DESIGN.md §9). SSM/hybrid time-mix and MoE expert einsums still need
# dense weights and keep the per-layer transient expand.
_STREAM_FAMILIES = ("dense_lm", "vlm_lm", "audio_lm")


def _stream_packed(cfg: ModelConfig) -> bool:
    """Whether packed layer weights can skip the per-layer dense expand:
    the attention/MLP blocks stream DbbWeight leaves straight through the
    DBB Pallas kernels (the dispatch registry's dbb routes, DESIGN.md
    §11), so the weight stays compressed end-to-end — HBM holds only
    values+bitmask and the kernel decompresses tiles in VMEM."""
    from repro.kernels.dispatch import pallas_route_active
    return cfg.family in _STREAM_FAMILIES and pallas_route_active(cfg)


def _unpack_layer(lp: Dict, cfg: ModelConfig) -> Dict:
    """Per-layer DBB decompression inside the scan body: the stacked
    weights stay packed in HBM; only the current layer's dense form is
    live (§Perf iteration 17). No-op for dense trees. Under the packed
    streaming fast path (DESIGN.md §9) even that per-layer transient is
    skipped — the kernels consume the compressed leaves directly."""
    if _stream_packed(cfg):
        return lp
    from repro.core.dbb_linear import maybe_decompress_tree
    return maybe_decompress_tree(lp, dtype=dtype_of(cfg))


def _attn_mlp_layer(lp: Dict, cfg: ModelConfig, x: jax.Array,
                    window_override: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    lp = _unpack_layer(lp, cfg)
    h = norm_apply(cfg.norm, lp["ln_attn"], x)
    x = x + attn.attention_apply(lp["attn"], cfg, h,
                                 window_override=window_override)
    h = norm_apply(cfg.norm, lp["ln_mlp"], x)
    if cfg.family == "moe_lm":
        y, aux = moe_apply(lp["moe"], cfg, h)
        return x + y, aux
    return x + mlp_apply(lp["mlp"], cfg, h), jnp.zeros((), jnp.float32)


def _wrap_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        # save matmul outputs (fastest bwd, largest live set)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    # auto: real-size models checkpoint at layer boundaries plus the two
    # named fat MLP up-projections (§Perf iteration 8) — skipping their
    # recompute buys back ~50% of the remat flops for ~56 MB/layer/shard;
    # smoke configs skip remat entirely.
    if cfg.d_model >= 1024:
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "mlp_wi", "mlp_wg"))
    return fn


def _scan_layers(stacked: Any, x: jax.Array, body) -> Tuple[jax.Array, jax.Array]:
    """body(lp, x) -> (x, aux). Returns (x, aux_sum)."""
    def step(carry, lp):
        x, aux = carry
        x, a = body(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# ---------------------------------------------------------------------------
# forward (train / prefill shapes)
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, tokens=None, embeds=None,
                  prefix_embeds=None) -> jax.Array:
    dtype = dtype_of(cfg)
    if embeds is not None:
        x = embeds.astype(dtype)
    else:
        x = embed_apply(params["embed"], tokens, dtype,
                        vocab_parallel=cfg.parallel != "dp")
        if cfg.family in ("dense_lm", "moe_lm", "vlm_lm"):
            x = x * (cfg.d_model ** 0.5)
    if prefix_embeds is not None:       # vlm: SigLIP patch embeddings
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    return x


def forward(params: Dict, cfg: ModelConfig, tokens=None, embeds=None,
            prefix_embeds=None, window_override: Optional[int] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden [B, S, d], moe aux loss scalar)."""
    x = _embed_inputs(params, cfg, tokens, embeds, prefix_embeds)
    from repro.dist.mesh_ctx import axis_size
    from repro.models.mlp import seq_parallel_ok
    if seq_parallel_ok(cfg, x.shape[1], axis_size("model")):
        # SP residual layout (the blocks gather/scatter at their edges)
        x = shard_hint(x, ("pod", "data"), "model", None)
    elif cfg.parallel == "dp":
        x = shard_hint(x, ("pod", "data", "model"), None, None)
    else:
        x = shard_hint(x, ("pod", "data"), None, None)

    if cfg.family == "rwkv6":
        body = _wrap_remat(
            lambda lp, xx: (rw.rwkv6_layer_apply(_unpack_layer(lp, cfg),
                                                 cfg, xx)[0],
                            jnp.zeros((), jnp.float32)), cfg)
        x, aux = _scan_layers(params["layers"], x, body)
    elif cfg.family == "zamba2":
        x, aux = _zamba2_forward(params, cfg, x, window_override)
    else:
        body = _wrap_remat(
            lambda lp, xx: _attn_mlp_layer(lp, cfg, xx, window_override), cfg)
        x, aux = _scan_layers(params["layers"], x, body)

    x = norm_apply(cfg.norm, params["final_norm"], x)
    return x, aux


def _zamba2_forward(params, cfg: ModelConfig, x, window_override=None):
    period = cfg.ssm.shared_period
    L = cfg.num_layers
    sb = params["shared_block"]
    aux = jnp.zeros((), jnp.float32)
    scfg = cfg.replace(family="dense_lm")

    def mamba_body(lp, xx):
        lp = _unpack_layer(lp, cfg)
        h = norm_apply(cfg.norm, lp["ln"], xx)
        y, _ = m2.mamba2_apply(lp["mamba"], cfg, h)
        return xx + y, jnp.zeros((), jnp.float32)

    body = _wrap_remat(mamba_body, cfg)
    # the shared block sits in the unrolled group loop — without its own
    # remat each invocation pins its full chunked-attention score tensors
    # (~5 GB/device per block on train_4k)
    shared_body = _wrap_remat(
        lambda sbp, xx: _attn_mlp_layer(sbp, scfg, xx,
                                        window_override=window_override),
        cfg)
    bounds = list(range(0, L, period)) + [L]
    for gi in range(len(bounds) - 1):
        g0, g1 = bounds[gi], bounds[gi + 1]
        group = jax.tree_util.tree_map(lambda a: a[g0:g1], params["layers"])
        x, _ = _scan_layers(group, x, body)
        if g1 < L or gi == len(bounds) - 2:
            x, _ = shared_body(sb, x)
    return x, aux


# ---------------------------------------------------------------------------
# caches, prefill and decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    dtype = dtype_of(cfg)
    if cfg.family == "rwkv6":
        return dict(rw.init_rwkv_state(cfg, batch, dtype),
                    length=jnp.zeros((batch,), jnp.int32))
    if cfg.family == "zamba2":
        n_groups = -(-cfg.num_layers // cfg.ssm.shared_period)
        d_in, h, p, n = m2._dims(cfg)
        cw = cfg.ssm.conv_width
        win = min(max_len, cfg.ssm.shared_window or max_len)
        return {
            "ssd": jnp.zeros((cfg.num_layers, batch, h, p, n), jnp.float32),
            "conv": jnp.zeros((cfg.num_layers, batch, cw - 1, d_in + 2 * n),
                              dtype),
            "shared_k": jnp.zeros((n_groups, batch, win,
                                   cfg.num_kv_heads, cfg.resolved_head_dim),
                                  dtype),
            "shared_v": jnp.zeros((n_groups, batch, win,
                                   cfg.num_kv_heads, cfg.resolved_head_dim),
                                  dtype),
            "length": jnp.zeros((batch,), jnp.int32),
        }
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((cfg.num_layers, batch, max_len, hkv, hd), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def _cached_layer_body(cfg: ModelConfig, attn_call):
    """One cached-step layer body (decode and speculative verify; the KV
    layout — contiguous vs paged, DESIGN.md §10 — and the step kind only
    change the attention call, so all four paths share this block and
    cannot drift)."""
    def body(x, xs):
        lp, ck, cv = xs
        lp = _unpack_layer(lp, cfg)
        h = norm_apply(cfg.norm, lp["ln_attn"], x)
        y, nk, nv = attn_call(lp, h, ck, cv)
        x = x + y
        h = norm_apply(cfg.norm, lp["ln_mlp"], x)
        if cfg.family == "moe_lm":
            z, _ = moe_apply(lp["moe"], cfg, h)
            x = x + z
        else:
            x = x + mlp_apply(lp["mlp"], cfg, h)
        return x, (nk, nv)
    return body


def verify_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict) -> Tuple[jax.Array, Dict]:
    """Speculative VERIFY: score T candidate tokens in ONE skinny-M
    batched step (DESIGN.md §15). ``tokens [B, T]`` carries the current
    token plus the T-1 draft tokens per row; every layer's K/V is written
    at cache slots ``length .. length+T-1`` and the returned hidden
    ``[B, T, d]`` yields the full model's distribution at each candidate
    position.

    ``cache["length"]`` is left UNTOUCHED: the caller advances it by the
    accepted count, which IS the rollback — stale K/V past the accepted
    prefix is masked by ``kpos <= length`` everywhere and overwritten by
    the next step, in both KV layouts."""
    assert cfg.family in ("dense_lm", "moe_lm", "vlm_lm",
                          "audio_lm"), cfg.family
    dtype = dtype_of(cfg)
    x = embed_apply(params["embed"], tokens, dtype,
                    vocab_parallel=cfg.parallel != "dp")
    if cfg.family in ("dense_lm", "moe_lm", "vlm_lm"):
        x = x * (cfg.d_model ** 0.5)
    start = cache.get("start")
    lengths = cache["length"]

    if "k_pages" in cache:
        table = cache["block_table"]
        body = _cached_layer_body(
            cfg, lambda lp, h, kp, vp: attn.paged_verify_attention_apply(
                lp["attn"], cfg, h, kp, vp, table, lengths, start=start))
        x, (nkp, nvp) = jax.lax.scan(
            body, x, (params["layers"], cache["k_pages"],
                      cache["v_pages"]))
        x = norm_apply(cfg.norm, params["final_norm"], x)
        return x, dict(cache, k_pages=nkp, v_pages=nvp)

    body = _cached_layer_body(
        cfg, lambda lp, h, ck, cv: attn.verify_attention_apply(
            lp["attn"], cfg, h, ck, cv, lengths, start=start))
    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = norm_apply(cfg.norm, params["final_norm"], x)
    return x, dict(cache, k=nk, v=nv)


def decode_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict, embeds: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict]:
    """One new token for every sequence. tokens: [B] (or embeds [B,1,d]).
    Returns (hidden [B,1,d], updated cache).

    If the cache carries ragged-prefill offsets (``cache["start"]``, set by
    `prefill(start=...)`), attention masks the left-pad slots and shifts
    RoPE positions per row (DESIGN.md §5)."""
    dtype = dtype_of(cfg)
    if embeds is not None:
        x = embeds.astype(dtype)
    else:
        x = embed_apply(params["embed"], tokens[:, None], dtype,
                        vocab_parallel=cfg.parallel != "dp")
        if cfg.family in ("dense_lm", "moe_lm", "vlm_lm"):
            x = x * (cfg.d_model ** 0.5)

    if cfg.family == "rwkv6":
        def body(x, xs):
            lp, st = xs
            y, new_st = rw.rwkv6_decode_step(_unpack_layer(lp, cfg), cfg,
                                             x, st)
            return y, new_st

        st = {"wkv": cache["wkv"], "shift_tm": cache["shift_tm"],
              "shift_cm": cache["shift_cm"]}
        x, new_st = jax.lax.scan(body, x, (params["layers"], st))
        new_cache = dict(new_st, length=cache["length"] + 1)
        x = norm_apply(cfg.norm, params["final_norm"], x)
        return x, new_cache

    if cfg.family == "zamba2":
        return _zamba2_decode(params, cfg, x, cache)

    start = cache.get("start")

    def make_body(attn_call):
        return _cached_layer_body(cfg, attn_call)

    if "k_pages" in cache:
        # paged KV cache (DESIGN.md §10): per-layer page pools scan with
        # the layer stack; the block table / lengths / starts are
        # row-indexed and shared across layers (one allocation serves all
        # L pools at the same physical page index)
        table = cache["block_table"]
        body = make_body(lambda lp, h, kp, vp: attn.paged_decode_attention_apply(
            lp["attn"], cfg, h, kp, vp, table, cache["length"], start=start))
        x, (nkp, nvp) = jax.lax.scan(
            body, x, (params["layers"], cache["k_pages"], cache["v_pages"]))
        x = norm_apply(cfg.norm, params["final_norm"], x)
        return x, {"k_pages": nkp, "v_pages": nvp, "block_table": table,
                   "length": cache["length"] + 1,
                   "start": (start if start is not None
                             else jnp.zeros_like(cache["length"]))}

    body = make_body(lambda lp, h, ck, cv: attn.decode_attention_apply(
        lp["attn"], cfg, h, ck, cv, cache["length"], start=start))
    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = norm_apply(cfg.norm, params["final_norm"], x)
    new_cache = {"k": nk, "v": nv, "length": cache["length"] + 1}
    if start is not None:
        new_cache["start"] = start
    return x, new_cache


def _zamba2_decode(params, cfg: ModelConfig, x, cache):
    period = cfg.ssm.shared_period
    L = cfg.num_layers
    sb = params["shared_block"]
    win = cache["shared_k"].shape[2]

    def mamba_body(x, xs):
        lp, ssd, conv = xs
        lp = _unpack_layer(lp, cfg)
        h = norm_apply(cfg.norm, lp["ln"], x)
        y, (nssd, nconv) = m2.mamba2_apply(lp["mamba"], cfg, h, state=ssd,
                                           conv_ctx=conv)
        return x + y, (nssd, nconv)

    bounds = list(range(0, L, period)) + [L]
    new_ssd, new_conv = [], []
    new_sk, new_sv = [], []
    scfg = cfg.replace(family="dense_lm")
    for gi in range(len(bounds) - 1):
        g0, g1 = bounds[gi], bounds[gi + 1]
        sl = lambda a: a[g0:g1]
        x, (nssd, nconv) = jax.lax.scan(
            mamba_body, x,
            (jax.tree_util.tree_map(sl, params["layers"]),
             cache["ssd"][g0:g1], cache["conv"][g0:g1]))
        new_ssd.append(nssd)
        new_conv.append(nconv)
        if g1 < L or gi == len(bounds) - 2:
            h = norm_apply(cfg.norm, sb["ln_attn"], x)
            y, nk, nv = attn.decode_attention_apply(
                sb["attn"], scfg, h, cache["shared_k"][gi],
                cache["shared_v"][gi], cache["length"], ring=True)
            x = x + y
            h = norm_apply(cfg.norm, sb["ln_mlp"], x)
            x = x + mlp_apply(sb["mlp"], scfg, h)
            new_sk.append(nk)
            new_sv.append(nv)
    new_cache = {
        "ssd": jnp.concatenate(new_ssd, 0),
        "conv": jnp.concatenate(new_conv, 0),
        "shared_k": jnp.stack(new_sk, 0),
        "shared_v": jnp.stack(new_sv, 0),
        "length": cache["length"] + 1,
    }
    x = norm_apply(cfg.norm, params["final_norm"], x)
    return x, new_cache


def prefill(params: Dict, cfg: ModelConfig, tokens=None, embeds=None,
            prefix_embeds=None, cache: Optional[Dict] = None,
            start: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Dict]:
    """Full-context forward that also fills the cache (serving prefill).

    For attention archs this recomputes K/V per layer into the cache; for
    SSM/hybrid archs it runs the stateful forward and stores final states.

    start [B] (optional): per-row count of left-pad tokens in a ragged
    batch. Attention archs shift RoPE positions to ``t - start`` and mask
    the pad keys so every row prefills exactly as it would solo; the
    offsets ride in the returned cache (``cache["start"]``) for the decode
    steps (DESIGN.md §5). SSM/hybrid archs ignore the hint — their
    recurrent state consumes pads by construction, so ragged exactness
    there needs right-padding + state masking (not yet implemented).
    """
    x = _embed_inputs(params, cfg, tokens, embeds, prefix_embeds)
    b, s, _ = x.shape
    if cache is None:
        cache = init_cache(cfg, b, s)

    if cfg.family == "rwkv6":
        def body(x, lp):
            y, st = rw.rwkv6_layer_apply(_unpack_layer(lp, cfg), cfg, x)
            return y, st

        x, st = jax.lax.scan(body, x, params["layers"])
        cache = dict(st, length=cache["length"] + s)
        x = norm_apply(cfg.norm, params["final_norm"], x)
        return x, cache

    if cfg.family == "zamba2":
        return _zamba2_prefill(params, cfg, x, cache)

    # per-row ragged positions: pads (t < start) sit at negative logical
    # positions, which the attention mask excludes as keys
    positions = jnp.arange(s)[None, :]
    if start is not None:
        positions = positions - start[:, None]

    def body(x, xs):
        lp, ck, cv = xs
        lp = _unpack_layer(lp, cfg)
        h = norm_apply(cfg.norm, lp["ln_attn"], x)
        q, k, v = attn._project_qkv(lp["attn"], cfg, h, positions)
        nk = jax.lax.dynamic_update_slice(ck, k, (0, 0, 0, 0))
        nv = jax.lax.dynamic_update_slice(cv, v, (0, 0, 0, 0))
        y = attn.attention_apply(lp["attn"], cfg, h, positions=positions,
                                 ragged=start is not None, qkv=(q, k, v))
        x = x + y
        h = norm_apply(cfg.norm, lp["ln_mlp"], x)
        if cfg.family == "moe_lm":
            z, _ = moe_apply(lp["moe"], cfg, h)
            x = x + z
        else:
            x = x + mlp_apply(lp["mlp"], cfg, h)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = norm_apply(cfg.norm, params["final_norm"], x)
    new_cache = {"k": nk, "v": nv, "length": cache["length"] + s}
    if start is not None:
        new_cache["start"] = start
    return x, new_cache


def prefill_packed(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                   seg_ids: jax.Array, positions: jax.Array,
                   rows: jax.Array, cols: jax.Array, cache: Dict
                   ) -> Tuple[jax.Array, Dict]:
    """Padding-free packed prefill (DESIGN.md §12): the ragged batch's
    tokens ride concatenated in ``tokens [1, Tp]`` (Tp = bucketed total),
    with per-token metadata instead of a [B, T_max] grid —

      seg_ids   [Tp]    owning request per packed position (non-decreasing;
                        padding carries a larger sentinel)
      positions [1, Tp] logical position within the owning request (RoPE +
                        block-diagonal-causal masking)
      rows/cols [Tp]    KV scatter address per token: (batch row, slot) for
                        a contiguous cache, (physical page, offset) for a
                        paged pool. Padding rows carry an out-of-range row
                        sentinel and are DROPPED by the scatter — no pad
                        token ever lands in a cache.

    Returns (hidden [1, Tp, d], cache with K/V scattered in). Bookkeeping
    leaves (length / start / block_table) are untouched: the engine
    installs them when a request's prefill completes, which is what keeps
    half-prefilled rows invisible to the decode batch."""
    assert cfg.family in ("dense_lm", "moe_lm", "vlm_lm", "audio_lm"), cfg.family
    x = _embed_inputs(params, cfg, tokens)

    def body(x, xs):
        lp, ck, cv = xs
        lp = _unpack_layer(lp, cfg)
        h = norm_apply(cfg.norm, lp["ln_attn"], x)
        q, k, v = attn._project_qkv(lp["attn"], cfg, h, positions)
        nk = ck.at[rows, cols].set(k[0].astype(ck.dtype), mode="drop")
        nv = cv.at[rows, cols].set(v[0].astype(cv.dtype), mode="drop")
        y = attn.packed_attention_apply(lp["attn"], cfg, h, seg_ids,
                                        positions, qkv=(q, k, v))
        x = x + y
        h = norm_apply(cfg.norm, lp["ln_mlp"], x)
        if cfg.family == "moe_lm":
            z, _ = moe_apply(lp["moe"], cfg, h)
            x = x + z
        else:
            x = x + mlp_apply(lp["mlp"], cfg, h)
        return x, (nk, nv)

    kk, vv = (("k_pages", "v_pages") if "k_pages" in cache else ("k", "v"))
    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache[kk],
                                         cache[vv]))
    x = norm_apply(cfg.norm, params["final_norm"], x)
    return x, dict(cache, **{kk: nk, vv: nv})


def prefill_continue(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                     positions: jax.Array, rows: jax.Array, cols: jax.Array,
                     kv_sel: jax.Array, cache: Dict
                     ) -> Tuple[jax.Array, Dict]:
    """Chunked-prefill continuation for ONE request (DESIGN.md §12):
    ``tokens [1, C]`` is the next chunk of a long prompt whose earlier
    chunks already sit in the cache; ``positions [1, C]`` its absolute
    positions (``offset .. offset+C-1`` — packed-admitted rows have no
    left-pad, so logical == absolute). rows/cols address the K/V scatter
    exactly as in `prefill_packed`. ``kv_sel`` selects the row's cache for
    attention: the slot index (contiguous) or the [n_log] block-table row
    (paged). The chunk attends its own fresh keys plus every earlier slot
    through the causal mask — never another row's."""
    assert cfg.family in ("dense_lm", "moe_lm", "vlm_lm", "audio_lm"), cfg.family
    x = _embed_inputs(params, cfg, tokens)
    offset = positions[0, 0]
    paged = "k_pages" in cache
    if paged:
        from repro.kernels.attn.ref import gather_pages

    def body(x, xs):
        lp, ck, cv = xs
        lp = _unpack_layer(lp, cfg)
        h = norm_apply(cfg.norm, lp["ln_attn"], x)
        q, k, v = attn._project_qkv(lp["attn"], cfg, h, positions)
        nk = ck.at[rows, cols].set(k[0].astype(ck.dtype), mode="drop")
        nv = cv.at[rows, cols].set(v[0].astype(cv.dtype), mode="drop")
        if paged:
            krow = gather_pages(nk, kv_sel[None])       # [1, S, Hkv, D]
            vrow = gather_pages(nv, kv_sel[None])
        else:
            krow = jax.lax.dynamic_slice_in_dim(nk, kv_sel, 1, axis=0)
            vrow = jax.lax.dynamic_slice_in_dim(nv, kv_sel, 1, axis=0)
        y = attn.chunk_attention_apply(lp["attn"], cfg, q, krow, vrow,
                                       offset)
        x = x + y
        h = norm_apply(cfg.norm, lp["ln_mlp"], x)
        if cfg.family == "moe_lm":
            z, _ = moe_apply(lp["moe"], cfg, h)
            x = x + z
        else:
            x = x + mlp_apply(lp["mlp"], cfg, h)
        return x, (nk, nv)

    kk, vv = (("k_pages", "v_pages") if paged else ("k", "v"))
    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache[kk],
                                         cache[vv]))
    x = norm_apply(cfg.norm, params["final_norm"], x)
    return x, dict(cache, **{kk: nk, vv: nv})


def _zamba2_prefill(params, cfg: ModelConfig, x: jax.Array, cache: Dict
                    ) -> Tuple[jax.Array, Dict]:
    """Full-context zamba2 forward that also fills the hybrid cache:
    per-layer Mamba2 (ssd, conv) final states + ring-buffered shared-attn
    K/V for the last `win` positions."""
    b, s, _ = x.shape
    period = cfg.ssm.shared_period
    L = cfg.num_layers
    sb = params["shared_block"]
    win = cache["shared_k"].shape[2]
    scfg = cfg.replace(family="dense_lm")

    def mamba_body(xx, lp):
        lp = _unpack_layer(lp, cfg)
        h = norm_apply(cfg.norm, lp["ln"], xx)
        y, (ssd, conv) = m2.mamba2_apply(lp["mamba"], cfg, h)
        return xx + y, (ssd, conv)

    bounds = list(range(0, L, period)) + [L]
    ssd_parts, conv_parts, sk_parts, sv_parts = [], [], [], []
    # ring slots of the last `win` absolute positions
    tail = min(win, s)
    slots = (jnp.arange(s - tail, s)) % win
    for gi in range(len(bounds) - 1):
        g0, g1 = bounds[gi], bounds[gi + 1]
        group = jax.tree_util.tree_map(lambda a: a[g0:g1], params["layers"])
        x, (ssd_g, conv_g) = jax.lax.scan(mamba_body, x, group)
        ssd_parts.append(ssd_g)
        conv_parts.append(conv_g)
        if g1 < L or gi == len(bounds) - 2:
            h = norm_apply(cfg.norm, sb["ln_attn"], x)
            _, k, v = attn._project_qkv(sb["attn"], scfg, h,
                                        jnp.arange(s)[None, :])
            nk = cache["shared_k"][gi].at[:, slots].set(
                k[:, s - tail:].astype(cache["shared_k"].dtype))
            nv = cache["shared_v"][gi].at[:, slots].set(
                v[:, s - tail:].astype(cache["shared_v"].dtype))
            sk_parts.append(nk)
            sv_parts.append(nv)
            y = attn.attention_apply(sb["attn"], scfg, h,
                                     window_override=win)
            x = x + y
            h = norm_apply(cfg.norm, sb["ln_mlp"], x)
            x = x + mlp_apply(sb["mlp"], scfg, h)
    x = norm_apply(cfg.norm, params["final_norm"], x)
    new_cache = {
        "ssd": jnp.concatenate(ssd_parts, 0),
        "conv": jnp.concatenate(conv_parts, 0).astype(cache["conv"].dtype),
        "shared_k": jnp.stack(sk_parts, 0),
        "shared_v": jnp.stack(sv_parts, 0),
        "length": cache["length"] + s,
    }
    return x, new_cache
