"""The paper's own model family: small CNNs with convolution *lowered to
GEMM* (im2col), exactly the premise of the paper ("CNN layers are typically
implemented by lowering 2D convolution to GEMM kernels").

Every conv/fc weight is a GEMM weight matrix [K, N] with K = kh·kw·c_in,
so the DBB 8×1 blocks run along the GEMM contraction dim — the same layout
the STA-DBB hardware consumes, and the layout `core.dbb`/`kernels.dbb_gemm`
expect. The forward can route matmuls through the Pallas kernels
(`matmul="sta" | "dbb"`) or plain XLA (training).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.dbb import DbbWeight
from repro.kernels.dbb_gemm.ops import dbb_gemm_packed
from repro.models.common import linear_apply, normal_init

__all__ = ["cnn_init", "cnn_apply", "im2col"]


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           pad: str = "SAME") -> jax.Array:
    """x: [B, H, W, C] -> patches [B, Ho, Wo, kh*kw*C]."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches yields channel-major [C*kh*kw]; reorder to
    # [kh*kw*C] so K blocks run over spatial-then-channel (any fixed order
    # works for DBB; this matches the weight reshape below).
    b, ho, wo, ckk = patches.shape
    c = x.shape[-1]
    patches = patches.reshape(b, ho, wo, c, kh * kw)
    patches = jnp.moveaxis(patches, -2, -1)
    return patches.reshape(b, ho, wo, kh * kw * c)


def _matmul(x: jax.Array, w, mode: str, bias=None,
            act: str = "none") -> jax.Array:
    """GEMM with optional fused bias/activation epilogue.

    Pallas routes ("sta" / packed DbbWeight) fuse bias+act into the kernel's
    final-K store (DESIGN.md §7); the XLA route applies them as separate ops
    (differentiable — the training path)."""
    if isinstance(w, DbbWeight):
        return dbb_gemm_packed(x, w, bias, act=act)
    p = {"w": w} if bias is None else {"w": w, "b": bias}
    return linear_apply(p, x, act=act, fused=mode == "sta")


def cnn_init(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    params: Dict = {}
    cin, k = cfg.cnn_in_ch, cfg.cnn_kernel
    keys = jax.random.split(key, len(cfg.cnn_channels) + 1)
    for i, cout in enumerate(cfg.cnn_channels):
        kdim = k * k * cin
        params[f"conv{i}"] = {
            "w": normal_init(keys[i], (kdim, cout), 1.0 / math.sqrt(kdim),
                             dtype),
            "b": jnp.zeros((cout,), dtype),
        }
        cin = cout
    img = cfg.cnn_img // (2 ** len(cfg.cnn_channels))
    fdim = cin * img * img
    params["fc"] = {
        "w": normal_init(keys[-1], (fdim, cfg.cnn_classes),
                         1.0 / math.sqrt(fdim), dtype),
        "b": jnp.zeros((cfg.cnn_classes,), dtype),
    }
    return params


def cnn_apply(params: Dict, cfg: ModelConfig, images: jax.Array,
              matmul: str = "xla") -> jax.Array:
    """images: [B, H, W, C] -> logits [B, classes]."""
    x = images
    k = cfg.cnn_kernel
    for i, cout in enumerate(cfg.cnn_channels):
        b, h, w, c = x.shape
        cols = im2col(x, k, k)                       # [B,H,W,k*k*C]
        y = _matmul(cols.reshape(b * h * w, -1), params[f"conv{i}"]["w"],
                    matmul, bias=params[f"conv{i}"]["b"], act="relu")
        y = y.reshape(b, h, w, cout)
        x = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    b = x.shape[0]
    flat = x.reshape(b, -1)
    return _matmul(flat, params["fc"]["w"], matmul, bias=params["fc"]["b"])
