"""The paper's own model family: small CNNs with convolution *lowered to
GEMM*, exactly the premise of the paper ("CNN layers are typically
implemented by lowering 2D convolution to GEMM kernels").

Every conv/fc weight is a GEMM weight matrix [K, N] with K = kh·kw·c_in,
so the DBB 8×1 blocks run along the GEMM contraction dim — the same layout
the STA-DBB hardware consumes, and the layout `core.dbb` and the DBB
kernels behind `kernels.dispatch` expect.

Routing (DESIGN.md §8): ``matmul="sta" | "dbb"`` lowers each conv through
the *implicit-GEMM* Pallas kernels (`kernels.conv_gemm`) — the im2col
patch matrix is gathered in-kernel from the NHWC block in VMEM and never
materialized in HBM (a kh·kw× activation saving). ``use_kernel=False``
keeps those routes on the explicit im2col + GEMM oracle, and
``matmul="xla"`` is the plain differentiable path (training).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.dbb import DbbWeight
from repro.kernels.conv_gemm.ref import im2col  # noqa: F401 (canonical def,
#                                                 re-exported for callers)
from repro.models.common import normal_init

__all__ = ["cnn_init", "cnn_apply", "im2col"]


def _matmul(x: jax.Array, w, mode: str, bias=None,
            act: str = "none", cfg: Optional[ModelConfig] = None
            ) -> jax.Array:
    """GEMM with optional fused bias/activation epilogue, routed by the
    kernel dispatch registry (DESIGN.md §11).

    Pallas routes ("sta" / packed DbbWeight) fuse bias+act into the kernel's
    final-K store (DESIGN.md §7); the XLA route applies them as separate ops
    (differentiable — the training path)."""
    from repro.kernels import dispatch
    return dispatch.matmul(x, w, bias, act=act, cfg=cfg,
                           pallas=(mode == "sta" or isinstance(w, DbbWeight)))


def _conv(x: jax.Array, w, bias, k: int, act: str = "relu",
          use_kernel: bool = True, cfg: Optional[ModelConfig] = None
          ) -> jax.Array:
    """One conv layer through the dispatch registry's conv domain: dense
    weights take the implicit-GEMM STA variant, packed `DbbWeight` the DBB
    variant (compressed weight stream + in-VMEM decompress).
    use_kernel=False pins the explicit im2col + GEMM oracle route."""
    from repro.kernels import dispatch
    return dispatch.conv(x, w, bias, kh=k, kw=k, act=act, cfg=cfg,
                         use_kernel=use_kernel)


def cnn_init(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    params: Dict = {}
    cin, k = cfg.cnn_in_ch, cfg.cnn_kernel
    keys = jax.random.split(key, len(cfg.cnn_channels) + 1)
    for i, cout in enumerate(cfg.cnn_channels):
        kdim = k * k * cin
        params[f"conv{i}"] = {
            "w": normal_init(keys[i], (kdim, cout), 1.0 / math.sqrt(kdim),
                             dtype),
            "b": jnp.zeros((cout,), dtype),
        }
        cin = cout
    img = cfg.cnn_img // (2 ** len(cfg.cnn_channels))
    fdim = cin * img * img
    params["fc"] = {
        "w": normal_init(keys[-1], (fdim, cfg.cnn_classes),
                         1.0 / math.sqrt(fdim), dtype),
        "b": jnp.zeros((cfg.cnn_classes,), dtype),
    }
    return params


def cnn_apply(params: Dict, cfg: ModelConfig, images: jax.Array,
              matmul: str = "xla", use_kernel: bool = True) -> jax.Array:
    """images: [B, H, W, C] -> logits [B, classes].

    matmul="sta"|"dbb" routes convs through the implicit-GEMM kernels (the
    im2col tensor never exists in HBM); use_kernel=False downgrades those
    routes to the explicit im2col + GEMM fallback. matmul="xla" is the
    plain differentiable lowering (training)."""
    x = images
    k = cfg.cnn_kernel
    for i, cout in enumerate(cfg.cnn_channels):
        p = params[f"conv{i}"]
        if matmul in ("sta", "dbb"):
            y = _conv(x, p["w"], p["b"], k, act="relu",
                      use_kernel=use_kernel, cfg=cfg)
        else:
            b, h, w, c = x.shape
            cols = im2col(x, k, k)                   # [B,H,W,k*k*C]
            y = _matmul(cols.reshape(b * h * w, -1), p["w"], matmul,
                        bias=p["b"], act="relu", cfg=cfg)
            y = y.reshape(b, h, w, cout)
        x = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    b = x.shape[0]
    flat = x.reshape(b, -1)
    return _matmul(flat, params["fc"]["w"], matmul, bias=params["fc"]["b"],
                   cfg=cfg)
