"""Gated / plain MLP blocks (the main DBB surface in every architecture).

Two execution paths:
  * GSPMD (default, single-device tests): plain matmuls, the partitioner
    inserts collectives.
  * explicit-TP (`_mlp_tp`, picked when a mesh with a model axis is live
    and d_ff divides): Megatron column→row parallel inside one shard_map,
    so the boundary psum runs on the *storage dtype* (bf16). GSPMD's own
    placement reduced the f32 dot outputs — 2× the wire bytes for no
    benefit (§Perf iteration 5; ~130 GB/step on qwen train_4k).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core.dbb import DbbWeight
from repro.dist.compat import shard_map
from repro.dist.mesh_ctx import current_mesh, data_axes_of, shard_tp
from repro.models.common import linear_init, use_fused_gemm

__all__ = ["mlp_init", "mlp_apply"]

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def mlp_init(key, d: int, f: int, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"wi": linear_init(ks[0], d, f, dtype),
         "wo": linear_init(ks[1], f, d, dtype,
                           scale=1.0 / (f ** 0.5 * (2 * cfg.num_layers) ** 0.5))}
    if cfg.mlp_gated:
        p["wg"] = linear_init(ks[2], d, f, dtype)
    return p


def batch_axes_for(mesh, batch: int):
    daxes = data_axes_of(mesh)
    for k in range(len(daxes), 0, -1):
        n = 1
        for a in daxes[:k]:
            n *= mesh.shape[a]
        if batch % n == 0:
            return daxes[:k] if k > 1 else daxes[0]
    return None


def _tp_size(mesh) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def _fused_gemm(x: jax.Array, pp: Dict, act: str,
                cfg: ModelConfig) -> jax.Array:
    """One fused-epilogue GEMM against a dense or DBB-packed weight —
    `kernels.dispatch` owns the route: packed weights (decode fast path,
    DESIGN.md §9) stream compressed through the DBB kernels, dense ones
    take the STA kernels, skinny vs M-tiled by the registry's cost model
    (§11)."""
    from repro.kernels import dispatch
    return dispatch.matmul(x, pp["w"], pp.get("b"), act=act,
                           out_dtype=x.dtype, cfg=cfg, pallas=True)


def _dense_w(pp: Dict, dtype) -> jax.Array:
    """Dense weight for the XLA path; DbbWeight leaves (which only the
    fused route is supposed to see) expand as a safety net."""
    w = pp["w"]
    if isinstance(w, DbbWeight):
        from repro.core.dbb_linear import decompress_xla
        return decompress_xla(w, dtype=dtype)
    return w.astype(dtype)


def _mlp_fused(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Single-device serving path: every GEMM through the STA Pallas kernel
    (dense weights) or the DBB kernel (packed weights stream compressed),
    the activation fused into the up-projection's final-K store (DESIGN.md
    §7) — the [tokens, d_ff] pre-activation never round-trips through HBM.
    Gated MLPs fuse the act into the gate GEMM and multiply elementwise."""
    h = _fused_gemm(x, p["wi"], "none" if cfg.mlp_gated else cfg.act, cfg)
    if cfg.mlp_gated:
        h = _fused_gemm(x, p["wg"], cfg.act, cfg) * h
    return _fused_gemm(h, p["wo"], "none", cfg)


def _mlp_dense(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    from jax.ad_checkpoint import checkpoint_name
    if use_fused_gemm(cfg):
        return _mlp_fused(p, cfg, x)
    act = _ACTS[cfg.act]
    # named for the selective-remat policy (§Perf iteration 8): saving the
    # two fat up-projections skips their recompute in the backward pass at
    # ~56 MB/layer/shard — the best flops-per-byte save in the block
    h = checkpoint_name(x @ _dense_w(p["wi"], x.dtype), "mlp_wi")
    if cfg.mlp_gated:
        h = act(checkpoint_name(x @ _dense_w(p["wg"], x.dtype),
                                "mlp_wg")) * h
    else:
        h = act(h)
    return h @ _dense_w(p["wo"], x.dtype)


def seq_parallel_ok(cfg: ModelConfig, seq: int, tp: int) -> bool:
    """Megatron-SP eligibility: standard transformer stacks whose sequence
    divides the model axis (hybrid SSM stacks keep full-seq residuals —
    the recurrence would need halo exchanges)."""
    return (cfg.parallel != "dp"
            and cfg.family in ("dense_lm", "moe_lm", "vlm_lm", "audio_lm")
            and seq % tp == 0 and seq > tp)


def mlp_apply(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    stp = shard_tp()
    if stp > 1:
        # Already inside a TP shard_map body (the serving wrapper,
        # DESIGN.md §14): wi/wg arrive column-sharded, wo row-sharded per
        # the param specs, so `_mlp_dense` runs the per-shard Pallas
        # kernels on local slices and one chunked boundary all-reduce
        # completes the block (issued per chunk so XLA's async scheduler
        # overlaps wire time with the epilogue stores). No nested
        # shard_map — collectives bind to the enclosing mesh axes.
        from repro.dist.collectives import overlapped_psum
        return overlapped_psum(_mlp_dense(p, cfg, x), "model")
    mesh = current_mesh()
    tp = _tp_size(mesh) if cfg.parallel != "dp" else 1
    wi = p["wi"]["w"]
    f = wi.n_dim if isinstance(wi, DbbWeight) else wi.shape[-1]
    if tp > 1 and f % tp == 0 and x.ndim == 3:
        ba = batch_axes_for(mesh, x.shape[0])
        sp = seq_parallel_ok(cfg, x.shape[1], tp)
        wspecs = {"wi": {"w": P(None, "model")},
                  "wo": {"w": P("model", None)}}
        if cfg.mlp_gated:
            wspecs["wg"] = {"w": P(None, "model")}
        xspec = P(ba, "model", None) if sp else P(ba, None, None)

        def fn(xl, pl):
            if sp:      # gather the sequence shards at block entry (SP)
                xl = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
            y = _mlp_dense(pl, cfg, xl)      # local f-slice, partial on d
            if sp:      # reduce-scatter back to the seq-sharded residual
                return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                            tiled=True)
            return jax.lax.psum(y, "model")  # bf16 boundary reduce

        return shard_map(
            fn, mesh=mesh,
            in_specs=(xspec, wspecs),
            out_specs=xspec,
            check_vma=False)(x, {k: p[k] for k in wspecs})
    return _mlp_dense(p, cfg, x)
