"""Model substrate: every assigned architecture family in pure-functional JAX."""
