from repro.data.pipeline import (DataState, SyntheticCNN, SyntheticLM,
                                 make_pipeline)

__all__ = ["SyntheticLM", "SyntheticCNN", "DataState", "make_pipeline"]
