"""Deterministic synthetic data pipeline, sharded per host.

Stateless addressing — ``batch_at(step)`` derives every batch purely from
(seed, step, host shard), so:
  * restart/resume is exact (checkpoint stores only the step counter);
  * skip-ahead is O(1) (no stream to fast-forward through);
  * every host materializes only its slice of the global batch.

The LM stream is a seeded order-2 Markov chain over the vocab (learnable
structure, so convergence tests and the Table I analogue are meaningful);
the CNN stream draws class-conditional patterns + noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.config import ModelConfig, ShapeSpec

__all__ = ["DataState", "SyntheticLM", "SyntheticCNN", "make_pipeline"]


@dataclasses.dataclass
class DataState:
    """Everything needed to resume the pipeline exactly."""
    step: int = 0
    seed: int = 0


def _rng(seed: int, step: int, host: int) -> np.random.Generator:
    # SeedSequence spawning keys are collision-free across (seed, step, host)
    return np.random.default_rng(np.random.SeedSequence(
        entropy=seed, spawn_key=(step, host)))


class SyntheticLM:
    """Order-2 Markov token stream with a host-sharded global batch."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                 host_index: int = 0, host_count: int = 1,
                 markov_states: int = 64):
        assert shape.global_batch % host_count == 0, (
            shape.global_batch, host_count)
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = shape.global_batch // host_count
        v = cfg.vocab_size
        self.m = min(markov_states, v)
        # fixed (per-seed) sparse-ish transition structure
        g = np.random.default_rng(seed)
        self.trans = g.integers(0, self.m, size=(self.m, self.m, 4))
        self.emit = g.integers(0, v, size=(self.m,))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        g = _rng(self.seed, step, self.host_index)
        b, s = self.local_batch, self.shape.seq_len
        st = g.integers(0, self.m, size=(b, 2))
        choices = g.integers(0, 4, size=(b, s))
        toks = np.empty((b, s), np.int32)
        s0, s1 = st[:, 0], st[:, 1]
        rows = np.arange(b)
        for t in range(s):
            nxt = self.trans[s0, s1, choices[rows, t]]
            toks[:, t] = self.emit[nxt]
            s0, s1 = s1, nxt
        # standard causal LM: input toks[t], label toks[t+1], last masked
        labels = np.concatenate([toks[:, 1:], np.zeros((b, 1), np.int32)],
                                axis=1)
        batch = {"tokens": toks.astype(np.int32),
                 "labels": labels.astype(np.int32)}
        batch["loss_mask"] = np.ones((b, s), np.float32)
        batch["loss_mask"][:, -1] = 0.0
        if self.cfg.embeds_input:
            # audio stub: frame embeddings derived from the token ids
            d = self.cfg.d_model
            emb = _rng(self.seed ^ 0x5EED, 0, 0).standard_normal(
                (self.m, d)).astype(np.float32)
            frames = emb[toks % self.m] * 0.1
            batch["embeds"] = frames.astype(np.float32)
            del batch["tokens"]
        if self.cfg.prefix_embed_len:
            d = self.cfg.d_model
            batch["prefix_embeds"] = g.standard_normal(
                (b, self.cfg.prefix_embed_len, d)).astype(np.float32) * 0.1
            # prefix positions don't contribute to the LM loss
            pm = np.zeros((b, self.cfg.prefix_embed_len), np.float32)
            batch["loss_mask"] = np.concatenate(
                [pm, batch["loss_mask"]], axis=1)
            batch["labels"] = np.concatenate(
                [np.zeros((b, self.cfg.prefix_embed_len), np.int32),
                 batch["labels"]], axis=1)
        return batch


class SyntheticCNN:
    """Class-conditional pattern + noise images (paper Table I substrate)."""

    def __init__(self, cfg: ModelConfig, batch: int, seed: int = 0,
                 host_index: int = 0, host_count: int = 1):
        assert batch % host_count == 0
        self.cfg = cfg
        self.local_batch = batch // host_count
        self.seed = seed
        self.host_index = host_index
        g = np.random.default_rng(seed)
        c, img, ch = cfg.cnn_classes, cfg.cnn_img, cfg.cnn_in_ch
        self.protos = g.standard_normal((c, img, img, ch)).astype(np.float32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        g = _rng(self.seed, step, self.host_index)
        b = self.local_batch
        labels = g.integers(0, self.cfg.cnn_classes, size=(b,))
        noise = g.standard_normal(
            (b, self.cfg.cnn_img, self.cfg.cnn_img,
             self.cfg.cnn_in_ch)).astype(np.float32)
        images = self.protos[labels] + 0.7 * noise
        return {"images": images.astype(np.float32),
                "labels": labels.astype(np.int32)}


def make_pipeline(cfg: ModelConfig, shape: Optional[ShapeSpec] = None,
                  seed: int = 0, host_index: int = 0, host_count: int = 1,
                  cnn_batch: int = 64):
    if cfg.family == "cnn":
        return SyntheticCNN(cfg, cnn_batch, seed, host_index, host_count)
    assert shape is not None
    return SyntheticLM(cfg, shape, seed, host_index, host_count)
