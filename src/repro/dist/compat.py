"""jax version compatibility shims for the distribution layer.

The codebase targets the current jax naming (``jax.shard_map`` with
``check_vma``); older jaxlibs (like this container's 0.4.x) ship it as
``jax.experimental.shard_map.shard_map`` with ``check_rep``. One wrapper
keeps every call site on the new spelling.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6: experimental location, check_vma spelled check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
