"""Vocab-parallel collectives + dense oracles.

The two ops whose naive forms materialize [tokens, V] tensors are the
embedding gather and the LM-head cross-entropy. Both get shard_map
implementations that keep the vocab axis sharded over "model": each shard
works on its vocab slice and one psum combines the scalars — unsharded
logits never exist (DESIGN.md §6 discusses why this matters at V ≥ 100k).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.dist.mesh_ctx import current_mesh

__all__ = ["dense_ce", "dense_ce_chunked", "vocab_parallel_ce",
           "vocab_parallel_embed", "cross_entropy"]


def _masked_mean(nll: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    if mask is None:
        return nll.mean()
    m = mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def dense_ce(h: jax.Array, w: jax.Array, labels: jax.Array,
             mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean CE with full [.., V] logits. h [B,S,d] · w [d,V]."""
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return _masked_mean(lse - ll, mask)


def dense_ce_chunked(h: jax.Array, w: jax.Array, labels: jax.Array,
                     mask: Optional[jax.Array] = None,
                     rows: int = 8192) -> jax.Array:
    """CE with token-chunked logits (§Perf: live logits capped at
    [rows, V]); each chunk is rematerialized in the backward pass, so
    gradients are bit-identical to `dense_ce` up to reduction order."""
    b, s, d = h.shape
    t = b * s
    hf = h.reshape(t, d)
    lf = labels.reshape(t)
    mf = (jnp.ones((t,), jnp.float32) if mask is None
          else mask.reshape(t).astype(jnp.float32))
    # pad the token axis up to a rows multiple (mask 0 ⇒ zero contribution)
    # rather than searching for a divisor — a prime t would otherwise
    # collapse to one chunk and materialize the full [t, V] logits, the
    # exact blow-up this path exists to cap
    rows_eff = min(rows, t)
    t_pad = -(-t // rows_eff) * rows_eff
    if t_pad != t:
        hf = jnp.pad(hf, ((0, t_pad - t), (0, 0)))
        lf = jnp.pad(lf, (0, t_pad - t))
        mf = jnp.pad(mf, (0, t_pad - t))
    n_chunks = t_pad // rows_eff

    @jax.checkpoint
    def one(carry, xs):
        hc, lc, mc = xs
        logits = hc.astype(jnp.float32) @ w.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        nll_sum, m_sum = carry
        return (nll_sum + ((lse - ll) * mc).sum(), m_sum + mc.sum()), None

    (nll_sum, m_sum), _ = jax.lax.scan(
        one, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hf.reshape(n_chunks, rows_eff, d),
         lf.reshape(n_chunks, rows_eff),
         mf.reshape(n_chunks, rows_eff)))
    return nll_sum / jnp.maximum(m_sum, 1.0)


def vocab_parallel_ce(h: jax.Array, w: jax.Array, labels: jax.Array,
                      mesh, mask: Optional[jax.Array] = None) -> jax.Array:
    """CE with the head weight column-sharded over "model": each shard
    computes its vocab slice's partial logsumexp and the label logit when
    the label lands in its slice; two scalar psums combine them."""
    tp = mesh.shape["model"]
    v = w.shape[-1]
    v_loc = v // tp

    def shard_fn(hl, wl, lab, m):
        idx = jax.lax.axis_index("model")
        logits = hl.astype(jnp.float32) @ wl.astype(jnp.float32)
        # global logsumexp = logsumexp over per-shard logsumexps. The
        # gathered piece is [tp, ...] scalars-per-token — tiny — and
        # all_gather (unlike pmax) differentiates cleanly on every jax.
        lse_loc = jax.nn.logsumexp(logits, axis=-1)
        lse = jax.nn.logsumexp(
            jax.lax.all_gather(lse_loc, "model"), axis=0)
        # label logit: owned by exactly one shard
        lab_loc = lab - idx * v_loc
        in_range = (lab_loc >= 0) & (lab_loc < v_loc)
        safe = jnp.clip(lab_loc, 0, v_loc - 1)
        ll_loc = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        ll = jax.lax.psum(jnp.where(in_range, ll_loc, 0.0), "model")
        return _masked_mean(lse - ll, m)

    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(None, "model"), P(), P()),
        out_specs=P(),
        check_vma=False)(h, w, labels, mask)


def vocab_parallel_embed(table: jax.Array, tokens: jax.Array, dtype,
                         mesh) -> jax.Array:
    """Row-sharded embedding gather: each shard serves the tokens that fall
    in its vocab slice, one psum assembles the [B, S, d] output — the
    [V, d] table is never all-gathered."""
    tp = mesh.shape["model"]
    v = table.shape[0]
    v_loc = v // tp

    def shard_fn(tl, toks):
        idx = jax.lax.axis_index("model")
        loc = toks - idx * v_loc
        in_range = (loc >= 0) & (loc < v_loc)
        safe = jnp.clip(loc, 0, v_loc - 1)
        emb = tl[safe].astype(jnp.float32)
        emb = jnp.where(in_range[..., None], emb, 0.0)
        return jax.lax.psum(emb, "model")

    out = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P("model", None), P()),
        out_specs=P(),
        check_vma=False)(table, tokens)
    return out.astype(dtype)


def cross_entropy(hidden: jax.Array, w_head: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  vocab_parallel: bool = True) -> jax.Array:
    """LM-head CE dispatcher: vocab-parallel when a mesh with a non-trivial
    model axis is live and the vocab divides; token-chunked dense when the
    full logits tensor would be large; plain dense otherwise."""
    mesh = current_mesh()
    v = w_head.shape[-1]
    if (vocab_parallel and mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1 and v % mesh.shape["model"] == 0):
        return vocab_parallel_ce(hidden, w_head, labels, mesh, mask)
    tokens = 1
    for s in labels.shape:
        tokens *= s
    if tokens * v > (1 << 28):          # cap live logits at ~1 GB f32
        return dense_ce_chunked(hidden, w_head, labels, mask)
    return dense_ce(hidden, w_head, labels, mask)
