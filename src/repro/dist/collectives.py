"""Vocab-parallel collectives + dense oracles.

The two ops whose naive forms materialize [tokens, V] tensors are the
embedding gather and the LM-head cross-entropy. Both get shard_map
implementations that keep the vocab axis sharded over "model": each shard
works on its vocab slice and one psum combines the scalars — unsharded
logits never exist (DESIGN.md §6 discusses why this matters at V ≥ 100k).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.dist.mesh_ctx import current_mesh

__all__ = ["dense_ce", "dense_ce_chunked", "vocab_parallel_ce",
           "vocab_parallel_embed", "cross_entropy", "axis_size",
           "overlapped_psum", "shard_embed_lookup", "shard_greedy",
           "shard_sample", "greedy_vocab_parallel", "greedy_scatter"]


def axis_size(name: str = "model") -> int:
    """Size of a named collective axis, from inside a shard_map/pmap body.

    ``jax.lax.psum(1, name)`` is the canonical trick — jax folds a psum of
    the unit constant to the axis size at trace time. Outside any axis
    binding jax raises a bare ``NameError``/``KeyError`` naming the axis;
    wrap it in an actionable error instead. (For the *mesh* axis size
    outside a shard body, use `repro.dist.mesh_ctx.axis_size`, which
    returns 1 when no mesh is live.)"""
    try:
        return int(jax.lax.psum(1, name))
    except (NameError, KeyError, ValueError) as e:
        raise RuntimeError(
            f"collectives.axis_size({name!r}) called outside a mesh/"
            f"shard_map context: no collective axis named {name!r} is "
            "bound. Call it from inside a shard_map body (e.g. under "
            "serve's shard_tp_ctx), or use repro.dist.mesh_ctx.axis_size "
            "for the context-mesh axis size.") from e


def overlapped_psum(y: jax.Array, axis: str = "model",
                    chunks: int = 2) -> jax.Array:
    """Boundary all-reduce split along the last dim into ``chunks``
    independent psums. Each element is still summed exactly once, so the
    result is bit-identical to one psum — but the chunks are independent
    collective ops, which lets XLA's async collective scheduler start the
    first chunk's wire transfer while the producing GEMM's epilogue is
    still storing the later chunks (the overlap timeline in DESIGN.md
    §14). Falls back to a single psum when the dim doesn't split."""
    if chunks <= 1 or y.shape[-1] % chunks != 0:
        return jax.lax.psum(y, axis)
    parts = jnp.split(y, chunks, axis=-1)
    return jnp.concatenate([jax.lax.psum(p, axis) for p in parts], axis=-1)


def _masked_mean(nll: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    if mask is None:
        return nll.mean()
    m = mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def dense_ce(h: jax.Array, w: jax.Array, labels: jax.Array,
             mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean CE with full [.., V] logits. h [B,S,d] · w [d,V]."""
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return _masked_mean(lse - ll, mask)


def dense_ce_chunked(h: jax.Array, w: jax.Array, labels: jax.Array,
                     mask: Optional[jax.Array] = None,
                     rows: int = 8192) -> jax.Array:
    """CE with token-chunked logits (§Perf: live logits capped at
    [rows, V]); each chunk is rematerialized in the backward pass, so
    gradients are bit-identical to `dense_ce` up to reduction order."""
    b, s, d = h.shape
    t = b * s
    hf = h.reshape(t, d)
    lf = labels.reshape(t)
    mf = (jnp.ones((t,), jnp.float32) if mask is None
          else mask.reshape(t).astype(jnp.float32))
    # pad the token axis up to a rows multiple (mask 0 ⇒ zero contribution)
    # rather than searching for a divisor — a prime t would otherwise
    # collapse to one chunk and materialize the full [t, V] logits, the
    # exact blow-up this path exists to cap
    rows_eff = min(rows, t)
    t_pad = -(-t // rows_eff) * rows_eff
    if t_pad != t:
        hf = jnp.pad(hf, ((0, t_pad - t), (0, 0)))
        lf = jnp.pad(lf, (0, t_pad - t))
        mf = jnp.pad(mf, (0, t_pad - t))
    n_chunks = t_pad // rows_eff

    @jax.checkpoint
    def one(carry, xs):
        hc, lc, mc = xs
        logits = hc.astype(jnp.float32) @ w.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        nll_sum, m_sum = carry
        return (nll_sum + ((lse - ll) * mc).sum(), m_sum + mc.sum()), None

    (nll_sum, m_sum), _ = jax.lax.scan(
        one, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hf.reshape(n_chunks, rows_eff, d),
         lf.reshape(n_chunks, rows_eff),
         mf.reshape(n_chunks, rows_eff)))
    return nll_sum / jnp.maximum(m_sum, 1.0)


def vocab_parallel_ce(h: jax.Array, w: jax.Array, labels: jax.Array,
                      mesh, mask: Optional[jax.Array] = None) -> jax.Array:
    """CE with the head weight column-sharded over "model": each shard
    computes its vocab slice's partial logsumexp and the label logit when
    the label lands in its slice; two scalar psums combine them."""
    tp = mesh.shape["model"]
    v = w.shape[-1]
    v_loc = v // tp

    def shard_fn(hl, wl, lab, m):
        idx = jax.lax.axis_index("model")
        logits = hl.astype(jnp.float32) @ wl.astype(jnp.float32)
        # global logsumexp = logsumexp over per-shard logsumexps. The
        # gathered piece is [tp, ...] scalars-per-token — tiny — and
        # all_gather (unlike pmax) differentiates cleanly on every jax.
        lse_loc = jax.nn.logsumexp(logits, axis=-1)
        lse = jax.nn.logsumexp(
            jax.lax.all_gather(lse_loc, "model"), axis=0)
        # label logit: owned by exactly one shard
        lab_loc = lab - idx * v_loc
        in_range = (lab_loc >= 0) & (lab_loc < v_loc)
        safe = jnp.clip(lab_loc, 0, v_loc - 1)
        ll_loc = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        ll = jax.lax.psum(jnp.where(in_range, ll_loc, 0.0), "model")
        return _masked_mean(lse - ll, m)

    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(None, "model"), P(), P()),
        out_specs=P(),
        check_vma=False)(h, w, labels, mask)


def shard_embed_lookup(table_local: jax.Array, tokens: jax.Array, dtype,
                       axis: str = "model") -> jax.Array:
    """Per-shard body of the row-sharded embedding gather: the local table
    holds one contiguous vocab slice; serve the in-slice tokens and psum
    the rest to zero-contributions. Callable from any shard_map body over
    ``axis`` (the TP serving wrapper enters here via `embed_apply` when
    `shard_tp()` is live)."""
    idx = jax.lax.axis_index(axis)
    v_loc = table_local.shape[0]
    loc = tokens - idx * v_loc
    in_range = (loc >= 0) & (loc < v_loc)
    safe = jnp.clip(loc, 0, v_loc - 1)
    emb = table_local[safe].astype(jnp.float32)
    emb = jnp.where(in_range[..., None], emb, 0.0)
    return jax.lax.psum(emb, axis).astype(dtype)


def vocab_parallel_embed(table: jax.Array, tokens: jax.Array, dtype,
                         mesh) -> jax.Array:
    """Row-sharded embedding gather: each shard serves the tokens that fall
    in its vocab slice, one psum assembles the [B, S, d] output — the
    [V, d] table is never all-gathered."""
    out = shard_map(
        lambda tl, toks: shard_embed_lookup(tl, toks, jnp.float32),
        mesh=mesh,
        in_specs=(P("model", None), P()),
        out_specs=P(),
        check_vma=False)(table, tokens)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# vocab-parallel greedy head (serving): the decode-step argmax without an
# unsharded [B, vocab] logits tensor ever existing (DESIGN.md §14)
# ---------------------------------------------------------------------------

def _greedy_combine(logits_loc: jax.Array, axis: str = "model") -> jax.Array:
    """Global greedy argmax from per-shard [B, v/tp] logit slices. Each
    shard reduces its slice to one (max, argmax) pair per row; the only
    cross-shard traffic is the [tp, B] all_gather of those scalars.
    Tie-breaking matches `jnp.argmax` on the full vector: shards are
    ordered by vocab offset and `argmax` picks the first maximum both
    within a slice and across the gathered axis."""
    v_loc = logits_loc.shape[-1]
    idx = jax.lax.axis_index(axis)
    loc_max = logits_loc.max(axis=-1)                       # [B]
    loc_arg = logits_loc.argmax(axis=-1) + idx * v_loc      # global ids
    all_max = jax.lax.all_gather(loc_max, axis)             # [tp, B]
    all_arg = jax.lax.all_gather(loc_arg, axis)             # [tp, B]
    winner = jnp.argmax(all_max, axis=0)                    # [B]
    return jnp.take_along_axis(
        all_arg, winner[None], axis=0)[0].astype(jnp.int32)


def shard_greedy(h: jax.Array, w_head_local: jax.Array, *,
                 impl: str = "xla", cfg=None,
                 axis: str = "model") -> jax.Array:
    """Greedy head GEMV from inside a shard_map body: ``w_head_local``
    is the column slice [d, v/tp], so the GEMV itself is local (the
    skinny Pallas route applies at the local width) and only the scalar
    (max, argmax) combine crosses shards."""
    from repro.kernels import dispatch
    logits = dispatch.matmul(h, w_head_local.astype(jnp.float32), cfg=cfg,
                             pallas=(impl == "pallas"), gemv=True)
    return _greedy_combine(logits, axis)


def shard_sample(h: jax.Array, w_head_local: jax.Array, counts: jax.Array,
                 temp, rep, pres, freq, seed, step, *,
                 top_k=None, top_p=None, use_tt: bool = False,
                 impl: str = "xla", cfg=None,
                 axis: str = "model") -> jax.Array:
    """Vocab-parallel sampling head from inside a shard_map body — the
    sampling twin of `shard_greedy` (DESIGN.md §15).

    Each shard runs the head GEMV + sampling epilogue on its column
    slice ``[d, v/tp]`` with noise keyed to GLOBAL vocab ids (the shard
    offset feeds the counter hash), reduces to one (best score, global
    argmax) pair per row, and the same [tp, B] scalar all_gather combine
    the greedy head uses picks the winner — bit-identical to a
    single-device run over the full row, because per-shard scores equal
    the corresponding slice of the full-row scores and the combine keeps
    `jnp.argmax`'s first-max order across vocab-ordered shards.

    ``counts`` arrives replicated ``[B, V]`` (it is per-request state,
    not weight); each shard slices its window. ``use_tt`` (STATIC) is
    the top-k/top-p escape hatch: the masks are global order statistics,
    so the shards all-gather the [B, V] logits once and run the full XLA
    reference sampler identically — correctness over wire-efficiency for
    the rows that ask for it.
    """
    from repro.kernels import dispatch
    idx = jax.lax.axis_index(axis)
    v_loc = w_head_local.shape[-1]
    base = idx * v_loc
    if use_tt:
        from repro.kernels.sample.ref import sample_logits
        logits_loc = dispatch.matmul(h, w_head_local.astype(jnp.float32),
                                     cfg=cfg, pallas=(impl == "pallas"),
                                     gemv=True)
        logits = jax.lax.all_gather(logits_loc, axis, axis=-1, tiled=True)
        return sample_logits(logits, counts, temp, top_k, top_p, rep,
                             pres, freq, seed, step, use_tt=True)
    counts_loc = jax.lax.dynamic_slice_in_dim(counts, base, v_loc, axis=1)
    score, tok_loc = dispatch.head_sample(
        h, w_head_local, counts_loc, temp, rep, pres, freq, seed, step,
        base=base, cfg=cfg, pallas=(impl == "pallas"), return_score=True)
    all_max = jax.lax.all_gather(score, axis)               # [tp, B]
    all_arg = jax.lax.all_gather(tok_loc + base, axis)      # global ids
    winner = jnp.argmax(all_max, axis=0)
    return jnp.take_along_axis(
        all_arg, winner[None], axis=0)[0].astype(jnp.int32)


def greedy_vocab_parallel(hidden: jax.Array, w_head: jax.Array, mesh,
                          *, impl: str = "xla", cfg=None) -> jax.Array:
    """Vocab-parallel greedy head for a *global* graph under a mesh:
    column-shards the head weight over "model", computes each [B, v/tp]
    logit slice per shard and combines (max, argmax) scalars. The GSPMD
    alternative (sharded matmul + global argmax) all-gathers the full
    [B, vocab] logits every step; here the wire carries [tp, B] scalars.
    ``hidden`` is the last-position activations [B, d] (f32)."""
    def shard_fn(hl, wl):
        return shard_greedy(hl, wl, impl=impl, cfg=cfg)

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(None, "model")),
        out_specs=P(),
        check_vma=False)(hidden, w_head)


def greedy_scatter(hidden: jax.Array, w_head: jax.Array, mesh,
                   ) -> jax.Array:
    """`psum_scatter`-based vocab-parallel greedy head for a K(d)-sharded
    head weight (ZeRO'd lm_head / row-sharded tied table): each shard
    holds partial [B, vocab] logits from its d-slice; `psum_scatter`
    reduces them straight into per-shard [B, vocab/tp] slices — each hop
    moves [B, vocab/tp], never all-gathering the full [B, vocab] — and
    the same scalar (max, argmax) combine finishes the argmax."""
    tp = mesh.shape["model"]
    v = w_head.shape[-1]
    assert v % tp == 0, (v, tp)

    def shard_fn(hl, wl):
        partial = hl.astype(jnp.float32) @ wl.astype(jnp.float32)
        mine = jax.lax.psum_scatter(partial, "model",
                                    scatter_dimension=partial.ndim - 1,
                                    tiled=True)           # [B, v/tp]
        return _greedy_combine(mine, "model")

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(None, "model"), P("model", None)),
        out_specs=P(),
        check_vma=False)(hidden, w_head)


def cross_entropy(hidden: jax.Array, w_head: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  vocab_parallel: bool = True) -> jax.Array:
    """LM-head CE dispatcher: vocab-parallel when a mesh with a non-trivial
    model axis is live and the vocab divides; token-chunked dense when the
    full logits tensor would be large; plain dense otherwise."""
    mesh = current_mesh()
    v = w_head.shape[-1]
    if (vocab_parallel and mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1 and v % mesh.shape["model"] == 0):
        return vocab_parallel_ce(hidden, w_head, labels, mesh, mask)
    tokens = 1
    for s in labels.shape:
        tokens *= s
    if tokens * v > (1 << 28):          # cap live logits at ~1 GB f32
        return dense_ce_chunked(hidden, w_head, labels, mask)
    return dense_ce(hidden, w_head, labels, mask)
