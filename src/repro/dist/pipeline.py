"""GPipe-style microbatch pipeline over one mesh axis.

`stack_stages` splits a stacked layer tree [L, ...] into S contiguous
stages [S, L/S, ...]; `pipeline_forward` runs M microbatches through the S
stages on an S-device ring: at step t, stage s processes microbatch
t - s, and `ppermute` hands activations to stage s+1. Total steps
M + S - 1; the classic (S-1)/M bubble.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map

__all__ = ["stack_stages", "pipeline_forward"]


def stack_stages(stacked: Any, n_stages: int) -> Any:
    """[L, ...] layer stacks → [S, L/S, ...] stage stacks (pytree-wide)."""
    def re(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree_util.tree_map(re, stacked)


def pipeline_forward(stages: Any, x: jax.Array,
                     stage_fn: Callable[[Any, jax.Array], jax.Array],
                     mesh, axis: str = "pod") -> jax.Array:
    """Run microbatches x [M, B, ...] through `stages` ([S, ...] trees,
    sharded over `axis`) with stage_fn(stage_weights, act) per stage.

    Returns [M, B, ...] identical (up to reduction order) to running all
    layers sequentially on one device.
    """
    s_total = mesh.shape[axis]
    m_total = x.shape[0]
    perm = [(i, i + 1) for i in range(s_total - 1)]

    def shard_fn(stage_local, xs):
        # stage_local: [1, ...] slice of the stage stack — drop the axis dim
        ws = jax.tree_util.tree_map(lambda a: a[0], stage_local)
        sidx = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        for t in range(m_total + s_total - 1):
            m = t - sidx                       # this stage's microbatch id
            first_in = xs[jnp.clip(m, 0, m_total - 1)]
            inp = jnp.where(sidx == 0, first_in, buf)
            y = stage_fn(ws, inp)
            live = (m >= 0) & (m < m_total) & (sidx == s_total - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(live, y, outs[jnp.clip(m, 0, m_total - 1)]),
                jnp.clip(m, 0, m_total - 1), 0)
            buf = jax.lax.ppermute(y, axis, perm)
        # only the last stage holds real outputs; psum replicates them
        outs = jnp.where(sidx == s_total - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False)(stages, x)
