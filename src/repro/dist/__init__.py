"""Distribution layer: mesh context, sharding rules, collectives, pipeline.

mesh_ctx:    the session-wide mesh contextvar (`use_mesh` / `current_mesh`)
             plus divisibility-safe sharding hints.
sharding:    PartitionSpec inference for param / optimizer / cache / batch
             trees (Megatron TP rules + ZeRO/FSDP data-axis sharding).
collectives: vocab-parallel embedding + cross-entropy (the two ops whose
             naive forms materialize vocab-sized tensors), dense oracles.
pipeline:    GPipe-style microbatch pipeline over a mesh axis.
"""
